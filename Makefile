# Build-time entry points.  The Rust crate is self-contained after
# `make artifacts` has run once on a machine with jax (the compile
# path is Python-only; see python/compile/aot.py).
#
# NOTE offline images: regeneration *works* wherever jax is installed,
# but replaying the artifacts (rust/tests/engine_parity.rs golden
# tests, the `hlo` engine) additionally needs a PJRT-enabled `xla`
# binding — the vendored rust/vendor/xla stub cannot execute HLO, so
# on stub builds the golden tests must keep skipping: do not commit
# rust/artifacts/ into a tree that only builds the stub.

.PHONY: artifacts artifacts-core test bench

# Full variant sweep (Tables 2-6, Fig. 2 — plus goldens, including the
# residual-model goldens for the reconciled apply_model).
artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts --set full

# Quickstart subset: mlp + mlp_mini train/eval with goldens.
artifacts-core:
	cd python && python3 -m compile.aot --out ../rust/artifacts --set core

test:
	cd rust && cargo test -q

bench:
	cd rust && cargo bench
