//! Tenant isolation under full contention — the ISSUE-9 acceptance
//! bar.  Two zoo models co-scheduled on 2 lanes, swept across
//! {train, serve, train+serve} roles × accelerated tiers, with
//! concurrent driver threads per tenant, must be **bit-identical** to
//! the same work run solo:
//!
//! - train: after N fleet steps on the same data, every tenant's
//!   latent weights equal its solo engine's exactly;
//! - serve: every request's logits equal a solo engine's on the same
//!   snapshot (sequential batch-1 submissions per tenant keep the BN
//!   batch composition deterministic);
//! - train+serve: logits served after auto-publish equal a solo
//!   mirror's weights re-packed at the same publish boundary;
//! - the planned [`bnn_edge::memmodel::fleet_envelope`] equals the
//!   measured fleet steady state exactly once trained tenants' packed
//!   caches fill (≥2 steps).
//!
//! (The zero-allocation steady-state assert lives in its own binary,
//! rust/tests/memtrack_multi.rs — the tracking allocator's counters
//! are process-global.)

use std::sync::Arc;

use bnn_edge::models::{get, lower, Graph};
use bnn_edge::naive::{build_engine, Accel, Plan, StepEngine};
use bnn_edge::serve::{
    InferAlgo, MultiModelServer, PackedInferEngine, TenantRole, TenantSpec, WeightSnapshot,
};
use bnn_edge::util::rng::Pcg32;

const MODELS: [&str; 2] = ["mlp_mini", "cnv_mini"];
const TIERS: [Accel; 2] = [Accel::Blocked, Accel::Tiled(2)];
const STEPS: usize = 4;
const BATCH: usize = 8;

fn graph_for(model: &str) -> Graph {
    lower(&get(model).unwrap()).unwrap()
}

fn spec_for(tid: usize, model: &str, role: TenantRole, accel: Accel) -> TenantSpec {
    let mut s = TenantSpec::new(model, model, role);
    s.accel = accel;
    s.seed = 50 + tid as u64;
    s.batch = BATCH;
    s.max_batch = 4;
    s
}

/// Deterministic per-tenant training batches — the fleet driver and
/// the solo mirror construct identical streams.
fn train_batch(rng: &mut Pcg32, graph: &Graph, step: usize) -> (Vec<f32>, Vec<usize>) {
    let x = rng.normal_vec(graph.input_elems * BATCH);
    let y = (0..BATCH).map(|i| (i + step) % graph.classes).collect();
    (x, y)
}

#[test]
fn train_tenants_match_solo_under_contention() {
    for accel in TIERS {
        let specs: Vec<TenantSpec> = MODELS
            .iter()
            .enumerate()
            .map(|(tid, m)| spec_for(tid, m, TenantRole::Train, accel))
            .collect();
        let (client, server) = MultiModelServer::new(specs, 2).unwrap();
        let planned = server.fleet_envelope().unwrap().total_bytes();
        let h = std::thread::spawn(move || server.run());

        // both tenants trained concurrently — full lane contention
        let mut drivers = Vec::new();
        for (tid, model) in MODELS.into_iter().enumerate() {
            let c = client.clone();
            drivers.push(std::thread::spawn(move || {
                let graph = graph_for(model);
                let mut rng = Pcg32::new(70 + tid as u64);
                for step in 0..STEPS {
                    let (x, y) = train_batch(&mut rng, &graph, step);
                    c.train_step(tid, &x, &y, 0.01).unwrap();
                }
            }));
        }
        for d in drivers {
            d.join().unwrap();
        }
        client.shutdown();
        let tenants = h.join().unwrap().unwrap();

        // solo mirrors: same seeds, same data, no contention
        for (tid, model) in MODELS.into_iter().enumerate() {
            let graph = graph_for(model);
            let mut solo =
                build_engine("proposed", &graph, BATCH, "adam", accel, 50 + tid as u64).unwrap();
            let mut rng = Pcg32::new(70 + tid as u64);
            for step in 0..STEPS {
                let (x, y) = train_batch(&mut rng, &graph, step);
                solo.train_step(&x, &y, 0.01).unwrap();
            }
            assert_eq!(
                tenants[tid].train_engine().unwrap().weights_snapshot(),
                solo.weights_snapshot(),
                "{model} ({accel:?}): fleet weights != solo weights"
            );
            assert_eq!(tenants[tid].steps(), STEPS as u64);
        }

        // ≥2 steps ran: the packed caches are full and the planned
        // envelope prices the measured fleet exactly
        let measured: usize = tenants.iter().map(|t| t.steady_state_bytes()).sum();
        assert_eq!(planned as usize, measured, "{accel:?}: envelope mismatch");
    }
}

#[test]
fn serve_tenants_match_solo_under_contention() {
    for accel in TIERS {
        let specs: Vec<TenantSpec> = MODELS
            .iter()
            .enumerate()
            .map(|(tid, m)| spec_for(tid, m, TenantRole::Serve, accel))
            .collect();
        let (client, server) = MultiModelServer::new(specs, 2).unwrap();
        // serve-only: exact before any quantum runs
        let planned = server.fleet_envelope().unwrap().total_bytes();
        assert_eq!(planned as usize, server.steady_state_bytes());
        let h = std::thread::spawn(move || server.run());

        let mut drivers = Vec::new();
        for (tid, model) in MODELS.into_iter().enumerate() {
            let c = client.clone();
            drivers.push(std::thread::spawn(move || {
                let graph = graph_for(model);
                // a serve-only tenant packs its initial snapshot from
                // a throwaway batch-1 trainer at spec.seed; weight
                // init depends only on seed + shapes, so this is the
                // same snapshot bit for bit
                let seeded =
                    build_engine("proposed", &graph, 1, "adam", accel, 50 + tid as u64).unwrap();
                let plan = Plan::from_graph(&graph).unwrap();
                let snap = Arc::new(
                    WeightSnapshot::pack(&plan, &seeded.weights_snapshot(), 0).unwrap(),
                );
                let mut solo =
                    PackedInferEngine::new(&graph, InferAlgo::Proposed, accel, 4, snap).unwrap();
                let mut rng = Pcg32::new(80 + tid as u64);
                let mut got = vec![0.0f32; graph.classes];
                let mut want = vec![0.0f32; graph.classes];
                for _ in 0..16 {
                    let x = rng.normal_vec(graph.input_elems);
                    c.infer_one(tid, &x, &mut got).unwrap();
                    solo.infer_into(&x, 1, &mut want).unwrap();
                    assert_eq!(got, want, "{model} ({accel:?}): logits != solo");
                }
            }));
        }
        for d in drivers {
            d.join().unwrap();
        }
        client.shutdown();
        let tenants = h.join().unwrap().unwrap();
        assert!(tenants.iter().all(|t| t.served() == 16));
    }
}

#[test]
fn trainserve_tenants_serve_their_own_published_weights() {
    for accel in TIERS {
        let specs: Vec<TenantSpec> = MODELS
            .iter()
            .enumerate()
            .map(|(tid, m)| {
                let mut s = spec_for(tid, m, TenantRole::TrainServe, accel);
                s.publish_every = 2;
                s
            })
            .collect();
        let (client, server) = MultiModelServer::new(specs, 2).unwrap();
        let planned = server.fleet_envelope().unwrap().total_bytes();
        let h = std::thread::spawn(move || server.run());

        // each driver trains its tenant and then probes the serve
        // side; the probe logits are checked against a solo mirror
        // re-packed at the same publish boundary
        let mut drivers = Vec::new();
        for (tid, model) in MODELS.into_iter().enumerate() {
            let c = client.clone();
            drivers.push(std::thread::spawn(move || -> (Vec<f32>, Vec<f32>) {
                let graph = graph_for(model);
                let mut rng = Pcg32::new(90 + tid as u64);
                for step in 0..STEPS {
                    let (x, y) = train_batch(&mut rng, &graph, step);
                    c.train_step(tid, &x, &y, 0.01).unwrap();
                }
                // STEPS=4, publish_every=2: version 2 installed at
                // the step-4 quantum, strictly before this submit
                let probe = rng.normal_vec(graph.input_elems);
                let mut got = vec![0.0f32; graph.classes];
                c.infer_one(tid, &probe, &mut got).unwrap();
                (probe, got)
            }));
        }
        let probes: Vec<(Vec<f32>, Vec<f32>)> =
            drivers.into_iter().map(|d| d.join().unwrap()).collect();
        client.shutdown();
        let tenants = h.join().unwrap().unwrap();

        for (tid, model) in MODELS.into_iter().enumerate() {
            let graph = graph_for(model);
            let plan = Plan::from_graph(&graph).unwrap();
            let mut solo =
                build_engine("proposed", &graph, BATCH, "adam", accel, 50 + tid as u64).unwrap();
            let mut rng = Pcg32::new(90 + tid as u64);
            for step in 0..STEPS {
                let (x, y) = train_batch(&mut rng, &graph, step);
                solo.train_step(&x, &y, 0.01).unwrap();
            }
            assert_eq!(
                tenants[tid].train_engine().unwrap().weights_snapshot(),
                solo.weights_snapshot(),
                "{model} ({accel:?}): fleet weights != solo weights"
            );
            let mirror =
                Arc::new(WeightSnapshot::pack(&plan, &solo.weights_snapshot(), 2).unwrap());
            let mut reference =
                PackedInferEngine::new(&graph, InferAlgo::Proposed, accel, 4, mirror).unwrap();
            let (probe, got) = &probes[tid];
            let mut want = vec![0.0f32; graph.classes];
            reference.infer_into(probe, 1, &mut want).unwrap();
            assert_eq!(got, &want, "{model} ({accel:?}): served logits != mirror");
            assert_eq!(tenants[tid].published(), 2);
        }

        let measured: usize = tenants.iter().map(|t| t.steady_state_bytes()).sum();
        assert_eq!(planned as usize, measured, "{accel:?}: envelope mismatch");
    }
}
