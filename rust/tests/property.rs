//! Randomized property tests (mini-proptest: seeded PCG sweeps with
//! failure-case printing) over the substrates' invariants —
//! DESIGN.md §Key-invariants.

use bnn_edge::bitops::{
    col2im_tap_scatter, conv_dx_streaming, gemm, im2col_packed, simd, tune, BPanels, Backend,
    BitMatrix, ConvGeom, KernelCfg, MicroKernel, Pool,
};
use bnn_edge::data;
use bnn_edge::federated::{
    count_votes_scalar, count_votes_sharded, count_votes_words, sign_vote, vote_weight,
};
use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower, names, LayerSpec, ModelSpec};
use bnn_edge::naive::{
    col2im, im2col, maxpool_backward_into, maxpool_forward_into, pool_out_dims, transpose, Accel,
    ProposedTrainer, StandardTrainer, StepEngine,
};
use bnn_edge::util::f16::{f16_bits_to_f32, f32_to_f16_bits, q16};
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;

const CASES: usize = 60;

#[test]
fn prop_memmodel_monotone_in_batch() {
    // modeled footprint is monotone nondecreasing in batch size for
    // every model / config / optimizer
    let mut g = Pcg32::new(1);
    for _ in 0..CASES {
        let model = names()[g.below(names().len())];
        let graph = lower(&get(model).unwrap()).unwrap();
        let cfg = match g.below(3) {
            0 => DtypeConfig::standard(),
            1 => DtypeConfig::proposed(),
            _ => DtypeConfig::ablation("boolgrad_l1").unwrap(),
        };
        let opt = [Optimizer::Adam, Optimizer::Sgd, Optimizer::Bop][g.below(3)];
        let b1 = 1 + g.below(500);
        let b2 = b1 + 1 + g.below(500);
        let m1 = breakdown(&graph, b1, &cfg, opt).total_bytes();
        let m2 = breakdown(&graph, b2, &cfg, opt).total_bytes();
        assert!(m2 >= m1, "{model} {b1}->{b2}: {m1} > {m2}");
    }
}

#[test]
fn prop_proposed_never_larger_than_standard() {
    let mut g = Pcg32::new(2);
    for _ in 0..CASES {
        let model = names()[g.below(names().len())];
        let graph = lower(&get(model).unwrap()).unwrap();
        let b = 1 + g.below(1000);
        for opt in [Optimizer::Adam, Optimizer::Sgd, Optimizer::Bop] {
            let s = breakdown(&graph, b, &DtypeConfig::standard(), opt).total_bytes();
            let p = breakdown(&graph, b, &DtypeConfig::proposed(), opt).total_bytes();
            assert!(p < s, "{model} b={b}: proposed {p} >= standard {s}");
            // and the saving is at least 2x (the f16 floor)
            assert!(s / p >= 2.0, "{model} b={b}: only {}x", s / p);
        }
    }
}

#[test]
fn prop_breakdown_total_is_row_sum() {
    let mut g = Pcg32::new(3);
    for _ in 0..CASES {
        let model = names()[g.below(names().len())];
        let graph = lower(&get(model).unwrap()).unwrap();
        let b = 1 + g.below(300);
        let bd = breakdown(&graph, b, &DtypeConfig::proposed(), Optimizer::Adam);
        let sum: f64 = bd.rows.iter().map(|r| r.bytes).sum();
        assert!((sum - bd.total_bytes()).abs() < 1e-6);
    }
}

#[test]
fn prop_xnor_gemm_matches_dense_reference() {
    let mut g = Pcg32::new(4);
    for case in 0..CASES {
        let m = 1 + g.below(12);
        let k = 1 + g.below(200);
        let n = 1 + g.below(12);
        let a = g.normal_vec(m * k);
        let bt = g.normal_vec(n * k);
        let ap = BitMatrix::pack(m, k, &a);
        let btp = BitMatrix::pack(n, k, &bt);
        let mut fast = vec![0.0; m * n];
        gemm::xnor_gemm(&ap, &btp, &mut fast);
        let sgn = |x: f32| if x >= 0.0 { 1.0 } else { -1.0f32 };
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0;
                for kk in 0..k {
                    want += sgn(a[i * k + kk]) * sgn(bt[j * k + kk]);
                }
                assert_eq!(fast[i * n + j], want, "case {case} ({m},{k},{n})@({i},{j})");
            }
        }
    }
}

#[test]
fn prop_f16_roundtrip_error_bounded() {
    // |q16(x) - x| <= 2^-11 * |x| for normal-range values (half ULP)
    let mut g = Pcg32::new(5);
    for _ in 0..10_000 {
        let x = (g.next_f32() - 0.5) * 2000.0;
        if x.abs() < 1e-4 {
            continue;
        }
        let err = (q16(x) - x).abs();
        assert!(err <= x.abs() * 4.9e-4, "x={x} err={err}");
    }
}

#[test]
fn prop_f16_order_preserving() {
    let mut g = Pcg32::new(6);
    for _ in 0..5_000 {
        let a = (g.next_f32() - 0.5) * 100.0;
        let b = (g.next_f32() - 0.5) * 100.0;
        if a < b {
            assert!(q16(a) <= q16(b), "{a} {b}");
        }
    }
}

#[test]
fn prop_f16_bits_exhaustive_finite_roundtrip() {
    // every finite f16 bit pattern round-trips exactly through f32
    for bits in 0..=0xffffu16 {
        let exp = (bits >> 10) & 0x1f;
        if exp == 31 {
            continue; // inf/nan
        }
        let x = f16_bits_to_f32(bits);
        assert_eq!(f32_to_f16_bits(x), bits, "bits {bits:#06x} -> {x}");
    }
}

#[test]
fn prop_sign_vote_bounded_and_odd() {
    // |vote| <= 1, and vote(-updates) == -vote(updates)
    let mut g = Pcg32::new(7);
    for _ in 0..CASES {
        let n = 1 + g.below(100);
        let k = 1 + g.below(7);
        let ms: Vec<BitMatrix> = (0..k)
            .map(|_| BitMatrix::pack(1, n, &g.normal_vec(n)))
            .collect();
        let refs: Vec<&BitMatrix> = ms.iter().collect();
        let v = sign_vote(&refs);
        assert!(v.iter().all(|&x| (-1..=1).contains(&x)));
        // negate all updates: flip every bit
        let neg: Vec<BitMatrix> = ms
            .iter()
            .map(|m| {
                let vals: Vec<f32> = m.unpack().iter().map(|x| -x).collect();
                BitMatrix::pack(1, n, &vals)
            })
            .collect();
        let nrefs: Vec<&BitMatrix> = neg.iter().collect();
        let nv = sign_vote(&nrefs);
        for (a, b) in v.iter().zip(&nv) {
            assert_eq!(*a, -b);
        }
    }
}

#[test]
fn prop_word_tally_matches_scalar() {
    // the word-level (stack → transpose → popcount) tally is bit-exact
    // vs the scalar bit-probe reference: random shapes (deliberately
    // straddling word boundaries), random staleness weights, every
    // pool width, and the sharded two-level path
    let mut g = Pcg32::new(29);
    for case in 0..CASES {
        let rows = 1 + g.below(3);
        // mix off-word-grid cols (1..130) with exact multiples of 64
        let cols = if case % 4 == 0 { 64 * (1 + g.below(3)) } else { 1 + g.below(130) };
        let k = 1 + g.below(80);
        let ms: Vec<BitMatrix> = (0..k)
            .map(|_| BitMatrix::pack(rows, cols, &g.normal_vec(rows * cols)))
            .collect();
        let refs: Vec<&BitMatrix> = ms.iter().collect();
        // staleness-style weights incl. zeros (inadmissible updates)
        let ws: Vec<u32> = (0..k).map(|_| g.below(4) as u32).collect();
        if ws.iter().all(|&w| w == 0) {
            continue;
        }
        let want = count_votes_scalar(&refs, &ws);
        for threads in [1, 2, 4] {
            let got = count_votes_words(&refs, &ws, &Pool::new(threads));
            assert_eq!(got, want, "k={k} {rows}x{cols} t{threads}");
        }
        let shards = 1 + g.below(4);
        assert_eq!(count_votes_sharded(&refs, &ws, shards), want, "shards={shards}");
    }
    // duplicated update + its negation at equal weight ⇒ exact tie
    let a = BitMatrix::pack(1, 67, &g.normal_vec(67));
    let neg: Vec<f32> = a.unpack().iter().map(|x| -x).collect();
    let b = BitMatrix::pack(1, 67, &neg);
    let w = vote_weight(0, 2).unwrap();
    let v = count_votes_words(&[&a, &b], &[w, w], &Pool::new(2));
    assert!(v.signs().iter().all(|&s| s == 0), "tie must vote 0");
}

#[test]
fn prop_dataset_deterministic_and_disjoint_splits() {
    let mut g = Pcg32::new(8);
    for _ in 0..10 {
        let seed = g.next_u64();
        let a = data::build("syn-cifar16", 64, 32, seed).unwrap();
        let b = data::build("syn-cifar16", 64, 32, seed).unwrap();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_x, b.test_x);
        // train and test are different draws
        assert_ne!(a.train_x[..100], a.test_x[..100]);
    }
}

#[test]
fn prop_json_numeric_roundtrip() {
    let mut g = Pcg32::new(9);
    for _ in 0..500 {
        let x = (g.next_f32() as f64 - 0.5) * 10f64.powi(g.below(9) as i32 - 4);
        let s = Json::Num(x).to_string();
        let back = Json::parse(&s).unwrap().as_f64().unwrap();
        assert!(
            (back - x).abs() <= x.abs() * 1e-9 + 1e-12,
            "{x} -> {s} -> {back}"
        );
    }
}

#[test]
fn prop_bitmatrix_pack_get_agree() {
    let mut g = Pcg32::new(10);
    for _ in 0..CASES {
        let r = 1 + g.below(20);
        let c = 1 + g.below(200);
        let xs = g.normal_vec(r * c);
        let m = BitMatrix::pack(r, c, &xs);
        for _ in 0..20 {
            let i = g.below(r);
            let j = g.below(c);
            let want = if xs[i * c + j] >= 0.0 { 1.0 } else { -1.0 };
            assert_eq!(m.get(i, j), want);
        }
    }
}

#[test]
fn prop_tiled_and_parallel_xnor_bit_exact_vs_naive() {
    // the tentpole invariant: every kernel tier and thread count is
    // bit-exact against the naive triple loop, across odd shapes
    // (K not a multiple of 64, M/N below the 4×4 tile, single
    // row/col) — tier-1 for the tiled backend.  With AVX2/NEON
    // detected, xnor_gemm_tiled/parallel run the SIMD panels, so this
    // is also the SIMD-vs-scalar GEMM exactness sweep.
    let mut g = Pcg32::new(21);
    for case in 0..CASES {
        let m = 1 + g.below(20);
        let k = 1 + g.below(400);
        let n = 1 + g.below(20);
        let a = g.normal_vec(m * k);
        let bt = g.normal_vec(n * k);
        let ap = BitMatrix::pack(m, k, &a);
        let btp = BitMatrix::pack(n, k, &bt);
        let mut want = vec![0.0; m * n];
        gemm::xnor_gemm_naive(&ap, &btp, &mut want);
        let mut tiled = vec![0.0; m * n];
        gemm::xnor_gemm_tiled(&ap, &btp, &mut tiled);
        assert_eq!(tiled, want, "case {case} tiled ({m},{k},{n})");
        for threads in [1, 2, 4] {
            let mut par = vec![0.0; m * n];
            gemm::xnor_gemm_parallel(&ap, &btp, &mut par, &Pool::new(threads));
            assert_eq!(par, want, "case {case} t={threads} ({m},{k},{n})");
        }
    }
}

#[test]
fn prop_block_transpose_matches_scalar() {
    // word-level 64×64 block transpose == bit-by-bit scalar transpose
    let mut g = Pcg32::new(22);
    for case in 0..CASES {
        let r = 1 + g.below(150);
        let c = 1 + g.below(150);
        let xs = g.normal_vec(r * c);
        let m = BitMatrix::pack(r, c, &xs);
        let t = m.transpose();
        // scalar reference
        let mut want = BitMatrix::zeros(c, r);
        for i in 0..r {
            for j in 0..c {
                if m.get(i, j) == 1.0 {
                    want.data[j * want.words_per_row + (i >> 6)] |= 1u64 << (i & 63);
                }
            }
        }
        assert_eq!(t, want, "case {case} ({r}x{c})");
        assert_eq!(t.transpose(), m, "case {case} involution ({r}x{c})");
    }
}

#[test]
fn prop_backend_dispatch_agrees_everywhere() {
    let mut g = Pcg32::new(23);
    for case in 0..30 {
        let m = 1 + g.below(10);
        let k = 1 + g.below(150);
        let n = 1 + g.below(10);
        let a = g.normal_vec(m * k);
        let bt = g.normal_vec(n * k);
        let ap = BitMatrix::pack(m, k, &a);
        let btp = BitMatrix::pack(n, k, &bt);
        let mut want = vec![0.0; m * n];
        Backend::Naive.xnor_gemm(&ap, &btp, &mut want);
        for be in [Backend::Blocked, Backend::Tiled { threads: 2 }] {
            let mut got = vec![0.0; m * n];
            be.xnor_gemm(&ap, &btp, &mut got);
            assert_eq!(got, want, "case {case} {}", be.label());
        }
    }
}

/// Random conv geometry across the full space the engines now
/// execute: kside 1/3/5 (plus 7 for SAME), stride 1/2, SAME or VALID.
fn random_geom(g: &mut Pcg32) -> (usize, ConvGeom) {
    let b = 1 + g.below(2);
    let kside = [1usize, 3, 5, 7][g.below(4)];
    let stride = 1 + g.below(2);
    let h = kside.max(2) + g.below(5);
    let w = kside.max(2) + g.below(5);
    let cin = 1 + g.below(9);
    let geom = if g.below(2) == 0 {
        ConvGeom::same(h, w, cin, kside, stride)
    } else {
        ConvGeom::valid(h, w, cin, kside, stride)
    };
    (b, geom)
}

#[test]
fn prop_im2col_packed_matches_reference() {
    // the fused bit-im2col is bit-exact against f32 im2col + pack —
    // SAME and VALID, stride 1/2, kside 1..7, patch widths off the
    // u64 word grid, every pool thread count (bands must tile the
    // rows exactly)
    let mut g = Pcg32::new(25);
    for case in 0..CASES {
        let (b, geom) = if case % 3 == 0 {
            // keep the wide-cin word-grid offenders of the old sweep
            let kside = [1usize, 3, 5][g.below(3)];
            let b = 1 + 2 * g.below(2); // 1 or 3
            let h = kside.max(2) + g.below(6);
            let w = kside.max(2) + g.below(6);
            let cin = 1 + g.below(70); // k²·cin rarely a multiple of 64
            (b, ConvGeom::same1(h, w, cin, kside))
        } else {
            random_geom(&mut g)
        };
        // exact zeros must pack as +1, like the f32 reference
        let x: Vec<f32> = g
            .normal_vec(geom.in_len(b))
            .into_iter()
            .enumerate()
            .map(|(i, v)| if i % 13 == 0 { 0.0 } else { v })
            .collect();
        let want = BitMatrix::pack(geom.rows(b), geom.k(), &im2col(&x, b, geom));
        for threads in [1, 2, 4] {
            let got = im2col_packed(&x, b, geom, &Pool::new(threads));
            assert_eq!(got, want, "case {case} {geom:?} b{b} t{threads}");
        }
    }
}

#[test]
fn prop_simd_gemm_bit_exact_vs_scalar_kernels() {
    // the dispatched SIMD popcount kernels and the tiled GEMM built
    // on them against the forced-scalar paths, across thread counts
    let mut g = Pcg32::new(26);
    for case in 0..CASES {
        let len = g.below(40);
        let a: Vec<u64> = (0..len).map(|_| g.next_u64()).collect();
        let bs: Vec<Vec<u64>> =
            (0..4).map(|_| (0..len).map(|_| g.next_u64()).collect()).collect();
        assert_eq!(
            simd::xor_popcount(&a, &bs[0]),
            simd::xor_popcount_scalar(&a, &bs[0]),
            "case {case} len {len}"
        );
        assert_eq!(
            simd::xor_popcount_1x4(&a, &bs[0], &bs[1], &bs[2], &bs[3]),
            simd::xor_popcount_1x4_scalar(&a, &bs[0], &bs[1], &bs[2], &bs[3]),
            "case {case} len {len}"
        );
    }
    for case in 0..30 {
        let m = 1 + g.below(16);
        let k = 1 + g.below(400);
        let n = 1 + g.below(16);
        let a = g.normal_vec(m * k);
        let bt = g.normal_vec(n * k);
        let ap = BitMatrix::pack(m, k, &a);
        let btp = BitMatrix::pack(n, k, &bt);
        let mut scalar = vec![0.0; m * n];
        gemm::xnor_gemm_tiled_scalar(&ap, &btp, &mut scalar);
        let mut dispatched = vec![0.0; m * n];
        gemm::xnor_gemm_tiled(&ap, &btp, &mut dispatched);
        assert_eq!(dispatched, scalar, "case {case} tiled ({m},{k},{n})");
        for threads in [1, 2, 4] {
            let mut par = vec![0.0; m * n];
            gemm::xnor_gemm_parallel(&ap, &btp, &mut par, &Pool::new(threads));
            assert_eq!(par, scalar, "case {case} t={threads} ({m},{k},{n})");
        }
    }
}

/// Apply the streaming col2im operator to a full (rows × k) patch
/// matrix: per-tap panels scattered via `col2im_tap_scatter` — the
/// operator form of the fused dX path.
fn streaming_col2im(c: &[f32], b: usize, g: ConvGeom) -> Vec<f32> {
    let k = g.k();
    let rows = g.rows(b);
    let mut dx = vec![0.0f32; g.in_len(b)];
    let mut panel = vec![0.0f32; rows * g.cin];
    for ky in 0..g.kside {
        for kx in 0..g.kside {
            let tap = ky * g.kside + kx;
            for r in 0..rows {
                panel[r * g.cin..(r + 1) * g.cin]
                    .copy_from_slice(&c[r * k + tap * g.cin..r * k + (tap + 1) * g.cin]);
            }
            col2im_tap_scatter(&mut dx, &panel, b, g, ky, kx);
        }
    }
    dx
}

#[test]
fn prop_streaming_col2im_adjoint_of_im2col() {
    // <im2col(x), c> == <x, streaming_col2im(c)> — the adjointness
    // that makes the tap-streamed dX a correct conv backward, across
    // SAME/VALID, strides and ksides (dots accumulated in f64)
    let mut g = Pcg32::new(27);
    for case in 0..CASES {
        let (b, geom) = random_geom(&mut g);
        let x = g.normal_vec(geom.in_len(b));
        let c = g.normal_vec(geom.rows(b) * geom.k());
        let cols = im2col(&x, b, geom);
        let lhs: f64 = cols.iter().zip(&c).map(|(a, v)| *a as f64 * *v as f64).sum();
        let dx = streaming_col2im(&c, b, geom);
        let rhs: f64 = x.iter().zip(&dx).map(|(a, v)| *a as f64 * *v as f64).sum();
        assert!(
            (lhs - rhs).abs() <= 1e-3 * (1.0 + lhs.abs()),
            "case {case} {geom:?} b{b}: {lhs} vs {rhs}"
        );
        // and the streaming operator equals the batch col2im
        let want = col2im(&c, b, geom);
        for i in 0..want.len() {
            assert!(
                (dx[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                "case {case} @ {i}: {} vs {}",
                dx[i],
                want[i]
            );
        }
    }
}

#[test]
fn prop_conv_dx_streaming_matches_prefusion_reference() {
    // the fused dX — tap-streamed panels off the *packed* Ŵᵀ —
    // against the pre-fusion dcols = ∂Y·Ŵᵀ + col2im pipeline, across
    // geometries, backends and thread counts (exact across fused tiers)
    let mut g = Pcg32::new(28);
    for case in 0..30 {
        let (b, geom) = random_geom(&mut g);
        let k = geom.k();
        let rows = geom.rows(b);
        let cout = 1 + g.below(7);
        let dy = g.normal_vec(rows * cout);
        let wt = BitMatrix::pack(cout, k, &g.normal_vec(cout * k));
        let wt_f = wt.unpack();
        let mut dcols = vec![0.0f32; rows * k];
        gemm::gemm_f32(rows, cout, k, &dy, &wt_f, &mut dcols);
        let want = col2im(&dcols, b, geom);
        let first = conv_dx_streaming(&dy, &wt, b, geom, Backend::Blocked);
        for i in 0..want.len() {
            assert!(
                (first[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                "case {case} {geom:?} @ {i}: {} vs {}",
                first[i],
                want[i]
            );
        }
        for threads in [1, 2, 4] {
            let got = conv_dx_streaming(&dy, &wt, b, geom, Backend::Tiled { threads });
            assert_eq!(got, first, "case {case} t{threads}");
        }
    }
}

#[test]
fn prop_packed_at_gemm_bit_exact_vs_densified() {
    // the fused dW contraction off the packed X̂ panel is bit-identical
    // to unpacking, transposing and running the dense f32 GEMM — any
    // shape, any thread count (bands split k, never the reduction)
    let mut g = Pcg32::new(29);
    for case in 0..CASES {
        let rows = 1 + g.below(40);
        let k = 1 + g.below(200);
        let n = 1 + g.below(12);
        let av = g.normal_vec(rows * k);
        let b = g.normal_vec(rows * n);
        let a = BitMatrix::pack(rows, k, &av);
        let at = transpose(&a.unpack(), rows, k); // (k × rows) ±1
        let mut want = vec![0.0f32; k * n];
        gemm::gemm_f32(k, rows, n, &at, &b, &mut want);
        for threads in [1, 2, 4] {
            let mut got = vec![0.0f32; k * n];
            gemm::packed_at_gemm_f32(&a, &b, n, &mut got, &Pool::new(threads));
            assert_eq!(got, want, "case {case} t={threads} ({rows},{k},{n})");
        }
    }
}

/// Small conv net for the train-step equivalence sweep: a stride-1
/// stem, then either a plain conv (SAME or VALID, any stride) or a
/// ResNetE-style two-conv residual block (SAME; stride-2 blocks get
/// the strided channel-doubling shortcut).
fn conv_spec(kside: usize, stride: usize, valid: bool, residual: bool) -> ModelSpec {
    let body = if residual {
        LayerSpec::residual(8, kside, stride, false)
    } else {
        let c = LayerSpec::conv_s(6, kside, stride);
        if valid {
            c.valid()
        } else {
            c
        }
    };
    ModelSpec {
        name: format!("prop_conv_k{kside}_s{stride}_v{valid}_r{residual}"),
        input_shape: vec![12, 12, 3],
        classes: 10,
        layers: vec![
            LayerSpec::conv(4, 3).as_first(),
            body,
            LayerSpec::flatten(),
            LayerSpec::dense(10),
        ],
    }
}

#[test]
fn train_step_fused_backward_matches_prefusion_reference() {
    // full train-step gradient equivalence: the fused conv backward
    // (streaming dX + packed dW) against the pre-fusion reference
    // path (kept under Accel::Naive), both engines, across the whole
    // geometry space the engines now execute — kside 3/5/7, stride
    // 1/2, SAME and VALID, residual on/off — and threads 1/2/4.  SGD
    // keeps the update linear in the gradient, so the layer-level
    // 1e-4 gradient agreement carries to the weights.
    let mut g = Pcg32::new(30);
    let mut configs: Vec<(usize, usize, bool, bool)> = Vec::new();
    for kside in [3usize, 5, 7] {
        for stride in [1usize, 2] {
            configs.push((kside, stride, false, false)); // SAME
            configs.push((kside, stride, true, false)); // VALID
            configs.push((kside, stride, false, true)); // SAME residual
        }
    }
    // kside 1 keeps the legacy pad-free case covered
    configs.push((1, 1, false, false));
    for (kside, stride, valid, residual) in configs {
        let tag = format!("k{kside} s{stride} valid={valid} res={residual}");
        let graph = lower(&conv_spec(kside, stride, valid, residual)).unwrap();
        let batch = 4;
        let x = g.normal_vec(batch * 12 * 12 * 3);
        let y: Vec<usize> = (0..batch).map(|i| i % 10).collect();

        // standard engine: reference vs every fused tier
        let mut reference =
            StandardTrainer::new(&graph, batch, "sgd", Accel::Naive, 7).unwrap();
        let (rl, _) = reference.train_step(&x, &y, 0.01).unwrap();
        let rw = reference.weights_snapshot();
        for accel in [Accel::Blocked, Accel::Tiled(1), Accel::Tiled(2), Accel::Tiled(4)] {
            let mut t = StandardTrainer::new(&graph, batch, "sgd", accel, 7).unwrap();
            let (l, _) = t.train_step(&x, &y, 0.01).unwrap();
            assert!(
                (l - rl).abs() <= 1e-4 * (1.0 + rl.abs()),
                "std {tag} {accel:?}: {l} vs {rl}"
            );
            for (wa, wb) in rw.iter().zip(t.weights_snapshot().iter()) {
                for (u, v) in wa.iter().zip(wb) {
                    assert!((u - v).abs() <= 1e-4, "std {tag} {accel:?}: {u} vs {v}");
                }
            }
        }

        // proposed engine: every fused tier is *identical* (same
        // kernels; pool bands never split a reduction)...
        let mut blocked =
            ProposedTrainer::new(&graph, batch, "sgd", Accel::Blocked, 7).unwrap();
        let mut losses = Vec::new();
        for _ in 0..3 {
            losses.push(blocked.train_step(&x, &y, 0.01).unwrap().0);
        }
        let bw = blocked.weights_snapshot();
        for threads in [1usize, 2, 4] {
            let mut t =
                ProposedTrainer::new(&graph, batch, "sgd", Accel::Tiled(threads), 7).unwrap();
            for (si, &want) in losses.iter().enumerate() {
                let (l, _) = t.train_step(&x, &y, 0.01).unwrap();
                assert_eq!(l, want, "prop {tag} t{threads} step {si}");
            }
            assert_eq!(t.weights_snapshot(), bw, "prop {tag} t{threads}");
        }
        // ...and the naive reference tracks the fused trajectory (the
        // packed ∂Ŵ sign quantization can amplify a ~1e-6 dX
        // summation-order difference on a near-zero accumulation, so
        // the band is loose — a geometry bug would diverge by O(1))
        let mut naive = ProposedTrainer::new(&graph, batch, "sgd", Accel::Naive, 7).unwrap();
        let mut nl = 0.0;
        for _ in 0..3 {
            nl = naive.train_step(&x, &y, 0.01).unwrap().0;
        }
        let bl = *losses.last().unwrap();
        assert!(
            (nl - bl).abs() <= 2e-2 * (1.0 + bl.abs()),
            "prop {tag}: naive {nl} vs fused {bl}"
        );
    }
}

#[test]
fn residual_minis_fused_matches_reference_across_threads() {
    // the ISSUE acceptance bar: resnete_mini / bireal_mini
    // fused-vs-reference gradients agree at 1e-4 across threads 1/2/4
    let mut g = Pcg32::new(31);
    for model in ["resnete_mini", "bireal_mini"] {
        let graph = lower(&get(model).unwrap()).unwrap();
        let batch = 4;
        let x = g.normal_vec(batch * 16 * 16 * 3);
        let y: Vec<usize> = (0..batch).map(|i| i % 10).collect();
        let mut reference =
            StandardTrainer::new(&graph, batch, "sgd", Accel::Naive, 11).unwrap();
        let (rl, _) = reference.train_step(&x, &y, 0.01).unwrap();
        let rw = reference.weights_snapshot();
        for threads in [1usize, 2, 4] {
            let mut t =
                StandardTrainer::new(&graph, batch, "sgd", Accel::Tiled(threads), 11).unwrap();
            let (l, _) = t.train_step(&x, &y, 0.01).unwrap();
            assert!(
                (l - rl).abs() <= 1e-4 * (1.0 + rl.abs()),
                "{model} t{threads}: {l} vs {rl}"
            );
            for (wa, wb) in rw.iter().zip(t.weights_snapshot().iter()) {
                for (u, v) in wa.iter().zip(wb) {
                    assert!((u - v).abs() <= 1e-4, "{model} t{threads}: {u} vs {v}");
                }
            }
        }
        // proposed engine: fused tiers identical across threads
        let mut blocked =
            ProposedTrainer::new(&graph, batch, "sgd", Accel::Blocked, 11).unwrap();
        let (bl, _) = blocked.train_step(&x, &y, 0.01).unwrap();
        let bw = blocked.weights_snapshot();
        for threads in [1usize, 2, 4] {
            let mut t =
                ProposedTrainer::new(&graph, batch, "sgd", Accel::Tiled(threads), 11).unwrap();
            let (l, _) = t.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(l, bl, "{model} t{threads}");
            assert_eq!(t.weights_snapshot(), bw, "{model} t{threads}");
        }
    }
}

// ------------------------------------------------------------ §Autotuner

/// Serializes the tests that flip the process-global tune mode; every
/// other test runs under the deterministic `Fixed` default.  (Tuned
/// dispatch is bit-exact, so a concurrent reader would still compute
/// correct products — the lock just keeps mode transitions ordered.)
static TUNE_MODE: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn prop_every_tuner_candidate_bit_exact_vs_naive() {
    // the invariant the autotuner rests on: every (micro-kernel,
    // K-tile, row-band) config it may ever pick computes the identical
    // integer popcount product — with and without interleaved B
    // panels, across odd shapes (K off the word grid, M/N below the
    // register blocks) and thread counts — so tuning is purely a perf
    // decision and `--tune=auto` can never change a result
    let mut g = Pcg32::new(32);
    let micros = [
        MicroKernel::Scalar4x4,
        MicroKernel::Simd1x4,
        MicroKernel::Simd1x8,
        MicroKernel::Simd2x4,
        MicroKernel::Panel8,
    ];
    for case in 0..20 {
        let m = 1 + g.below(20);
        let k = 1 + g.below(400);
        let n = 1 + g.below(20);
        let ap = BitMatrix::pack(m, k, &g.normal_vec(m * k));
        let btp = BitMatrix::pack(n, k, &g.normal_vec(n * k));
        let panels = BPanels::pack(&btp);
        let mut want = vec![0.0; m * n];
        gemm::xnor_gemm_naive(&ap, &btp, &mut want);
        for &micro in &micros {
            for kc_words in [32usize, 128] {
                for band_rows in [0usize, 3] {
                    let cfg = KernelCfg { micro, kc_words, band_rows };
                    for threads in [1usize, 2, 4] {
                        // Panel8 without panels exercises the fallback
                        for bp in [None, Some(&panels)] {
                            let mut got = vec![9.0; m * n];
                            gemm::xnor_gemm_with(
                                cfg,
                                &ap,
                                &btp,
                                bp,
                                &mut got,
                                &Pool::new(threads),
                            );
                            assert_eq!(
                                got,
                                want,
                                "case {case} ({m},{k},{n}) {} t{threads} panels={}",
                                cfg.label(),
                                bp.is_some()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_bpanels_gemm_bit_exact_vs_naive() {
    // the interleaved 8-column panel kernel (what the weight cache
    // hands wide layers) against the naive triple loop: panel tails
    // (n % 8 != 0), single-column B, K straddling word boundaries
    let mut g = Pcg32::new(33);
    for case in 0..CASES {
        let m = 1 + g.below(24);
        let k = 1 + g.below(300);
        let n = 1 + g.below(30);
        let ap = BitMatrix::pack(m, k, &g.normal_vec(m * k));
        let btp = BitMatrix::pack(n, k, &g.normal_vec(n * k));
        let panels = BPanels::pack(&btp);
        assert_eq!(panels.data.len(), BPanels::words_for(n, btp.words_per_row));
        let mut want = vec![0.0; m * n];
        gemm::xnor_gemm_naive(&ap, &btp, &mut want);
        let cfg = KernelCfg { micro: MicroKernel::Panel8, kc_words: 128, band_rows: 0 };
        for threads in [1usize, 2, 4] {
            let mut got = vec![0.0; m * n];
            gemm::xnor_gemm_with(cfg, &ap, &btp, Some(&panels), &mut got, &Pool::new(threads));
            assert_eq!(got, want, "case {case} ({m},{k},{n}) t{threads}");
        }
    }
}

#[test]
fn tune_auto_caches_winner_and_leaves_valid_product() {
    let _guard = TUNE_MODE.lock().unwrap_or_else(|e| e.into_inner());
    let mut g = Pcg32::new(34);
    // a shape class nothing else in the process tunes
    let (m, k, n) = (13usize, 777usize, 9usize);
    let ap = BitMatrix::pack(m, k, &g.normal_vec(m * k));
    let btp = BitMatrix::pack(n, k, &g.normal_vec(n * k));
    let mut want = vec![0.0; m * n];
    gemm::xnor_gemm_naive(&ap, &btp, &mut want);
    let pool = Pool::new(2);

    // a miss in auto mode microbenches on the real operands and must
    // leave `out` holding the true product
    tune::set_mode(tune::Mode::Auto);
    let mut out = vec![0.0; m * n];
    let cfg = tune::config_for(&ap, &btp, None, &mut out, &pool);
    tune::set_mode(tune::Mode::Fixed);
    assert_eq!(out, want, "auto-tune bench must leave a valid product");

    // the winner is cached under its shape class...
    let key = tune::ShapeKey::of(m, btp.words_per_row, n, false, pool.threads());
    assert_eq!(tune::lookup(&key), Some(cfg));
    assert_eq!(tune::current_config(m, btp.words_per_row, n, false, 2), KernelCfg::fixed());

    // ...and a registry hit replays it without touching the operands
    tune::set_mode(tune::Mode::Auto);
    let mut out2 = vec![7.0; m * n];
    let cfg2 = tune::config_for(&ap, &btp, None, &mut out2, &pool);
    assert_eq!(tune::current_config(m, btp.words_per_row, n, false, 2), cfg);
    tune::set_mode(tune::Mode::Fixed);
    assert_eq!(cfg2, cfg, "cache hit must replay the stored winner");
    assert!(out2.iter().all(|&v| v == 7.0), "cache hit must not run a GEMM");

    // fixed mode: the deterministic config, no registry traffic
    let before = tune::len();
    let cfg3 = tune::config_for(&ap, &btp, None, &mut out2, &pool);
    assert_eq!(cfg3, KernelCfg::fixed());
    assert_eq!(tune::len(), before);
}

#[test]
fn tiled_backend_auto_dispatch_bit_exact() {
    // end-to-end through Backend::Tiled: flipping the autotuner on
    // (tune + replay, with packed panels) never changes a single bit
    // of the product vs the fixed dispatch and the naive loop
    let _guard = TUNE_MODE.lock().unwrap_or_else(|e| e.into_inner());
    let mut g = Pcg32::new(35);
    for case in 0..10 {
        let m = 1 + g.below(30);
        let k = 1 + g.below(500);
        let n = 1 + g.below(40);
        let ap = BitMatrix::pack(m, k, &g.normal_vec(m * k));
        let btp = BitMatrix::pack(n, k, &g.normal_vec(n * k));
        let panels = if case % 2 == 0 { Some(BPanels::pack(&btp)) } else { None };
        let mut want = vec![0.0; m * n];
        gemm::xnor_gemm_naive(&ap, &btp, &mut want);
        for threads in [1usize, 2, 4] {
            let be = Backend::Tiled { threads };
            let mut fixed = vec![0.0; m * n];
            be.xnor_gemm_packed(&ap, &btp, panels.as_ref(), &mut fixed);
            assert_eq!(fixed, want, "case {case} fixed t{threads}");
            tune::set_mode(tune::Mode::Auto);
            let mut tuned = vec![0.0; m * n];
            be.xnor_gemm_packed(&ap, &btp, panels.as_ref(), &mut tuned); // tunes
            assert_eq!(tuned, want, "case {case} tuning call t{threads}");
            be.xnor_gemm_packed(&ap, &btp, panels.as_ref(), &mut tuned); // replays
            tune::set_mode(tune::Mode::Fixed);
            assert_eq!(tuned, want, "case {case} tuned t{threads}");
        }
    }
}

// ----------------------------------------------------- §General max-pool

#[test]
fn prop_general_maxpool_matches_per_window_reference() {
    // forward: every output cell is the window max and the mask points
    // at the *first* cell attaining it (scan order ky, kx — ties
    // forced via quantized inputs); backward: gradients route to
    // exactly the masked winners, overlapping windows accumulate, and
    // the gradient mass is preserved
    let mut g = Pcg32::new(36);
    for case in 0..CASES {
        let kside = 2 + g.below(3); // 2..=4
        let stride = 1 + g.below(3); // 1..=3 (stride < kside overlaps)
        let (oh, ow) = (1 + g.below(4), 1 + g.below(4));
        let h = (oh - 1) * stride + kside;
        let w = (ow - 1) * stride + kside;
        let (b, c) = (1 + g.below(2), 1 + g.below(5));
        assert_eq!(pool_out_dims(h, w, kside, stride), (oh, ow), "case {case}");
        // quarter-grid values make in-window ties common
        let x: Vec<f32> =
            g.normal_vec(b * h * w * c).iter().map(|v| (v * 4.0).round() / 4.0).collect();
        let cells = b * oh * ow * c;
        let mut out = vec![0.0f32; cells];
        let mut mask = vec![0u32; cells];
        maxpool_forward_into(&x, b, h, w, c, kside, stride, &mut out, &mut mask);
        let at = |bi: usize, oy: usize, ox: usize, m: usize, ch: usize| {
            let (ky, kx) = (m / kside, m % kside);
            x[((bi * h + oy * stride + ky) * w + ox * stride + kx) * c + ch]
        };
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ch in 0..c {
                        let o = ((bi * oh + oy) * ow + ox) * c + ch;
                        let win: Vec<f32> =
                            (0..kside * kside).map(|m| at(bi, oy, ox, m, ch)).collect();
                        let best = win.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                        let tag = format!("case {case} k{kside} s{stride} @({bi},{oy},{ox},{ch})");
                        assert_eq!(out[o], best, "{tag}: not the window max");
                        let widx = mask[o] as usize;
                        assert_eq!(win[widx], best, "{tag}: mask not at a max");
                        assert!(
                            win[..widx].iter().all(|&v| v < best),
                            "{tag}: mask skipped an earlier winner (tie-break)"
                        );
                    }
                }
            }
        }
        // backward: scatter a random upstream gradient through the mask
        let dout = g.normal_vec(cells);
        let mut dx = vec![0.0f32; b * h * w * c];
        maxpool_backward_into(&dout, &mask, b, h, w, c, kside, stride, &mut dx);
        let mut want = vec![0.0f32; b * h * w * c];
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ch in 0..c {
                        let o = ((bi * oh + oy) * ow + ox) * c + ch;
                        let (ky, kx) = (mask[o] as usize / kside, mask[o] as usize % kside);
                        want[((bi * h + oy * stride + ky) * w + ox * stride + kx) * c + ch] +=
                            dout[o];
                    }
                }
            }
        }
        assert_eq!(dx, want, "case {case} k{kside} s{stride} backward routing");
        let mass_in: f64 = dout.iter().map(|&v| v as f64).sum();
        let mass_out: f64 = dx.iter().map(|&v| v as f64).sum();
        assert!(
            (mass_in - mass_out).abs() <= 1e-3 * (1.0 + mass_in.abs()),
            "case {case}: gradient mass {mass_in} vs {mass_out}"
        );
    }
}

/// Conv → general pool → conv net for the end-to-end pool sweep.
fn pool_spec(kside: usize, stride: usize, hw: usize) -> ModelSpec {
    ModelSpec {
        name: format!("prop_pool_k{kside}_s{stride}"),
        input_shape: vec![hw, hw, 3],
        classes: 10,
        layers: vec![
            LayerSpec::conv(4, 3).as_first(),
            LayerSpec::maxpool_k(kside, stride),
            LayerSpec::conv(6, 3),
            LayerSpec::flatten(),
            LayerSpec::dense(10),
        ],
    }
}

#[test]
fn train_step_general_pool_matches_reference_across_tiers() {
    // 3×3 stride-2 over an odd map, the overlapping 3×3 stride-1 and
    // 2×2 stride-1 — the geometries the 2×2-only engines used to
    // reject — taking full gradient steps on every accel tier
    let mut g = Pcg32::new(37);
    for (kside, stride, hw) in [(3usize, 2usize, 9usize), (3, 1, 7), (2, 1, 8)] {
        let graph = lower(&pool_spec(kside, stride, hw)).unwrap();
        let batch = 4;
        let x = g.normal_vec(batch * hw * hw * 3);
        let y: Vec<usize> = (0..batch).map(|i| i % 10).collect();
        let tag = format!("pool k{kside} s{stride} {hw}x{hw}");

        // standard engine: naive reference vs the fused tiers (1e-4)
        let mut reference =
            StandardTrainer::new(&graph, batch, "sgd", Accel::Naive, 7).unwrap();
        let (rl, _) = reference.train_step(&x, &y, 0.01).unwrap();
        let rw = reference.weights_snapshot();
        for accel in [Accel::Blocked, Accel::Tiled(2)] {
            let mut t = StandardTrainer::new(&graph, batch, "sgd", accel, 7).unwrap();
            let (l, _) = t.train_step(&x, &y, 0.01).unwrap();
            assert!(
                (l - rl).abs() <= 1e-4 * (1.0 + rl.abs()),
                "{tag} {accel:?}: {l} vs {rl}"
            );
            for (wa, wb) in rw.iter().zip(t.weights_snapshot().iter()) {
                for (u, v) in wa.iter().zip(wb) {
                    assert!((u - v).abs() <= 1e-4, "{tag} {accel:?}: {u} vs {v}");
                }
            }
        }

        // proposed engine: every fused tier identical bit-for-bit
        // (this walks the retained u32 winner-mask path — the general
        // pool's backward state — on both the blocked and tiled tiers)
        let mut blocked =
            ProposedTrainer::new(&graph, batch, "sgd", Accel::Blocked, 7).unwrap();
        let mut losses = Vec::new();
        for _ in 0..2 {
            losses.push(blocked.train_step(&x, &y, 0.01).unwrap().0);
        }
        let bw = blocked.weights_snapshot();
        for threads in [1usize, 2, 4] {
            let mut t =
                ProposedTrainer::new(&graph, batch, "sgd", Accel::Tiled(threads), 7).unwrap();
            for (si, &want) in losses.iter().enumerate() {
                let (l, _) = t.train_step(&x, &y, 0.01).unwrap();
                assert_eq!(l, want, "{tag} t{threads} step {si}");
            }
            assert_eq!(t.weights_snapshot(), bw, "{tag} t{threads}");
        }
    }
}

#[test]
fn prop_pack_f16_t_matches_scalar_pack_transpose() {
    let mut g = Pcg32::new(24);
    for case in 0..CASES {
        let k = 1 + g.below(150);
        let n = 1 + g.below(100);
        let xs = g.normal_vec(k * n);
        let bits: Vec<u16> = xs.iter().map(|&v| f32_to_f16_bits(v)).collect();
        let direct = BitMatrix::pack_f16_t(&bits, k, n);
        // scalar reference straight from the f16 sign convention:
        // +1 unless strictly negative (sign bit set and magnitude > 0)
        let mut want = BitMatrix::zeros(n, k);
        for kk in 0..k {
            for j in 0..n {
                let h = bits[kk * n + j];
                if h >> 15 == 0 || h & 0x7fff == 0 {
                    want.data[j * want.words_per_row + (kk >> 6)] |= 1u64 << (kk & 63);
                }
            }
        }
        assert_eq!(direct, want, "case {case} ({k}x{n})");
    }
}
