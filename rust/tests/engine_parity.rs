//! Engine parity: the pure-Rust naive engines agree with the
//! Python-lowered HLO step on identical inputs — DESIGN.md's
//! "Engines agree" invariant, cross-language and cross-implementation.
//!
//! Uses the golden records (fixed-seed params/batch dumped by aot.py):
//! the naive StandardTrainer ingests the golden parameters and batch
//! and must reproduce the golden loss/accuracy.

use bnn_edge::models::{get, lower, names};
use bnn_edge::naive::{build_engine, Accel, Plan, StandardTrainer, StepEngine};
use bnn_edge::runtime::{Engine, IoKind};
use bnn_edge::util::rng::Pcg32;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Parity tests need `make artifacts`; skip cleanly when absent.
fn artifacts_present() -> bool {
    if artifacts_dir().is_dir() {
        return true;
    }
    eprintln!("skipping parity test: {} missing (run `make artifacts`)", artifacts_dir().display());
    false
}

#[test]
fn every_zoo_model_plans_and_takes_a_step_on_every_tier() {
    // the PR-4 acceptance sweep: all zoo models — including the CNV
    // family and the full/mini residual nets that previously errored
    // with "use the HLO runtime" — build a Plan and complete a
    // gradient step on every Accel tier with both engines.  Full-scale
    // models run at batch 1 (ImageNet-scale maps; the point is
    // geometry coverage, not throughput), minis at batch 4.
    let mut rng = Pcg32::new(17);
    for (mi, model) in names().iter().enumerate() {
        let model = *model;
        let graph = lower(&get(model).unwrap()).unwrap();
        let plan = Plan::from_graph(&graph)
            .unwrap_or_else(|e| panic!("{model} failed to plan: {e}"));
        assert!(plan.weight_layers() > 0, "{model}");
        let small = model.ends_with("_mini") || model == "mlp";
        let batch = if small { 4 } else { 1 };
        let x = rng.normal_vec(batch * graph.input_elems);
        let y: Vec<usize> = (0..batch).map(|i| i % graph.classes).collect();
        for accel in [Accel::Naive, Accel::Blocked, Accel::Tiled(2)] {
            // the Naive tier is the scalar direct-conv reference:
            // running *both* engines over ImageNet-geometry maps there
            // would dominate the suite's wall clock, so full-scale
            // models alternate the engine per model — every model
            // still completes a step on every tier, and both engines
            // are still exercised on full-scale Naive across the zoo
            let algos: &[&str] = if small || accel != Accel::Naive {
                &["standard", "proposed"]
            } else if mi % 2 == 0 {
                &["standard"]
            } else {
                &["proposed"]
            };
            for algo in algos {
                let mut eng = build_engine(algo, &graph, batch, "sgd", accel, 3)
                    .unwrap_or_else(|e| panic!("{model}/{algo}/{accel:?}: {e}"));
                let (loss, acc) = eng
                    .train_step(&x, &y, 0.01)
                    .unwrap_or_else(|e| panic!("{model}/{algo}/{accel:?} step: {e}"));
                assert!(loss.is_finite(), "{model}/{algo}/{accel:?}: loss {loss}");
                assert!((0.0..=1.0).contains(&acc), "{model}/{algo}/{accel:?}");
                // and eval runs on the stepped weights
                let (el, _) = eng.eval(&x, &y).unwrap();
                assert!(el.is_finite(), "{model}/{algo}/{accel:?} eval");
            }
        }
    }
}

#[test]
fn microbatch_sweep_matches_reference_gradients() {
    // ISSUE-5 satellite: micro ∈ {1, B/2, B} on both engines.
    //
    // Batch norm couples samples *within* its normalization group, so
    // a microbatched step uses per-chunk (ghost) BN statistics — the
    // standard gradient-accumulation semantics.  Exact equality with
    // the full-batch step is therefore only defined at micro = B
    // (asserted bit-exact below); for micro < B the mathematically
    // exact invariant is that the accumulated gradient equals the
    // *mean of independent chunk gradients* taken at the same
    // weights, which plain SGD exposes as first-step weight deltas.
    // That reference match is asserted at 1e-5 on the (all-f32)
    // standard engine; the proposed engine's weight path binarizes
    // the accumulated ∂W (sign of a sum ≠ mean of signs), so it is
    // pinned by micro = B exactness plus the β-path check in
    // rust/tests/memtrack_step.rs.
    use bnn_edge::util::rng::Pcg32;
    let batch = 8usize;
    for model in ["mlp_mini", "cnv_mini"] {
        let graph = lower(&get(model).unwrap()).unwrap();
        let mut rng = Pcg32::new(5);
        let x = rng.normal_vec(batch * graph.input_elems);
        let y: Vec<usize> = (0..batch).map(|i| i % graph.classes).collect();

        for algo in ["standard", "proposed"] {
            // micro = B: bit-identical to the default engine
            let mut full = build_engine(algo, &graph, batch, "sgd", Accel::Tiled(2), 7)
                .unwrap();
            let mut micro_b = bnn_edge::naive::build_engine_micro(
                algo,
                &graph,
                batch,
                batch,
                "sgd",
                Accel::Tiled(2),
                7,
            )
            .unwrap();
            for step in 0..2 {
                let (lf, _) = full.train_step(&x, &y, 0.01).unwrap();
                let (lm, _) = micro_b.train_step(&x, &y, 0.01).unwrap();
                assert_eq!(lf, lm, "{model}/{algo} micro=B step {step}");
            }
            assert_eq!(
                full.weights_snapshot(),
                micro_b.weights_snapshot(),
                "{model}/{algo} micro=B"
            );
        }

        // micro ∈ {1, B/2}: standard-engine deltas equal the mean of
        // independent chunk deltas within 1e-5
        for micro in [1usize, batch / 2] {
            let chunks = batch / micro;
            let mut m = bnn_edge::naive::build_engine_micro(
                "standard",
                &graph,
                batch,
                micro,
                "sgd",
                Accel::Tiled(2),
                7,
            )
            .unwrap();
            let w0 = m.weights_snapshot();
            // small enough that no per-chunk update crosses the ±1 weight
            // clip (clipping is outside the linear-in-gradient regime the
            // mean-of-chunk-deltas identity relies on)
            let lr = 0.01f32;
            let mut want: Vec<Vec<f32>> = w0.iter().map(|v| vec![0.0; v.len()]).collect();
            for ci in 0..chunks {
                let mut r =
                    build_engine("standard", &graph, micro, "sgd", Accel::Tiled(2), 7)
                        .unwrap();
                r.load_weights(&w0).unwrap();
                r.train_step(
                    &x[ci * micro * graph.input_elems..(ci + 1) * micro * graph.input_elems],
                    &y[ci * micro..(ci + 1) * micro],
                    lr,
                )
                .unwrap();
                for (acc, (after, before)) in
                    want.iter_mut().zip(r.weights_snapshot().iter().zip(&w0))
                {
                    for (a, (u, v)) in acc.iter_mut().zip(after.iter().zip(before)) {
                        *a += (u - v) / chunks as f32;
                    }
                }
            }
            m.train_step(&x, &y, lr).unwrap();
            for (li, (after, (before, wnt))) in
                m.weights_snapshot().iter().zip(w0.iter().zip(&want)).enumerate()
            {
                for i in 0..after.len() {
                    let got = after[i] - before[i];
                    assert!(
                        (got - wnt[i]).abs() <= 1e-5 + 1e-5 * wnt[i].abs(),
                        "{model} micro={micro} layer {li} @ {i}: {got} vs {}",
                        wnt[i]
                    );
                }
            }
        }
    }
}

#[test]
fn naive_standard_matches_hlo_golden_loss() {
    if !artifacts_present() {
        return;
    }
    let eng = Engine::cpu(artifacts_dir()).unwrap();
    let name = "mlp_mini_standard_adam_b64";
    let art = eng.load(name).unwrap();
    let golden = eng.golden(name).unwrap();
    let m = &art.manifest;

    // golden params -> naive engine (snapshot layout = [w, beta, ...])
    let graph = lower(&get("mlp_mini").unwrap()).unwrap();
    let mut naive = StandardTrainer::new(&graph, m.batch, "adam", Accel::Blocked, 0).unwrap();
    let params: Vec<Vec<f32>> = m
        .input_indices(IoKind::Param)
        .into_iter()
        .map(|i| golden.inputs[i].data.clone())
        .collect();
    naive.load_weights(&params).unwrap();

    // golden batch
    let xi = m.input_indices(IoKind::X)[0];
    let yi = m.input_indices(IoKind::Y)[0];
    let x = &golden.inputs[xi].data;
    let labels: Vec<usize> = golden.inputs[yi]
        .data
        .chunks(m.classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect();

    let (loss, acc) = naive.train_step(x, &labels, 0.001).unwrap();
    let loss_idx = m.output_index("loss").unwrap() ;
    let acc_idx = m.output_index("acc").unwrap();
    let want_loss = golden.outputs[loss_idx].item().unwrap();
    let want_acc = golden.outputs[acc_idx].item().unwrap();

    assert!(
        (loss - want_loss).abs() < 5e-3,
        "loss: naive {loss} vs HLO {want_loss}"
    );
    assert!(
        (acc - want_acc).abs() < 1e-6,
        "acc: naive {acc} vs HLO {want_acc}"
    );
}

#[test]
#[ignore = "replay needs a PJRT-enabled xla binding (offline stub cannot execute HLO) — run `make artifacts` + this test on a PJRT machine"]
fn residual_golden_loss_matches_after_apply_model_reconciliation() {
    // ROADMAP PR-4 quirk, reconciled in PR 5: Python apply_model used
    // to (a) apply l.stride to BOTH ResNetE block convs and (b) skip
    // around each conv separately, while the Rust engines lower one
    // skip around the 2-conv block with a stride-1 second conv.
    // python/compile/models.py now implements the Rust semantics
    // (verified against a numpy mirror at 1e-8 — see CHANGES.md), and
    // `make artifacts` (ISSUE-6: aot.py now emits goldens for the
    // residual standard/adam b64 variants; generation re-verified
    // under ISSUE-10 on jax 0.4.37 — the full set builds all 85
    // artifacts including both residual goldens) produces the ground
    // truth this test replays.  The
    // remaining blocker is executing the replay: `Engine::cpu` needs
    // a PJRT-enabled `xla` binding, and the offline image vendors a
    // stub whose constructors error — hence #[ignore] stays until the
    // suite runs where PJRT exists (see the Makefile note).
    if !artifacts_present() {
        return;
    }
    let eng = Engine::cpu(artifacts_dir()).unwrap();
    for (model, name) in [
        ("resnete_mini", "resnete_mini_standard_adam_b64"),
        ("bireal_mini", "bireal_mini_standard_adam_b64"),
    ] {
        let art = match eng.load(name) {
            Ok(a) => a,
            Err(_) => continue, // artifact set without residual goldens
        };
        let golden = eng.golden(name).unwrap();
        let m = &art.manifest;
        let graph = lower(&get(model).unwrap()).unwrap();
        let mut naive =
            StandardTrainer::new(&graph, m.batch, "adam", Accel::Blocked, 0).unwrap();
        let params: Vec<Vec<f32>> = m
            .input_indices(IoKind::Param)
            .into_iter()
            .map(|i| golden.inputs[i].data.clone())
            .collect();
        naive.load_weights(&params).unwrap();
        let xi = m.input_indices(IoKind::X)[0];
        let yi = m.input_indices(IoKind::Y)[0];
        let labels: Vec<usize> = golden.inputs[yi]
            .data
            .chunks(m.classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            })
            .collect();
        let (loss, _) = naive.train_step(&golden.inputs[xi].data, &labels, 0.001).unwrap();
        let want = golden.outputs[m.output_index("loss").unwrap()].item().unwrap();
        assert!(
            (loss - want).abs() < 5e-3,
            "{model}: naive {loss} vs HLO golden {want}"
        );
    }
}

#[test]
fn naive_and_hlo_converge_to_similar_loss() {
    // run both engines for 15 steps on the same fixed batch from the
    // golden record; final losses must be in the same regime
    if !artifacts_present() {
        return;
    }
    let eng = Engine::cpu(artifacts_dir()).unwrap();
    let name = "mlp_mini_standard_adam_b64";
    let art = eng.load(name).unwrap();
    let golden = eng.golden(name).unwrap();
    let m = &art.manifest;

    let xi = m.input_indices(IoKind::X)[0];
    let yi = m.input_indices(IoKind::Y)[0];
    let x = golden.inputs[xi].data.clone();
    let labels: Vec<usize> = golden.inputs[yi]
        .data
        .chunks(m.classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        })
        .collect();

    // HLO side
    let mut inputs = golden.inputs.clone();
    let n_state = m.input_indices(IoKind::Param).len() + m.input_indices(IoKind::Opt).len();
    let loss_idx = m.output_index("loss").unwrap();
    let mut hlo_loss = 0.0;
    for _ in 0..15 {
        let outs = art.run(&inputs).unwrap();
        hlo_loss = outs[loss_idx].item().unwrap();
        for (i, t) in outs.into_iter().take(n_state).enumerate() {
            inputs[i] = t;
        }
    }

    // naive side, from the same golden init
    let graph = lower(&get("mlp_mini").unwrap()).unwrap();
    let mut naive = StandardTrainer::new(&graph, m.batch, "adam", Accel::Blocked, 0).unwrap();
    let params: Vec<Vec<f32>> = m
        .input_indices(IoKind::Param)
        .into_iter()
        .map(|i| golden.inputs[i].data.clone())
        .collect();
    naive.load_weights(&params).unwrap();
    let mut nv_loss = 0.0;
    for _ in 0..15 {
        let (l, _) = naive.train_step(&x, &labels, 0.001).unwrap();
        nv_loss = l;
    }

    assert!(
        (hlo_loss - nv_loss).abs() < 0.25 * hlo_loss.max(nv_loss),
        "divergent training: hlo {hlo_loss} vs naive {nv_loss}"
    );
}

#[test]
fn conv_golden_pallas_agrees() {
    // the pallas conv artifact (im2col + binary_matmul kernel) golden
    // validates the channel-ordering fix across the whole stack
    let eng = Engine::cpu(artifacts_dir()).unwrap();
    let name = "cnv_mini_proposed_adam_b100_pallas";
    let art = eng.load(name).unwrap();
    let golden = eng.golden(name).unwrap();
    let outs = art.run(&golden.inputs).unwrap();
    for (i, (got, want)) in outs.iter().zip(&golden.outputs).enumerate() {
        let d = got.max_abs_diff(want);
        // Accumulation-order differences between the tracing-time
        // interpret run (golden) and the compiled HLO can flip the
        // *sign* of a near-zero dW accumulation, which binarization
        // then amplifies to a 2/sqrt(N) step in the Adam moments.
        // Params move by <= 2*lr from such a flip; moments by
        // 2*(1-b1)/sqrt(N).  Kind-aware tolerances:
        let tol = match art.manifest.outputs[i].kind {
            bnn_edge::runtime::IoKind::Opt => 5e-2,
            _ => 5e-3,
        };
        assert!(d <= tol, "output {i} ('{}') diff {d}", art.manifest.outputs[i].name);
    }
}
