//! Steady-state training steps are **allocation-free** and microbatch
//! accumulation really caps the step's peak memory — the measured
//! twins of the step-arena work (`naive::arena`) and of
//! `memmodel::step_envelope`.
//!
//! This integration binary installs the tracking allocator (the lib
//! test harness cannot) and asserts, with `memtrack::alloc_count`:
//!
//! 1. after one warmup step, subsequent training steps perform *zero*
//!    heap allocations — both engines, multiple zoo models, the tiled
//!    backend at 1 and 2 threads (the ISSUE acceptance bar) — and the
//!    kernel autotuner keeps it that way: one Auto-mode step pays the
//!    per-shape registry inserts, replay steps allocate nothing;
//! 2. after the same warmup (plus one eval to pool its d-buffer),
//!    `eval` calls — alone or interleaved with training — are also
//!    allocation-free (the forward-only scratch path, ISSUE-6);
//! 3. `--microbatch B/4` drops the measured peak step memory ≥2× on
//!    binarynet_mini at B=64, with `memmodel::step_envelope` — a pure
//!    fold over the compiled schedule — *equal* to the measured
//!    steady footprint, byte for byte;
//! 4. microbatched gradients equal the mean of independent per-chunk
//!    gradients (the accumulation-correctness invariant, asserted at
//!    1e-5 on both engines).
//!
//! Single `#[test]`: peak tracking is process-global, so keeping one
//! test in this binary avoids cross-test allocation noise.

use bnn_edge::memmodel::{step_envelope, Optimizer};
use bnn_edge::memtrack::{self, TrackingAlloc};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine_micro, Accel, StepEngine};
use bnn_edge::util::rng::Pcg32;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn toy(batch: usize, elems: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
    let mut g = Pcg32::new(seed);
    let x = g.normal_vec(batch * elems);
    let y = (0..batch).map(|i| i % classes).collect();
    (x, y)
}

#[test]
fn steady_state_steps_allocate_nothing_and_microbatch_caps_peak() {
    assert!(memtrack::is_active(), "tracking allocator not installed");

    // ---- 1. zero steady-state allocations (acceptance: ≥2 zoo
    // models × both engines × tiled backend, threads 1 and 2)
    for model in ["cnv_mini", "binarynet_mini"] {
        let graph = lower(&get(model).unwrap()).unwrap();
        let (x, y) = toy(8, graph.input_elems, graph.classes, 1);
        for algo in ["standard", "proposed"] {
            for threads in [1usize, 2] {
                let mut e = build_engine_micro(
                    algo,
                    &graph,
                    8,
                    0,
                    "adam",
                    Accel::Tiled(threads),
                    3,
                )
                .unwrap();
                // warmup: populates the arena pool, spawns the worker
                // pool, fills the packed-weight cache storage
                e.train_step(&x, &y, 0.01).unwrap();
                e.train_step(&x, &y, 0.01).unwrap();
                let before = memtrack::alloc_count();
                for _ in 0..3 {
                    e.train_step(&x, &y, 0.01).unwrap();
                }
                let allocs = memtrack::alloc_count() - before;
                assert_eq!(
                    allocs, 0,
                    "{model}/{algo}/t{threads}: steady-state steps performed {allocs} \
                     heap allocations (want zero)"
                );
            }
        }
    }

    // ---- 1b. evaluation is allocation-free too (ISSUE-6 satellite):
    // eval shares the step arena's forward-only scratch path.  One
    // eval warmup is required on top of the train warmup — eval takes
    // a d = batch×classes gradient buffer the training step's
    // microbatch-sized takes don't necessarily pre-pool — after which
    // interleaved eval/train steady state performs zero allocations.
    {
        let graph = lower(&get("cnv_mini").unwrap()).unwrap();
        let (x, y) = toy(8, graph.input_elems, graph.classes, 9);
        for algo in ["standard", "proposed"] {
            let mut e =
                build_engine_micro(algo, &graph, 8, 0, "adam", Accel::Tiled(2), 3).unwrap();
            e.train_step(&x, &y, 0.01).unwrap();
            e.train_step(&x, &y, 0.01).unwrap();
            e.eval(&x, &y).unwrap();
            let before = memtrack::alloc_count();
            for _ in 0..3 {
                e.eval(&x, &y).unwrap();
            }
            e.train_step(&x, &y, 0.01).unwrap();
            e.eval(&x, &y).unwrap();
            let allocs = memtrack::alloc_count() - before;
            assert_eq!(
                allocs, 0,
                "{algo}: steady-state eval performed {allocs} heap allocations (want zero)"
            );
        }
    }

    // ---- 1c. the autotuner preserves the zero-alloc steady state:
    // the first step under tune::Mode::Auto microbenches each GEMM
    // shape class on the arena's own buffers and pays one registry
    // insert per class — the only allocations tuning ever makes —
    // after which every step replays the cached winners through an
    // atomic load + read-locked hash lookup (run_rows_chunk drives
    // tuned row-bands from stack context, no heap traffic)
    {
        use bnn_edge::bitops::tune;
        let graph = lower(&get("cnv_mini").unwrap()).unwrap();
        let (x, y) = toy(8, graph.input_elems, graph.classes, 21);
        for algo in ["standard", "proposed"] {
            let mut e =
                build_engine_micro(algo, &graph, 8, 0, "adam", Accel::Tiled(2), 3).unwrap();
            e.train_step(&x, &y, 0.01).unwrap();
            e.train_step(&x, &y, 0.01).unwrap();
            tune::set_mode(tune::Mode::Auto);
            // the tuning step (benches candidates, inserts winners)
            e.train_step(&x, &y, 0.01).unwrap();
            assert!(tune::len() > 0, "{algo}: auto step tuned no GEMM shape classes");
            let before = memtrack::alloc_count();
            for _ in 0..3 {
                e.train_step(&x, &y, 0.01).unwrap();
            }
            let allocs = memtrack::alloc_count() - before;
            tune::set_mode(tune::Mode::Fixed);
            assert_eq!(
                allocs, 0,
                "{algo}: tuned steady-state steps performed {allocs} heap \
                 allocations (want zero)"
            );
        }
    }

    // microbatched steady state is allocation-free too
    {
        let graph = lower(&get("binarynet_mini").unwrap()).unwrap();
        let (x, y) = toy(16, graph.input_elems, graph.classes, 2);
        for algo in ["standard", "proposed"] {
            let mut e =
                build_engine_micro(algo, &graph, 16, 4, "adam", Accel::Tiled(2), 3).unwrap();
            e.train_step(&x, &y, 0.01).unwrap();
            e.train_step(&x, &y, 0.01).unwrap();
            let before = memtrack::alloc_count();
            e.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(
                memtrack::alloc_count() - before,
                0,
                "{algo}: microbatched steady step allocated"
            );
        }
    }

    // ---- 2. microbatch B/4 drops the measured steady footprint ≥2×
    // on binarynet_mini at B=64, and step_envelope — a pure fold over
    // the compiled schedule since the schedule-compiler work — equals
    // the measured steady state *exactly* (the old ±25% band is gone)
    {
        let graph = lower(&get("binarynet_mini").unwrap()).unwrap();
        let (x, y) = toy(64, graph.input_elems, graph.classes, 3);
        for algo in ["standard", "proposed"] {
            let measure = |micro: usize| -> (usize, f64) {
                let mut e =
                    build_engine_micro(algo, &graph, 64, micro, "adam", Accel::Tiled(1), 3)
                        .unwrap();
                e.train_step(&x, &y, 0.01).unwrap();
                e.train_step(&x, &y, 0.01).unwrap();
                let steady = e.state_bytes() + e.arena_bytes();
                let env =
                    step_envelope(&graph, algo, Optimizer::Adam, 64, micro).unwrap();
                (steady, env.total_bytes())
            };
            let (full, full_env) = measure(0);
            let (quarter, quarter_env) = measure(16);
            let drop = full as f64 / quarter as f64;
            assert!(
                drop >= 2.0,
                "{algo}: microbatch 16/64 dropped the measured steady footprint only \
                 {drop:.2}x ({full} -> {quarter})"
            );
            for (tag, measured, planned) in
                [("full", full, full_env), ("micro", quarter, quarter_env)]
            {
                assert_eq!(
                    planned as usize, measured,
                    "{algo}/{tag}: envelope must equal the measured steady state \
                     exactly (planned {planned:.0} vs measured {measured})"
                );
            }
        }
    }

    // ---- 3. accumulated gradients = mean of independent chunk
    // gradients (plain SGD first-step delta is -lr·grad)
    {
        let graph = lower(&get("cnv_mini").unwrap()).unwrap();
        let (batch, micro) = (8usize, 2usize);
        let chunks = batch / micro;
        let (x, y) = toy(batch, graph.input_elems, graph.classes, 4);
        let lr = 0.01f32; // below any ±1 clip crossing (see engine_parity sweep)
        for algo in ["standard", "proposed"] {
            let mut m =
                build_engine_micro(algo, &graph, batch, micro, "sgd", Accel::Tiled(1), 11)
                    .unwrap();
            let w0 = m.weights_snapshot();
            let mut want: Vec<Vec<f32>> = w0.iter().map(|v| vec![0.0; v.len()]).collect();
            for ci in 0..chunks {
                let mut r =
                    build_engine_micro(algo, &graph, micro, 0, "sgd", Accel::Tiled(1), 11)
                        .unwrap();
                r.load_weights(&w0).unwrap();
                r.train_step(
                    &x[ci * micro * graph.input_elems..(ci + 1) * micro * graph.input_elems],
                    &y[ci * micro..(ci + 1) * micro],
                    lr,
                )
                .unwrap();
                for (acc, (after, before)) in
                    want.iter_mut().zip(r.weights_snapshot().iter().zip(&w0))
                {
                    for (a, (u, v)) in acc.iter_mut().zip(after.iter().zip(before)) {
                        *a += (u - v) / chunks as f32;
                    }
                }
            }
            m.train_step(&x, &y, lr).unwrap();
            let after = m.weights_snapshot();
            if algo == "standard" {
                // linear in the gradient: deltas match the chunk mean
                for (li, (aft, (bef, wnt))) in
                    after.iter().zip(w0.iter().zip(&want)).enumerate()
                {
                    for i in 0..aft.len() {
                        let got = aft[i] - bef[i];
                        assert!(
                            (got - wnt[i]).abs() <= 1e-5 + 1e-5 * wnt[i].abs(),
                            "standard layer {li} @ {i}: {got} vs {}",
                            wnt[i]
                        );
                    }
                }
            } else {
                // the proposed engine binarizes the *accumulated* ∂W;
                // per-chunk reference steps binarize per-chunk signs,
                // so weight deltas agree only through the sign
                // structure — but β (linear path, no binarization)
                // must match up to its f16 storage quantum (2⁻¹¹
                // relative per rounding, both sides round once)
                for (li, (aft, (bef, wnt))) in
                    after.iter().zip(w0.iter().zip(&want)).enumerate()
                {
                    if li % 2 == 0 {
                        continue; // weight slots: sign-quantized
                    }
                    for i in 0..aft.len() {
                        let got = aft[i] - bef[i];
                        assert!(
                            (got - wnt[i]).abs() <= 1e-4 + 2e-3 * wnt[i].abs(),
                            "proposed β layer {li} @ {i}: {got} vs {}",
                            wnt[i]
                        );
                    }
                }
            }
        }
    }
}
