//! Serving parity: the forward-only `PackedInferEngine` reproduces the
//! training engines' `eval` **bit-exactly** on the same Accel tier.
//!
//! Both sides share every kernel (pack/im2col/XNOR-GEMM/BN) and the
//! snapshot stores exact f32 weight images, so equality is `==` on
//! (loss, acc) — not a tolerance.  The sweep covers all zoo models ×
//! all tiers; tiers must match across the comparison because the Naive
//! f32 GEMM accumulates in a different order than Blocked/Tiled.
//!
//! Also pins the publish contract: a snapshot published mid-flight is
//! installed only at a batch boundary, so every response is computed
//! against exactly one snapshot — old or new, never a mix.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use bnn_edge::models::{get, lower, names};
use bnn_edge::naive::{build_engine, Accel, Plan, StepEngine};
use bnn_edge::serve::{BatchServer, InferAlgo, PackedInferEngine, WeightSnapshot};
use bnn_edge::util::rng::Pcg32;

fn infer_algo(s: &str) -> InferAlgo {
    InferAlgo::parse(s).unwrap()
}

/// Build a trainer, snapshot its weights, and return (trainer-eval,
/// serve-eval) results on the same batch + tier.  Bit-equal or bust.
fn check(model: &str, algo: &str, accel: Accel, batch: usize) {
    let graph = lower(&get(model).unwrap()).unwrap();
    let plan = Plan::from_graph(&graph).unwrap();
    let mut trainer = build_engine(algo, &graph, batch, "adam", accel, 29).unwrap();
    let snap =
        Arc::new(WeightSnapshot::pack(&plan, &trainer.weights_snapshot(), 0).unwrap());
    let mut serve =
        PackedInferEngine::new(&graph, infer_algo(algo), accel, batch, snap).unwrap();

    let mut rng = Pcg32::new(1000 + batch as u64);
    let x = rng.normal_vec(batch * graph.input_elems);
    let y: Vec<usize> = (0..batch).map(|i| i % graph.classes).collect();

    let want = trainer.eval(&x, &y).unwrap();
    let got = serve.eval(&x, &y).unwrap();
    assert_eq!(got, want, "{model}/{algo}/{accel:?} b={batch}: serve vs trainer eval");
}

#[test]
fn serve_eval_is_bit_exact_with_trainer_eval_across_the_zoo() {
    for (mi, model) in names().iter().enumerate() {
        let model = *model;
        let small = model.ends_with("_mini") || model == "mlp";
        for accel in [Accel::Naive, Accel::Blocked, Accel::Tiled(2)] {
            // wall-clock control, same policy as engine_parity.rs: the
            // scalar Naive tier runs full-scale models on alternating
            // engines, and caps the mini batch sweep at 7 (batch 64
            // there is pure repetition of the same scalar kernels)
            let batches: &[usize] = if !small {
                &[1]
            } else if accel == Accel::Naive {
                &[1, 7]
            } else {
                &[1, 7, 64]
            };
            let algos: &[&str] = if small || accel != Accel::Naive {
                &["standard", "proposed"]
            } else if mi % 2 == 0 {
                &["standard"]
            } else {
                &["proposed"]
            };
            for algo in algos {
                for &b in batches {
                    check(model, algo, accel, b);
                }
            }
        }
    }
}

#[test]
fn batch64_naive_tier_still_matches_on_a_dense_model() {
    // keep one large-batch probe on the scalar tier: the dense mini is
    // cheap enough and covers Naive's distinct f32 accumulation order
    // at a batch size where the blocked tiers would diverge if the
    // serve path ever mixed tiers
    check("mlp_mini", "standard", Accel::Naive, 64);
    check("mlp_mini", "proposed", Accel::Naive, 64);
}

#[test]
fn publish_mid_flight_is_never_mixed() {
    // max_batch = 1 makes every response a batch-1 forward, so each
    // must bit-match one of the two snapshots' reference logits —
    // proving a published snapshot never splices into an in-flight
    // request.  Clients hammer a fixed input while a publisher swaps
    // the weights midway through.
    let graph = lower(&get("cnv_mini").unwrap()).unwrap();
    let plan = Plan::from_graph(&graph).unwrap();
    let snap_for = |seed: u64, version: u64| {
        let t = build_engine("proposed", &graph, 1, "adam", Accel::Tiled(2), seed).unwrap();
        Arc::new(WeightSnapshot::pack(&plan, &t.weights_snapshot(), version).unwrap())
    };
    let snap0 = snap_for(4, 0);
    let snap1 = snap_for(77, 1);
    let mk = |snap: &Arc<WeightSnapshot>| {
        PackedInferEngine::new(&graph, InferAlgo::Proposed, Accel::Tiled(2), 1, Arc::clone(snap))
            .unwrap()
    };

    let mut rng = Pcg32::new(9);
    let x = Arc::new(rng.normal_vec(graph.input_elems));
    let cl = graph.classes;
    let mut want0 = vec![0.0f32; cl];
    mk(&snap0).infer_into(&x[..], 1, &mut want0).unwrap();
    let mut want1 = vec![0.0f32; cl];
    mk(&snap1).infer_into(&x[..], 1, &mut want1).unwrap();
    assert_ne!(want0, want1);

    let (batcher, server) = BatchServer::new(mk(&snap0), 100, 8).unwrap();
    let server = std::thread::spawn(move || server.run());

    let published = Arc::new(AtomicBool::new(false));
    let mut clients = Vec::new();
    for _ in 0..3 {
        let b = batcher.clone();
        let x = Arc::clone(&x);
        let (w0, w1) = (want0.clone(), want1.clone());
        let published = Arc::clone(&published);
        let snap1 = Arc::clone(&snap1);
        clients.push(std::thread::spawn(move || {
            let mut out = vec![0.0f32; w0.len()];
            let mut saw_new = false;
            for i in 0..40 {
                b.infer_one(&x[..], &mut out).unwrap();
                if out == w1 {
                    saw_new = true;
                } else {
                    assert_eq!(out, w0, "request {i}: response matches neither snapshot");
                    assert!(
                        !saw_new,
                        "request {i}: old weights served after new ones (install went back)"
                    );
                }
                if i == 10 && !published.swap(true, Ordering::Relaxed) {
                    b.publish(Arc::clone(&snap1));
                }
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    batcher.shutdown();
    let engine = server.join().unwrap().unwrap();
    assert_eq!(engine.snapshot().version(), 1, "publish never landed");
}
