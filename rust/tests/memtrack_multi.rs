//! The multi-tenant runtime is **allocation-free** in steady state —
//! the ISSUE-9 zero-allocation bar, measured with the tracking
//! allocator (its own binary: `memtrack::alloc_count` is
//! process-global, so each binary keeps its asserts in one `#[test]`).
//!
//! A 2-lane fleet (one TrainServe tenant, one Serve tenant) takes
//! concurrent train + infer traffic from pre-spawned client threads.
//! After a warm phase (arena pools filled, packed-weight caches
//! populated, queue/condvar paths exercised) a barrier-fenced
//! measured window of mixed quanta must perform **zero** heap
//! allocations across the whole process — clients, lanes, and both
//! tenants' engines.  Auto-publish is the one deliberate allocator
//! (it packs a fresh snapshot), so the measured fleet runs
//! `publish_every = 0`.

use std::sync::{Arc, Barrier};

use bnn_edge::memtrack::{self, TrackingAlloc};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::Accel;
use bnn_edge::serve::{MultiModelServer, TenantRole, TenantSpec};
use bnn_edge::util::rng::Pcg32;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

const WARM: usize = 6;
const MEASURED: usize = 12;

#[test]
fn steady_state_fleet_allocates_nothing() {
    assert!(memtrack::is_active(), "tracking allocator not installed");

    let mut ts = TenantSpec::new("ts", "mlp_mini", TenantRole::TrainServe);
    ts.accel = Accel::Tiled(2);
    ts.batch = 8;
    ts.max_batch = 4;
    ts.publish_every = 0; // auto-publish packs a snapshot: excluded
    let mut sv = TenantSpec::new("sv", "cnv_mini", TenantRole::Serve);
    sv.accel = Accel::Tiled(2);
    sv.max_batch = 4;
    sv.seed = 43;

    let (client, server) = MultiModelServer::new(vec![ts, sv], 2).unwrap();
    let h = std::thread::spawn(move || server.run());

    // fence the measured window: [0] warm done → main snapshots,
    // [1] window opens, [2] window closed → main snapshots again
    let gates: Vec<Arc<Barrier>> = (0..3).map(|_| Arc::new(Barrier::new(4))).collect();

    let mut drivers = Vec::new();
    // infer clients, one per tenant — inputs pre-generated
    for tid in 0..2usize {
        let c = client.clone();
        let g = gates.clone();
        drivers.push(std::thread::spawn(move || {
            let model = ["mlp_mini", "cnv_mini"][tid];
            let graph = lower(&get(model).unwrap()).unwrap();
            let mut rng = Pcg32::new(60 + tid as u64);
            let x = rng.normal_vec(graph.input_elems);
            let mut out = vec![0.0f32; graph.classes];
            for _ in 0..WARM {
                c.infer_one(tid, &x, &mut out).unwrap();
            }
            g[0].wait();
            g[1].wait();
            for _ in 0..MEASURED {
                c.infer_one(tid, &x, &mut out).unwrap();
            }
            g[2].wait();
        }));
    }
    // training feeder for tenant 0 — batches pre-generated
    {
        let c = client.clone();
        let g = gates.clone();
        drivers.push(std::thread::spawn(move || {
            let graph = lower(&get("mlp_mini").unwrap()).unwrap();
            let mut rng = Pcg32::new(66);
            let x = rng.normal_vec(graph.input_elems * 8);
            let y: Vec<usize> = (0..8).map(|i| i % graph.classes).collect();
            // ≥2 warm steps: optimizer state + packed caches filled
            for _ in 0..3 {
                c.train_step(0, &x, &y, 0.01).unwrap();
            }
            g[0].wait();
            g[1].wait();
            for _ in 0..3 {
                c.train_step(0, &x, &y, 0.01).unwrap();
            }
            g[2].wait();
        }));
    }

    gates[0].wait();
    let before = memtrack::alloc_count();
    gates[1].wait();
    gates[2].wait();
    let allocs = memtrack::alloc_count() - before;

    for d in drivers {
        d.join().unwrap();
    }
    client.shutdown();
    let tenants = h.join().unwrap().unwrap();
    assert_eq!(
        allocs, 0,
        "steady-state fleet performed {allocs} heap allocations (want zero)"
    );
    assert!(tenants.iter().all(|t| t.is_idle()));
    assert_eq!(tenants[0].steps(), 6);
}
