//! Coordinator integration over the HLO engine: full runs, cross-
//! engine weight transfer, dev-based LR behaviour, and the memory
//! envelope on real configurations.

use bnn_edge::coordinator::{EngineKind, MemoryEnvelope, RunConfig, Runner};

fn base(engine: EngineKind) -> RunConfig {
    RunConfig {
        engine,
        n_train: 640,
        n_test: 128,
        epochs: 8,
        eval_every_steps: 10,
        batch: 64,
        lr: 0.003,
        artifacts_dir: std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts"),
        ..Default::default()
    }
}

/// HLO tests need `make artifacts` (and a PJRT-enabled build); skip
/// cleanly when the artifact set is absent instead of failing.
fn artifacts_present() -> bool {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.is_dir() {
        return true;
    }
    eprintln!("skipping HLO test: {} missing (run `make artifacts`)", dir.display());
    false
}

#[test]
fn hlo_run_proposed_learns() {
    if !artifacts_present() {
        return;
    }
    let mut r = Runner::new(base(EngineKind::Hlo)).unwrap();
    let res = r.run().unwrap();
    assert!(res.best_test_acc > 0.22, "acc {}", res.best_test_acc);
    assert!(res.metrics.steps_monotone());
    let first = res.metrics.points.first().unwrap().train_loss;
    assert!(res.final_train_loss < first);
}

#[test]
fn hlo_run_standard_learns() {
    if !artifacts_present() {
        return;
    }
    let mut cfg = base(EngineKind::Hlo);
    cfg.algo = "standard".into();
    let mut r = Runner::new(cfg).unwrap();
    let res = r.run().unwrap();
    assert!(res.best_test_acc > 0.25, "acc {}", res.best_test_acc);
}

#[test]
fn metrics_jsonl_written() {
    // engine-agnostic behaviour: run on the pure-Rust engine so the
    // test works without artifacts
    let path = std::env::temp_dir().join("bnn_edge_test_metrics.jsonl");
    let mut cfg = base(EngineKind::Blocked);
    cfg.epochs = 1;
    cfg.metrics_path = Some(path.clone());
    Runner::new(cfg).unwrap().run().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() >= 10);
    for line in text.lines() {
        bnn_edge::util::json::Json::parse(line).unwrap();
    }
    let _ = std::fs::remove_file(path);
}

#[test]
fn seeds_change_results_deterministically() {
    let run = |seed: u64| {
        let mut cfg = base(EngineKind::Blocked);
        cfg.epochs = 1;
        cfg.seed = seed;
        Runner::new(cfg).unwrap().run().unwrap().final_train_loss
    };
    let a1 = run(1);
    let a2 = run(1);
    let b = run(2);
    assert_eq!(a1, a2, "same seed must reproduce bit-identically");
    assert_ne!(a1, b, "different seeds must differ");
}

#[test]
fn envelope_rejects_oversized_hlo_run() {
    let mut cfg = base(EngineKind::Hlo);
    cfg.envelope = Some(MemoryEnvelope::mib(0.01));
    assert!(Runner::new(cfg).is_err());
}

#[test]
fn weights_transfer_naive_to_hlo_eval() {
    // train with the pure-Rust engine, evaluate through the HLO eval
    // artifact: snapshots are engine-portable (same [w, beta] layout)
    use bnn_edge::coordinator::HloEngine;
    use bnn_edge::models::{get, lower};
    use bnn_edge::naive::{build_engine, Accel, StepEngine};
    use bnn_edge::runtime::Engine;

    if !artifacts_present() {
        return;
    }
    let graph = lower(&get("mlp_mini").unwrap()).unwrap();
    let ds = bnn_edge::data::build("syn-mnist64", 256, 64, 3).unwrap();
    let mut naive = build_engine("proposed", &graph, 64, "adam", Accel::Blocked, 3).unwrap();
    for step in 0..12 {
        let lo = (step * 64) % 192;
        let x = &ds.train_x[lo * 64..(lo + 64) * 64];
        let y = &ds.train_y[lo..lo + 64];
        naive.train_step(x, y, 0.003).unwrap();
    }
    let eng = Engine::cpu(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .unwrap();
    let mut hlo = HloEngine::new(
        &eng,
        "mlp_mini_proposed_adam_b64",
        Some("mlp_mini_proposed_b64_eval"),
        0,
    )
    .unwrap();
    hlo.load_weights(&naive.weights_snapshot()).unwrap();
    let (l_naive, a_naive) = naive.eval(&ds.test_x, &ds.test_y).unwrap();
    let (l_hlo, a_hlo) = hlo.eval(&ds.test_x, &ds.test_y).unwrap();
    // same weights, same eval batch: same numbers (f16 storage in the
    // naive engine vs f32 interchange costs a little slack)
    assert!(
        (l_naive - l_hlo).abs() < 0.05 * l_naive.max(l_hlo),
        "loss {l_naive} vs {l_hlo}"
    );
    assert!((a_naive - a_hlo).abs() <= 0.08, "acc {a_naive} vs {a_hlo}");
}
