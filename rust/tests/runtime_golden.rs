//! Integration: the Rust PJRT runtime reproduces the Python/JAX golden
//! step outputs — the L2 <-> L3 numerical contract.
//!
//! Requires `make artifacts` (the `core` set suffices).

use bnn_edge::runtime::{Engine, IoKind, Tensor};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// All of these need `make artifacts`; skip cleanly when absent.
fn artifacts_present() -> bool {
    if artifacts_dir().is_dir() {
        return true;
    }
    eprintln!(
        "skipping golden test: {} missing (run `make artifacts`)",
        artifacts_dir().display()
    );
    false
}

fn engine() -> Engine {
    Engine::cpu(artifacts_dir()).expect("artifacts missing — run `make artifacts`")
}

fn check_golden(name: &str, tol: f32) {
    if !artifacts_present() {
        return;
    }
    let eng = engine();
    let art = eng.load(name).unwrap();
    let golden = eng.golden(name).unwrap();
    let outs = art.run(&golden.inputs).unwrap();
    assert_eq!(outs.len(), golden.outputs.len());
    for (i, (got, want)) in outs.iter().zip(&golden.outputs).enumerate() {
        let d = got.max_abs_diff(want);
        assert!(
            d <= tol,
            "{name}: output {i} ('{}') max|diff| = {d} > {tol}",
            art.manifest.outputs[i].name
        );
    }
}

#[test]
fn golden_mlp_mini_standard() {
    check_golden("mlp_mini_standard_adam_b64", 1e-5);
}

#[test]
fn golden_mlp_mini_proposed() {
    check_golden("mlp_mini_proposed_adam_b64", 1e-5);
}

#[test]
fn golden_mlp_mini_proposed_pallas() {
    // the Pallas-kernel variant must agree with python too
    check_golden("mlp_mini_proposed_adam_b64_pallas", 1e-5);
}

#[test]
fn pallas_and_ref_variants_agree() {
    if !artifacts_present() {
        return;
    }
    // Same step, kernels vs pure-jnp ops: identical math, so outputs
    // must agree tightly when fed the *same* golden inputs.
    let eng = engine();
    let a = eng.load("mlp_mini_proposed_adam_b64").unwrap();
    let golden = eng.golden("mlp_mini_proposed_adam_b64").unwrap();
    let b = eng.load("mlp_mini_proposed_adam_b64_pallas").unwrap();
    let oa = a.run(&golden.inputs).unwrap();
    let ob = b.run(&golden.inputs).unwrap();
    for (i, (x, y)) in oa.iter().zip(&ob).enumerate() {
        let d = x.max_abs_diff(y);
        assert!(d <= 1e-4, "output {i} differs by {d}");
    }
}

#[test]
fn train_step_improves_loss_over_iterations() {
    if !artifacts_present() {
        return;
    }
    // Drive the artifact as the coordinator will: feed outputs back as
    // inputs for several steps; loss must trend down on a fixed batch.
    let eng = engine();
    let art = eng.load("mlp_mini_proposed_adam_b64").unwrap();
    let golden = eng.golden("mlp_mini_proposed_adam_b64").unwrap();
    let m = &art.manifest;
    let n_state = m.input_indices(IoKind::Param).len()
        + m.input_indices(IoKind::Opt).len();

    let mut inputs = golden.inputs.clone();
    let loss_idx = m.output_index("loss").unwrap();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..20 {
        let outs = art.run(&inputs).unwrap();
        last = outs[loss_idx].item().unwrap();
        first.get_or_insert(last);
        // feed params + opt state back; x, y, lr stay fixed
        for (i, t) in outs.into_iter().take(n_state).enumerate() {
            inputs[i] = t;
        }
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.8,
        "loss did not improve: first {first}, last {last}"
    );
}

#[test]
fn manifest_shapes_roundtrip() {
    if !artifacts_present() {
        return;
    }
    let eng = engine();
    let art = eng.load("mlp_mini_standard_adam_b64").unwrap();
    let m = &art.manifest;
    assert_eq!(m.kind, "train");
    assert_eq!(m.batch, 64);
    // wrong-shaped input must be rejected before reaching PJRT
    let mut bad: Vec<Tensor> =
        m.inputs.iter().map(|s| Tensor::zeros(&s.shape)).collect();
    bad[0] = Tensor::zeros(&[1, 1]);
    assert!(art.run(&bad).is_err());
}

#[test]
fn eval_artifact_runs() {
    if !artifacts_present() {
        return;
    }
    let eng = engine();
    let art = eng.load("mlp_mini_proposed_b64_eval").unwrap();
    let inputs: Vec<Tensor> = art
        .manifest
        .inputs
        .iter()
        .map(|s| Tensor::zeros(&s.shape))
        .collect();
    let outs = art.run(&inputs).unwrap();
    assert_eq!(outs.len(), 2); // loss, acc
    assert!(outs[1].item().unwrap() >= 0.0);
}
