//! The serving path is **allocation-free** in steady state — the
//! ISSUE-6 acceptance bar, measured with the tracking allocator (a
//! separate binary from memtrack_step.rs: `memtrack::alloc_count` is
//! process-global, so each binary keeps its asserts in one `#[test]`
//! to avoid cross-test counter noise).
//!
//! 1. after `PackedInferEngine::warmup` (descending batch sizes — the
//!    arena's buffer classes are monotone in batch, so warming the
//!    largest pre-pools every smaller one) plus one `eval` per batch
//!    size (eval takes a d-buffer `infer_into` never does), mixed-size
//!    `infer_into` + `eval` traffic performs **zero** heap
//!    allocations — both algorithms, conv + dense models, tiled
//!    backend;
//! 2. the full dynamic-batching loop — client enqueue, server gather,
//!    packed forward, scatter, wake — is also allocation-free once a
//!    few requests have flowed.

use bnn_edge::memmodel::serve_envelope;
use bnn_edge::memtrack::{self, TrackingAlloc};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine, Accel, Plan, StepEngine};
use bnn_edge::serve::{BatchServer, InferAlgo, PackedInferEngine, WeightSnapshot};
use bnn_edge::util::rng::Pcg32;
use std::sync::Arc;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn engine_for(model: &str, algo: &str, max_batch: usize) -> PackedInferEngine {
    let graph = lower(&get(model).unwrap()).unwrap();
    let plan = Plan::from_graph(&graph).unwrap();
    let trainer = build_engine(algo, &graph, 2, "adam", Accel::Tiled(2), 21).unwrap();
    let snap =
        Arc::new(WeightSnapshot::pack(&plan, &trainer.weights_snapshot(), 0).unwrap());
    PackedInferEngine::new(
        &graph,
        InferAlgo::parse(algo).unwrap(),
        Accel::Tiled(2),
        max_batch,
        snap,
    )
    .unwrap()
}

#[test]
fn steady_state_serving_allocates_nothing() {
    assert!(memtrack::is_active(), "tracking allocator not installed");

    // ---- 1. warmed engine: mixed-size infer + eval traffic
    let sizes = [1usize, 3, 6];
    let max_batch = 6;
    for model in ["cnv_mini", "mlp_mini"] {
        let graph = lower(&get(model).unwrap()).unwrap();
        for algo in ["standard", "proposed"] {
            let mut e = engine_for(model, algo, max_batch);
            e.warmup().unwrap();

            // pre-build every input/output outside the measured window
            let mut rng = Pcg32::new(31);
            let xs: Vec<Vec<f32>> =
                sizes.iter().map(|&b| rng.normal_vec(b * graph.input_elems)).collect();
            let ys: Vec<Vec<usize>> = sizes
                .iter()
                .map(|&b| (0..b).map(|i| i % graph.classes).collect())
                .collect();
            let mut logits = vec![0.0f32; max_batch * graph.classes];

            // eval warmup: its d-buffer class isn't taken by infer_into
            for (x, y) in xs.iter().zip(&ys) {
                e.eval(x, y).unwrap();
            }

            let before = memtrack::alloc_count();
            for round in 0..3 {
                for (i, &b) in sizes.iter().enumerate() {
                    e.infer_into(&xs[i], b, &mut logits[..b * graph.classes]).unwrap();
                    let (loss, _) = e.eval(&xs[i], &ys[i]).unwrap();
                    assert!(loss.is_finite(), "{model}/{algo} round {round}");
                }
            }
            let allocs = memtrack::alloc_count() - before;
            assert_eq!(
                allocs, 0,
                "{model}/{algo}: steady-state serving performed {allocs} heap \
                 allocations (want zero)"
            );

            // the serve envelope is a pure fold over the compiled
            // serve schedule — exact, not banded
            let env = serve_envelope(&graph, algo, max_batch).unwrap();
            assert_eq!(
                env.arena_bytes,
                e.arena_bytes(),
                "{model}/{algo}: serve_envelope arena must equal the engine's \
                 installed slot table exactly"
            );
        }
    }

    // ---- 2. the dynamic-batching loop end to end
    {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let engine = engine_for("mlp_mini", "proposed", 4);
        let (batcher, server) = BatchServer::new(engine, 50, 16).unwrap();
        let h = std::thread::spawn(move || server.run());

        let mut rng = Pcg32::new(41);
        let x = rng.normal_vec(graph.input_elems);
        let mut out = vec![0.0f32; graph.classes];
        // warm the request path (lazy lock/condvar init, first wakeups)
        for _ in 0..6 {
            batcher.infer_one(&x, &mut out).unwrap();
        }
        let before = memtrack::alloc_count();
        for _ in 0..12 {
            batcher.infer_one(&x, &mut out).unwrap();
        }
        let allocs = memtrack::alloc_count() - before;
        assert_eq!(
            allocs, 0,
            "dynamic batching request path performed {allocs} heap allocations \
             (want zero)"
        );
        batcher.shutdown();
        h.join().unwrap().unwrap();
    }
}
