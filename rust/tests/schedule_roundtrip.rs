//! The schedule compiler's contracts, end to end:
//!
//! 1. **JSON round-trip is lossless** — `to_json` → text → parse →
//!    `from_json` reproduces the compiled `StepSchedule` exactly
//!    (structural equality, every event/slot/op preserved);
//! 2. **a deserialized schedule executes bit-identically** — install
//!    it into a fresh trainer and every train/eval result and weight
//!    bit matches the trainer running its own compiled schedule;
//! 3. **coloring never overlaps live ranges** — replaying each pass's
//!    event stream (repeats + tail) slot by slot, no `Take` ever hits
//!    an occupied slot, no take exceeds its slot's capacity, and
//!    every pass returns all slots (the zero-alloc steady state
//!    depends on this) — swept across the whole zoo × microbatch ×
//!    accelerator tiers × serve batch;
//! 4. **coloring never loses to the old best-fit pool**, and strictly
//!    beats it on at least two zoo models (the CI regression gate's
//!    in-tree twin);
//! 5. **the binarynet_mini dump is golden** — pinned at
//!    `tests/golden/schedule_binarynet_mini.json`, byte-compared
//!    (deterministic: BTreeMap keys, no floats in event streams).
//!    Bless with `UPDATE_GOLDEN=1 cargo test`.

use std::sync::Arc;

use bnn_edge::models::{get, lower, names};
use bnn_edge::naive::schedule::{
    compile_serve, compile_step, BufEvent, PoolKind, StepSchedule, POOLS,
};
use bnn_edge::naive::{Accel, Plan, ProposedTrainer, StandardTrainer, StepEngine};
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;

fn plan_for(model: &str) -> Plan {
    Plan::from_graph(&lower(&get(model).unwrap()).unwrap()).unwrap()
}

fn round_trip(s: &StepSchedule) -> StepSchedule {
    let text = s.to_json().to_string_pretty();
    StepSchedule::from_json(&Json::parse(&text).unwrap()).unwrap()
}

#[test]
fn json_round_trip_is_lossless() {
    for model in ["binarynet_mini", "bireal_mini", "mlp_mini"] {
        let plan = plan_for(model);
        for algo in ["standard", "proposed"] {
            for naive in [false, true] {
                let s = compile_step(&plan, algo, naive, 4, 2).unwrap();
                assert_eq!(s, round_trip(&s), "{model}/{algo}/naive={naive} step");
                let s = compile_serve(&plan, algo, naive, 3).unwrap();
                assert_eq!(s, round_trip(&s), "{model}/{algo}/naive={naive} serve");
            }
        }
    }
}

/// A trainer running a schedule that went through JSON must be
/// bit-identical to one running its own compiled schedule.
macro_rules! check_serialized_execution {
    ($T:ty, $graph:expr, $x:expr, $y:expr) => {{
        let mk = || <$T>::with_microbatch($graph, 8, 2, "adam", Accel::Blocked, 7).unwrap();
        let mut a = mk();
        let mut b = mk();
        b.install_schedule(Arc::new(round_trip(a.schedule())));
        for step in 0..3 {
            let (la, aa) = a.train_step($x, $y, 0.01).unwrap();
            let (lb, ab) = b.train_step($x, $y, 0.01).unwrap();
            assert_eq!(la.to_bits(), lb.to_bits(), "train loss diverged at step {step}");
            assert_eq!(aa.to_bits(), ab.to_bits(), "train acc diverged at step {step}");
        }
        let (la, _) = a.eval($x, $y).unwrap();
        let (lb, _) = b.eval($x, $y).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits(), "eval loss diverged");
        for (wa, wb) in a.weights_snapshot().iter().zip(&b.weights_snapshot()) {
            for (u, v) in wa.iter().zip(wb) {
                assert_eq!(u.to_bits(), v.to_bits(), "weights diverged");
            }
        }
    }};
}

#[test]
fn deserialized_schedule_executes_bit_identically() {
    let graph = lower(&get("cnv_mini").unwrap()).unwrap();
    let mut rng = Pcg32::new(5);
    let x = rng.normal_vec(8 * graph.input_elems);
    let y: Vec<usize> = (0..8).map(|i| i % graph.classes).collect();
    check_serialized_execution!(StandardTrainer, &graph, &x, &y);
    check_serialized_execution!(ProposedTrainer, &graph, &x, &y);
}

/// Replay one pass's stream against the slot table: a `Take` must hit
/// a vacant slot with sufficient capacity, a `Put` an occupied one,
/// and after `repeats` rounds plus the tail every slot is vacant
/// again (so the next pass's identical replay cannot collide — the
/// executor's zero-alloc steady state).
fn replay_pass(s: &StepSchedule, pass: &bnn_edge::naive::schedule::PassEvents) {
    let mut occupied: [Vec<bool>; POOLS] =
        std::array::from_fn(|p| vec![false; s.slots.caps[p].len()]);
    let mut check = |ev: &BufEvent, where_: &str| match *ev {
        BufEvent::Take { pool, slot, len, .. } => {
            let p = pool.idx();
            assert!(
                slot < s.slots.caps[p].len(),
                "{}/{}/{where_}: take {pool:?} slot {slot} out of range",
                s.model,
                pass.name
            );
            assert!(
                !occupied[p][slot],
                "{}/{}/{where_}: overlapping live ranges on {pool:?} slot {slot}",
                s.model,
                pass.name
            );
            assert!(
                len <= s.slots.caps[p][slot],
                "{}/{}/{where_}: take len {len} exceeds {pool:?} slot {slot} cap {}",
                s.model,
                pass.name,
                s.slots.caps[p][slot]
            );
            occupied[p][slot] = true;
        }
        BufEvent::Put { pool, slot } => {
            let p = pool.idx();
            assert!(
                occupied[p][slot],
                "{}/{}/{where_}: put of vacant {pool:?} slot {slot}",
                s.model,
                pass.name
            );
            occupied[p][slot] = false;
        }
    };
    for _ in 0..pass.repeats {
        for ev in &pass.events {
            check(ev, "body");
        }
    }
    for ev in &pass.tail {
        check(ev, "tail");
    }
    for (p, occ) in occupied.iter().enumerate() {
        for (slot, &o) in occ.iter().enumerate() {
            assert!(
                !o,
                "{}/{}: {} slot {slot} still occupied at pass end",
                s.model,
                pass.name,
                PoolKind::ALL[p].name()
            );
        }
    }
}

#[test]
fn coloring_never_overlaps_and_beats_bestfit_across_the_zoo() {
    let mut strictly_better = 0usize;
    for &model in names() {
        let plan = plan_for(model);
        let mut model_improved = false;
        for algo in ["standard", "proposed"] {
            for naive in [false, true] {
                for (micro, chunks) in [(8usize, 1usize), (4, 2)] {
                    let s = compile_step(&plan, algo, naive, micro, chunks).unwrap();
                    for pass in &s.passes {
                        replay_pass(&s, pass);
                    }
                    assert!(
                        s.arena_bytes() <= s.uncolored_bytes,
                        "{model}/{algo}/naive={naive}/m{micro}x{chunks}: colored \
                         {} > uncolored {}",
                        s.arena_bytes(),
                        s.uncolored_bytes
                    );
                    if s.arena_bytes() < s.uncolored_bytes {
                        model_improved = true;
                    }
                }
                let s = compile_serve(&plan, algo, naive, 4).unwrap();
                for pass in &s.passes {
                    replay_pass(&s, pass);
                }
                assert!(
                    s.arena_bytes() <= s.uncolored_bytes,
                    "{model}/{algo}/naive={naive}/serve: colored {} > uncolored {}",
                    s.arena_bytes(),
                    s.uncolored_bytes
                );
            }
        }
        if model_improved {
            strictly_better += 1;
        }
    }
    assert!(
        strictly_better >= 2,
        "coloring strictly beat best-fit on only {strictly_better} zoo models (want ≥2)"
    );
}

#[test]
fn binarynet_mini_schedule_is_golden() {
    let golden_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/schedule_binarynet_mini.json");
    let plan = plan_for("binarynet_mini");
    let mut dump = Json::obj();
    for algo in ["standard", "proposed"] {
        dump.set(algo, compile_step(&plan, algo, false, 4, 2).unwrap().to_json());
    }
    let text = dump.to_string_pretty();
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !golden_path.exists() {
        std::fs::create_dir_all(golden_path.parent().unwrap()).unwrap();
        std::fs::write(&golden_path, &text).unwrap();
        eprintln!("blessed {} — commit it", golden_path.display());
        return;
    }
    let want = std::fs::read_to_string(&golden_path).unwrap();
    assert_eq!(
        text.trim(),
        want.trim(),
        "binarynet_mini schedule drifted from the golden dump; if intentional, \
         re-bless with UPDATE_GOLDEN=1 and commit"
    );
}
