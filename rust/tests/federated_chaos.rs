//! Chaos acceptance tests for the federated fleet: every fault kind ×
//! {above, below} quorum, plus the headline determinism claim — a
//! seeded hostile schedule over 20+ rounds replays bit-identically,
//! commits every quorum-reachable round, and never rolls back.
//!
//! The matrix runs on the simulated (virtual-time) transport so every
//! assertion is exact; the threaded transport gets a wall-clock
//! hostile smoke with timing-robust assertions only.

use bnn_edge::federated::{
    AsyncConfig, Fault, FaultPlan, FedConfig, FedResult, FleetMode, Leader,
};

fn sim_cfg(workers: usize, rounds: usize, plan: FaultPlan) -> FedConfig {
    let mut cfg = FedConfig::fleet(workers);
    cfg.rounds = rounds;
    cfg.local_steps = 2;
    cfg.batch = 16;
    cfg.samples_per_worker = 64;
    cfg.plan = plan;
    cfg.mode = FleetMode::Sim { shards: 2, noise_log2: 4 };
    cfg
}

fn run(cfg: FedConfig) -> FedResult {
    Leader::new(cfg).unwrap().run().unwrap()
}

/// Shared invariants every schedule must uphold.
fn assert_invariants(r: &FedResult, quorum: usize) {
    // commits are exactly the quorum-reachable rounds, in order
    let mut last = None;
    for s in &r.round_stats {
        assert_eq!(
            s.committed,
            s.admitted >= quorum,
            "round {}: admitted {} vs quorum {}",
            s.round,
            s.admitted,
            quorum
        );
        if s.committed {
            if let Some(prev) = last {
                assert!(s.round > prev, "rollback: {} after {}", s.round, prev);
            }
            last = Some(s.round);
        }
    }
    assert_eq!(r.rounds_committed, r.round_stats.iter().filter(|s| s.committed).count());
    // weights stay in the unit box and finite under every schedule
    for w in &r.final_weights {
        assert!(w.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
    }
}

#[test]
fn hostile_20_rounds_is_deterministic_and_commits_reachable_rounds() {
    // the acceptance run: 100 sim workers, 20 rounds, all five fault
    // kinds live; two same-seed runs must be bit-identical
    let mk = || {
        let mut cfg = sim_cfg(100, 20, FaultPlan::hostile(1234));
        cfg.mode = FleetMode::Sim { shards: 4, noise_log2: 4 };
        cfg
    };
    let a = run(mk());
    let b = run(mk());
    let quorum = AsyncConfig::majority(100).quorum;
    assert_invariants(&a, quorum);
    assert!(a.rounds_committed >= 12, "{}/{}", a.rounds_committed, a.rounds_attempted);
    assert_eq!(a.final_weights, b.final_weights, "same seed must replay bit-identically");
    assert_eq!(a.rounds_committed, b.rounds_committed);
    for (x, y) in a.round_stats.iter().zip(&b.round_stats) {
        assert_eq!((x.admitted, x.fresh, x.stale), (y.admitted, y.fresh, y.stale));
    }
}

#[test]
fn shard_topology_does_not_change_the_answer() {
    // counts are associative: 2-shard and 5-shard fleets over the
    // same workers/seed/plan produce bit-identical final weights
    let mk = |shards| {
        let mut cfg = sim_cfg(40, 8, FaultPlan::hostile(77));
        cfg.mode = FleetMode::Sim { shards, noise_log2: 4 };
        cfg
    };
    let a = run(mk(2));
    let b = run(mk(5));
    assert_eq!(a.final_weights, b.final_weights);
    assert_eq!(a.rounds_committed, b.rounds_committed);
}

#[test]
fn crash_above_quorum_commits_and_rejoins() {
    let plan = FaultPlan::scripted([(0, 1, Fault::Crash { outage: 2 })]);
    let r = run(sim_cfg(4, 5, plan));
    let quorum = AsyncConfig::majority(4).quorum; // 3
    assert_invariants(&r, quorum);
    assert_eq!(r.rounds_committed, 5, "3 of 4 keeps quorum");
    assert_eq!(r.round_stats[1].admitted, 3);
    assert_eq!(r.round_stats[1].timeouts, 1);
    // outage over + backoff elapsed: the crashed worker rejoins
    assert_eq!(r.round_stats[3].fresh, 4, "worker 0 rejoined");
}

#[test]
fn crash_below_quorum_stalls_then_recovers() {
    let plan = FaultPlan::scripted([(0, 1, Fault::Crash { outage: 2 })]);
    let mut cfg = sim_cfg(4, 5, plan);
    cfg.async_cfg.quorum = 4; // unanimous: one crash stalls the round
    let r = run(cfg);
    assert_invariants(&r, 4);
    assert!(!r.round_stats[1].committed, "below quorum must stall");
    assert!(r.round_stats[1].mean_loss.is_nan());
    assert!(r.round_stats[3].committed, "fleet recovers after rejoin");
    assert!(r.rounds_committed >= 3);
}

#[test]
fn stall_above_quorum_discounts_the_late_vote() {
    let plan = FaultPlan::scripted([(1, 0, Fault::Stall { rounds: 1, millis: 0 })]);
    let r = run(sim_cfg(4, 3, plan));
    assert_invariants(&r, AsyncConfig::majority(4).quorum);
    assert_eq!(r.round_stats[0].admitted, 3);
    assert_eq!(r.round_stats[1].stale, 1, "late update admitted next round");
    assert_eq!(r.rounds_committed, 3);
}

#[test]
fn stall_below_quorum_commits_on_late_delivery() {
    // unanimous quorum: the stalled round cannot commit, the next one
    // admits the stale vote and can
    let plan = FaultPlan::scripted([(1, 0, Fault::Stall { rounds: 1, millis: 0 })]);
    let mut cfg = sim_cfg(2, 3, plan);
    cfg.async_cfg.quorum = 2;
    let r = run(cfg);
    assert_invariants(&r, 2);
    assert!(!r.round_stats[0].committed);
    assert!(r.round_stats[1].committed, "stale vote completes the quorum");
    assert_eq!(r.round_stats[1].stale, 1);
}

#[test]
fn drop_uplink_above_quorum_commits() {
    let plan = FaultPlan::scripted([(2, 0, Fault::DropUplink), (2, 1, Fault::DropUplink)]);
    let r = run(sim_cfg(4, 4, plan));
    assert_invariants(&r, AsyncConfig::majority(4).quorum);
    assert_eq!(r.rounds_committed, 4);
    assert_eq!(r.round_stats[0].timeouts, 1);
}

#[test]
fn drop_uplink_below_quorum_stalls_without_corruption() {
    let plan = FaultPlan::scripted([(0, 1, Fault::DropUplink), (1, 1, Fault::DropUplink)]);
    let mut cfg = sim_cfg(3, 4, plan);
    cfg.async_cfg.quorum = 2;
    let r = run(cfg);
    assert_invariants(&r, 2);
    assert!(!r.round_stats[1].committed, "1 of 3 is below quorum");
    // droppers sit out round 2 as stragglers, rejoin at round 3
    assert!(r.round_stats[3].committed);
    assert!(r.round_stats[0].committed && r.rounds_committed >= 2);
}

#[test]
fn corrupt_worker_is_quarantined_and_fleet_survives() {
    let plan = FaultPlan::scripted([(3, 0, Fault::Corrupt)]);
    let r = run(sim_cfg(5, 4, plan));
    assert_invariants(&r, AsyncConfig::majority(5).quorum);
    assert_eq!(r.quarantined, 1);
    assert_eq!(r.rounds_committed, 4);
    // the quarantined worker never contributes again
    for s in &r.round_stats {
        assert!(s.admitted <= 4, "round {}: {}", s.round, s.admitted);
    }
}

#[test]
fn corrupt_majority_below_quorum_never_commits_garbage() {
    // 3 of 4 workers are malicious in round 0: quorum becomes
    // unreachable forever — the leader must stop cleanly with round 0
    // state intact, not aggregate a poisoned minority
    let plan = FaultPlan::scripted([
        (0, 0, Fault::Corrupt),
        (1, 0, Fault::Corrupt),
        (2, 0, Fault::Corrupt),
    ]);
    let r = run(sim_cfg(4, 5, plan));
    assert_invariants(&r, AsyncConfig::majority(4).quorum);
    assert_eq!(r.rounds_committed, 0);
    assert_eq!(r.quarantined, 3);
    assert!(r.rounds_attempted < 5, "unreachable quorum exits early");
}

#[test]
fn threaded_hostile_smoke_survives() {
    // wall-clock transport: assertions limited to what timing cannot
    // perturb — invariants hold, no panic, leader drains cleanly
    let mut cfg = FedConfig::fleet(3);
    cfg.rounds = 4;
    cfg.local_steps = 2;
    cfg.batch = 16;
    cfg.samples_per_worker = 48;
    cfg.plan = FaultPlan::hostile(5);
    cfg.async_cfg.deadline_ms = 400;
    cfg.async_cfg.retry_budget = 1;
    let r = run(cfg);
    assert_invariants(&r, AsyncConfig::majority(3).quorum);
    assert_eq!(r.rounds_attempted, r.round_stats.len());
}
