//! Measured transient conv memory: the fused bit-im2col really
//! eliminates the f32 cols buffer (the `memtrack` counterpart of
//! `memmodel::conv_cols_transient`).
//!
//! This integration binary installs the tracking allocator (the lib
//! test harness cannot), measures the pre-fusion path — f32 `im2col`
//! then `BitMatrix::pack`, exactly what the engines ran before this
//! PR — against `bitops::im2col_packed`, and asserts the drop against
//! the modeled figures.
//!
//! Single `#[test]`: peak tracking is process-global, so keeping one
//! test in this binary avoids cross-test allocation noise.

use bnn_edge::bitops::{im2col_packed, BitMatrix, Pool};
use bnn_edge::memtrack::{measure, TrackingAlloc};
use bnn_edge::naive::im2col;
use bnn_edge::util::rng::Pcg32;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

#[test]
fn fused_bit_im2col_eliminates_f32_cols_buffer() {
    assert!(bnn_edge::memtrack::is_active(), "tracking allocator not installed");

    // a binary conv shape off the word grid: K = 297 bits
    let (b, h, w, cin, kside) = (2usize, 16usize, 16usize, 33usize, 3usize);
    let k = kside * kside * cin;
    let rows = b * h * w;
    let cols_bytes = rows * k * 4; // the pre-fusion f32 im2col buffer
    let packed_bytes = rows * k.div_ceil(64) * 8;

    let mut g = Pcg32::new(1);
    let x = g.normal_vec(b * h * w * cin);

    // pre-fusion: materialize f32 cols, then bit-pack (both live at
    // the pack — the PR-1 binary conv path)
    let (pre_m, pre) = measure(|| {
        let cols = im2col(&x, b, h, w, cin, kside);
        std::hint::black_box(BitMatrix::pack(rows, k, &cols))
    });
    // fused: straight to the packed panel
    let (post_m, post) = measure(|| {
        std::hint::black_box(im2col_packed(&x, b, h, w, cin, kside, &Pool::serial()))
    });
    assert_eq!(post_m, pre_m, "paths must produce identical panels");

    // pre-fusion peak contains the full f32 buffer + the panel
    assert!(
        pre.growth() >= cols_bytes + packed_bytes,
        "pre-fusion peak {} < cols {} + panel {}",
        pre.growth(),
        cols_bytes,
        packed_bytes
    );
    // fused peak holds the packed panel but nowhere near the f32
    // buffer: zero f32 im2col bytes on the binary path
    assert!(post.growth() >= packed_bytes);
    assert!(
        post.growth() < cols_bytes / 8,
        "fused peak {} should be far below the f32 cols buffer {}",
        post.growth(),
        cols_bytes
    );
    // and the measured drop matches the modeled ~33x within slack
    // (allocator rounding; K=297 is not word-aligned so modeled
    // ratio here is (rows*k*4 + panel) / panel ≈ 30.7)
    let measured_ratio = pre.growth() as f64 / post.growth() as f64;
    let modeled_ratio = (cols_bytes + packed_bytes) as f64 / packed_bytes as f64;
    assert!(
        measured_ratio > modeled_ratio * 0.5,
        "measured {measured_ratio:.1}x vs modeled {modeled_ratio:.1}x"
    );
}
