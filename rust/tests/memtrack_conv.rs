//! Measured transient conv memory: the fused bit-im2col really
//! eliminates the f32 cols buffer, and the fused conv *backward*
//! really eliminates the rows×k patch-gradient buffers (the
//! `memtrack` counterparts of `memmodel::conv_cols_transient` and
//! `memmodel::conv_backward_transient`).
//!
//! This integration binary installs the tracking allocator (the lib
//! test harness cannot), measures the pre-fusion paths — exactly what
//! the engines ran before fusion — against the fused kernels, and
//! asserts the drops against the modeled figures.
//!
//! Single `#[test]`: peak tracking is process-global, so keeping one
//! test in this binary avoids cross-test allocation noise.

use bnn_edge::bitops::im2col::{conv_dw_first_streaming_into, conv_fwd_first_streaming_into};
use bnn_edge::bitops::{
    conv_dx_streaming, im2col_packed, packed_at_gemm_f32, subtract_pad_dw_contrib, Backend,
    BitMatrix, ConvGeom, Pool,
};
use bnn_edge::memtrack::{measure, TrackingAlloc};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{col2im, im2col, transpose};
use bnn_edge::util::rng::Pcg32;

#[global_allocator]
static ALLOC: TrackingAlloc = TrackingAlloc;

fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    Backend::Blocked.gemm_f32(m, k, n, a, b, out)
}

#[test]
fn fused_conv_pipeline_eliminates_rows_x_k_f32_buffers() {
    assert!(bnn_edge::memtrack::is_active(), "tracking allocator not installed");

    // a binary conv shape off the word grid: K = 297 bits
    let (b, h, w, cin, kside) = (2usize, 16usize, 16usize, 33usize, 3usize);
    let geom = ConvGeom::same1(h, w, cin, kside);
    let k = geom.k();
    let rows = geom.rows(b);
    let cols_bytes = rows * k * 4; // the pre-fusion f32 im2col buffer
    let packed_bytes = rows * k.div_ceil(64) * 8;

    let mut g = Pcg32::new(1);
    let x = g.normal_vec(b * h * w * cin);

    // pre-fusion: materialize f32 cols, then bit-pack (both live at
    // the pack — the PR-1 binary conv path)
    let (pre_m, pre) = measure(|| {
        let cols = im2col(&x, b, geom);
        std::hint::black_box(BitMatrix::pack(rows, k, &cols))
    });
    // fused: straight to the packed panel
    let (post_m, post) = measure(|| {
        std::hint::black_box(im2col_packed(&x, b, geom, &Pool::serial()))
    });
    assert_eq!(post_m, pre_m, "paths must produce identical panels");

    // pre-fusion peak contains the full f32 buffer + the panel
    assert!(
        pre.growth() >= cols_bytes + packed_bytes,
        "pre-fusion peak {} < cols {} + panel {}",
        pre.growth(),
        cols_bytes,
        packed_bytes
    );
    // fused peak holds the packed panel but nowhere near the f32
    // buffer: zero f32 im2col bytes on the binary path
    assert!(post.growth() >= packed_bytes);
    assert!(
        post.growth() < cols_bytes / 8,
        "fused peak {} should be far below the f32 cols buffer {}",
        post.growth(),
        cols_bytes
    );
    // and the measured drop matches the modeled ~33x within slack
    // (allocator rounding; K=297 is not word-aligned so modeled
    // ratio here is (rows*k*4 + panel) / panel ≈ 30.7)
    let measured_ratio = pre.growth() as f64 / post.growth() as f64;
    let modeled_ratio = (cols_bytes + packed_bytes) as f64 / packed_bytes as f64;
    assert!(
        measured_ratio > modeled_ratio * 0.5,
        "measured {measured_ratio:.1}x vs modeled {modeled_ratio:.1}x"
    );

    // ---- conv backward: the step-peak holder after the forward fused.
    // Pre-fusion (the PR-2 baseline) the layer arm held THREE rows×k
    // f32 buffers live at peak — dX patch grads `dcols`, the dW im2col
    // `cols` and its transpose — plus the unpacked Ŵᵀ.  The fused
    // backward streams dX tap-by-tap (one rows×cin panel) and
    // contracts dW from a re-packed 1-bit panel.
    let cout = 32usize;
    let dy = g.normal_vec(rows * cout);
    let wt = BitMatrix::pack(cout, k, &g.normal_vec(cout * k));

    let ((dx1, dw1), pre_b) = measure(|| {
        let wt_f = wt.unpack(); // the signed_wt the engines consumed
        let mut dcols = vec![0.0f32; rows * k];
        gemm_f32(rows, cout, k, &dy, &wt_f, &mut dcols);
        let dx = col2im(&dcols, b, geom);
        let xhat: Vec<f32> =
            x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        let cols = im2col(&xhat, b, geom);
        let colst = transpose(&cols, rows, k);
        let mut dw = vec![0.0f32; k * cout];
        gemm_f32(k, rows, cout, &colst, &dy, &mut dw);
        (dx, dw) // dcols/cols/colst all live to here, as in the engines
    });
    let ((dx2, dw2), post_b) = measure(|| {
        let dx = conv_dx_streaming(&dy, &wt, b, geom, Backend::Blocked);
        let xh = im2col_packed(&x, b, geom, &Pool::serial());
        let mut dw = vec![0.0f32; k * cout];
        packed_at_gemm_f32(&xh, &dy, cout, &mut dw, &Pool::serial());
        subtract_pad_dw_contrib(&mut dw, &dy, b, geom, cout);
        (dx, dw)
    });

    // fused-backward gradients match the pre-fusion reference
    for (i, (a, bb)) in dx1.iter().zip(&dx2).enumerate() {
        assert!((a - bb).abs() <= 1e-4 * (1.0 + a.abs()), "dx @ {i}: {a} vs {bb}");
    }
    for (i, (a, bb)) in dw1.iter().zip(&dw2).enumerate() {
        assert!((a - bb).abs() <= 1e-4 * (1.0 + a.abs()), "dw @ {i}: {a} vs {bb}");
    }

    // both measurements necessarily retain the outputs (dx, dw);
    // everything else is the transient under test
    let out_bytes = dx1.len() * 4 + dw1.len() * 4;
    let pre_transient = pre_b.growth().saturating_sub(out_bytes);
    let post_transient = post_b.growth().saturating_sub(out_bytes);
    // pre-fusion peak really held ~3 rows×k f32 buffers at once
    assert!(
        pre_transient >= 3 * cols_bytes,
        "pre-fusion backward peak {pre_transient} < 3 x rows*k buffer {cols_bytes}"
    );
    // fused path allocates NO rows×k f32 buffer anywhere
    assert!(
        post_transient < cols_bytes,
        "fused backward transient {post_transient} should be below one rows*k f32 \
         buffer {cols_bytes}"
    );
    // the acceptance bar: step-peak transient drops >= 3x measured...
    let measured_b = pre_transient as f64 / post_transient as f64;
    assert!(measured_b >= 3.0, "measured backward drop only {measured_b:.1}x");
    // ...and tracks the modeled drop (memmodel::conv_backward_transient
    // formulae instantiated on this geometry)
    let modeled_pre = 3.0 * (rows * k * 4) as f64;
    let modeled_post = (rows * cin * 4) as f64 + (rows * k.div_ceil(64) * 8) as f64;
    let modeled_b = modeled_pre / modeled_post;
    assert!(
        measured_b > modeled_b * 0.5,
        "measured {measured_b:.1}x vs modeled {modeled_b:.1}x"
    );

    // the lib-side model agrees at BinaryNet scale (acceptance: >= 3x)
    let graph = lower(&get("binarynet").unwrap()).unwrap();
    let m_pre = bnn_edge::memmodel::conv_backward_transient(&graph, 100, false);
    let m_post = bnn_edge::memmodel::conv_backward_transient(&graph, 100, true);
    assert!(m_pre.total() / m_post.total() >= 3.0);

    // ---- strided geometry (ResNet stage-entry shape): rows are the
    // *output* positions, so the fused backward's measured peak must
    // track rows_out × Cin — pricing input positions (the old
    // in_elems/pos fallback, stride² larger) would overshoot 4x.
    let sg = ConvGeom::same(16, 16, 33, 3, 2);
    let (sb, scout) = (2usize, 32usize);
    let srows = sg.rows(sb);
    let sx = g.normal_vec(sg.in_len(sb));
    let sdy = g.normal_vec(srows * scout);
    let swt = BitMatrix::pack(scout, sg.k(), &g.normal_vec(scout * sg.k()));
    let (_sgrads, strided_m) = measure(|| {
        let dx = conv_dx_streaming(&sdy, &swt, sb, sg, Backend::Blocked);
        let xh = im2col_packed(&sx, sb, sg, &Pool::serial());
        let mut dw = vec![0.0f32; sg.k() * scout];
        packed_at_gemm_f32(&xh, &sdy, scout, &mut dw, &Pool::serial());
        subtract_pad_dw_contrib(&mut dw, &sdy, sb, sg, scout);
        (dx, dw)
    });
    let s_out_bytes = sg.in_len(sb) * 4 + sg.k() * scout * 4;
    let s_transient = strided_m.growth().saturating_sub(s_out_bytes);
    // modeled fused transient: one rows_out × cin panel + the packed
    // panel + the per-tap weight slice (cout × cin)
    let s_modeled = srows * sg.cin * 4
        + srows * sg.k().div_ceil(64) * 8
        + scout * sg.cin * 4;
    assert!(
        s_transient < 2 * s_modeled,
        "strided fused backward transient {s_transient} vs modeled {s_modeled}"
    );
    // and far below one rows_out × k f32 buffer (the pre-fusion floor)
    assert!(s_transient < srows * sg.k() * 4, "{s_transient}");

    // the lib-side model prices ResNet shapes with exact Cin now
    let rg = lower(&get("resnete18").unwrap()).unwrap();
    let rt = bnn_edge::memmodel::conv_backward_transient(&rg, 4, true);
    assert_eq!(rt.dcols_f32_bytes, 0.0);
    assert!(rt.panel_f32_bytes > 0.0);

    // ---- first (real-input) conv: the last rows×k f32 cols buffer.
    // Pre-fusion both directions materialized the full f32 im2col of
    // the real input; the streaming path gathers one rows×Cin tap
    // panel (k²× smaller) and accumulates per-tap GEMMs.  Measured
    // twin of `memmodel::first_conv_transient`.
    let (fb, fgeom, fcout) = (2usize, ConvGeom::same1(16, 16, 3, 3), 32usize);
    let fk = fgeom.k();
    let frows = fgeom.rows(fb);
    let f_cols_bytes = frows * fk * 4;
    let f_panel_bytes = frows * fgeom.cin * 4;
    let fx = g.normal_vec(fgeom.in_len(fb));
    let fw = g.normal_vec(fk * fcout);
    let fdy = g.normal_vec(frows * fcout);

    // forward: pre-fusion f32 im2col + GEMM vs streaming taps
    let (y1, pre_f) = measure(|| {
        let cols = im2col(&fx, fb, fgeom);
        let mut y = vec![0.0f32; frows * fcout];
        gemm_f32(frows, fk, fcout, &cols, &fw, &mut y);
        y
    });
    let (y2, post_f) = measure(|| {
        let mut y = vec![0.0f32; frows * fcout];
        let mut panel = vec![0.0f32; frows * fgeom.cin];
        conv_fwd_first_streaming_into(&fx, &fw, fb, fgeom, fcout, Backend::Blocked, &mut y, &mut panel);
        y
    });
    // same ascending-k accumulation order per cell: bit-identical
    assert_eq!(y1, y2, "streaming first-conv forward must match unfused");
    let f_out = frows * fcout * 4;
    assert!(pre_f.growth().saturating_sub(f_out) >= f_cols_bytes);
    assert!(
        post_f.growth().saturating_sub(f_out) < f_cols_bytes / 4,
        "fused first-conv forward transient {} should be far below the f32 cols {}",
        post_f.growth().saturating_sub(f_out),
        f_cols_bytes
    );

    // backward dW: pre-fusion im2col + transpose + GEMM vs streaming
    let (dwa, pre_w) = measure(|| {
        let cols = im2col(&fx, fb, fgeom);
        let colst = transpose(&cols, frows, fk);
        let mut dw = vec![0.0f32; fk * fcout];
        gemm_f32(fk, frows, fcout, &colst, &fdy, &mut dw);
        dw
    });
    let (dwb, post_w) = measure(|| {
        let mut dw = vec![0.0f32; fk * fcout];
        let mut panel = vec![0.0f32; frows * fgeom.cin];
        conv_dw_first_streaming_into(&fx, &fdy, fb, fgeom, fcout, Backend::Blocked, &mut dw, &mut panel);
        dw
    });
    assert_eq!(dwa, dwb, "streaming first-conv dW must match unfused");
    let w_out = fk * fcout * 4;
    // pre-fusion held cols AND its transpose live at the GEMM
    assert!(pre_w.growth().saturating_sub(w_out) >= 2 * f_cols_bytes);
    assert!(
        post_w.growth().saturating_sub(w_out) < f_cols_bytes / 4,
        "fused first-conv dW transient {} should be far below the f32 cols {}",
        post_w.growth().saturating_sub(w_out),
        f_cols_bytes
    );

    // the lib-side model agrees: fused prices one rows×Cin panel,
    // unfused the rows×k cols buffer, a k² = 9x drop on this shape
    let mg = lower(&get("cnv_mini").unwrap()).unwrap();
    let t_pre = bnn_edge::memmodel::first_conv_transient(&mg, 8, false);
    let t_post = bnn_edge::memmodel::first_conv_transient(&mg, 8, true);
    assert_eq!(t_pre.panel_f32_bytes, 0.0);
    assert_eq!(t_post.cols_f32_bytes, 0.0);
    assert!(t_pre.total() / t_post.total() >= 8.9, "{}", t_pre.total() / t_post.total());
    // and on THIS measured geometry the modeled ratio matches
    assert_eq!(f_cols_bytes / f_panel_bytes, fk / fgeom.cin);
}
