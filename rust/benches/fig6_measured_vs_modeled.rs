//! Fig. 6 — measured vs modeled memory for the naïve prototypes
//! (MLP / MNIST-class data, Adam), across batch sizes.
//!
//! Paper: measured ≈ modeled with a ~5% constant process overhead
//! plus a batch-correlated activation-copy overhead, far more
//! pronounced for the standard algorithm (f32 copies vs bool).
//! Measured here with the tracking global allocator: persistent
//! engine state + peak transient growth during one training step.

mod common;

use bnn_edge::data::build;
use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::memtrack;
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine, Accel};
use bnn_edge::report::series_table;
use bnn_edge::util::MIB;

#[global_allocator]
static ALLOC: memtrack::TrackingAlloc = memtrack::TrackingAlloc;

fn main() {
    let g = lower(&get("mlp").unwrap()).unwrap();
    let batches = [25usize, 50, 100, 200, 400];
    let mut points = Vec::new();
    for &b in &batches {
        let ds = build("syn-mnist", b, 0, 1).unwrap();
        let mut ys = Vec::new();
        for algo in ["standard", "proposed"] {
            let mut engine = build_engine(algo, &g, b, "adam", Accel::Naive, 1).unwrap();
            engine.train_step(&ds.train_x, &ds.train_y, 0.001).unwrap();
            let (_, stats) =
                memtrack::measure(|| engine.train_step(&ds.train_x, &ds.train_y, 0.001));
            let measured =
                (stats.growth() + engine.state_bytes()) as f64 / MIB;
            let modeled = breakdown(
                &g,
                b,
                &DtypeConfig::ablation(algo).unwrap(),
                Optimizer::Adam,
            )
            .total_bytes()
                / MIB;
            ys.push(Some(measured));
            ys.push(Some(modeled));
            ys.push(Some(measured / modeled));
        }
        points.push((b as f64, ys));
    }
    let md = series_table(
        "Fig. 6 — measured (tracking allocator) vs modeled MiB, naive MLP prototypes",
        "batch",
        &[
            "std measured",
            "std modeled",
            "std ratio",
            "prop measured",
            "prop modeled",
            "prop ratio",
        ],
        &points,
        2,
    );
    common::emit("fig6.md", &md);
    println!("paper: measured/modeled ratios slightly above 1.0, growing with batch");
    println!("       (activation-copy overhead), larger for the standard algorithm");
}
