//! Fig. 2 — batch size vs training memory footprint and test accuracy
//! for three optimizers (BinaryNet-class model).
//!
//! Paper: geomean 4.81× memory reduction across the sweep; ~10× batch
//! headroom at iso-memory; accuracy flat-to-slightly-better under the
//! proposed scheme.

mod common;

use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::report::series_table;
use bnn_edge::util::stats::geomean;
use bnn_edge::util::MIB;

fn main() {
    let g = lower(&get("binarynet").unwrap()).unwrap();

    // modeled memory sweep (full-scale model, wide batch range)
    let batches_model = [25usize, 50, 100, 200, 400, 800, 1600, 3200];
    let mut mem_points = Vec::new();
    let mut factors = Vec::new();
    for &b in &batches_model {
        let s = breakdown(&g, b, &DtypeConfig::standard(), Optimizer::Adam).total_bytes() / MIB;
        let p = breakdown(&g, b, &DtypeConfig::proposed(), Optimizer::Adam).total_bytes() / MIB;
        factors.push(s / p);
        mem_points.push((b as f64, vec![Some(s), Some(p), Some(s / p)]));
    }
    let md_mem = series_table(
        "Fig. 2 (memory) — modeled MiB vs batch, BinaryNet",
        "batch",
        &["standard MiB", "proposed MiB", "reduction x"],
        &mem_points,
        2,
    );
    common::emit("fig2_memory.md", &md_mem);
    println!(
        "geomean reduction across sweep: ours {:.2}x (paper 4.81x across optimizers)",
        geomean(&factors)
    );

    // trained accuracy sweep (mini model, HLO engine)
    let batches_train = [16usize, 64, 256];
    let mut acc_points = Vec::new();
    for &b in &batches_train {
        let mut ys = Vec::new();
        for opt in ["adam", "sgd", "bop"] {
            for algo in ["standard", "proposed"] {
                let mut cfg = common::bench_cfg("binarynet_mini", algo, opt, b);
                cfg.n_train = 1024;
                cfg.epochs = if b >= 256 { 5 } else { 3 };
                let r = common::run(cfg);
                ys.push(Some(r.best_test_acc as f64 * 100.0));
            }
        }
        acc_points.push((b as f64, ys));
    }
    let md_acc = series_table(
        "Fig. 2 (accuracy) — test acc % vs batch (mini surrogate)",
        "batch",
        &["adam std", "adam prop", "sgd std", "sgd prop", "bop std", "bop prop"],
        &acc_points,
        1,
    );
    common::emit("fig2_accuracy.md", &md_acc);
}
