//! §Perf federated bench — the 10³-worker aggregation story.
//!
//! Three row kinds in `BENCH_fed.json`:
//!
//! - `kind = "tally"` — the word-level vote tally (stack → word
//!   transpose → SIMD popcount, sharded) vs the scalar bit-probe
//!   reference over 10³ packed worker updates of a dense model's
//!   weight vector.  CI gates `tally_speedup >= 10` on the dense
//!   models — the per-round aggregation cost is what actually caps
//!   fleet size at the root.
//! - `kind = "fleet"` — end-to-end simulated-fleet rounds at 10³
//!   workers (clean and hostile chaos): rounds/sec, admitted uplink
//!   bytes/round, commit-latency p50/p99.
//! - `kind = "accuracy"` — federated (threaded small fleet) vs
//!   centralized training at matched total step count: test accuracy
//!   of both, and the gap the sign-vote aggregation costs.
//!
//! Flags: `--smoke` (trimmed sweep for CI), `--out PATH` (default
//! `BENCH_fed.json`).

use std::time::Instant;

use bnn_edge::bitops::BitMatrix;
use bnn_edge::data::build;
use bnn_edge::federated::{
    count_votes_scalar, count_votes_sharded, AsyncConfig, FaultPlan, FedConfig, FleetMode,
    Leader,
};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine, Accel};
use bnn_edge::util::bench::write_json_rows;
use bnn_edge::util::cli::Args;
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;
use bnn_edge::util::stats::percentile;

/// Total packed weight elements of a model (w + beta per layer).
fn model_weights(model: &str) -> usize {
    let graph = lower(&get(model).unwrap()).unwrap();
    graph
        .nodes
        .iter()
        .filter(|n| n.is_matmul())
        .map(|n| n.w_elems + n.channels)
        .sum()
}

/// Word-level vs scalar tally over `workers` synthetic updates.
fn bench_tally(model: &str, workers: usize, shards: usize, reps: usize) -> Json {
    let n = model_weights(model);
    let mut g = Pcg32::new(0xFED);
    let updates: Vec<BitMatrix> =
        (0..workers).map(|_| BitMatrix::pack(1, n, &g.normal_vec(n))).collect();
    let refs: Vec<&BitMatrix> = updates.iter().collect();
    // realistic staleness mix: mostly fresh, some discounted
    let ws: Vec<u32> = (0..workers).map(|i| [3u32, 3, 3, 3, 3, 3, 2, 1][i % 8]).collect();

    // one correctness check before timing anything
    assert_eq!(
        count_votes_sharded(&refs, &ws, shards),
        count_votes_scalar(&refs, &ws),
        "word tally must be bit-exact"
    );

    let mut t_scalar = f64::MAX;
    let mut t_words = f64::MAX;
    for _ in 0..reps {
        let t0 = Instant::now();
        let v = count_votes_scalar(&refs, &ws);
        t_scalar = t_scalar.min(t0.elapsed().as_secs_f64() * 1e3);
        std::hint::black_box(v);
        let t0 = Instant::now();
        let v = count_votes_sharded(&refs, &ws, shards);
        t_words = t_words.min(t0.elapsed().as_secs_f64() * 1e3);
        std::hint::black_box(v);
    }
    let speedup = t_scalar / t_words.max(1e-9);
    println!(
        "tally {model:>10} k={workers} n={n}: scalar {t_scalar:>8.2}ms  words {t_words:>7.2}ms  {speedup:>5.1}x"
    );
    let mut row = Json::obj();
    row.set("kind", Json::from("tally"));
    row.set("model", Json::from(model));
    row.set("workers", Json::from(workers));
    row.set("n_weights", Json::from(n));
    row.set("shards", Json::from(shards));
    row.set("tally_scalar_ms", Json::from(t_scalar));
    row.set("tally_words_ms", Json::from(t_words));
    row.set("tally_speedup", Json::from(speedup));
    row
}

/// End-to-end simulated fleet at `workers`, clean or hostile.
fn bench_fleet(model: &str, workers: usize, rounds: usize, chaos: &str) -> Json {
    let mut cfg = FedConfig::fleet(workers);
    cfg.model = model.into();
    cfg.rounds = rounds;
    cfg.local_steps = 4;
    cfg.batch = 32;
    cfg.samples_per_worker = 128;
    cfg.plan = FaultPlan::parse(chaos, 42).unwrap();
    cfg.mode = FleetMode::Sim { shards: 8, noise_log2: 4 };
    let t0 = Instant::now();
    let r = Leader::new(cfg).unwrap().run().unwrap();
    let elapsed = t0.elapsed().as_secs_f64();
    let commit_ms: Vec<f64> = r.round_stats.iter().map(|s| s.commit_ms).collect();
    let bytes: f64 = r.round_stats.iter().map(|s| s.uplink_bytes as f64).sum::<f64>()
        / r.rounds_attempted.max(1) as f64;
    let rps = r.rounds_attempted as f64 / elapsed.max(1e-12);
    println!(
        "fleet {model:>10} w={workers} chaos={chaos}: {}/{} committed  {rps:>5.2} rounds/s  {:.1} KiB/round  p50 {:.1}ms p99 {:.1}ms",
        r.rounds_committed,
        r.rounds_attempted,
        bytes / 1024.0,
        percentile(&commit_ms, 50.0),
        percentile(&commit_ms, 99.0),
    );
    let mut row = Json::obj();
    row.set("kind", Json::from("fleet"));
    row.set("model", Json::from(model));
    row.set("workers", Json::from(workers));
    row.set("chaos", Json::from(chaos));
    row.set("rounds", Json::from(r.rounds_attempted));
    row.set("rounds_committed", Json::from(r.rounds_committed));
    row.set("rounds_per_sec", Json::from(rps));
    row.set("bytes_per_round", Json::from(bytes));
    row.set("commit_p50_ms", Json::from(percentile(&commit_ms, 50.0)));
    row.set("commit_p99_ms", Json::from(percentile(&commit_ms, 99.0)));
    row.set("quarantined", Json::from(r.quarantined));
    row
}

/// Federated (threaded fleet) vs centralized at matched step budget.
fn bench_accuracy(model: &str, workers: usize, rounds: usize, local_steps: usize) -> Json {
    let batch = 32;
    let mut cfg = FedConfig::fleet(workers);
    cfg.model = model.into();
    cfg.rounds = rounds;
    cfg.local_steps = local_steps;
    cfg.batch = batch;
    cfg.samples_per_worker = 128;
    cfg.fed_lr = 0.02;
    cfg.async_cfg = AsyncConfig::majority(workers);
    cfg.mode = FleetMode::Threads;
    let seed = cfg.seed;
    let dataset = cfg.dataset.clone();
    let r = Leader::new(cfg).unwrap().run().unwrap();

    let graph = lower(&get(model).unwrap()).unwrap();
    let n_test = 256;
    let ds = build(&dataset, workers * 128, n_test, seed).unwrap();
    let k = ds.sample_elems();
    let eval_acc = |weights: &[Vec<f32>]| -> f64 {
        let mut e = build_engine("proposed", &graph, batch, "adam", Accel::Blocked, seed)
            .unwrap();
        e.load_weights(weights).unwrap();
        let mut acc = 0.0f64;
        let batches = n_test / batch;
        for bi in 0..batches {
            let x = &ds.test_x[bi * batch * k..(bi + 1) * batch * k];
            let y = &ds.test_y[bi * batch..(bi + 1) * batch];
            acc += e.eval(x, y).unwrap().1 as f64;
        }
        acc / batches as f64
    };
    let fed_acc = eval_acc(&r.final_weights);

    // centralized: same init, same total optimizer steps, full data
    let mut central =
        build_engine("proposed", &graph, batch, "adam", Accel::Blocked, seed).unwrap();
    let mut w0 = Leader::new({
        let mut c = FedConfig::fleet(1);
        c.model = model.into();
        c.rounds = 0;
        c.batch = batch;
        c.samples_per_worker = batch;
        c
    })
    .unwrap();
    central.load_weights(&w0.run().unwrap().final_weights).unwrap();
    let n_batches = (ds.train_y.len() / batch).max(1);
    for s in 0..rounds * local_steps {
        let bi = s % n_batches;
        let x = &ds.train_x[bi * batch * k..(bi + 1) * batch * k];
        let y = &ds.train_y[bi * batch..(bi + 1) * batch];
        central.train_step(x, y, 0.002).unwrap();
    }
    let central_acc = eval_acc(&central.weights_snapshot());
    println!(
        "acc   {model:>10} w={workers} r={rounds}: federated {fed_acc:.3}  centralized {central_acc:.3}  gap {:+.3}",
        fed_acc - central_acc
    );
    let mut row = Json::obj();
    row.set("kind", Json::from("accuracy"));
    row.set("model", Json::from(model));
    row.set("workers", Json::from(workers));
    row.set("rounds", Json::from(rounds));
    row.set("local_steps", Json::from(local_steps));
    row.set("fed_acc", Json::from(fed_acc));
    row.set("central_acc", Json::from(central_acc));
    row.set("acc_gap", Json::from(fed_acc - central_acc));
    row
}

fn main() {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let out_path = args.str_or("out", "BENCH_fed.json");

    // dense models: the tally gate's subjects (conv models tally the
    // same packed vectors, just smaller)
    let tally_models: Vec<&str> = if smoke { vec!["mlp_mini", "mlp"] } else {
        vec!["mlp_mini", "mlp", "cnv_mini"]
    };
    let reps = if smoke { 3 } else { 7 };

    let mut rows = Vec::new();
    for model in &tally_models {
        rows.push(bench_tally(model, 1000, 4, reps));
    }
    let fleet_rounds = if smoke { 5 } else { 12 };
    for chaos in ["none", "hostile"] {
        rows.push(bench_fleet("mlp_mini", 1000, fleet_rounds, chaos));
    }
    if !smoke {
        rows.push(bench_fleet("mlp_mini", 200, fleet_rounds, "hostile"));
    }
    let (acc_rounds, acc_steps) = if smoke { (4, 6) } else { (10, 10) };
    rows.push(bench_accuracy("mlp_mini", 4, acc_rounds, acc_steps));

    write_json_rows(&out_path, rows).expect("write BENCH_fed.json");
    println!("wrote {out_path}");
}
