//! Table 3 — robustness asymmetry: applying the proposed
//! approximations to non-binary networks degrades them far more than
//! it degrades BNNs.
//!
//! Paper (Δpp from each family's standard baseline):
//!   NN under proposed: −8.2 … −17.9 pp;  BNN under proposed:
//!   −2.1 … +0.4 pp.  Reproduction target: NN degradation clearly
//!   exceeds BNN degradation on every model.

mod common;

use bnn_edge::report::{acc_table, AccRow};

fn main() {
    let mut rows = Vec::new();
    let mut summary = Vec::new();
    for (model, batch) in [("mlp_mini", 64), ("cnv_mini", 100), ("binarynet_mini", 100)] {
        let nn_std = common::run(common::bench_cfg(model, "nn_standard", "adam", batch));
        let nn_prop = common::run(common::bench_cfg(model, "nn_proposed", "adam", batch));
        let bnn_std = common::run(common::bench_cfg(model, "standard", "adam", batch));
        let bnn_prop = common::run(common::bench_cfg(model, "proposed", "adam", batch));

        for (label, base, acc) in [
            (format!("{model} NN standard"), nn_std.best_test_acc, nn_std.best_test_acc),
            (format!("{model} NN +proposed approximations"), nn_std.best_test_acc, nn_prop.best_test_acc),
            (format!("{model} BNN standard"), bnn_std.best_test_acc, bnn_std.best_test_acc),
            (format!("{model} BNN proposed"), bnn_std.best_test_acc, bnn_prop.best_test_acc),
        ] {
            rows.push(AccRow { label, baseline_acc: base, acc, mib: None, mib_factor: None });
        }
        let nn_drop = (nn_std.best_test_acc - nn_prop.best_test_acc) * 100.0;
        let bnn_drop = (bnn_std.best_test_acc - bnn_prop.best_test_acc) * 100.0;
        summary.push(format!(
            "{model}: NN drop {nn_drop:+.2} pp vs BNN drop {bnn_drop:+.2} pp  ({})",
            if nn_drop > bnn_drop { "asymmetry holds" } else { "ASYMMETRY VIOLATED" }
        ));
    }
    let md = acc_table(
        "Table 3 — NN vs BNN robustness to the proposed approximations",
        &rows,
    );
    common::emit("table3.md", &md);
    println!("paper: NN drops 8.2-17.9 pp, BNN drops -0.4..2.1 pp");
    for s in &summary {
        println!("{s}");
    }
}
