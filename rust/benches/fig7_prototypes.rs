//! Fig. 7 — embedded prototypes: measured peak memory vs training
//! time per batch (a, b) and modeled energy per batch (c), for
//!
//!   naive-standard, naive-proposed     (direct loops — the paper's
//!                                       naïve C++ prototypes)
//!   blocked-standard, blocked-proposed (im2col + blocked GEMM — the
//!                                       paper's CBLAS acceleration)
//!   HLO/PJRT                           (the full-framework stand-in
//!                                       for the paper's Keras row)
//!
//! Paper's shape: acceleration buys ~10× speed for 1.6-2.1× memory;
//! the framework (Keras) is fastest but needs orders of magnitude
//! more memory; proposed stays 2-4.5× smaller than standard at every
//! point; energy savings are modest (1.02-1.18×).

mod common;

use bnn_edge::coordinator::{EngineKind, RunConfig, Runner};
use bnn_edge::data::build;
use bnn_edge::energy::step_cost;
use bnn_edge::memmodel::DtypeConfig;
use bnn_edge::memtrack;
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine, Accel};
use bnn_edge::util::bench::fmt_time;
use bnn_edge::util::table::{Align, Table};
use bnn_edge::util::MIB;

#[global_allocator]
static ALLOC: memtrack::TrackingAlloc = memtrack::TrackingAlloc;

fn measure_engine(
    model: &str,
    algo: &str,
    accel: Accel,
    batch: usize,
) -> (f64, f64) {
    let g = lower(&get(model).unwrap()).unwrap();
    let ds = build(bnn_edge::config::dataset_for(model), batch, 0, 1).unwrap();
    let mut engine = build_engine(algo, &g, batch, "adam", accel, 1).unwrap();
    engine.train_step(&ds.train_x, &ds.train_y, 0.001).unwrap();
    let t0 = std::time::Instant::now();
    let reps = 3;
    let (_, stats) = memtrack::measure(|| {
        for _ in 0..reps {
            engine.train_step(&ds.train_x, &ds.train_y, 0.001).unwrap();
        }
    });
    let time_per_batch = t0.elapsed().as_secs_f64() / reps as f64;
    let mem = (stats.growth() + engine.state_bytes()) as f64 / MIB;
    (mem, time_per_batch)
}

fn measure_hlo(model: &str, algo: &str, batch: usize) -> Option<(f64, f64)> {
    let cfg = RunConfig {
        model: model.into(),
        algo: algo.into(),
        dataset: bnn_edge::config::dataset_for(model).into(),
        batch,
        epochs: 1,
        max_steps: Some(3),
        n_train: batch * 4,
        n_test: batch,
        eval_every_steps: 1000,
        engine: EngineKind::Hlo,
        ..Default::default()
    };
    let mut runner = Runner::new(cfg).ok()?;
    let ds = build(bnn_edge::config::dataset_for(model), batch, 0, 1).unwrap();
    let eng = runner.engine_mut();
    eng.train_step(&ds.train_x, &ds.train_y, 0.001).ok()?;
    let t0 = std::time::Instant::now();
    let (_, stats) = memtrack::measure(|| {
        for _ in 0..3 {
            eng.train_step(&ds.train_x, &ds.train_y, 0.001).unwrap();
        }
    });
    let t = t0.elapsed().as_secs_f64() / 3.0;
    // XLA allocates outside the rust allocator too; state_bytes is
    // the rust-visible parameter footprint (the paper's Keras row is
    // likewise dominated by framework overhead we cannot see — noted)
    Some(((stats.growth() + eng.state_bytes()) as f64 / MIB, t))
}

fn main() {
    for (model, batch) in [("mlp", 200), ("binarynet_mini", 40)] {
        let mut t = Table::new(
            &format!("Fig. 7 — {model} (B={batch}): memory vs time vs energy per batch"),
            &["Implementation", "Peak MiB", "s/batch", "mJ/batch (modeled)"],
        )
        .align(0, Align::Left);
        let g = lower(&get(model).unwrap()).unwrap();
        for (label, algo, accel) in [
            ("naive standard", "standard", Accel::Naive),
            ("naive proposed", "proposed", Accel::Naive),
            ("accel standard", "standard", Accel::Blocked),
            ("accel proposed", "proposed", Accel::Blocked),
        ] {
            let (mem, time) = measure_engine(model, algo, accel, batch);
            let dt = DtypeConfig::ablation(algo).unwrap();
            let mj = step_cost(&g, batch, &dt, 2.0).energy_mj();
            t.row(&[
                label.to_string(),
                format!("{mem:.2}"),
                fmt_time(time),
                format!("{mj:.2}"),
            ]);
        }
        if let Some((mem, time)) = measure_hlo(model, "proposed", if model == "mlp" { 100 } else { 100 }) {
            let dt = DtypeConfig::ablation("proposed").unwrap();
            let mj = step_cost(&g, 100, &dt, 2.0).energy_mj();
            t.row(&[
                "XLA/PJRT framework (B=100)".to_string(),
                format!("{mem:.2}+runtime"),
                fmt_time(time),
                format!("{mj:.2}"),
            ]);
        }
        common::emit(&format!("fig7_{model}.md"), &t.to_markdown());
    }
    println!("paper: accel ~10x faster for 1.6-2.1x memory; proposed 2.2-4.5x");
    println!("       smaller than standard; energy savings 1.02-1.18x");
}
