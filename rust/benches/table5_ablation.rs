//! Table 5 — impact of each data-representation step, per optimizer
//! (BinaryNet-class model, CIFAR-10-class data, B=100).
//!
//! Paper's shape: f16 is free (±0.03 pp); bool ∂W costs ≈1 pp under
//! ℓ2 BN; ℓ1 BN recovers it; the full proposed scheme lands within
//! ±1 pp of standard while cutting memory 3.7–4.9×.

mod common;

use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::report::{acc_table, AccRow};
use bnn_edge::util::MIB;

fn main() {
    let g = lower(&get("binarynet").unwrap()).unwrap();
    let mut rows = Vec::new();
    for opt in ["adam", "sgd", "bop"] {
        let mopt = Optimizer::parse(opt).unwrap();
        let base_mib = breakdown(&g, 100, &DtypeConfig::standard(), mopt).total_bytes() / MIB;
        let mut baseline = 0.0f32;
        for algo in ["standard", "f16", "boolgrad_l2", "boolgrad_l1", "proposed"] {
            let r = common::run(common::bench_cfg("binarynet_mini", algo, opt, 100));
            if algo == "standard" {
                baseline = r.best_test_acc;
            }
            let mib = breakdown(&g, 100, &DtypeConfig::ablation(algo).unwrap(), mopt)
                .total_bytes()
                / MIB;
            rows.push(AccRow {
                label: format!("{opt} / {algo}"),
                baseline_acc: baseline,
                acc: r.best_test_acc,
                mib: Some(mib),
                mib_factor: Some(base_mib / mib),
            });
        }
    }
    let md = acc_table(
        "Table 5 — data representation ablation x optimizer (BinaryNet)",
        &rows,
    );
    common::emit("table5.md", &md);
    println!("paper memory ladders: adam 512.81/256.41/231.33/231.33/138.15 MiB");
    println!("                      sgd  459.32/229.66/204.58/204.58/109.20 MiB");
    println!("                      bop  405.83/202.92/177.84/177.84/ 82.45 MiB");
}
