//! Table 6 + Fig. 5 — ImageNet-class residual BNNs (ResNetE-18,
//! Bi-Real-18): per-approximation accuracy (mini surrogates) and the
//! full-scale modeled memory at the paper's B=4096.
//!
//! Paper: proposed = −1.7/−2.3 pp, 70.11 → 18.54 GiB (3.78×); single
//! approximations cost ≤1.3 pp each.  Our absolute GiB differ (the
//! paper's TPU memory model charges the non-binary stem differently)
//! — the reduction factor and accuracy ordering are the target.

mod common;

use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::report::{acc_table, AccRow};
use bnn_edge::util::GIB;

fn main() {
    let mut rows = Vec::new();
    for (mini, full) in [("resnete_mini", "resnete18"), ("bireal_mini", "bireal18")] {
        let g = lower(&get(full).unwrap()).unwrap();
        let base_gib =
            breakdown(&g, 4096, &DtypeConfig::standard(), Optimizer::Adam).total_bytes() / GIB;
        let mut baseline = 0.0f32;
        // Table 6 rows: none, all-16-bit, bool dW only, l1 BN only,
        // prop BN only, full proposed — mapped to our configs
        let table6_rows: [(&str, &str, &str); 6] = [
            ("none", "standard", "standard"),
            ("all-bf16", "f16", "f16"),
            ("bool dW only", "boolgrad_l2", "boolgrad"),
            ("l1 batch norm only", "boolgrad_l1", "l1_bn"),
            ("prop batch norm only", "proposed", "prop_bn"),
            ("proposed (all)", "proposed", "proposed"),
        ];
        for (label, run_algo, mem_key) in table6_rows {
            // accuracy runs reuse ablation artifacts; 'prop bn only'
            // and 'proposed' share the proposed training step (the BN
            // change is the dominant term), distinguished by memory
            let r = common::run(common::bench_cfg(mini, run_algo, "adam", 64));
            if label == "none" {
                baseline = r.best_test_acc;
            }
            let gib = breakdown(&g, 4096, &DtypeConfig::table6(mem_key).unwrap(), Optimizer::Adam)
                .total_bytes()
                / GIB;
            rows.push(AccRow {
                label: format!("{full} {label}"),
                baseline_acc: baseline,
                acc: r.best_test_acc,
                mib: Some(gib), // column reads GiB here
                mib_factor: Some(base_gib / gib),
            });
        }
    }
    let md = acc_table(
        "Table 6 — ImageNet-class residual BNNs (memory column in GiB, B=4096)",
        &rows,
    );
    common::emit("table6.md", &md);
    println!("paper: ResNetE-18 none 70.11 GiB -> proposed 18.54 GiB (3.78x), -1.73 pp");
    println!("       Bi-Real-18 same memory, -2.26 pp");
}
