//! Table 2 — variable representation & lifetime breakdown
//! (BinaryNet, CIFAR-10-class input, Adam, B=100), standard vs
//! proposed, plus the model-sizing throughput microbench.
//!
//! Paper: total 512.81 MiB → 138.15 MiB (3.71×), X 111.33 → 3.48.

mod common;

use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::report;
use bnn_edge::util::bench::Bencher;
use bnn_edge::util::MIB;

fn main() {
    let g = lower(&get("binarynet").unwrap()).unwrap();
    let std = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam);
    let prop = breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Adam);
    let md = report::table2(&std, &prop);
    common::emit("table2.md", &md);
    println!(
        "paper: 512.81 -> 138.15 MiB (3.71x) | ours: {:.2} -> {:.2} MiB ({:.2}x)",
        std.total_mib(),
        prop.total_mib(),
        std.total_bytes() / prop.total_bytes()
    );

    // the same breakdown for every zoo model (the memory-model sweep)
    for model in ["mlp", "cnv", "binarynet", "resnete18", "bireal18"] {
        let g = lower(&get(model).unwrap()).unwrap();
        let s = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam);
        let p = breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Adam);
        println!(
            "{model:>12}: {:>9.2} -> {:>8.2} MiB  ({:.2}x)",
            s.total_bytes() / MIB,
            p.total_bytes() / MIB,
            s.total_bytes() / p.total_bytes()
        );
    }

    // microbench: the analysis itself is cheap enough to gate every
    // run (the coordinator calls it per admission check)
    let mut b = Bencher::quick();
    b.bench("memmodel::breakdown(binarynet)", || {
        let r = breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Adam);
        bnn_edge::util::bench::black_box(r.total_bytes());
    });
}
