//! Figs. 3/4 — validation-accuracy-vs-time curves for the Table 4
//! configurations: the paper's convergence-rate claim ("no discernible
//! change in convergence rate").
//!
//! Emits aligned curves (standard vs proposed) and a quantitative
//! convergence check: steps to reach 90% of final accuracy must be
//! comparable (within 1.5x) between the two algorithms.

mod common;

use bnn_edge::report::series_table;

fn steps_to_frac(curve: &[(usize, f32)], frac: f32) -> usize {
    let last = curve.last().map(|p| p.1).unwrap_or(0.0);
    let target = last * frac;
    curve
        .iter()
        .find(|(_, a)| *a >= target)
        .map(|(s, _)| *s)
        .unwrap_or(usize::MAX)
}

fn main() {
    for (model, batch) in [("mlp_mini", 64), ("binarynet_mini", 100)] {
        let mut curves = Vec::new();
        for algo in ["standard", "proposed"] {
            let mut cfg = common::bench_cfg(model, algo, "adam", batch);
            cfg.eval_every_steps = 6;
            cfg.epochs = 4;
            cfg.metrics_path =
                Some(format!("results/fig3_{model}_{algo}.jsonl").into());
            let r = common::run(cfg);
            curves.push((algo, r.metrics.val_curve()));
        }
        // align on step index
        let steps: Vec<usize> = curves[0].1.iter().map(|p| p.0).collect();
        let mut points = Vec::new();
        for (i, &s) in steps.iter().enumerate() {
            let ys = curves
                .iter()
                .map(|(_, c)| c.get(i).map(|p| p.1 as f64 * 100.0))
                .collect();
            points.push((s as f64, ys));
        }
        let md = series_table(
            &format!("Fig. 3/4 — validation accuracy vs step, {model} (B={batch})"),
            "step",
            &["standard %", "proposed %"],
            &points,
            1,
        );
        common::emit(&format!("fig3_{model}.md"), &md);

        let s_std = steps_to_frac(&curves[0].1, 0.9);
        let s_prop = steps_to_frac(&curves[1].1, 0.9);
        let ratio = s_prop as f64 / s_std.max(1) as f64;
        println!(
            "{model}: steps to 90%-of-final acc — std {s_std}, prop {s_prop} \
             (ratio {ratio:.2}; paper: no discernible change)"
        );
    }
}
