//! §Perf multi-tenant bench — the ISSUE-9 headline: N co-scheduled
//! tenants vs the same N time-sliced serially, through the identical
//! [`bnn_edge::serve::MultiModelServer`] stack (only `lanes` differs:
//! 1 = time-sliced serial execution, 2 = co-scheduled).  The win
//! comes from work conservation: while one tenant's quantum is in a
//! serial pack/BN region, another lane drives a second tenant's
//! schedule instead of idling — plus true parallelism for the mini
//! models whose kernels stay below the pool's inline threshold.
//!
//! Emits `BENCH_multi.json`, one row per tenant per run:
//! `{kind, pair, mode, lanes, tenant, p50_us, p99_us, aggregate_qps,
//! fleet_envelope_bytes, measured_bytes, sweeps, contended_sweeps}`
//! (`kind = "pair"` for the serve-pair sweep; `kind = "live"` adds
//! `steps` + `published` for the train-and-serve fleet).  CI gates on
//! co-scheduled aggregate ≥1.5× time-sliced at equal-or-better
//! per-tenant p99 on ≥2 pairs, and `fleet_envelope_bytes ==
//! measured_bytes` on every row (bit-identity to solo runs is pinned
//! separately in rust/tests/multi_tenant.rs).  Flags: `--smoke`,
//! `--out PATH` (default `BENCH_multi.json`).

use std::time::Instant;

use bnn_edge::models::{get, lower};
use bnn_edge::naive::Accel;
use bnn_edge::serve::{MultiModelServer, TenantRole, TenantSpec};
use bnn_edge::util::bench::write_json_rows;
use bnn_edge::util::cli::Args;
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;
use bnn_edge::util::stats::percentile;

struct FleetStats {
    /// Client-observed latencies (µs), per tenant.
    lat_us: Vec<Vec<f64>>,
    aggregate_qps: f64,
    planned_bytes: usize,
    measured_bytes: usize,
    sweeps: u64,
    contended: u64,
    steps: u64,
    published: u64,
}

/// Drive `clients × per_client` closed-loop batch-1 requests per
/// serving tenant (plus `train_steps` fed to tenant 0 when it
/// trains), all concurrently, and return per-tenant latencies.
fn run_fleet(
    specs: &[TenantSpec],
    lanes: usize,
    clients: usize,
    per_client: usize,
    train_steps: usize,
) -> FleetStats {
    let (client, server) = MultiModelServer::new(specs.to_vec(), lanes).unwrap();
    let planned = server.fleet_envelope().unwrap().total_bytes() as usize;
    let sw0 = bnn_edge::bitops::sweep_stats();
    let h = std::thread::spawn(move || server.run());

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (tid, spec) in specs.iter().enumerate() {
        if !spec.role.serves() {
            continue;
        }
        let graph = lower(&get(&spec.model).unwrap()).unwrap();
        for c in 0..clients as u64 {
            let cl = client.clone();
            let (ie, ncl) = (graph.input_elems, graph.classes);
            handles.push(std::thread::spawn(move || {
                let mut rng = Pcg32::new(0x3417 + tid as u64 * 131 + c);
                let x = rng.normal_vec(ie);
                let mut out = vec![0.0f32; ncl];
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let t = Instant::now();
                    cl.infer_one(tid, &x, &mut out).unwrap();
                    lat.push(t.elapsed().as_secs_f64() * 1e6);
                }
                (tid, lat)
            }));
        }
    }
    let feeder = if train_steps > 0 && specs[0].role.trains() {
        let cl = client.clone();
        let graph = lower(&get(&specs[0].model).unwrap()).unwrap();
        let bsz = specs[0].batch;
        Some(std::thread::spawn(move || {
            let mut rng = Pcg32::new(0xbeef);
            for _ in 0..train_steps {
                let x = rng.normal_vec(graph.input_elems * bsz);
                let y: Vec<usize> = (0..bsz).map(|i| (i * 7) % graph.classes).collect();
                cl.train_step(0, &x, &y, 0.01).unwrap();
            }
        }))
    } else {
        None
    };

    let mut lat_us: Vec<Vec<f64>> = vec![Vec::new(); specs.len()];
    let mut total = 0usize;
    for h in handles {
        let (tid, lat) = h.join().unwrap();
        total += lat.len();
        lat_us[tid].extend(lat);
    }
    if let Some(f) = feeder {
        f.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    client.shutdown();
    let tenants = h.join().unwrap().unwrap();
    let sw1 = bnn_edge::bitops::sweep_stats();

    let measured: usize = tenants.iter().map(|t| t.steady_state_bytes()).sum();
    // the acceptance bar: the planned fold prices the measured fleet
    // exactly (trained tenants reach steady state after ≥2 steps)
    if train_steps == 0 || train_steps >= 2 {
        assert_eq!(planned, measured, "fleet envelope != measured steady state");
    }
    FleetStats {
        lat_us,
        aggregate_qps: total as f64 / wall.max(1e-12),
        planned_bytes: planned,
        measured_bytes: measured,
        sweeps: sw1.sweeps - sw0.sweeps,
        contended: sw1.contended - sw0.contended,
        steps: tenants.iter().map(|t| t.steps()).sum(),
        published: tenants.iter().map(|t| t.published()).sum(),
    }
}

fn serve_spec(tid: usize, model: &str) -> TenantSpec {
    let mut s = TenantSpec::new(model, model, TenantRole::Serve);
    s.accel = Accel::Tiled(2);
    s.seed = 5 + tid as u64;
    s.max_batch = 8;
    s.queue_cap = 32;
    s
}

fn main() {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let out_path = args.str_or("out", "BENCH_multi.json");

    let pairs: Vec<(&str, &str)> = if smoke {
        vec![("mlp_mini", "cnv_mini"), ("mlp_mini", "mlp"), ("cnv_mini", "mlp")]
    } else {
        vec![
            ("mlp_mini", "cnv_mini"),
            ("mlp_mini", "mlp"),
            ("cnv_mini", "mlp"),
            ("mlp", "binarynet_mini"),
        ]
    };
    let (clients, per_client) = if smoke { (4, 30) } else { (4, 100) };

    let mut rows = Vec::new();
    for (a, b) in &pairs {
        let pair = format!("{a}+{b}");
        let specs = vec![serve_spec(0, a), serve_spec(1, b)];
        for (mode, lanes) in [("timesliced", 1usize), ("cosched", 2)] {
            let s = run_fleet(&specs, lanes, clients, per_client, 0);
            println!(
                "{mode:>10} {pair:<24} {lanes} lane(s): {:>9.1} req/s  \
                 p99 [{:>7.0}us, {:>7.0}us]  ({} sweeps, {} contended)",
                s.aggregate_qps,
                percentile(&s.lat_us[0], 99.0),
                percentile(&s.lat_us[1], 99.0),
                s.sweeps,
                s.contended
            );
            for (tid, spec) in specs.iter().enumerate() {
                let mut row = Json::obj();
                row.set("kind", Json::from("pair"));
                row.set("pair", Json::from(pair.as_str()));
                row.set("mode", Json::from(mode));
                row.set("lanes", Json::from(lanes));
                row.set("tenant", Json::from(spec.model.as_str()));
                row.set("p50_us", Json::from(percentile(&s.lat_us[tid], 50.0)));
                row.set("p99_us", Json::from(percentile(&s.lat_us[tid], 99.0)));
                row.set("aggregate_qps", Json::from(s.aggregate_qps));
                row.set("fleet_envelope_bytes", Json::from(s.planned_bytes));
                row.set("measured_bytes", Json::from(s.measured_bytes));
                row.set("sweeps", Json::from(s.sweeps as usize));
                row.set("contended_sweeps", Json::from(s.contended as usize));
                rows.push(row);
            }
        }
    }

    // live train-and-serve: tenant 0 trains + publishes while both
    // tenants serve — the envelope assert inside run_fleet covers the
    // trained-tenant fold
    let mut ts = TenantSpec::new("mlp_mini", "mlp_mini", TenantRole::TrainServe);
    ts.accel = Accel::Tiled(2);
    ts.seed = 5;
    ts.batch = 16;
    ts.max_batch = 8;
    ts.queue_cap = 32;
    ts.publish_every = 2;
    let specs = vec![ts, serve_spec(1, "cnv_mini")];
    let train_steps = if smoke { 4 } else { 8 };
    let s = run_fleet(&specs, 2, clients, per_client, train_steps);
    println!(
        "      live mlp_mini(train+serve)+cnv_mini: {:>9.1} req/s  {} steps, {} publishes",
        s.aggregate_qps, s.steps, s.published
    );
    for (tid, spec) in specs.iter().enumerate() {
        let mut row = Json::obj();
        row.set("kind", Json::from("live"));
        row.set("pair", Json::from("mlp_mini+cnv_mini"));
        row.set("mode", Json::from("cosched"));
        row.set("lanes", Json::from(2usize));
        row.set("tenant", Json::from(spec.model.as_str()));
        row.set("p50_us", Json::from(percentile(&s.lat_us[tid], 50.0)));
        row.set("p99_us", Json::from(percentile(&s.lat_us[tid], 99.0)));
        row.set("aggregate_qps", Json::from(s.aggregate_qps));
        row.set("fleet_envelope_bytes", Json::from(s.planned_bytes));
        row.set("measured_bytes", Json::from(s.measured_bytes));
        row.set("sweeps", Json::from(s.sweeps as usize));
        row.set("contended_sweeps", Json::from(s.contended as usize));
        row.set("steps", Json::from(s.steps as usize));
        row.set("published", Json::from(s.published as usize));
        rows.push(row);
    }

    write_json_rows(&out_path, rows).expect("write BENCH_multi.json");
    println!("wrote {out_path}");
}
