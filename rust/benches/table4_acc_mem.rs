//! Table 4 — accuracy + modeled memory for the model/dataset grid,
//! standard vs proposed (Adam, B=100).
//!
//! Paper: Δacc within [−2.1, +0.4] pp; memory 2.78–4.17×, geomean
//! 3.67×.  Reproduction target: small accuracy deltas (|Δ| ≲ few pp)
//! with the same memory factors (full-scale models).

mod common;

use bnn_edge::memmodel::{breakdown, DtypeConfig, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::report::{acc_table, AccRow};
use bnn_edge::util::stats::geomean;
use bnn_edge::util::MIB;

fn main() {
    // (mini model for accuracy, full model for paper-scale memory,
    //  dataset, paper std/prop MiB)
    let grid = [
        ("mlp_mini", "mlp", "syn-mnist64", 7.40, 2.65),
        ("cnv_mini", "cnv", "syn-cifar16", 134.05, 32.16),
        ("cnv_mini", "cnv", "syn-svhn16", 134.05, 32.16),
        ("binarynet_mini", "binarynet", "syn-cifar16", 512.81, 138.15),
        ("binarynet_mini", "binarynet", "syn-svhn16", 512.81, 138.15),
    ];
    let mut rows = Vec::new();
    let mut factors = Vec::new();
    for (mini, full, ds, paper_std, paper_prop) in grid {
        let batch = if mini == "mlp_mini" { 64 } else { 100 };
        let mut cstd = common::bench_cfg(mini, "standard", "adam", batch);
        cstd.dataset = ds.into();
        let mut cprop = common::bench_cfg(mini, "proposed", "adam", batch);
        cprop.dataset = ds.into();
        let rstd = common::run(cstd);
        let rprop = common::run(cprop);

        let g = lower(&get(full).unwrap()).unwrap();
        let smib =
            breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam).total_bytes() / MIB;
        let pmib =
            breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Adam).total_bytes() / MIB;
        factors.push(smib / pmib);
        rows.push(AccRow {
            label: format!("{full}/{ds} standard (paper {paper_std} MiB)"),
            baseline_acc: rstd.best_test_acc,
            acc: rstd.best_test_acc,
            mib: Some(smib),
            mib_factor: None,
        });
        rows.push(AccRow {
            label: format!("{full}/{ds} proposed (paper {paper_prop} MiB)"),
            baseline_acc: rstd.best_test_acc,
            acc: rprop.best_test_acc,
            mib: Some(pmib),
            mib_factor: Some(smib / pmib),
        });
    }
    let md = acc_table("Table 4 — accuracy and modeled memory, std vs proposed", &rows);
    common::emit("table4.md", &md);
    println!(
        "geomean memory reduction: ours {:.2}x (paper 3.67x)",
        geomean(&factors)
    );
}
