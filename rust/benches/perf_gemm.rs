//! §Perf microbenches — the L3 hot paths, swept across every GEMM
//! backend tier (naive / blocked / tiled×threads).
//!
//! Emits `BENCH_gemm.json` (stable schema: `{backend, m, k, n,
//! giops, threads}`, plus `tuned_config`/`tuned_giops` on the tiled
//! rows) so each PR's throughput is diffable against the last — the
//! perf trajectory the CI smoke job archives.  Also times the
//! word-level pack/transpose overheads (the energy model's E_PACK
//! term) and full naive-engine step times (Fig. 7's time axis).
//!
//! Each tiled row is benched twice: fixed dispatch (the deterministic
//! default every run gets) and autotuned dispatch (`tune::Mode::Auto`
//! flipped on just for the second pass) — the tuned-vs-fixed ratio is
//! what CI gates on.  Wide shapes also pack [`BPanels`], exercising
//! the interleaved panel kernel the weight cache feeds the engines.
//!
//! Flags: `--smoke` (quick sampling + trimmed shape set for CI; the
//! acceptance shape is still included so the CI artifact records the
//! tiled-vs-blocked ratio), `--out PATH` (default `BENCH_gemm.json`),
//! `--backends naive,blocked,tiled` (optional subset; tiled uses
//! `--threads`, 0 = auto).

mod common;

use bnn_edge::bitops::{cache, gemm, tune, Backend, BitMatrix, BPanels};
use bnn_edge::data::build;
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine, Accel};
use bnn_edge::util::bench::{black_box, write_json_rows, Bencher};
use bnn_edge::util::cli::Args;
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;

fn main() {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let out_path = args.str_or("out", "BENCH_gemm.json");
    let mut bench = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut g = Pcg32::new(1);

    // default sweep: every tier, tiled at 1/2/4 threads; `--backends`
    // narrows it (names parsed by Backend::parse, tiled honoring
    // `--threads`)
    let backends: Vec<Backend> = match args.get("backends") {
        None => vec![
            Backend::Naive,
            Backend::Blocked,
            Backend::Tiled { threads: 1 },
            Backend::Tiled { threads: 2 },
            Backend::Tiled { threads: 4 },
        ],
        Some(list) => list
            .split(',')
            .map(|s| Backend::parse(s.trim(), args.threads().unwrap_or(0)))
            .collect::<Result<_, _>>()
            .expect("--backends"),
    };

    // Headline first: the ISSUE acceptance shape (BinaryNet fc
    // class) is benched even in smoke mode so the CI artifact always
    // records the tiled-vs-blocked ratio at the shape the acceptance
    // criterion names; full mode adds the fc1/conv-class shapes.
    let shapes: &[(usize, usize, usize, &str)] = if smoke {
        &[
            (256, 4096, 4096, "fc 256x4096x4096"),
            (64, 512, 256, "smoke 64x512x256"),
        ]
    } else {
        &[
            (256, 4096, 4096, "fc 256x4096x4096"),
            (100, 8192, 1024, "fc1 100x8192x1024"),
            (512, 1152, 128, "conv 512x1152x128"),
        ]
    };

    let mut rows: Vec<Json> = Vec::new();
    for &(m, k, n, label) in shapes {
        let a = g.normal_vec(m * k);
        let bt = g.normal_vec(n * k); // already transposed layout
        let ap = BitMatrix::pack(m, k, &a);
        let btp = BitMatrix::pack(n, k, &bt);
        // wide layers get interleaved B panels, as the weight cache
        // would hand the engines
        let panels =
            if cache::panels_worthwhile(n) { Some(BPanels::pack(&btp)) } else { None };
        let mut out = vec![0.0f32; m * n];
        let ops = 2.0 * (m * k * n) as f64;

        let mut blocked_giops = 0.0f64;
        for &be in &backends {
            let r = bench.bench(&format!("xnor {:<9} {label}", be.label()), || {
                be.xnor_gemm_packed(&ap, &btp, panels.as_ref(), &mut out);
                black_box(out[0]);
            });
            let giops = r.giops(ops);
            if be == Backend::Blocked {
                blocked_giops = giops;
            }
            let rel = if blocked_giops > 0.0 {
                format!(" ({:.2}x blocked)", giops / blocked_giops)
            } else {
                String::new()
            };
            println!("  -> {:<9} {label}: {giops:.2} GiOp/s{rel}", be.label());
            let mut row = Json::obj();
            row.set("backend", Json::from(be.name()));
            row.set("m", Json::from(m));
            row.set("k", Json::from(k));
            row.set("n", Json::from(n));
            row.set("giops", Json::from(giops));
            row.set("threads", Json::from(be.threads()));

            // second pass with the autotuner on: first call tunes the
            // shape class on these very buffers, the timed loop then
            // replays the cached winner (only Tiled dispatches tuned)
            if matches!(be, Backend::Tiled { .. }) {
                tune::set_mode(tune::Mode::Auto);
                be.xnor_gemm_packed(&ap, &btp, panels.as_ref(), &mut out);
                let r = bench.bench(&format!("xnor {:<9} {label} tuned", be.label()), || {
                    be.xnor_gemm_packed(&ap, &btp, panels.as_ref(), &mut out);
                    black_box(out[0]);
                });
                let tuned_giops = r.giops(ops);
                let cfg = tune::current_config(
                    m,
                    btp.words_per_row,
                    n,
                    panels.is_some(),
                    be.threads(),
                );
                tune::set_mode(tune::Mode::Fixed);
                println!(
                    "  -> {:<9} {label} tuned [{}]: {tuned_giops:.2} GiOp/s ({:.2}x fixed)",
                    be.label(),
                    cfg.label(),
                    tuned_giops / giops.max(1e-12)
                );
                row.set("tuned_config", Json::from(cfg.label()));
                row.set("tuned_giops", Json::from(tuned_giops));
            }
            rows.push(row);
        }

        // dense f32 comparison (what the standard engine pays) —
        // skipped on the headline shape, where scalar f32 would take
        // tens of seconds per iteration
        if m * k * n <= 1_000_000_000 {
            let b = g.normal_vec(k * n);
            let r = bench.bench(&format!("f32 blocked   {label}"), || {
                gemm::gemm_f32(m, k, n, &a, &b, &mut out);
                black_box(out[0]);
            });
            println!(
                "  -> f32 blocked {label}: {:.2} GFLOP/s",
                r.giops(ops)
            );
        }
    }

    // pack / transpose overhead (the energy model's E_PACK term) —
    // both word-level now
    let (pr, pc) = if smoke { (64, 512) } else { (100, 8192) };
    let xs = g.normal_vec(pr * pc);
    bench.bench(&format!("pack {pr}x{pc}"), || {
        black_box(BitMatrix::pack(pr, pc, &xs));
    });
    let packed = BitMatrix::pack(pr, pc, &xs);
    bench.bench(&format!("bit transpose {pr}x{pc}"), || {
        black_box(packed.transpose());
    });

    // full naive-engine step times (Fig. 7's time axis), now with the
    // tiled backend alongside
    if !smoke {
        for (model, batch) in [("mlp", 100), ("binarynet_mini", 32)] {
            let graph = lower(&get(model).unwrap()).unwrap();
            let ds = build(bnn_edge::config::dataset_for(model), batch, 0, 1).unwrap();
            for (algo, accel, label) in [
                ("standard", Accel::Blocked, "blocked std"),
                ("proposed", Accel::Blocked, "blocked prop"),
                ("proposed", Accel::Tiled(0), "tiled   prop"),
            ] {
                let mut e = build_engine(algo, &graph, batch, "adam", accel, 1).unwrap();
                bench.bench(&format!("step {label} {model} b{batch}"), || {
                    e.train_step(&ds.train_x, &ds.train_y, 0.001).unwrap();
                });
            }
        }
    }

    write_json_rows(&out_path, rows).expect("write BENCH_gemm.json");
    println!("wrote {out_path}");
}
