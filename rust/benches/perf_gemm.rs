//! §Perf microbenches — the L3 hot paths.
//!
//! XNOR-popcount GEMM (naive vs blocked) vs dense f32 GEMM at the
//! paper's layer shapes, plus pack/transpose overheads and the naive
//! engines' full step time.  Results feed EXPERIMENTS.md §Perf.

mod common;

use bnn_edge::bitops::{gemm, BitMatrix};
use bnn_edge::data::build;
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine, Accel};
use bnn_edge::util::bench::{black_box, Bencher};
use bnn_edge::util::rng::Pcg32;

fn main() {
    let mut bench = Bencher::default();
    let mut g = Pcg32::new(1);

    // BinaryNet fc1-class GEMM: (100 x 8192) @ (8192 x 1024)
    // and a conv-class GEMM: (6400 x 1152) @ (1152 x 128)
    for (m, k, n, label) in [
        (100, 8192, 1024, "fc1 100x8192x1024"),
        (512, 1152, 128, "conv 512x1152x128"),
    ] {
        let a = g.normal_vec(m * k);
        let b = g.normal_vec(n * k); // already transposed layout
        let ap = BitMatrix::pack(m, k, &a);
        let btp = BitMatrix::pack(n, k, &b);
        let mut out = vec![0.0f32; m * n];

        bench.bench(&format!("xnor_naive   {label}"), || {
            gemm::xnor_gemm_naive(&ap, &btp, &mut out);
            black_box(out[0]);
        });
        bench.bench(&format!("xnor_blocked {label}"), || {
            gemm::xnor_gemm(&ap, &btp, &mut out);
            black_box(out[0]);
        });
        // dense f32 comparison (what the standard engine pays)
        let bt = g.normal_vec(k * n);
        bench.bench(&format!("f32_blocked  {label}"), || {
            gemm::gemm_f32(m, k, n, &a, &bt, &mut out);
            black_box(out[0]);
        });
        let ops = 2.0 * (m * k * n) as f64;
        let r = bench.results();
        let tx = r[r.len() - 2].median_s();
        let tf = r[r.len() - 1].median_s();
        println!(
            "  -> xnor {:.2} Gop/s, f32 {:.2} GFLOP/s, xnor speedup {:.1}x",
            ops / tx / 1e9,
            ops / tf / 1e9,
            tf / tx
        );
    }

    // pack/unpack overhead (the energy model's E_PACK term)
    let xs = g.normal_vec(100 * 8192);
    bench.bench("pack 100x8192", || {
        black_box(BitMatrix::pack(100, 8192, &xs));
    });

    // full naive-engine step times (Fig. 7's time axis)
    for (model, batch) in [("mlp", 100), ("binarynet_mini", 32)] {
        let graph = lower(&get(model).unwrap()).unwrap();
        let ds = build(bnn_edge::config::dataset_for(model), batch, 0, 1).unwrap();
        for (algo, accel, label) in [
            ("standard", Accel::Blocked, "blocked std"),
            ("proposed", Accel::Blocked, "blocked prop"),
        ] {
            let mut e = build_engine(algo, &graph, batch, "adam", accel, 1).unwrap();
            bench.bench(&format!("step {label} {model} b{batch}"), || {
                e.train_step(&ds.train_x, &ds.train_y, 0.001).unwrap();
            });
        }
    }
}
