//! §Perf serving bench — dynamic batching vs serial batch-1 serving,
//! measured end to end through the [`bnn_edge::serve`] stack.
//!
//! Both modes run the *same* served system (clients → queue →
//! [`BatchServer`] → warmed [`PackedInferEngine`]) under the same
//! closed-loop offered load; the only difference is the batch cap:
//! `max_batch = 1` (serial batch-1, every forward is one request) vs
//! `max_batch = N` (dynamic batching).  That makes the comparison
//! apples to apples: identical sync overhead, identical queueing
//! discipline — the delta is purely what batch coalescing buys the
//! packed XNOR kernels (rows scale with the coalesced batch, so
//! dense-dominated models gain the most: a batch-1 dense GEMM is a
//! single-row panel).
//!
//! Emits `BENCH_serve.json` rows `{mode, engine, model, backend,
//! threads, offered_qps, offered_rps, max_batch, slo_us, p50_us,
//! p99_us, achieved_qps, steady_state_bytes}`.  Two load shapes:
//!
//! - **closed-loop** (`mode = serial|dynamic`): every client fires
//!   its next request the moment the last returns — saturation, so
//!   `offered_rps == achieved_qps` by construction.  CI gates on
//!   `dynamic.achieved_qps >= 3x serial.achieved_qps` at
//!   equal-or-better p99 on the dense models.
//! - **open-loop** (`mode = open`): seeded Poisson arrivals at a
//!   configured `offered_rps` (fractions of the measured closed-loop
//!   saturation), latency measured from each request's *scheduled*
//!   arrival — so queueing delay from falling behind counts against
//!   the server.  This is the p50/p99-vs-load curve a deployment
//!   actually sees below saturation.
//!
//! Flags: `--smoke` (trimmed sweep for CI), `--out PATH` (default
//! `BENCH_serve.json`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine, Accel, Plan, StepEngine};
use bnn_edge::serve::{BatchServer, InferAlgo, PackedInferEngine, WeightSnapshot};
use bnn_edge::util::bench::write_json_rows;
use bnn_edge::util::cli::Args;
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;
use bnn_edge::util::stats::percentile;

struct LoadResult {
    p50_us: f64,
    p99_us: f64,
    qps: f64,
    steady_state_bytes: usize,
}

/// Drive `clients × per_client` closed-loop requests through a served
/// engine capped at `max_batch`; returns client-observed latencies.
#[allow(clippy::too_many_arguments)]
fn run_load(
    graph: &bnn_edge::models::Graph,
    algo: &str,
    accel: Accel,
    max_batch: usize,
    slo_us: u64,
    clients: usize,
    per_client: usize,
    snap: &Arc<WeightSnapshot>,
) -> LoadResult {
    let engine = PackedInferEngine::new(
        graph,
        InferAlgo::parse(algo).unwrap(),
        accel,
        max_batch,
        Arc::clone(snap),
    )
    .unwrap();
    let (batcher, server) = BatchServer::new(engine, slo_us, max_batch.max(4) * 4).unwrap();
    let steady = server.steady_state_bytes();
    let h = std::thread::spawn(move || server.run());

    let ie = graph.input_elems;
    let cl = graph.classes;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients as u64 {
        let b = batcher.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg32::new(0x5e4e + c);
            let x = rng.normal_vec(ie);
            let mut out = vec![0.0f32; cl];
            let mut lat = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let t = Instant::now();
                b.infer_one(&x, &mut out).unwrap();
                lat.push(t.elapsed().as_secs_f64() * 1e6);
            }
            lat
        }));
    }
    let mut lat = Vec::with_capacity(clients * per_client);
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let elapsed = t0.elapsed().as_secs_f64();
    batcher.shutdown();
    h.join().unwrap().unwrap();
    LoadResult {
        p50_us: percentile(&lat, 50.0),
        p99_us: percentile(&lat, 99.0),
        qps: lat.len() as f64 / elapsed.max(1e-12),
        steady_state_bytes: steady,
    }
}

/// Drive open-loop load: each client draws seeded Poisson
/// interarrivals (`-ln(1-u)/rate`) totalling `offered_rps` across
/// `clients`, sleeps until each scheduled arrival, and measures
/// latency from that schedule — late departures accrue queueing
/// delay instead of silently thinning the offered load.
#[allow(clippy::too_many_arguments)]
fn run_open_load(
    graph: &bnn_edge::models::Graph,
    algo: &str,
    accel: Accel,
    max_batch: usize,
    slo_us: u64,
    clients: usize,
    per_client: usize,
    snap: &Arc<WeightSnapshot>,
    offered_rps: f64,
) -> LoadResult {
    let engine = PackedInferEngine::new(
        graph,
        InferAlgo::parse(algo).unwrap(),
        accel,
        max_batch,
        Arc::clone(snap),
    )
    .unwrap();
    let (batcher, server) = BatchServer::new(engine, slo_us, max_batch.max(4) * 4).unwrap();
    let steady = server.steady_state_bytes();
    let h = std::thread::spawn(move || server.run());

    let ie = graph.input_elems;
    let cl = graph.classes;
    let rate = offered_rps / clients as f64; // per-client arrival rate
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients as u64 {
        let b = batcher.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg32::new(0xa11 + c);
            let x = rng.normal_vec(ie);
            let mut out = vec![0.0f32; cl];
            let mut lat = Vec::with_capacity(per_client);
            let start = Instant::now();
            let mut next_s = 0.0f64; // scheduled arrival, s after start
            for _ in 0..per_client {
                let u = rng.next_f32() as f64;
                next_s += -(1.0 - u).ln() / rate;
                let now = start.elapsed().as_secs_f64();
                if next_s > now {
                    std::thread::sleep(Duration::from_secs_f64(next_s - now));
                }
                b.infer_one(&x, &mut out).unwrap();
                lat.push((start.elapsed().as_secs_f64() - next_s) * 1e6);
            }
            lat
        }));
    }
    let mut lat = Vec::with_capacity(clients * per_client);
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let elapsed = t0.elapsed().as_secs_f64();
    batcher.shutdown();
    h.join().unwrap().unwrap();
    LoadResult {
        p50_us: percentile(&lat, 50.0),
        p99_us: percentile(&lat, 99.0),
        qps: lat.len() as f64 / elapsed.max(1e-12),
        steady_state_bytes: steady,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let out_path = args.str_or("out", "BENCH_serve.json");

    // dense models lead: batch-1 dense GEMMs are single-row panels,
    // the case dynamic batching exists for (and the CI gate's models)
    let models: Vec<&str> = if smoke {
        vec!["mlp_mini", "mlp"]
    } else {
        vec!["mlp_mini", "mlp", "cnv_mini", "binarynet_mini"]
    };
    let backends: Vec<(Accel, &str, usize)> = if smoke {
        vec![(Accel::Tiled(2), "tiled", 2)]
    } else {
        vec![(Accel::Blocked, "blocked", 1), (Accel::Tiled(2), "tiled", 2)]
    };
    let (clients, per_client) = if smoke { (4, 60) } else { (8, 200) };
    let (max_batch, slo_us) = (8usize, 200u64);

    let mut rows = Vec::new();
    for model in &models {
        let graph = lower(&get(model).unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        for (accel, bname, threads) in &backends {
            for algo in ["standard", "proposed"] {
                let trainer = build_engine(algo, &graph, 1, "adam", *accel, 13).unwrap();
                let snap = Arc::new(
                    WeightSnapshot::pack(&plan, &trainer.weights_snapshot(), 0).unwrap(),
                );
                drop(trainer);
                let make_row = |mode: &str, mb: usize, offered_rps: f64, r: &LoadResult| {
                    let mut row = Json::obj();
                    row.set("mode", Json::from(mode));
                    row.set("engine", Json::from(algo));
                    row.set("model", Json::from(*model));
                    row.set("backend", Json::from(*bname));
                    row.set("threads", Json::from(*threads));
                    row.set("offered_qps", Json::from(offered_rps));
                    row.set("offered_rps", Json::from(offered_rps));
                    row.set("max_batch", Json::from(mb));
                    row.set("slo_us", Json::from(slo_us as usize));
                    row.set("p50_us", Json::from(r.p50_us));
                    row.set("p99_us", Json::from(r.p99_us));
                    row.set("achieved_qps", Json::from(r.qps));
                    row.set("steady_state_bytes", Json::from(r.steady_state_bytes));
                    row
                };
                let mut saturation = 0.0f64;
                for (mode, mb) in [("serial", 1usize), ("dynamic", max_batch)] {
                    let r = run_load(
                        &graph, algo, *accel, mb, slo_us, clients, per_client, &snap,
                    );
                    println!(
                        "{mode:>7} {algo:>8} {model} {bname} t{threads} mb{mb}: \
                         {:>9.1} req/s  p50 {:>7.1}us  p99 {:>7.1}us  ({:.2} MiB)",
                        r.qps,
                        r.p50_us,
                        r.p99_us,
                        r.steady_state_bytes as f64 / bnn_edge::util::MIB
                    );
                    if mode == "dynamic" {
                        saturation = r.qps;
                    }
                    // closed loop: offered == achieved by construction
                    rows.push(make_row(mode, mb, r.qps, &r));
                }
                // open loop at fractions of the measured saturation:
                // the p50/p99-vs-offered-load curve
                let fractions: &[f64] = if smoke { &[0.5] } else { &[0.25, 0.5, 0.75] };
                for &f in fractions {
                    let offered = saturation * f;
                    let r = run_open_load(
                        &graph, algo, *accel, max_batch, slo_us, clients, per_client,
                        &snap, offered,
                    );
                    println!(
                        "   open {algo:>8} {model} {bname} t{threads} @{:>8.1} rps: \
                         {:>9.1} req/s  p50 {:>7.1}us  p99 {:>7.1}us",
                        offered, r.qps, r.p50_us, r.p99_us
                    );
                    rows.push(make_row("open", max_batch, offered, &r));
                }
            }
        }
    }
    write_json_rows(&out_path, rows).expect("write BENCH_serve.json");
    println!("wrote {out_path}");
}
