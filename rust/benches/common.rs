//! Shared helpers for the paper-reproduction benches.
//!
//! Every `rust/benches/*.rs` target regenerates one table or figure
//! of the paper; each prints the paper's value next to ours and
//! writes the rendered table to `results/`.
//!
//! Accuracy runs are scaled (mini models, synthetic data, few epochs)
//! — the *deltas and orderings* are the reproduction target, not
//! absolute accuracy.  See DESIGN.md §Substitutions.

#![allow(dead_code)]

use bnn_edge::coordinator::{EngineKind, RunConfig, RunResult, Runner};

/// Scaled run used by the accuracy benches (~70-90 HLO steps — BNNs
/// converge more slowly than their NN references, so runs must be
/// long enough for the binary nets to leave the noise floor).
pub fn bench_cfg(model: &str, algo: &str, opt: &str, batch: usize) -> RunConfig {
    RunConfig {
        model: model.into(),
        algo: algo.into(),
        optimizer: opt.into(),
        dataset: bnn_edge::config::dataset_for(model).into(),
        batch,
        epochs: 6,
        n_train: 1200,
        n_test: 400,
        eval_every_steps: 12,
        lr: if opt == "sgd" { 0.05 } else { 0.002 },
        engine: EngineKind::Hlo,
        seed: 42,
        ..Default::default()
    }
}

pub fn run(cfg: RunConfig) -> RunResult {
    let label = format!(
        "{} {} {} b{}",
        cfg.model, cfg.algo, cfg.optimizer, cfg.batch
    );
    let t0 = std::time::Instant::now();
    let mut runner = Runner::new(cfg).unwrap_or_else(|e| panic!("{label}: {e}"));
    let r = runner.run().unwrap_or_else(|e| panic!("{label}: {e}"));
    eprintln!(
        "  [{label}] best acc {:.1}% in {:.1}s ({} steps)",
        r.best_test_acc * 100.0,
        t0.elapsed().as_secs_f64(),
        r.steps
    );
    r
}

/// Print + persist a rendered section.
pub fn emit(file: &str, md: &str) {
    println!("{md}");
    bnn_edge::report::write_section(format!("results/{file}"), md)
        .expect("write results/");
}
