//! §Perf whole-step bench — the planned-arena training step, swept
//! across the zoo, both engines, backends and microbatch settings.
//!
//! Measures what the step-arena work actually delivers:
//!
//! - `steps_per_sec` — end-to-end training-step throughput (forward +
//!   backward + update; the steady state is allocation-free, so this
//!   is pure kernel time after the warmup step);
//! - `steady_state_bytes` — the **measured** resident footprint after
//!   warmup: `state_bytes()` (weights, momenta, accumulators, packed
//!   weight cache) + `arena_bytes()` (the recycled step pool);
//! - `envelope_bytes` — `memmodel::step_envelope`'s planned twin,
//!   now a pure fold over the compiled schedule and therefore exact:
//!   CI fails on *any* divergence from the measured steady state;
//! - `colored_arena_bytes` / `uncolored_arena_bytes` / `slots` — the
//!   schedule compiler's interval-colored slot table vs the old
//!   per-pass best-fit baseline.  CI fails if coloring ever regresses
//!   above the uncolored baseline for any zoo model.
//!
//! Emits `BENCH_step.json` (stable schema: `{engine, model, backend,
//! threads, batch, microbatch, steps_per_sec, steady_state_bytes,
//! envelope_bytes, colored_arena_bytes, uncolored_arena_bytes,
//! slots}`, plus `tuned_config`/`tuned_steps_per_sec` on tiled rows —
//! the whole-step tuned-vs-fixed ratio, with `tuned_config`
//! summarizing how many GEMM shape classes the step tuned).  Flags:
//! `--smoke` (trimmed sweep for CI), `--out PATH` (default
//! `BENCH_step.json`).

use bnn_edge::bitops::tune;
use bnn_edge::memmodel::{step_envelope, Optimizer};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{build_engine_micro, schedule, Accel, Plan};
use bnn_edge::util::bench::{write_json_rows, Bencher};
use bnn_edge::util::cli::Args;
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;

fn main() {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let out_path = args.str_or("out", "BENCH_step.json");
    let mut bench = if smoke { Bencher::quick() } else { Bencher::default() };

    // (model, batch, microbatches to sweep)
    let sweep: Vec<(&str, usize, Vec<usize>)> = if smoke {
        vec![
            ("cnv_mini", 16, vec![0, 4]),
            ("binarynet_mini", 16, vec![0, 4]),
        ]
    } else {
        vec![
            ("mlp_mini", 64, vec![0, 16]),
            ("cnv_mini", 32, vec![0, 8]),
            ("binarynet_mini", 64, vec![0, 16]),
            ("bireal_mini", 16, vec![0, 4]),
            ("resnete_mini", 16, vec![0, 4]),
        ]
    };
    let backends: Vec<(Accel, &str, usize)> = if smoke {
        vec![(Accel::Tiled(1), "tiled", 1), (Accel::Tiled(2), "tiled", 2)]
    } else {
        vec![
            (Accel::Blocked, "blocked", 1),
            (Accel::Tiled(1), "tiled", 1),
            (Accel::Tiled(2), "tiled", 2),
        ]
    };

    let mut rows = Vec::new();
    let mut rng = Pcg32::new(7);
    for (model, batch, micros) in &sweep {
        let batch = *batch;
        let graph = lower(&get(model).unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let x = rng.normal_vec(batch * graph.input_elems);
        let y: Vec<usize> = (0..batch).map(|i| i % graph.classes).collect();
        for micro in micros {
            for (accel, bname, threads) in &backends {
                for algo in ["standard", "proposed"] {
                    let mut e = build_engine_micro(
                        algo, &graph, batch, *micro, "adam", *accel, 1,
                    )
                    .unwrap();
                    // two warmup steps populate the arena pool (one
                    // reaches the fixed point on these traces; the
                    // second is margin), and the footprint is sampled
                    // *after* the bench loop so any residual growth
                    // during the timed steps is captured
                    e.train_step(&x, &y, 0.001).unwrap();
                    e.train_step(&x, &y, 0.001).unwrap();
                    let label = format!(
                        "{algo:>8} {model} b{batch} m{} {bname} t{threads}",
                        if *micro == 0 { batch } else { *micro }
                    );
                    let r = bench.bench(&label, || {
                        e.train_step(&x, &y, 0.001).unwrap();
                    });
                    let sps = 1.0 / r.median_s();
                    let steady = e.state_bytes() + e.arena_bytes();
                    let env = step_envelope(&graph, algo, Optimizer::Adam, batch, *micro)
                        .unwrap();
                    // the compiled slot table behind arena_bytes()
                    // (blocked/tiled share one choreography: naive=false)
                    let m = if *micro == 0 { batch } else { *micro };
                    let sched =
                        schedule::compile_step(&plan, algo, false, m, batch / m).unwrap();
                    let mut row = Json::obj();
                    row.set("engine", Json::from(algo));
                    row.set("model", Json::from(*model));
                    row.set("backend", Json::from(*bname));
                    row.set("threads", Json::from(*threads));
                    row.set("batch", Json::from(batch));
                    row.set(
                        "microbatch",
                        Json::from(if *micro == 0 { batch } else { *micro }),
                    );
                    row.set("steps_per_sec", Json::from(sps));
                    row.set("steady_state_bytes", Json::from(steady));
                    row.set("envelope_bytes", Json::from(env.total_bytes()));
                    row.set("colored_arena_bytes", Json::from(sched.arena_bytes()));
                    row.set("uncolored_arena_bytes", Json::from(sched.uncolored_bytes));
                    row.set("slots", Json::from(sched.slot_count()));

                    // tiled rows: re-bench the same engine with the
                    // autotuner on (one warmup step tunes every GEMM
                    // shape class the step touches, then the timed
                    // steps replay the cached winners)
                    if matches!(accel, Accel::Tiled(_)) {
                        let before = tune::len();
                        tune::set_mode(tune::Mode::Auto);
                        e.train_step(&x, &y, 0.001).unwrap();
                        let r = bench.bench(&format!("{label} tuned"), || {
                            e.train_step(&x, &y, 0.001).unwrap();
                        });
                        let tuned_sps = 1.0 / r.median_s();
                        tune::set_mode(tune::Mode::Fixed);
                        row.set(
                            "tuned_config",
                            Json::from(format!("auto({} shapes)", tune::len() - before)),
                        );
                        row.set("tuned_steps_per_sec", Json::from(tuned_sps));
                        println!(
                            "    tuned: {tuned_sps:.2} steps/s ({:.2}x fixed)",
                            tuned_sps / sps.max(1e-12)
                        );
                    }
                    rows.push(row);
                }
            }
        }
    }
    write_json_rows(&out_path, rows).expect("write BENCH_step.json");
    println!("wrote {out_path}");
}
