//! §Perf conv microbench — the end-to-end packed conv pipeline,
//! swept across model-zoo conv shapes (now including the strided
//! ResNet stem/stage geometries) and every GEMM backend tier.
//!
//! **Forward** (default): two pipelines per shape —
//!
//! - **fused**: `bitops::im2col_packed` signs+packs patches straight
//!   into bit panels (pool-threaded), then the XNOR GEMM — zero f32
//!   im2col bytes on the binary path;
//! - **`tiled-im2col`** (the PR-1 baseline): f32 `im2col`, then
//!   `BitMatrix::pack`, then the same tiled XNOR GEMM.
//!
//! Emits `BENCH_conv.json` (stable schema: `{backend, layer, h, w,
//! cin, cout, kside, stride, pad, batch, giops, threads,
//! im2col_f32_bytes}`, plus `tuned_config`/`tuned_giops` on tiled
//! forward rows) via `util::bench::write_json_rows`; `giops`
//! counts the conv GEMM ops (2·B·OH·OW·k²·Cin·Cout) over the *whole*
//! pipeline time, so im2col overheads depress it honestly.
//! `im2col_f32_bytes` records the transient f32 buffer each variant
//! materializes (0 = fused).
//!
//! **Backward** (`--backward`): the conv backward pipelines —
//!
//! - **fused**: `conv_dx_streaming` (tap-streamed dX, no rows×k
//!   `dcols`) + `im2col_packed` → `packed_at_gemm_f32` dW +
//!   `subtract_pad_dw_contrib`;
//! - **`tiled-im2col`** (the pre-fusion baseline): Ŵᵀ unpack → f32
//!   dcols GEMM → col2im, then sign → f32 im2col → transpose → dW
//!   GEMM.
//!
//! Emits `BENCH_conv_bwd.json` (same key, with `dcols_f32_bytes`);
//! `giops` counts both backward GEMMs (4·B·OH·OW·k²·Cin·Cout) over
//! the pipeline time, and fused rows carry `dcols_f32_bytes: 0`.
//!
//! Flags: `--smoke` (quick sampling + trimmed sweep for CI; keeps the
//! fused-vs-baseline pair the acceptance criterion needs),
//! `--backward`, `--out PATH` (default `BENCH_conv.json` /
//! `BENCH_conv_bwd.json`).

use bnn_edge::bitops::{
    conv_dx_streaming, im2col_packed, packed_at_gemm_f32, simd, subtract_pad_dw_contrib,
    tune, Backend, BitMatrix, ConvGeom,
};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{col2im, im2col, transpose, LayerPlan, Plan};
use bnn_edge::util::bench::{black_box, write_json_rows, Bencher};
use bnn_edge::util::cli::Args;
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;

struct Shape {
    layer: String,
    batch: usize,
    g: ConvGeom,
    cout: usize,
}

/// Non-first conv layers of the zoo models, deduped by geometry.
fn zoo_shapes(models: &[(&str, usize)]) -> Vec<Shape> {
    let mut out: Vec<Shape> = Vec::new();
    for &(model, batch) in models {
        let plan = Plan::from_graph(&lower(&get(model).unwrap()).unwrap()).unwrap();
        for (li, l) in plan.layers.iter().enumerate() {
            if let LayerPlan::Conv { g, cout, first: false } = *l {
                if out.iter().any(|s| (s.g, s.cout, s.batch) == (g, cout, batch)) {
                    continue;
                }
                out.push(Shape { layer: format!("{model}/conv{li}"), batch, g, cout });
            }
        }
    }
    out
}

/// Strided ResNet stem/stage geometries (reduced spatial scale so the
/// smoke sweep stays CI-sized; full 224-class maps only differ by a
/// constant spatial factor on these kernels).
fn strided_shapes(smoke: bool) -> Vec<Shape> {
    let mut out = vec![
        // stem-like: k7 s2 SAME over a real-input-sized channel count
        // is first-layer territory; the binary stage-entry convs are
        // the packed-path shapes — k3 s2 SAME, channels doubling
        Shape {
            layer: "resnet/stage2_entry".into(),
            batch: 8,
            g: ConvGeom::same(16, 16, 64, 3, 2),
            cout: 128,
        },
        Shape {
            layer: "resnet/stage3_entry".into(),
            batch: 8,
            g: ConvGeom::same(8, 8, 128, 3, 2),
            cout: 256,
        },
    ];
    if !smoke {
        out.push(Shape {
            layer: "resnet/stem_k7s2".into(),
            batch: 4,
            g: ConvGeom::same(32, 32, 16, 7, 2),
            cout: 64,
        });
        out.push(Shape {
            layer: "cnv/valid_s1".into(),
            batch: 8,
            g: ConvGeom::valid(30, 30, 64, 3, 1),
            cout: 64,
        });
    }
    out
}

fn push_row(
    rows: &mut Vec<Json>,
    backend: &str,
    s: &Shape,
    giops: f64,
    threads: usize,
    bytes_field: &str,
    bytes: usize,
) {
    let mut row = Json::obj();
    row.set("backend", Json::from(backend));
    row.set("layer", Json::from(s.layer.as_str()));
    row.set("h", Json::from(s.g.h));
    row.set("w", Json::from(s.g.w));
    row.set("cin", Json::from(s.g.cin));
    row.set("cout", Json::from(s.cout));
    row.set("kside", Json::from(s.g.kside));
    row.set("stride", Json::from(s.g.stride));
    // VALID iff the output dims satisfy the unpadded formula with no
    // pad — pad-0 SAME geometries (e.g. k3 s2 on even dims) still
    // overhang the bottom/right and must report "same".  The kside
    // bound keeps the subtraction safe for kernel-exceeds-map SAME
    // geometries.
    let valid = !s.g.padded()
        && s.g.kside <= s.g.h
        && s.g.kside <= s.g.w
        && s.g.oh == (s.g.h - s.g.kside) / s.g.stride + 1
        && s.g.ow == (s.g.w - s.g.kside) / s.g.stride + 1;
    row.set("pad", Json::from(if valid { "valid" } else { "same" }));
    row.set("batch", Json::from(s.batch));
    row.set("giops", Json::from(giops));
    row.set("threads", Json::from(threads));
    row.set(bytes_field, Json::from(bytes));
    rows.push(row);
}

fn main() {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let backward = args.bool("backward");
    let out_path =
        args.str_or("out", if backward { "BENCH_conv_bwd.json" } else { "BENCH_conv.json" });
    let mut bench = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut g = Pcg32::new(2);
    println!("simd level: {}", simd::label());

    // CNN zoo sweep: small CIFAR-class nets always; the full
    // BinaryNet conv stack only off-smoke (seconds per backend)
    let models: &[(&str, usize)] = if smoke {
        &[("cnv_mini", 8), ("binarynet_mini", 8), ("resnete_mini", 8)]
    } else {
        &[("cnv_mini", 8), ("binarynet_mini", 8), ("resnete_mini", 8), ("binarynet", 2)]
    };
    let mut shapes = zoo_shapes(models);
    shapes.extend(strided_shapes(smoke));

    // fused tiers: serial ones plus tiled across thread counts
    let backends: Vec<Backend> = if smoke {
        vec![Backend::Blocked, Backend::Tiled { threads: 2 }, Backend::Tiled { threads: 4 }]
    } else {
        vec![
            Backend::Naive,
            Backend::Blocked,
            Backend::Tiled { threads: 1 },
            Backend::Tiled { threads: 2 },
            Backend::Tiled { threads: 4 },
        ]
    };

    let mut rows: Vec<Json> = Vec::new();
    for s in &shapes {
        let (b, geom, cout) = (s.batch, s.g, s.cout);
        let k = geom.k();
        let orows = geom.rows(b);
        let x = g.normal_vec(geom.in_len(b));
        let wt_f = g.normal_vec(cout * k); // transposed (cout × k) layout
        let wt = BitMatrix::pack(cout, k, &wt_f);
        let label = format!(
            "{} b{b} {}x{}x{}->{cout} k{} s{}",
            s.layer, geom.h, geom.w, geom.cin, geom.kside, geom.stride
        );

        if backward {
            // conv backward: dX (streaming col2im) + dW (packed-A GEMM
            // + pad correction) — two GEMMs' worth of work
            let ops = 4.0 * (orows * k * cout) as f64;
            let dy = g.normal_vec(orows * cout);
            for &be in &backends {
                let pool = be.pool();
                let r = bench.bench(&format!("conv bwd fused {:<9} {label}", be.label()), || {
                    let dx = conv_dx_streaming(&dy, &wt, b, geom, be);
                    let xh = im2col_packed(&x, b, geom, &pool);
                    let mut dw = vec![0.0f32; k * cout];
                    packed_at_gemm_f32(&xh, &dy, cout, &mut dw, &pool);
                    subtract_pad_dw_contrib(&mut dw, &dy, b, geom, cout);
                    black_box(dx[0] + dw[0]);
                });
                let giops = r.giops(ops);
                println!("  -> bwd fused {:<9} {label}: {giops:.2} GiOp/s", be.label());
                push_row(&mut rows, be.name(), s, giops, be.threads(), "dcols_f32_bytes", 0);
            }
            // pre-fusion baseline: f32 dcols + col2im, f32 im2col +
            // transpose + dW GEMM (the PR-2 backward)
            for threads in [2usize, 4] {
                let be = Backend::Tiled { threads };
                let r = bench.bench(&format!("conv bwd im2col tiled({threads}) {label}"), || {
                    let wt_dense = wt.unpack();
                    let mut dcols = vec![0.0f32; orows * k];
                    be.gemm_f32(orows, cout, k, &dy, &wt_dense, &mut dcols);
                    let dx = col2im(&dcols, b, geom);
                    let xhat: Vec<f32> =
                        x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
                    let cols = im2col(&xhat, b, geom);
                    let colst = transpose(&cols, orows, k);
                    let mut dw = vec![0.0f32; k * cout];
                    be.gemm_f32(k, orows, cout, &colst, &dy, &mut dw);
                    black_box(dx[0] + dw[0]);
                });
                let base_giops = r.giops(ops);
                println!("  -> bwd im2col tiled({threads}) {label}: {base_giops:.2} GiOp/s");
                push_row(
                    &mut rows,
                    "tiled-im2col",
                    s,
                    base_giops,
                    threads,
                    "dcols_f32_bytes",
                    orows * k * 4,
                );
            }
            continue;
        }

        let ops = 2.0 * (orows * k * cout) as f64;
        let mut y = vec![0.0f32; orows * cout];

        // fused pipeline per backend tier; tiled tiers are benched a
        // second time with the autotuner on (the conv GEMM is the
        // tuner-dispatched stage), adding tuned_config/tuned_giops —
        // backward rows skip this, their GEMMs bypass the tuner
        for &be in &backends {
            let pool = be.pool();
            let r = bench.bench(&format!("conv fused {:<9} {label}", be.label()), || {
                let xh = im2col_packed(&x, b, geom, &pool);
                be.xnor_gemm(&xh, &wt, &mut y);
                black_box(y[0]);
            });
            let giops = r.giops(ops);
            println!("  -> fused {:<9} {label}: {giops:.2} GiOp/s", be.label());
            push_row(&mut rows, be.name(), s, giops, be.threads(), "im2col_f32_bytes", 0);

            if matches!(be, Backend::Tiled { .. }) {
                tune::set_mode(tune::Mode::Auto);
                let xh = im2col_packed(&x, b, geom, &pool);
                be.xnor_gemm(&xh, &wt, &mut y); // first call tunes the shape class
                let r = bench.bench(&format!("conv fused {:<9} {label} tuned", be.label()), || {
                    let xh = im2col_packed(&x, b, geom, &pool);
                    be.xnor_gemm(&xh, &wt, &mut y);
                    black_box(y[0]);
                });
                let tuned_giops = r.giops(ops);
                let cfg =
                    tune::current_config(orows, wt.words_per_row, cout, false, be.threads());
                tune::set_mode(tune::Mode::Fixed);
                println!(
                    "  -> fused {:<9} {label} tuned [{}]: {tuned_giops:.2} GiOp/s ({:.2}x fixed)",
                    be.label(),
                    cfg.label(),
                    tuned_giops / giops.max(1e-12)
                );
                let row = rows.last_mut().unwrap();
                row.set("tuned_config", Json::from(cfg.label()));
                row.set("tuned_giops", Json::from(tuned_giops));
            }
        }

        // PR-1 baseline: f32 im2col + pack + the same tiled GEMM
        for threads in [2usize, 4] {
            let be = Backend::Tiled { threads };
            let r = bench.bench(&format!("conv im2col tiled({threads}) {label}"), || {
                let cols = im2col(&x, b, geom);
                let xh = BitMatrix::pack(orows, k, &cols);
                be.xnor_gemm(&xh, &wt, &mut y);
                black_box(y[0]);
            });
            let base_giops = r.giops(ops);
            let fused = rows.iter().rev().find(|row| {
                let txt = |key: &str| row.req(key).ok().and_then(|v| v.as_str().ok());
                let num = |key: &str| row.req(key).ok().and_then(|v| v.as_f64().ok());
                txt("backend") == Some("tiled")
                    && txt("layer") == Some(s.layer.as_str())
                    && num("threads") == Some(threads as f64)
            });
            if let Some(f) = fused {
                let fg = f.req("giops").unwrap().as_f64().unwrap();
                println!(
                    "  -> tiled({threads}) fused/im2col ratio {label}: {:.2}x",
                    fg / base_giops
                );
            }
            push_row(
                &mut rows,
                "tiled-im2col",
                s,
                base_giops,
                threads,
                "im2col_f32_bytes",
                orows * k * 4,
            );
        }
    }

    write_json_rows(&out_path, rows).expect("write conv bench json");
    println!("wrote {out_path}");
}
