//! §Perf conv microbench — the end-to-end packed conv pipeline,
//! swept across model-zoo conv shapes and every GEMM backend tier.
//!
//! Two pipelines per shape:
//!
//! - **fused** (this PR): `bitops::im2col_packed` signs+packs patches
//!   straight into bit panels (pool-threaded), then the XNOR GEMM —
//!   zero f32 im2col bytes on the binary path;
//! - **`tiled-im2col`** (the PR-1 baseline): f32 `im2col`, then
//!   `BitMatrix::pack`, then the same tiled XNOR GEMM — the
//!   acceptance criterion diffs fused `tiled` rows against these.
//!
//! Emits `BENCH_conv.json` (stable schema: `{backend, layer, h, w,
//! cin, cout, kside, batch, giops, threads, im2col_f32_bytes}`) via
//! `util::bench::write_json_rows`; `giops` counts the conv GEMM ops
//! (2·B·H·W·k²·Cin·Cout) over the *whole* pipeline time, so im2col
//! overheads depress it honestly.  `im2col_f32_bytes` records the
//! transient f32 buffer each variant materializes (0 = fused).
//!
//! Flags: `--smoke` (quick sampling + trimmed sweep for CI; keeps the
//! fused-vs-baseline pair the acceptance criterion needs), `--out
//! PATH` (default `BENCH_conv.json`).

use bnn_edge::bitops::{im2col_packed, simd, Backend, BitMatrix};
use bnn_edge::models::{get, lower};
use bnn_edge::naive::{im2col, LayerPlan, Plan};
use bnn_edge::util::bench::{black_box, write_json_rows, Bencher};
use bnn_edge::util::cli::Args;
use bnn_edge::util::json::Json;
use bnn_edge::util::rng::Pcg32;

struct Shape {
    layer: String,
    batch: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    kside: usize,
}

/// Non-first conv layers of the zoo models, deduped by geometry.
fn zoo_shapes(models: &[(&str, usize)]) -> Vec<Shape> {
    let mut out: Vec<Shape> = Vec::new();
    for &(model, batch) in models {
        let plan = Plan::from_graph(&lower(&get(model).unwrap()).unwrap()).unwrap();
        for (li, l) in plan.layers.iter().enumerate() {
            if let LayerPlan::Conv { h, w, cin, cout, kside, first: false } = *l {
                if out.iter().any(|s| {
                    (s.h, s.w, s.cin, s.cout, s.kside, s.batch) == (h, w, cin, cout, kside, batch)
                }) {
                    continue;
                }
                out.push(Shape {
                    layer: format!("{model}/conv{li}"),
                    batch,
                    h,
                    w,
                    cin,
                    cout,
                    kside,
                });
            }
        }
    }
    out
}

fn push_row(
    rows: &mut Vec<Json>,
    backend: &str,
    s: &Shape,
    giops: f64,
    threads: usize,
    im2col_f32_bytes: usize,
) {
    let mut row = Json::obj();
    row.set("backend", Json::from(backend));
    row.set("layer", Json::from(s.layer.as_str()));
    row.set("h", Json::from(s.h));
    row.set("w", Json::from(s.w));
    row.set("cin", Json::from(s.cin));
    row.set("cout", Json::from(s.cout));
    row.set("kside", Json::from(s.kside));
    row.set("batch", Json::from(s.batch));
    row.set("giops", Json::from(giops));
    row.set("threads", Json::from(threads));
    row.set("im2col_f32_bytes", Json::from(im2col_f32_bytes));
    rows.push(row);
}

fn main() {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let out_path = args.str_or("out", "BENCH_conv.json");
    let mut bench = if smoke { Bencher::quick() } else { Bencher::default() };
    let mut g = Pcg32::new(2);
    println!("simd level: {}", simd::label());

    // CNN zoo sweep: small CIFAR-class nets always; the full
    // BinaryNet conv stack only off-smoke (seconds per backend)
    let models: &[(&str, usize)] = if smoke {
        &[("cnv_mini", 8), ("binarynet_mini", 8)]
    } else {
        &[("cnv_mini", 8), ("binarynet_mini", 8), ("binarynet", 2)]
    };
    let shapes = zoo_shapes(models);

    // fused tiers: serial ones plus tiled across thread counts
    let backends: Vec<Backend> = if smoke {
        vec![Backend::Blocked, Backend::Tiled { threads: 2 }, Backend::Tiled { threads: 4 }]
    } else {
        vec![
            Backend::Naive,
            Backend::Blocked,
            Backend::Tiled { threads: 1 },
            Backend::Tiled { threads: 2 },
            Backend::Tiled { threads: 4 },
        ]
    };

    let mut rows: Vec<Json> = Vec::new();
    for s in &shapes {
        let (b, h, w, cin, cout, kside) = (s.batch, s.h, s.w, s.cin, s.cout, s.kside);
        let k = kside * kside * cin;
        let orows = b * h * w;
        let ops = 2.0 * (orows * k * cout) as f64;
        let x = g.normal_vec(b * h * w * cin);
        let wt_f = g.normal_vec(cout * k); // transposed (cout × k) layout
        let wt = BitMatrix::pack(cout, k, &wt_f);
        let mut y = vec![0.0f32; orows * cout];
        let label = format!("{} b{b} {h}x{w}x{cin}->{cout} k{kside}", s.layer);

        // fused pipeline per backend tier
        for &be in &backends {
            let pool = be.pool();
            let r = bench.bench(&format!("conv fused {:<9} {label}", be.label()), || {
                let xh = im2col_packed(&x, b, h, w, cin, kside, &pool);
                be.xnor_gemm(&xh, &wt, &mut y);
                black_box(y[0]);
            });
            let giops = r.giops(ops);
            println!("  -> fused {:<9} {label}: {giops:.2} GiOp/s", be.label());
            push_row(&mut rows, be.name(), s, giops, be.threads(), 0);
        }

        // PR-1 baseline: f32 im2col + pack + the same tiled GEMM
        for threads in [2usize, 4] {
            let be = Backend::Tiled { threads };
            let r = bench.bench(&format!("conv im2col tiled({threads}) {label}"), || {
                let cols = im2col(&x, b, h, w, cin, kside);
                let xh = BitMatrix::pack(orows, k, &cols);
                be.xnor_gemm(&xh, &wt, &mut y);
                black_box(y[0]);
            });
            let base_giops = r.giops(ops);
            let fused = rows.iter().rev().find(|row| {
                let txt = |key: &str| row.req(key).ok().and_then(|v| v.as_str().ok());
                let num = |key: &str| row.req(key).ok().and_then(|v| v.as_f64().ok());
                txt("backend") == Some("tiled")
                    && txt("layer") == Some(s.layer.as_str())
                    && num("threads") == Some(threads as f64)
            });
            if let Some(f) = fused {
                let fg = f.req("giops").unwrap().as_f64().unwrap();
                println!(
                    "  -> tiled({threads}) fused/im2col ratio {label}: {:.2}x",
                    fg / base_giops
                );
            }
            push_row(&mut rows, "tiled-im2col", s, base_giops, threads, orows * k * 4);
        }
    }

    write_json_rows(&out_path, rows).expect("write BENCH_conv.json");
    println!("wrote {out_path}");
}
