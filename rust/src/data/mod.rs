//! Synthetic edge datasets.
//!
//! The paper trains on MNIST, CIFAR-10, SVHN and ImageNet; none are
//! redistributable inside this offline image, so we synthesize
//! class-conditional image distributions with the properties the
//! experiments actually exercise (DESIGN.md §Substitutions):
//!
//! - models are near-chance at init and must genuinely learn;
//! - a held-out test split measures generalization, not memorization;
//! - difficulty is controlled (noise + intra-class deformation), so
//!   the *relative* accuracy of training algorithms is meaningful;
//! - generation is deterministic in the seed (reproducible tables).
//!
//! Generator: each class owns `protos_per_class` latent prototype
//! images built from oriented sinusoidal gratings + blob mixtures
//! (digit-ish strokes for the MNIST-like sets); a sample picks a
//! prototype, applies a random shift/flip deformation, then adds
//! pixel noise.

use anyhow::{bail, Result};

use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Per-sample shape, `[h, w, c]` or `[feat]`.
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<usize>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<usize>,
}

impl Dataset {
    pub fn sample_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    /// One-hot encode labels for a batch slice.
    pub fn one_hot(&self, labels: &[usize]) -> Vec<f32> {
        let mut out = vec![0.0; labels.len() * self.classes];
        for (i, &l) in labels.iter().enumerate() {
            out[i * self.classes + l] = 1.0;
        }
        out
    }
}

/// Batch iterator with epoch shuffling.
pub struct Batches<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl<'a> Batches<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, rng: &mut Pcg32) -> Batches<'a> {
        let mut order: Vec<usize> = (0..ds.n_train()).collect();
        rng.shuffle(&mut order);
        Batches { ds, order, batch, pos: 0 }
    }

    /// Next (x, labels) batch; `None` at epoch end.  Short final
    /// batches are dropped (fixed-shape AOT executables).
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<(Vec<f32>, Vec<usize>)> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let k = self.ds.sample_elems();
        let mut x = Vec::with_capacity(self.batch * k);
        let mut y = Vec::with_capacity(self.batch);
        for &i in &self.order[self.pos..self.pos + self.batch] {
            x.extend_from_slice(&self.ds.train_x[i * k..(i + 1) * k]);
            y.push(self.ds.train_y[i]);
        }
        self.pos += self.batch;
        Some((x, y))
    }
}

/// Catalog of synthetic stand-ins (name → paper dataset).
pub fn catalog() -> &'static [(&'static str, &'static str)] {
    &[
        ("syn-mnist", "MNIST (28x28x1, strokes)"),
        ("syn-mnist64", "MNIST downscaled to the mlp_mini 64-feat input"),
        ("syn-cifar10", "CIFAR-10 (32x32x3, textures)"),
        ("syn-cifar16", "CIFAR-10 downscaled for *_mini models (16x16x3)"),
        ("syn-svhn", "SVHN (32x32x3, digit-ish on clutter)"),
        ("syn-svhn16", "SVHN downscaled for *_mini models (16x16x3)"),
        ("syn-imagenet16", "ImageNet surrogate for residual minis (16x16x3)"),
    ]
}

/// Build a dataset by name.  `n_train`/`n_test` samples, seeded.
pub fn build(name: &str, n_train: usize, n_test: usize, seed: u64) -> Result<Dataset> {
    let (shape, classes, noise, flat): (Vec<usize>, usize, f32, bool) = match name {
        "syn-mnist" => (vec![28, 28, 1], 10, 0.25, true),
        "syn-mnist64" => (vec![8, 8, 1], 10, 0.20, true),
        "syn-cifar10" => (vec![32, 32, 3], 10, 0.45, false),
        "syn-cifar16" => (vec![16, 16, 3], 10, 0.40, false),
        "syn-svhn" => (vec![32, 32, 3], 10, 0.35, false),
        "syn-svhn16" => (vec![16, 16, 3], 10, 0.30, false),
        "syn-imagenet16" => (vec![16, 16, 3], 10, 0.50, false),
        _ => bail!("unknown dataset '{name}' (see data::catalog())"),
    };
    let mut g = Pcg32::with_stream(seed, hash_name(name));
    let gen = ClassGen::new(&mut g, &shape, classes);
    let (train_x, train_y) = gen.sample_split(&mut g, n_train, noise);
    let (test_x, test_y) = gen.sample_split(&mut g, n_test, noise);
    let input_shape = if flat {
        vec![shape.iter().product()]
    } else {
        shape
    };
    Ok(Dataset {
        name: name.into(),
        input_shape,
        classes,
        train_x,
        train_y,
        test_x,
        test_y,
    })
}

fn hash_name(s: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h | 1
}

struct ClassGen {
    h: usize,
    w: usize,
    c: usize,
    protos: Vec<Vec<f32>>, // classes * protos_per_class images
    per_class: usize,
    classes: usize,
}

impl ClassGen {
    fn new(g: &mut Pcg32, shape: &[usize], classes: usize) -> ClassGen {
        let (h, w, c) = (shape[0], shape[1], shape[2]);
        let per_class = 4;
        let mut protos = Vec::with_capacity(classes * per_class);
        for class in 0..classes {
            for _ in 0..per_class {
                protos.push(Self::proto(g, h, w, c, class, classes));
            }
        }
        ClassGen { h, w, c, protos, per_class, classes }
    }

    /// A prototype: 2 oriented gratings + 3 gaussian blobs, with
    /// class-dependent orientation/frequency/polarity so classes are
    /// separable but overlapping (non-trivial task).
    fn proto(g: &mut Pcg32, h: usize, w: usize, c: usize, class: usize, classes: usize) -> Vec<f32> {
        let mut img = vec![0.0f32; h * w * c];
        let base_angle = class as f32 / classes as f32 * std::f32::consts::PI;
        for grating in 0..2 {
            let angle = base_angle + g.uniform(-0.2, 0.2) + grating as f32 * 0.7;
            let freq = 0.5 + (class % 5) as f32 * 0.35 + g.uniform(-0.1, 0.1);
            let (sa, ca) = angle.sin_cos();
            let phase = g.uniform(0.0, std::f32::consts::TAU);
            let chan_w: Vec<f32> = (0..c).map(|_| g.uniform(0.3, 1.0)).collect();
            for y in 0..h {
                for x in 0..w {
                    let t = (x as f32 * ca + y as f32 * sa) * freq + phase;
                    let v = t.sin() * 0.6;
                    for ch in 0..c {
                        img[(y * w + x) * c + ch] += v * chan_w[ch];
                    }
                }
            }
        }
        for _ in 0..3 {
            let (cx, cy) = (g.uniform(0.2, 0.8) * w as f32, g.uniform(0.2, 0.8) * h as f32);
            let sig = g.uniform(1.0, 2.5 + (class % 3) as f32);
            let amp = g.uniform(-1.0, 1.0) * if class % 2 == 0 { 1.0 } else { -1.0 };
            let chan = g.below(c);
            for y in 0..h {
                for x in 0..w {
                    let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                    img[(y * w + x) * c + chan] += amp * (-d2 / (2.0 * sig * sig)).exp();
                }
            }
        }
        img
    }

    fn sample_split(&self, g: &mut Pcg32, n: usize, noise: f32) -> (Vec<f32>, Vec<usize>) {
        let k = self.h * self.w * self.c;
        let mut xs = Vec::with_capacity(n * k);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let class = i % self.classes;
            let proto = &self.protos[class * self.per_class + g.below(self.per_class)];
            // deform: circular shift up to ±2 px each axis, h-flip
            let (dx, dy) = (g.below(5) as isize - 2, g.below(5) as isize - 2);
            let flip = g.next_f32() < 0.5;
            for y in 0..self.h {
                for x in 0..self.w {
                    let sx0 = if flip { self.w - 1 - x } else { x } as isize;
                    let sx = (sx0 + dx).rem_euclid(self.w as isize) as usize;
                    let sy = (y as isize + dy).rem_euclid(self.h as isize) as usize;
                    for ch in 0..self.c {
                        let v = proto[(sy * self.w + sx) * self.c + ch]
                            + noise * g.normal();
                        xs.push(v);
                    }
                }
            }
            ys.push(class);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let a = build("syn-mnist64", 64, 16, 7).unwrap();
        let b = build("syn-mnist64", 64, 16, 7).unwrap();
        assert_eq!(a.train_x, b.train_x);
        let c = build("syn-mnist64", 64, 16, 8).unwrap();
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn shapes_and_counts() {
        let d = build("syn-cifar16", 100, 20, 1).unwrap();
        assert_eq!(d.input_shape, vec![16, 16, 3]);
        assert_eq!(d.train_x.len(), 100 * 16 * 16 * 3);
        assert_eq!(d.n_test(), 20);
        let d = build("syn-mnist", 10, 5, 1).unwrap();
        assert_eq!(d.input_shape, vec![784]); // flattened for the MLP
    }

    #[test]
    fn classes_balanced() {
        let d = build("syn-svhn16", 100, 0, 2).unwrap();
        for cls in 0..10 {
            assert_eq!(d.train_y.iter().filter(|&&y| y == cls).count(), 10);
        }
    }

    #[test]
    fn classes_are_separable_by_nearest_prototype() {
        // sanity: 1-NN on class means beats chance by a wide margin,
        // so a real model can learn this task
        let d = build("syn-cifar16", 400, 100, 3).unwrap();
        let k = d.sample_elems();
        let mut means = vec![vec![0.0f64; k]; d.classes];
        let mut counts = vec![0usize; d.classes];
        for i in 0..d.n_train() {
            let c = d.train_y[i];
            counts[c] += 1;
            for j in 0..k {
                means[c][j] += d.train_x[i * k + j] as f64;
            }
        }
        for c in 0..d.classes {
            for j in 0..k {
                means[c][j] /= counts[c] as f64;
            }
        }
        let mut correct = 0;
        for i in 0..d.n_test() {
            let x = &d.test_x[i * k..(i + 1) * k];
            let mut best = (f64::INFINITY, 0);
            for c in 0..d.classes {
                let dist: f64 = x
                    .iter()
                    .zip(&means[c])
                    .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.test_y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n_test() as f64;
        assert!(acc > 0.35, "1-NN acc {acc} barely above chance");
        assert!(acc < 1.0, "task should not be trivial");
    }

    #[test]
    fn one_hot() {
        let d = build("syn-mnist64", 4, 0, 1).unwrap();
        let oh = d.one_hot(&[0, 3]);
        assert_eq!(oh.len(), 20);
        assert_eq!(oh[0], 1.0);
        assert_eq!(oh[13], 1.0);
        assert_eq!(oh.iter().sum::<f32>(), 2.0);
    }

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let d = build("syn-mnist64", 50, 0, 1).unwrap();
        let mut g = Pcg32::new(9);
        let mut it = Batches::new(&d, 16, &mut g);
        let mut n = 0;
        while let Some((x, y)) = it.next() {
            assert_eq!(x.len(), 16 * d.sample_elems());
            assert_eq!(y.len(), 16);
            n += 16;
        }
        assert_eq!(n, 48); // 50 -> 3 full batches, tail dropped
    }

    #[test]
    fn unknown_name_errors() {
        assert!(build("mnist", 1, 1, 0).is_err());
    }
}
