//! Artifact manifest: the positional I/O contract emitted by aot.py.

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::json::Json;

use super::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoKind {
    Param,
    Opt,
    X,
    Y,
    Lr,
    Metric,
}

impl IoKind {
    fn parse(s: &str) -> Result<IoKind> {
        Ok(match s {
            "param" => IoKind::Param,
            "opt" => IoKind::Opt,
            "x" => IoKind::X,
            "y" => IoKind::Y,
            "lr" => IoKind::Lr,
            "metric" => IoKind::Metric,
            _ => bail!("unknown io kind '{s}'"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: IoKind,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct GoldenInfo {
    pub file: String,
    pub sections: Vec<(usize, usize)>, // (offset_f32, len_f32)
    pub n_inputs: usize,
    pub n_outputs: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub model: String,
    pub algo: String,
    pub optimizer: Option<String>,
    pub kind: String, // "train" | "eval"
    pub batch: usize,
    pub classes: usize,
    pub input_shape: Vec<usize>,
    pub use_pallas: bool,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub golden: Option<GoldenInfo>,
}

fn parse_specs(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()?
        .iter()
        .map(|o| {
            Ok(IoSpec {
                name: o.req("name")?.as_str()?.to_string(),
                shape: o
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                kind: IoKind::parse(o.req("kind")?.as_str()?)?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path, name: &str) -> Result<Manifest> {
        let path = dir.join(format!("{name}.meta.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let golden = match j.get("golden") {
            Some(Json::Null) | None => None,
            Some(g) => Some(GoldenInfo {
                file: g.req("file")?.as_str()?.to_string(),
                sections: g
                    .req("sections")?
                    .as_arr()?
                    .iter()
                    .map(|s| {
                        Ok((
                            s.req("offset")?.as_usize()?,
                            s.req("len")?.as_usize()?,
                        ))
                    })
                    .collect::<Result<_>>()?,
                n_inputs: g.req("n_inputs")?.as_usize()?,
                n_outputs: g.req("n_outputs")?.as_usize()?,
            }),
        };
        Ok(Manifest {
            name: j.req("name")?.as_str()?.to_string(),
            model: j.req("model")?.as_str()?.to_string(),
            algo: j.req("algo")?.as_str()?.to_string(),
            optimizer: match j.get("optimizer") {
                Some(Json::Str(s)) => Some(s.clone()),
                _ => None,
            },
            kind: j.req("kind")?.as_str()?.to_string(),
            batch: j.req("batch")?.as_usize()?,
            classes: j.req("classes")?.as_usize()?,
            input_shape: j
                .req("input_shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            use_pallas: j.req("use_pallas")?.as_bool()?,
            inputs: parse_specs(j.req("inputs")?)?,
            outputs: parse_specs(j.req("outputs")?)?,
            golden,
        })
    }

    /// Indices of inputs of a given kind (e.g. all params).
    pub fn input_indices(&self, kind: IoKind) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.kind == kind)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }

    pub fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "artifact '{}' wants {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.inputs) {
            if t.shape != spec.shape {
                bail!(
                    "input '{}' shape mismatch: manifest {:?}, got {:?}",
                    spec.name,
                    spec.shape,
                    t.shape
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "m_std_adam_b4", "model": "m", "algo": "standard",
      "optimizer": "adam", "kind": "train", "batch": 4, "classes": 10,
      "input_shape": [8], "use_pallas": false,
      "inputs": [
        {"name": "w0", "shape": [8, 10], "kind": "param"},
        {"name": "beta0", "shape": [10], "kind": "param"},
        {"name": "t", "shape": [], "kind": "opt"},
        {"name": "x", "shape": [4, 8], "kind": "x"},
        {"name": "y", "shape": [4, 10], "kind": "y"},
        {"name": "lr", "shape": [], "kind": "lr"}
      ],
      "outputs": [
        {"name": "loss", "shape": [], "kind": "metric"},
        {"name": "acc", "shape": [], "kind": "metric"}
      ],
      "golden": null
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "m_std_adam_b4");
        assert_eq!(m.batch, 4);
        assert_eq!(m.inputs.len(), 6);
        assert_eq!(m.input_indices(IoKind::Param), vec![0, 1]);
        assert_eq!(m.input_indices(IoKind::Lr), vec![5]);
        assert_eq!(m.output_index("acc"), Some(1));
        assert!(m.golden.is_none());
        assert_eq!(m.inputs[0].numel(), 80);
    }

    #[test]
    fn check_inputs_validates() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mk = |shape: &[usize]| Tensor::zeros(shape);
        let good = vec![
            mk(&[8, 10]),
            mk(&[10]),
            mk(&[]),
            mk(&[4, 8]),
            mk(&[4, 10]),
            mk(&[]),
        ];
        assert!(m.check_inputs(&good).is_ok());
        let mut bad = good.clone();
        bad[0] = mk(&[8, 11]);
        assert!(m.check_inputs(&bad).is_err());
        assert!(m.check_inputs(&good[..5]).is_err());
    }
}
