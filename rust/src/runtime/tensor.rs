//! Dense f32 tensor: the marshalling type at the HLO boundary.
//!
//! All artifact I/O is f32 (reduced precision is emulated *inside*
//! the HLO and realized by the naive engine); a shape + flat Vec is
//! all the coordinator needs.

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Scalar extraction (loss/acc outputs).
    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor with {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Into an xla Literal with this shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            return Ok(lit.reshape(&[])?);
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    /// From an xla Literal (f32), imposing the manifest shape.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
        let data = lit
            .to_vec::<f32>()
            .context("literal is not f32 — manifest/HLO mismatch")?;
        Tensor::new(shape.to_vec(), data)
    }

    /// Max |a - b| across two tensors (golden comparisons).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(2.5).item().unwrap(), 2.5);
        assert!(Tensor::zeros(&[2]).item().is_err());
    }

    #[test]
    fn zeros_and_len() {
        let t = Tensor::zeros(&[4, 8]);
        assert_eq!(t.len(), 32);
        assert_eq!(t.rank(), 2);
        assert!(t.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
