//! Runtime: load and execute AOT-compiled HLO train/eval steps.
//!
//! The bridge pattern (from /opt/xla-example/load_hlo):
//!   `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `client.compile` → `execute`.
//!
//! HLO *text* is the interchange format — jax ≥ 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.  Python never runs at request time: after
//! `make artifacts` the Rust binary is self-contained.

mod golden;
mod manifest;
mod tensor;

pub use golden::Golden;
pub use manifest::{IoKind, IoSpec, Manifest};
pub use tensor::Tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

/// One compiled artifact: manifest + PJRT executable.
pub struct Artifact {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute the step.  `inputs` must match the manifest order and
    /// shapes exactly (checked).  Returns outputs in manifest order.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.manifest.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?;
        // aot.py lowers with return_tuple=True: one tuple of N outputs.
        let tuple = first.to_literal_sync()?.to_tuple()?;
        if tuple.len() != self.manifest.outputs.len() {
            bail!(
                "artifact '{}': {} outputs returned, manifest says {}",
                self.manifest.name,
                tuple.len(),
                self.manifest.outputs.len()
            );
        }
        tuple
            .into_iter()
            .zip(&self.manifest.outputs)
            .map(|(lit, spec)| Tensor::from_literal(&lit, &spec.shape))
            .collect()
    }

    pub fn name(&self) -> &str {
        &self.manifest.name
    }
}

/// PJRT engine: one CPU client + a compiled-executable cache keyed by
/// artifact name (compilation of a BinaryNet step takes seconds; the
/// sweep benches reuse executables heavily).
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Artifact>>>,
}

impl Engine {
    /// CPU engine rooted at an artifacts directory.
    pub fn cpu<P: AsRef<Path>>(artifacts_dir: P) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            bail!(
                "artifacts directory '{}' not found — run `make artifacts`",
                dir.display()
            );
        }
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
            dir,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Names of all artifacts present (from index.json if available,
    /// else a directory scan).
    pub fn available(&self) -> Result<Vec<String>> {
        let idx = self.dir.join("index.json");
        if idx.exists() {
            let text = std::fs::read_to_string(&idx)?;
            let v = crate::util::json::Json::parse(&text)?;
            return v
                .as_arr()?
                .iter()
                .map(|j| Ok(j.as_str()?.to_string()))
                .collect();
        }
        let mut names = Vec::new();
        for e in std::fs::read_dir(&self.dir)? {
            let p = e?.path();
            if let Some(n) = p.file_name().and_then(|s| s.to_str()) {
                if let Some(base) = n.strip_suffix(".meta.json") {
                    names.push(base.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }

    /// Load (or fetch cached) a compiled artifact by name.
    pub fn load(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let manifest = Manifest::load(&self.dir, name)
            .with_context(|| format!("loading manifest for '{name}'"))?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text '{}'", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of '{name}'"))?;
        let artifact = Arc::new(Artifact { manifest, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Drop cached executables (memory-envelope experiments).
    pub fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    /// Load the golden record for an artifact (if it has one).
    pub fn golden(&self, name: &str) -> Result<Golden> {
        let manifest = Manifest::load(&self.dir, name)?;
        Golden::load(&self.dir, &manifest)
    }
}
