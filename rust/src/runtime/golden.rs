//! Golden records: fixed-seed step inputs/outputs dumped by aot.py as
//! flat little-endian f32.  The Rust runtime must reproduce the
//! outputs bit-for-bit-ish (<= 1e-5) — this is the cross-language
//! numerical contract between L2 (JAX) and L3.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;
use super::Tensor;

pub struct Golden {
    pub inputs: Vec<Tensor>,
    pub outputs: Vec<Tensor>,
}

impl Golden {
    pub fn load(dir: &Path, manifest: &Manifest) -> Result<Golden> {
        let info = manifest
            .golden
            .as_ref()
            .with_context(|| format!("artifact '{}' has no golden", manifest.name))?;
        let raw = std::fs::read(dir.join(&info.file))?;
        if raw.len() % 4 != 0 {
            bail!("golden blob length {} not a multiple of 4", raw.len());
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        if info.sections.len() != info.n_inputs + info.n_outputs {
            bail!("golden section count mismatch");
        }
        let specs: Vec<&super::IoSpec> = manifest
            .inputs
            .iter()
            .chain(manifest.outputs.iter())
            .collect();
        if specs.len() != info.sections.len() {
            bail!(
                "golden sections ({}) != manifest io count ({})",
                info.sections.len(),
                specs.len()
            );
        }

        let mut tensors = Vec::with_capacity(specs.len());
        for (spec, &(off, len)) in specs.iter().zip(&info.sections) {
            if spec.numel() != len {
                bail!(
                    "golden section for '{}' has {} elements, shape {:?} wants {}",
                    spec.name,
                    len,
                    spec.shape,
                    spec.numel()
                );
            }
            let data = floats
                .get(off..off + len)
                .context("golden section out of range")?
                .to_vec();
            tensors.push(Tensor::new(spec.shape.clone(), data)?);
        }
        let outputs = tensors.split_off(info.n_inputs);
        Ok(Golden { inputs: tensors, outputs })
    }
}
