//! Per-step packed-weight cache.
//!
//! Binarized weights are constant *within* a training step: the
//! forward binary matmul, the backward dX matmul and (for the
//! standard engine) the dW matmul all consume the same Ŵ.  The
//! engines previously re-derived the packed/sign representation on
//! every matmul call; this cache packs each layer once per step and
//! is invalidated when the optimizer writes new weights, so the
//! amortized pack cost drops to one pack per layer per step — the
//! invariant the pack-count probe in the engine tests pins down.
//!
//! Two layouts are cached per layer, both lazily:
//! - `w`  — packed Ŵ   (k×n), what the standard engine's forward uses;
//! - `wt` — packed Ŵᵀ  (n×k), what the XNOR GEMM and the dX matmul
//!   use.  It can be packed directly (the proposed engine packs
//!   straight from f16 sign bits) or derived from a cached `w` by the
//!   word-level block transpose (not counted as a new pack).

use super::BitMatrix;

#[derive(Debug, Default)]
pub struct PackedWeightCache {
    w: Vec<Option<BitMatrix>>,
    wt: Vec<Option<BitMatrix>>,
    packs: usize,
}

impl PackedWeightCache {
    pub fn new(layers: usize) -> PackedWeightCache {
        PackedWeightCache {
            w: (0..layers).map(|_| None).collect(),
            wt: (0..layers).map(|_| None).collect(),
            packs: 0,
        }
    }

    pub fn layers(&self) -> usize {
        self.w.len()
    }

    /// Cached packed Ŵ for layer `wi`, packing via `pack` on miss.
    pub fn w(&mut self, wi: usize, pack: impl FnOnce() -> BitMatrix) -> &BitMatrix {
        if self.w[wi].is_none() {
            self.w[wi] = Some(pack());
            self.packs += 1;
        }
        self.w[wi].as_ref().unwrap()
    }

    /// Cached packed Ŵᵀ for layer `wi`, packing via `pack_t` on miss.
    pub fn wt(&mut self, wi: usize, pack_t: impl FnOnce() -> BitMatrix) -> &BitMatrix {
        if self.wt[wi].is_none() {
            self.wt[wi] = Some(pack_t());
            self.packs += 1;
        }
        self.wt[wi].as_ref().unwrap()
    }

    /// Cached packed Ŵᵀ derived from (possibly cached) Ŵ by block
    /// transpose; `pack_w` fills Ŵ on a double miss.  The transpose
    /// is word-level and does not count as a pack.
    pub fn wt_via_transpose(
        &mut self,
        wi: usize,
        pack_w: impl FnOnce() -> BitMatrix,
    ) -> &BitMatrix {
        if self.wt[wi].is_none() {
            if self.w[wi].is_none() {
                self.w[wi] = Some(pack_w());
                self.packs += 1;
            }
            self.wt[wi] = Some(self.w[wi].as_ref().unwrap().transpose());
        }
        self.wt[wi].as_ref().unwrap()
    }

    /// Drop layer `wi`'s cached representations (its weights changed).
    pub fn invalidate(&mut self, wi: usize) {
        self.w[wi] = None;
        self.wt[wi] = None;
    }

    /// Drop everything (end-of-step bulk update / snapshot load).
    pub fn invalidate_all(&mut self) {
        for e in self.w.iter_mut().chain(self.wt.iter_mut()) {
            *e = None;
        }
    }

    /// Total packs performed since construction — the probe the
    /// once-per-step tests assert on.
    pub fn pack_count(&self) -> usize {
        self.packs
    }

    /// Live cached bytes (for memory accounting).
    pub fn heap_bytes(&self) -> usize {
        self.w
            .iter()
            .chain(self.wt.iter())
            .flatten()
            .map(BitMatrix::heap_bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn packs_once_until_invalidated() {
        let mut g = Pcg32::new(12);
        let xs = g.normal_vec(6 * 70);
        let mut c = PackedWeightCache::new(2);
        for _ in 0..3 {
            let m = c.wt(0, || BitMatrix::pack(6, 70, &xs));
            assert_eq!(m.rows, 6);
        }
        assert_eq!(c.pack_count(), 1);
        c.invalidate(0);
        c.wt(0, || BitMatrix::pack(6, 70, &xs));
        assert_eq!(c.pack_count(), 2);
        assert!(c.heap_bytes() > 0);
        c.invalidate_all();
        assert_eq!(c.heap_bytes(), 0);
    }

    #[test]
    fn wt_via_transpose_reuses_w_and_counts_no_extra_pack() {
        let mut g = Pcg32::new(13);
        let xs = g.normal_vec(9 * 33);
        let mut c = PackedWeightCache::new(1);
        let w = c.w(0, || BitMatrix::pack(9, 33, &xs)).clone();
        let wt = c.wt_via_transpose(0, || panic!("w already cached")).clone();
        assert_eq!(c.pack_count(), 1);
        assert_eq!(wt, w.transpose());
        // double miss packs exactly once
        let mut c2 = PackedWeightCache::new(1);
        let wt2 = c2.wt_via_transpose(0, || BitMatrix::pack(9, 33, &xs)).clone();
        assert_eq!(c2.pack_count(), 1);
        assert_eq!(wt2, wt);
    }
}
