//! Per-step packed-weight cache.
//!
//! Binarized weights are constant *within* a training step: the
//! forward binary matmul, the backward dX matmul and (for the
//! standard engine) the dW matmul all consume the same Ŵ.  The
//! engines previously re-derived the packed/sign representation on
//! every matmul call; this cache packs each layer once per step and
//! is invalidated when the optimizer writes new weights, so the
//! amortized pack cost drops to one pack per layer per step — the
//! invariant the pack-count probe in the engine tests pins down.
//!
//! Since the step-arena work, invalidation marks entries *stale
//! without dropping their storage*: the next pack rewrites the same
//! word buffers in place, so steady-state training steps repack
//! weights with **zero heap allocations** (the fill closures write
//! via `BitMatrix::pack_into` / `transpose_into`).
//!
//! Two layouts are cached per layer, both lazily:
//! - `w`  — packed Ŵ   (k×n), what the standard engine's forward uses;
//! - `wt` — packed Ŵᵀ  (n×k), what the XNOR GEMM and the dX matmul
//!   use.  It can be packed directly (the proposed engine packs
//!   straight from f16 sign bits) or derived from a cached `w` by the
//!   word-level block transpose (not counted as a new pack).

use super::BitMatrix;

#[derive(Debug, Default)]
pub struct PackedWeightCache {
    w: Vec<BitMatrix>,
    w_valid: Vec<bool>,
    wt: Vec<BitMatrix>,
    wt_valid: Vec<bool>,
    packs: usize,
}

fn empty() -> BitMatrix {
    BitMatrix { rows: 0, cols: 0, words_per_row: 0, data: Vec::new() }
}

impl PackedWeightCache {
    pub fn new(layers: usize) -> PackedWeightCache {
        PackedWeightCache {
            w: (0..layers).map(|_| empty()).collect(),
            w_valid: vec![false; layers],
            wt: (0..layers).map(|_| empty()).collect(),
            wt_valid: vec![false; layers],
            packs: 0,
        }
    }

    pub fn layers(&self) -> usize {
        self.w.len()
    }

    /// Cached packed Ŵ for layer `wi`; on a miss `fill` rewrites the
    /// retained storage in place (use `BitMatrix::pack_into`).
    pub fn w(&mut self, wi: usize, fill: impl FnOnce(&mut BitMatrix)) -> &BitMatrix {
        if !self.w_valid[wi] {
            fill(&mut self.w[wi]);
            self.w_valid[wi] = true;
            self.packs += 1;
        }
        &self.w[wi]
    }

    /// Cached packed Ŵᵀ for layer `wi`; `fill_t` rewrites in place on
    /// a miss.
    pub fn wt(&mut self, wi: usize, fill_t: impl FnOnce(&mut BitMatrix)) -> &BitMatrix {
        if !self.wt_valid[wi] {
            fill_t(&mut self.wt[wi]);
            self.wt_valid[wi] = true;
            self.packs += 1;
        }
        &self.wt[wi]
    }

    /// Cached packed Ŵᵀ derived from (possibly cached) Ŵ by block
    /// transpose; `fill_w` fills Ŵ on a double miss.  The transpose
    /// is word-level and does not count as a pack.
    pub fn wt_via_transpose(
        &mut self,
        wi: usize,
        fill_w: impl FnOnce(&mut BitMatrix),
    ) -> &BitMatrix {
        if !self.wt_valid[wi] {
            if !self.w_valid[wi] {
                fill_w(&mut self.w[wi]);
                self.w_valid[wi] = true;
                self.packs += 1;
            }
            self.w[wi].transpose_into(&mut self.wt[wi]);
            self.wt_valid[wi] = true;
        }
        &self.wt[wi]
    }

    /// Mark layer `wi` stale (its weights changed).  Storage is
    /// retained for the in-place repack.
    pub fn invalidate(&mut self, wi: usize) {
        self.w_valid[wi] = false;
        self.wt_valid[wi] = false;
    }

    /// Mark everything stale (end-of-step bulk update / snapshot load).
    pub fn invalidate_all(&mut self) {
        self.w_valid.fill(false);
        self.wt_valid.fill(false);
    }

    /// Total packs performed since construction — the probe the
    /// once-per-step tests assert on.
    pub fn pack_count(&self) -> usize {
        self.packs
    }

    /// Resident cached bytes (storage persists across invalidation —
    /// that persistence is what makes steady-state repacks free).
    pub fn heap_bytes(&self) -> usize {
        self.w.iter().chain(self.wt.iter()).map(BitMatrix::heap_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn packs_once_until_invalidated() {
        let mut g = Pcg32::new(12);
        let xs = g.normal_vec(6 * 70);
        let mut c = PackedWeightCache::new(2);
        for _ in 0..3 {
            let m = c.wt(0, |dst| BitMatrix::pack_into(6, 70, &xs, dst));
            assert_eq!(m.rows, 6);
        }
        assert_eq!(c.pack_count(), 1);
        c.invalidate(0);
        c.wt(0, |dst| BitMatrix::pack_into(6, 70, &xs, dst));
        assert_eq!(c.pack_count(), 2);
        assert!(c.heap_bytes() > 0);
        // invalidation keeps the storage resident for in-place repacks
        let resident = c.heap_bytes();
        c.invalidate_all();
        assert_eq!(c.heap_bytes(), resident);
    }

    #[test]
    fn repack_after_invalidate_reuses_storage() {
        let mut g = Pcg32::new(14);
        let xs = g.normal_vec(9 * 128);
        let ys = g.normal_vec(9 * 128);
        let mut c = PackedWeightCache::new(1);
        c.w(0, |dst| BitMatrix::pack_into(9, 128, &xs, dst));
        let cap0 = c.heap_bytes();
        c.invalidate(0);
        let m = c.w(0, |dst| BitMatrix::pack_into(9, 128, &ys, dst)).clone();
        assert_eq!(c.heap_bytes(), cap0, "same storage, no growth");
        assert_eq!(m, BitMatrix::pack(9, 128, &ys), "repack sees new weights");
    }

    #[test]
    fn wt_via_transpose_reuses_w_and_counts_no_extra_pack() {
        let mut g = Pcg32::new(13);
        let xs = g.normal_vec(9 * 33);
        let mut c = PackedWeightCache::new(1);
        let w = c.w(0, |dst| BitMatrix::pack_into(9, 33, &xs, dst)).clone();
        let wt = c.wt_via_transpose(0, |_| panic!("w already cached")).clone();
        assert_eq!(c.pack_count(), 1);
        assert_eq!(wt, w.transpose());
        // double miss packs exactly once
        let mut c2 = PackedWeightCache::new(1);
        let wt2 = c2
            .wt_via_transpose(0, |dst| BitMatrix::pack_into(9, 33, &xs, dst))
            .clone();
        assert_eq!(c2.pack_count(), 1);
        assert_eq!(wt2, wt);
    }
}
