//! Per-step packed-weight cache.
//!
//! Binarized weights are constant *within* a training step: the
//! forward binary matmul, the backward dX matmul and (for the
//! standard engine) the dW matmul all consume the same Ŵ.  The
//! engines previously re-derived the packed/sign representation on
//! every matmul call; this cache packs each layer once per step and
//! is invalidated when the optimizer writes new weights, so the
//! amortized pack cost drops to one pack per layer per step — the
//! invariant the pack-count probe in the engine tests pins down.
//!
//! Since the step-arena work, invalidation marks entries *stale
//! without dropping their storage*: the next pack rewrites the same
//! word buffers in place, so steady-state training steps repack
//! weights with **zero heap allocations** (the fill closures write
//! via `BitMatrix::pack_into` / `transpose_into`).
//!
//! Two layouts are cached per layer, both lazily:
//! - `w`  — packed Ŵ   (k×n), what the standard engine's forward uses;
//! - `wt` — packed Ŵᵀ  (n×k), what the XNOR GEMM and the dX matmul
//!   use.  It can be packed directly (the proposed engine packs
//!   straight from f16 sign bits) or derived from a cached `w` by the
//!   word-level block transpose (not counted as a new pack).
//!
//! Wide layers (n ≥ [`PANEL_MIN_N`] output columns) additionally
//! cache `wt` re-laid-out as interleaved [`BPanels`] so the tiled
//! GEMM's panel micro-kernel streams B contiguously at BinaryNet fc
//! widths.  The threshold is a deterministic function of the layer
//! shape — `memmodel` mirrors it exactly — and panel storage follows
//! the same retain-on-invalidate discipline as the bit matrices.

use super::gemm::BPanels;
use super::BitMatrix;

/// Layers with at least this many output columns get a cached
/// [`BPanels`] layout alongside `wt`.  Below it the panel kernel has
/// nothing to win (B already fits in cache) and the extra resident
/// copy would be pure overhead; the rule must stay a pure function of
/// `n` so the `memmodel` envelope can reproduce it exactly.
pub const PANEL_MIN_N: usize = 256;

/// Deterministic panel rule shared with `memmodel`.
pub fn panels_worthwhile(n: usize) -> bool {
    n >= PANEL_MIN_N
}

#[derive(Debug, Default)]
pub struct PackedWeightCache {
    w: Vec<BitMatrix>,
    w_valid: Vec<bool>,
    wt: Vec<BitMatrix>,
    wt_valid: Vec<bool>,
    bp: Vec<BPanels>,
    bp_valid: Vec<bool>,
    packs: usize,
}

fn empty() -> BitMatrix {
    BitMatrix { rows: 0, cols: 0, words_per_row: 0, data: Vec::new() }
}

impl PackedWeightCache {
    pub fn new(layers: usize) -> PackedWeightCache {
        PackedWeightCache {
            w: (0..layers).map(|_| empty()).collect(),
            w_valid: vec![false; layers],
            wt: (0..layers).map(|_| empty()).collect(),
            wt_valid: vec![false; layers],
            bp: (0..layers).map(|_| BPanels::default()).collect(),
            bp_valid: vec![false; layers],
            packs: 0,
        }
    }

    pub fn layers(&self) -> usize {
        self.w.len()
    }

    /// Cached packed Ŵ for layer `wi`; on a miss `fill` rewrites the
    /// retained storage in place (use `BitMatrix::pack_into`).
    pub fn w(&mut self, wi: usize, fill: impl FnOnce(&mut BitMatrix)) -> &BitMatrix {
        if !self.w_valid[wi] {
            fill(&mut self.w[wi]);
            self.w_valid[wi] = true;
            self.packs += 1;
        }
        &self.w[wi]
    }

    /// Cached packed Ŵᵀ for layer `wi`; `fill_t` rewrites in place on
    /// a miss.
    pub fn wt(&mut self, wi: usize, fill_t: impl FnOnce(&mut BitMatrix)) -> &BitMatrix {
        if !self.wt_valid[wi] {
            fill_t(&mut self.wt[wi]);
            self.wt_valid[wi] = true;
            self.packs += 1;
        }
        &self.wt[wi]
    }

    /// Cached packed Ŵᵀ derived from (possibly cached) Ŵ by block
    /// transpose; `fill_w` fills Ŵ on a double miss.  The transpose
    /// is word-level and does not count as a pack.
    pub fn wt_via_transpose(
        &mut self,
        wi: usize,
        fill_w: impl FnOnce(&mut BitMatrix),
    ) -> &BitMatrix {
        if !self.wt_valid[wi] {
            if !self.w_valid[wi] {
                fill_w(&mut self.w[wi]);
                self.w_valid[wi] = true;
                self.packs += 1;
            }
            self.w[wi].transpose_into(&mut self.wt[wi]);
            self.wt_valid[wi] = true;
        }
        &self.wt[wi]
    }

    /// [`Self::wt`] plus the layer's cached B panels when the width
    /// rule says panels pay off ([`panels_worthwhile`]); panels are
    /// re-interleaved in place from the (possibly just-filled) `wt` on
    /// a miss — no allocation once warm, and not counted as a pack.
    pub fn wt_with_panels(
        &mut self,
        wi: usize,
        fill_t: impl FnOnce(&mut BitMatrix),
    ) -> (&BitMatrix, Option<&BPanels>) {
        if !self.wt_valid[wi] {
            fill_t(&mut self.wt[wi]);
            self.wt_valid[wi] = true;
            self.packs += 1;
        }
        let wt = &self.wt[wi];
        if !panels_worthwhile(wt.rows) {
            return (wt, None);
        }
        if !self.bp_valid[wi] {
            self.bp[wi].pack_into(wt);
            self.bp_valid[wi] = true;
        }
        (wt, Some(&self.bp[wi]))
    }

    /// [`Self::wt_via_transpose`] plus cached B panels (see
    /// [`Self::wt_with_panels`]).
    pub fn wt_via_transpose_with_panels(
        &mut self,
        wi: usize,
        fill_w: impl FnOnce(&mut BitMatrix),
    ) -> (&BitMatrix, Option<&BPanels>) {
        if !self.wt_valid[wi] {
            if !self.w_valid[wi] {
                fill_w(&mut self.w[wi]);
                self.w_valid[wi] = true;
                self.packs += 1;
            }
            self.w[wi].transpose_into(&mut self.wt[wi]);
            self.wt_valid[wi] = true;
        }
        let wt = &self.wt[wi];
        if !panels_worthwhile(wt.rows) {
            return (wt, None);
        }
        if !self.bp_valid[wi] {
            self.bp[wi].pack_into(wt);
            self.bp_valid[wi] = true;
        }
        (wt, Some(&self.bp[wi]))
    }

    /// Mark layer `wi` stale (its weights changed).  Storage is
    /// retained for the in-place repack.
    pub fn invalidate(&mut self, wi: usize) {
        self.w_valid[wi] = false;
        self.wt_valid[wi] = false;
        self.bp_valid[wi] = false;
    }

    /// Mark everything stale (end-of-step bulk update / snapshot load).
    pub fn invalidate_all(&mut self) {
        self.w_valid.fill(false);
        self.wt_valid.fill(false);
        self.bp_valid.fill(false);
    }

    /// Total packs performed since construction — the probe the
    /// once-per-step tests assert on.
    pub fn pack_count(&self) -> usize {
        self.packs
    }

    /// Resident cached bytes (storage persists across invalidation —
    /// that persistence is what makes steady-state repacks free).
    /// Includes the interleaved panel copies of wide layers.
    pub fn heap_bytes(&self) -> usize {
        self.w.iter().chain(self.wt.iter()).map(BitMatrix::heap_bytes).sum::<usize>()
            + self.bp.iter().map(BPanels::heap_bytes).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn packs_once_until_invalidated() {
        let mut g = Pcg32::new(12);
        let xs = g.normal_vec(6 * 70);
        let mut c = PackedWeightCache::new(2);
        for _ in 0..3 {
            let m = c.wt(0, |dst| BitMatrix::pack_into(6, 70, &xs, dst));
            assert_eq!(m.rows, 6);
        }
        assert_eq!(c.pack_count(), 1);
        c.invalidate(0);
        c.wt(0, |dst| BitMatrix::pack_into(6, 70, &xs, dst));
        assert_eq!(c.pack_count(), 2);
        assert!(c.heap_bytes() > 0);
        // invalidation keeps the storage resident for in-place repacks
        let resident = c.heap_bytes();
        c.invalidate_all();
        assert_eq!(c.heap_bytes(), resident);
    }

    #[test]
    fn repack_after_invalidate_reuses_storage() {
        let mut g = Pcg32::new(14);
        let xs = g.normal_vec(9 * 128);
        let ys = g.normal_vec(9 * 128);
        let mut c = PackedWeightCache::new(1);
        c.w(0, |dst| BitMatrix::pack_into(9, 128, &xs, dst));
        let cap0 = c.heap_bytes();
        c.invalidate(0);
        let m = c.w(0, |dst| BitMatrix::pack_into(9, 128, &ys, dst)).clone();
        assert_eq!(c.heap_bytes(), cap0, "same storage, no growth");
        assert_eq!(m, BitMatrix::pack(9, 128, &ys), "repack sees new weights");
    }

    #[test]
    fn panels_follow_the_width_rule_and_reuse_storage() {
        let mut g = Pcg32::new(15);
        let narrow = g.normal_vec(64 * 70); // n=64 < PANEL_MIN_N
        let wide = g.normal_vec(PANEL_MIN_N * 70);
        let wide2 = g.normal_vec(PANEL_MIN_N * 70);
        let mut c = PackedWeightCache::new(2);

        let (_, bp) = c.wt_with_panels(0, |dst| BitMatrix::pack_into(64, 70, &narrow, dst));
        assert!(bp.is_none(), "narrow layers stay panel-free");

        let (wt, bp) =
            c.wt_with_panels(1, |dst| BitMatrix::pack_into(PANEL_MIN_N, 70, &wide, dst));
        let bp = bp.expect("wide layer gets panels");
        assert_eq!((bp.n, bp.wpr), (wt.rows, wt.words_per_row));
        assert_eq!(bp.heap_bytes(), BPanels::words_for(PANEL_MIN_N, 70usize.div_ceil(64)) * 8);
        let resident = c.heap_bytes();
        assert_eq!(c.pack_count(), 2, "panel interleave is not a pack");

        // invalidate + repack with new weights: same storage, fresh panels
        c.invalidate(1);
        assert_eq!(c.heap_bytes(), resident, "panels stay resident when stale");
        let (wt, bp) =
            c.wt_with_panels(1, |dst| BitMatrix::pack_into(PANEL_MIN_N, 70, &wide2, dst));
        let bp = bp.expect("panels rebuilt");
        assert_eq!(bp.data, BPanels::pack(wt).data, "repacked panels match new weights");
        assert_eq!(c.heap_bytes(), resident, "no growth on same-shape repack");

        // the transpose-derived variant agrees
        let mut c2 = PackedWeightCache::new(1);
        let (wt2, bp2) = c2.wt_via_transpose_with_panels(0, |dst| {
            BitMatrix::pack_into(70, PANEL_MIN_N, &wide2, dst)
        });
        assert_eq!(wt2.rows, PANEL_MIN_N);
        assert_eq!(bp2.expect("wide via transpose").data, BPanels::pack(wt2).data);
    }

    #[test]
    fn wt_via_transpose_reuses_w_and_counts_no_extra_pack() {
        let mut g = Pcg32::new(13);
        let xs = g.normal_vec(9 * 33);
        let mut c = PackedWeightCache::new(1);
        let w = c.w(0, |dst| BitMatrix::pack_into(9, 33, &xs, dst)).clone();
        let wt = c.wt_via_transpose(0, |_| panic!("w already cached")).clone();
        assert_eq!(c.pack_count(), 1);
        assert_eq!(wt, w.transpose());
        // double miss packs exactly once
        let mut c2 = PackedWeightCache::new(1);
        let wt2 = c2
            .wt_via_transpose(0, |dst| BitMatrix::pack_into(9, 33, &xs, dst))
            .clone();
        assert_eq!(c2.pack_count(), 1);
        assert_eq!(wt2, wt);
    }
}
