//! Persistent row-parallel execution pool for the GEMM and bit-pack
//! kernels.
//!
//! PR 1 dispatched bands with `std::thread::scope`, paying a fresh
//! spawn (~tens of µs per worker) on *every* matmul.  At BinaryNet fc
//! sizes that is noise; at the small conv shapes edge training
//! actually runs (mini models, batch ≤ 32, layers of a few ms) it is
//! a measurable tax.  Workers are now **long-lived**: one
//! process-global worker set, grown to the largest count any pool
//! requests, fed jobs through a condvar-guarded slot — so a [`Pool`]
//! handle is a cheap `Arc` clone, per-call dispatch cost drops to a
//! lock + wakeup, and concurrent sessions (a trainer and a serve
//! loop, say) share workers instead of spawning competing sets.
//!
//! Parallelism model (unchanged): the output is split into contiguous
//! *row bands*.  Bands are claimed from an atomic counter by the
//! caller **and** the workers (the caller participates, so `threads`
//! counts it), every claimant writes a disjoint `&mut` band and reads
//! the shared operands.  No locks or atomics in the kernel hot path.
//!
//! Borrowed (non-`'static`) closures cross into the workers through a
//! type-erased raw-pointer job.  Soundness hinges on the drain
//! protocol: `run_rows` does not return until the job slot is cleared
//! *and* every worker that picked the job pointer has bumped back in
//! (`active == 0`), so the stack frame holding the closure and band
//! descriptors strictly outlives all worker access.  Panics inside a
//! band are caught per-band, the sweep completes, and the panic is
//! rethrown on the caller.
//!
//! Nested `run_rows` (a band closure that itself parallelizes) runs
//! inline — a thread-local flag short-circuits it — so kernels can
//! compose without deadlocking the slot.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Parallel sweeps published to the shared job slot since process
/// start (inline runs are not counted).
static SWEEPS: AtomicU64 = AtomicU64::new(0);
/// Sweeps that found the slot occupied on arrival and had to wait —
/// the multi-tenant co-scheduling contention signal: lanes running
/// different tenants' serial regions keep this near zero, lanes
/// racing large GEMMs push it up.
static SWEEPS_CONTENDED: AtomicU64 = AtomicU64::new(0);

/// Cumulative [`Pool`] sweep counters (process-global, monotone).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepStats {
    pub sweeps: u64,
    pub contended: u64,
}

/// Snapshot the process-global sweep counters.  Diff two snapshots to
/// attribute contention to a workload window.
pub fn sweep_stats() -> SweepStats {
    SweepStats {
        sweeps: SWEEPS.load(Ordering::Relaxed),
        contended: SWEEPS_CONTENDED.load(Ordering::Relaxed),
    }
}

/// Worker pool handle: a configured thread count plus a shared set of
/// persistent workers (`None` when `threads == 1`: inline only, no
/// spawns — a single code path serves serial and parallel backends).
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    shared: Option<Arc<Shared>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

impl PartialEq for Pool {
    fn eq(&self, other: &Pool) -> bool {
        self.threads == other.threads
    }
}
impl Eq for Pool {}

/// One published parallel sweep: a type-erased pointer to the
/// caller-stack [`Ctx`] plus its monomorphized band runner.
#[derive(Clone, Copy)]
struct Job {
    data: *const (),
    run: unsafe fn(*const ()),
}
// SAFETY: the pointed-to Ctx lives on the publishing caller's stack
// and is only dereferenced between publish and drain (see run_rows).
unsafe impl Send for Job {}

#[derive(Default)]
struct State {
    /// Current job, present from publish until the caller's drain.
    job: Option<Job>,
    /// Bumped per publish so a worker joins each job at most once.
    generation: u64,
    /// Workers currently inside `job.run`.
    active: usize,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new job.
    work: Condvar,
    /// Callers wait here for worker drain / slot release.
    done: Condvar,
}

/// Band-sweep descriptor shared between the caller and the workers
/// for one `run_rows` call.  Lives on the caller's stack.
struct Ctx<T, F> {
    out: *mut T,
    rows: usize,
    row_len: usize,
    band_rows: usize,
    n_bands: usize,
    next: AtomicUsize,
    panicked: AtomicBool,
    f: *const F,
}

/// Claims bands until the counter is exhausted.  Monomorphized per
/// `run_rows` call; reached only through `Job::run`.
unsafe fn run_ctx<T: Send, F: Fn(usize, &mut [T]) + Sync>(p: *const ()) {
    let ctx = unsafe { &*(p as *const Ctx<T, F>) };
    let f = unsafe { &*ctx.f };
    loop {
        let bi = ctx.next.fetch_add(1, Ordering::Relaxed);
        if bi >= ctx.n_bands {
            return;
        }
        let r0 = bi * ctx.band_rows;
        let rn = ctx.band_rows.min(ctx.rows - r0);
        // disjoint per band: band bi covers rows [r0, r0 + rn)
        let band = unsafe {
            std::slice::from_raw_parts_mut(ctx.out.add(r0 * ctx.row_len), rn * ctx.row_len)
        };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(r0, band))).is_err() {
            ctx.panicked.store(true, Ordering::Relaxed);
        }
    }
}

std::thread_local! {
    /// True while this thread is executing inside a pool sweep —
    /// makes a nested `run_rows` run inline instead of deadlocking
    /// on the job slot.
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen_gen: u64 = 0;
    let mut st = shared.state.lock().unwrap();
    loop {
        if let Some(job) = st.job {
            if st.generation != seen_gen {
                seen_gen = st.generation;
                st.active += 1;
                drop(st);
                IN_POOL.with(|c| c.set(true));
                unsafe { (job.run)(job.data) };
                IN_POOL.with(|c| c.set(false));
                st = shared.state.lock().unwrap();
                st.active -= 1;
                if st.active == 0 {
                    shared.done.notify_all();
                }
                continue;
            }
        }
        st = shared.work.wait(st).unwrap();
    }
}

/// Process-global registry: **one** persistent worker set shared by
/// every pool, grown to the largest worker count ever requested.
///
/// Keying worker sets by count (the pre-serve design) spawned a
/// *separate* set per distinct count: a trainer on `Pool::new(4)`
/// plus a serve loop on `Pool::new(3)` would run 3 + 2 = 5 workers
/// and two caller threads on a 4-core box — oversubscription exactly
/// when training and serving coexist.  With a single set the job slot
/// serializes concurrent sessions (one sweep runs at a time; queued
/// callers sleep on `done`), a sweep's band count still caps its own
/// parallelism at the *pool's* configured threads, and workers beyond
/// a small job's band count find the claim counter exhausted and go
/// back to waiting — composition instead of competition.
struct Registry {
    shared: Arc<Shared>,
    spawned: usize,
}

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| {
        Mutex::new(Registry {
            shared: Arc::new(Shared {
                state: Mutex::new(State::default()),
                work: Condvar::new(),
                done: Condvar::new(),
            }),
            spawned: 0,
        })
    })
}

fn global_shared_workers(workers: usize) -> Arc<Shared> {
    let mut reg = registry().lock().unwrap();
    while reg.spawned < workers {
        let i = reg.spawned;
        let s = Arc::clone(&reg.shared);
        std::thread::Builder::new()
            .name(format!("bitops-pool-{i}"))
            .spawn(move || worker_loop(s))
            .expect("spawn bitops pool worker");
        reg.spawned += 1;
    }
    Arc::clone(&reg.shared)
}

/// Workers currently spawned (the satellite regression probe: a
/// smaller pool created after a bigger one must spawn nothing).
pub fn spawned_workers() -> usize {
    registry().lock().unwrap().spawned
}

std::thread_local! {
    /// Per-thread mirror of the registry: engines construct a `Pool`
    /// per matmul (the `Backend` enum is `Copy` and cannot hold the
    /// `Arc`), so repeat lookups must not touch the global mutex.  A
    /// cached count means the global set already holds ≥ that many
    /// workers — the `Arc` is the same single set for every key.
    static LOCAL_POOLS: std::cell::RefCell<HashMap<usize, Arc<Shared>>> =
        std::cell::RefCell::new(HashMap::new());
}

fn shared_workers(workers: usize) -> Arc<Shared> {
    LOCAL_POOLS.with(|cache| {
        if let Some(sh) = cache.borrow().get(&workers) {
            return Arc::clone(sh);
        }
        let sh = global_shared_workers(workers);
        cache.borrow_mut().insert(workers, Arc::clone(&sh));
        sh
    })
}

impl Pool {
    /// `threads = 0` auto-detects from `available_parallelism`.  The
    /// handle uses `threads - 1` persistent workers (the caller is
    /// the remaining participant) out of the single process-global
    /// set, which grows to the largest count requested so far —
    /// handles with *different* counts share the same workers.
    pub fn new(threads: usize) -> Pool {
        let threads = Pool::resolve(threads);
        let shared = if threads > 1 { Some(shared_workers(threads - 1)) } else { None };
        Pool { threads, shared }
    }

    /// Resolve a configured thread count (`0` = auto-detect, probed
    /// once per process) without touching the worker registry.
    pub fn resolve(threads: usize) -> usize {
        if threads == 0 {
            static AUTO: OnceLock<usize> = OnceLock::new();
            *AUTO.get_or_init(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
        } else {
            threads
        }
    }

    /// Inline-only pool (the serial backends).
    pub fn serial() -> Pool {
        Pool { threads: 1, shared: None }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Outputs smaller than this run inline: for mini-model shapes
    /// even the persistent dispatch (lock + wakeup, ~µs) would exceed
    /// the kernel time and invert the blocked < tiled ordering.
    const MIN_PARALLEL_CELLS: usize = 4096;

    /// Split `rows` rows of `out` (each `row_len` elements) into at
    /// most `threads` contiguous bands and run `f(first_row, band)`
    /// on each band, in parallel (caller + persistent workers).
    /// `out.len()` must be `rows * row_len`; each band is a disjoint
    /// `&mut` sub-slice.  Small outputs (see
    /// [`Self::MIN_PARALLEL_CELLS`]) and nested calls run inline.
    pub fn run_rows<T, F>(&self, rows: usize, row_len: usize, out: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        self.run_rows_chunk(rows, row_len, 0, out, f)
    }

    /// [`Self::run_rows`] with an explicit band granularity: `chunk`
    /// rows per claimed band (0 = one even band per worker, the
    /// default split).  Smaller chunks trade dispatch overhead for
    /// dynamic load balancing; the kernel autotuner
    /// (`bitops::tune`) sweeps this axis per shape.  Bands are still
    /// claimed atomically and cover every row exactly once.
    pub fn run_rows_chunk<T, F>(
        &self,
        rows: usize,
        row_len: usize,
        chunk: usize,
        out: &mut [T],
        f: F,
    ) where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert_eq!(out.len(), rows * row_len, "band partition mismatch");
        if rows == 0 || row_len == 0 {
            return;
        }
        let workers = self.threads.min(rows); // both ≥ 1 here
        let shared = match &self.shared {
            Some(sh)
                if workers > 1
                    && out.len() >= Self::MIN_PARALLEL_CELLS
                    && !IN_POOL.with(|c| c.get()) =>
            {
                sh
            }
            _ => {
                f(0, out);
                return;
            }
        };
        let band_rows = if chunk == 0 { rows.div_ceil(workers) } else { chunk.min(rows) };
        let n_bands = rows.div_ceil(band_rows);
        let ctx = Ctx {
            out: out.as_mut_ptr(),
            rows,
            row_len,
            band_rows,
            n_bands,
            next: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            f: &f,
        };
        let job = Job {
            data: (&ctx as *const Ctx<T, F>).cast(),
            run: run_ctx::<T, F>,
        };
        {
            let mut st = shared.state.lock().unwrap();
            SWEEPS.fetch_add(1, Ordering::Relaxed);
            if st.job.is_some() {
                SWEEPS_CONTENDED.fetch_add(1, Ordering::Relaxed);
            }
            while st.job.is_some() {
                // another caller's sweep owns the slot: wait it out
                st = shared.done.wait(st).unwrap();
            }
            st.job = Some(job);
            st.generation = st.generation.wrapping_add(1);
            shared.work.notify_all();
        }
        // the caller is one of the `threads` participants
        IN_POOL.with(|c| c.set(true));
        unsafe { run_ctx::<T, F>(job.data) };
        IN_POOL.with(|c| c.set(false));
        // drain: all bands are claimed once the caller's sweep ends;
        // wait for workers still finishing theirs, then release the
        // slot.  Only after this may `ctx`/`f` leave scope.
        let mut st = shared.state.lock().unwrap();
        while st.active > 0 {
            st = shared.done.wait(st).unwrap();
        }
        st.job = None;
        shared.done.notify_all(); // release queued callers
        drop(st);
        if ctx.panicked.load(Ordering::Relaxed) {
            panic!("bitops::Pool: a parallel band panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn auto_detect_is_positive() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
        assert_eq!(Pool::serial().threads(), 1);
        assert_eq!(Pool::resolve(5), 5);
        assert!(Pool::resolve(0) >= 1);
    }

    #[test]
    fn bands_cover_all_rows_exactly_once() {
        // every cell written once with its global row id, any thread
        // count, including threads > rows and odd splits; row_len is
        // large enough that the bigger cases cross MIN_PARALLEL_CELLS
        // and genuinely band across workers
        for threads in [1, 2, 3, 4, 7, 16] {
            for rows in [1usize, 2, 5, 16, 33] {
                let row_len = 512;
                let mut out = vec![usize::MAX; rows * row_len];
                let calls = AtomicUsize::new(0);
                Pool::new(threads).run_rows(rows, row_len, &mut out, |r0, band| {
                    calls.fetch_add(1, Ordering::Relaxed);
                    for (i, row) in band.chunks_mut(row_len).enumerate() {
                        row.fill(r0 + i);
                    }
                });
                for r in 0..rows {
                    for c in 0..row_len {
                        assert_eq!(out[r * row_len + c], r, "t={threads} rows={rows}");
                    }
                }
                assert!(calls.load(Ordering::Relaxed) <= threads.min(rows));
            }
        }
    }

    #[test]
    fn chunked_bands_cover_all_rows_exactly_once() {
        // explicit chunk sizes, including ones that don't divide rows
        // and chunk > rows; same coverage invariant as the default split
        for threads in [2, 4] {
            for rows in [5usize, 16, 33] {
                for chunk in [1usize, 2, 7, 64] {
                    let row_len = 512;
                    let mut out = vec![usize::MAX; rows * row_len];
                    Pool::new(threads).run_rows_chunk(rows, row_len, chunk, &mut out, |r0, band| {
                        for (i, row) in band.chunks_mut(row_len).enumerate() {
                            row.fill(r0 + i);
                        }
                    });
                    for r in 0..rows {
                        for c in 0..row_len {
                            assert_eq!(
                                out[r * row_len + c],
                                r,
                                "t={threads} rows={rows} chunk={chunk}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sweep_stats_count_published_sweeps() {
        // a parallel sweep above MIN_PARALLEL_CELLS publishes to the
        // job slot and bumps the counter; an inline run does not
        let pool = Pool::new(2);
        let rows = 16;
        let row_len = 512; // 8192 cells >= MIN_PARALLEL_CELLS
        let before = sweep_stats();
        let mut out = vec![0u32; rows * row_len];
        pool.run_rows(rows, row_len, &mut out, |_, band| band.fill(1));
        let mid = sweep_stats();
        assert!(mid.sweeps >= before.sweeps + 1, "parallel sweep not counted");
        let mut tiny = vec![0u32; 8];
        pool.run_rows(8, 1, &mut tiny, |_, band| band.fill(1));
        // contended <= sweeps always holds (other tests run
        // concurrently, so only monotonicity is assertable)
        let after = sweep_stats();
        assert!(after.contended <= after.sweeps);
        assert!(after.sweeps >= mid.sweeps);
    }

    #[test]
    fn empty_work_is_a_noop() {
        let mut out: Vec<f32> = Vec::new();
        Pool::new(4).run_rows(0, 8, &mut out, |_, _| panic!("no work expected"));
        Pool::new(4).run_rows(8, 0, &mut out, |_, _| panic!("no work expected"));
    }

    #[test]
    fn persistent_workers_survive_many_dispatches() {
        // the amortization claim: one pool handle, hundreds of sweeps
        let pool = Pool::new(4);
        let rows = 16;
        let row_len = 512;
        for round in 0..200usize {
            let mut out = vec![0usize; rows * row_len];
            pool.run_rows(rows, row_len, &mut out, |r0, band| {
                for (i, row) in band.chunks_mut(row_len).enumerate() {
                    row.fill(round + r0 + i);
                }
            });
            for r in 0..rows {
                assert_eq!(out[r * row_len], round + r, "round {round}");
            }
        }
    }

    #[test]
    fn concurrent_callers_are_serialized_not_corrupted() {
        // several threads hammering the same shared worker set: the
        // job slot serializes sweeps, results stay disjoint
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let pool = Pool::new(3);
                    let rows = 32;
                    let row_len = 256;
                    for _ in 0..50 {
                        let mut out = vec![usize::MAX; rows * row_len];
                        pool.run_rows(rows, row_len, &mut out, |r0, band| {
                            for (i, row) in band.chunks_mut(row_len).enumerate() {
                                row.fill(t * 1000 + r0 + i);
                            }
                        });
                        for r in 0..rows {
                            assert_eq!(out[r * row_len + 7], t * 1000 + r);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn distinct_counts_share_one_worker_set() {
        // the trainer+serve composition bug: a smaller pool created
        // after a bigger one must NOT spawn a second worker set — the
        // global set grows to max(requested) - 1 and stops.  Raise
        // the high-water mark above anything other (concurrently
        // running) tests request, so the spawn count is stable while
        // we probe it.
        let top = 17.max(Pool::resolve(0) + 1);
        let _big = Pool::new(top);
        let after_big = spawned_workers();
        assert!(after_big >= top - 1, "{top}-thread pool needs >= {}", top - 1);
        let _small = Pool::new(3);
        let _smaller = Pool::new(2);
        assert_eq!(
            spawned_workers(),
            after_big,
            "smaller pools after a bigger one must spawn nothing"
        );
        // and both pool sizes still compute correctly on the shared set
        for pool in [Pool::new(4), Pool::new(2)] {
            let (rows, row_len) = (16, 512);
            let mut out = vec![usize::MAX; rows * row_len];
            pool.run_rows(rows, row_len, &mut out, |r0, band| {
                for (i, row) in band.chunks_mut(row_len).enumerate() {
                    row.fill(r0 + i);
                }
            });
            for r in 0..rows {
                assert_eq!(out[r * row_len], r, "t={}", pool.threads());
            }
        }
    }

    #[test]
    fn concurrent_sessions_with_mixed_counts_compose() {
        // a trainer (4 threads) and a serve loop (2 threads) — plus
        // two more sessions — hammering the single shared worker set
        // concurrently with *different* configured counts: sweeps
        // serialize through the job slot, results stay disjoint, and
        // no session deadlocks or corrupts another's bands
        let handles: Vec<_> = [4usize, 2, 3, 5]
            .into_iter()
            .enumerate()
            .map(|(t, threads)| {
                std::thread::spawn(move || {
                    let pool = Pool::new(threads);
                    let rows = 32;
                    let row_len = 256;
                    for round in 0..50 {
                        let mut out = vec![usize::MAX; rows * row_len];
                        pool.run_rows(rows, row_len, &mut out, |r0, band| {
                            for (i, row) in band.chunks_mut(row_len).enumerate() {
                                row.fill(t * 10_000 + round + r0 + i);
                            }
                        });
                        for r in 0..rows {
                            assert_eq!(
                                out[r * row_len + 13],
                                t * 10_000 + round + r,
                                "t={threads} round={round}"
                            );
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn nested_run_rows_runs_inline() {
        // a band closure that parallelizes again must not deadlock on
        // the job slot — it runs inline via the IN_POOL guard
        let pool = Pool::new(2);
        let rows = 8;
        let row_len = 1024;
        let mut out = vec![0usize; rows * row_len];
        let inner_pool = Pool::new(2);
        pool.run_rows(rows, row_len, &mut out, |r0, band| {
            let brows = band.len() / row_len;
            inner_pool.run_rows(brows, row_len, band, |ir0, iband| {
                for (i, row) in iband.chunks_mut(row_len).enumerate() {
                    row.fill(r0 + ir0 + i);
                }
            });
        });
        for r in 0..rows {
            assert_eq!(out[r * row_len], r);
        }
    }

    #[test]
    #[should_panic(expected = "parallel band panicked")]
    fn band_panics_propagate_to_caller() {
        let pool = Pool::new(2);
        let rows = 8;
        let row_len = 1024; // crosses MIN_PARALLEL_CELLS
        let mut out = vec![0u8; rows * row_len];
        pool.run_rows(rows, row_len, &mut out, |r0, _| {
            if r0 == 0 {
                panic!("boom");
            }
        });
    }
}
