//! Row-parallel execution pool for the GEMM kernels.
//!
//! A [`Pool`] is a lightweight handle holding a configured worker
//! count (from config/CLI; `0` = auto-detect).  Work is dispatched
//! with `std::thread::scope`, which lets the kernels borrow the
//! operands and disjoint output bands without `Arc`/cloning; the pool
//! handle itself is reusable across calls and steps, and spawn cost
//! (~tens of µs) is amortized over multi-millisecond GEMMs.
//!
//! Parallelism model: the output matrix is split into contiguous
//! *row bands*, one per worker, so every worker writes a disjoint
//! `&mut` slice and reads the shared packed operands.  No locks, no
//! atomics in the hot path.

/// Worker pool handle.  `threads == 1` runs inline (no spawns), so a
/// single code path serves both the serial and parallel backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// `threads = 0` auto-detects from `available_parallelism`.
    pub fn new(threads: usize) -> Pool {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Pool { threads: threads.max(1) }
    }

    /// Inline-only pool (the serial backends).
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Outputs smaller than this run inline: for mini-model shapes
    /// the scoped-spawn cost (~tens of µs/worker) would exceed the
    /// kernel time and invert the blocked < tiled ordering.
    const MIN_PARALLEL_CELLS: usize = 4096;

    /// Split `rows` rows of `out` (each `row_len` elements) into at
    /// most `threads` contiguous bands and run `f(first_row, band)`
    /// on each band, in parallel.  `out.len()` must be
    /// `rows * row_len`; each band is a disjoint `&mut` sub-slice.
    /// Small outputs (see [`Self::MIN_PARALLEL_CELLS`]) run inline.
    pub fn run_rows<T, F>(&self, rows: usize, row_len: usize, out: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert_eq!(out.len(), rows * row_len, "band partition mismatch");
        if rows == 0 || row_len == 0 {
            return;
        }
        let workers = self.threads.min(rows); // both ≥ 1 here
        if workers <= 1 || out.len() < Self::MIN_PARALLEL_CELLS {
            f(0, out);
            return;
        }
        let band_rows = rows.div_ceil(workers);
        std::thread::scope(|s| {
            for (bi, band) in out.chunks_mut(band_rows * row_len).enumerate() {
                let f = &f;
                s.spawn(move || f(bi * band_rows, band));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn auto_detect_is_positive() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
        assert_eq!(Pool::serial().threads(), 1);
    }

    #[test]
    fn bands_cover_all_rows_exactly_once() {
        // every cell written once with its global row id, any thread
        // count, including threads > rows and odd splits; row_len is
        // large enough that the bigger cases cross MIN_PARALLEL_CELLS
        // and genuinely band across workers
        for threads in [1, 2, 3, 4, 7, 16] {
            for rows in [1usize, 2, 5, 16, 33] {
                let row_len = 512;
                let mut out = vec![usize::MAX; rows * row_len];
                let calls = AtomicUsize::new(0);
                Pool::new(threads).run_rows(rows, row_len, &mut out, |r0, band| {
                    calls.fetch_add(1, Ordering::Relaxed);
                    for (i, row) in band.chunks_mut(row_len).enumerate() {
                        row.fill(r0 + i);
                    }
                });
                for r in 0..rows {
                    for c in 0..row_len {
                        assert_eq!(out[r * row_len + c], r, "t={threads} rows={rows}");
                    }
                }
                assert!(calls.load(Ordering::Relaxed) <= threads.min(rows));
            }
        }
    }

    #[test]
    fn empty_work_is_a_noop() {
        let mut out: Vec<f32> = Vec::new();
        Pool::new(4).run_rows(0, 8, &mut out, |_, _| panic!("no work expected"));
        Pool::new(4).run_rows(8, 0, &mut out, |_, _| panic!("no work expected"));
    }
}
