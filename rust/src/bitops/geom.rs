//! Conv geometry: one `Copy` struct carries everything a conv kernel
//! needs to agree about shapes — input/output spatial dims, kernel
//! side, stride and the explicit top/left padding — so stride-1 SAME,
//! strided SAME (TensorFlow convention: `out = ceil(in / stride)`,
//! extra pad on the bottom/right) and VALID (`out = (in − k)/stride
//! + 1`, no padding) all flow through the same packed pipeline.
//!
//! Output dims are *stored*, never re-inferred: every kernel indexes
//! output position `(oy, ox)` against input `(oy·stride + ky − pad_h,
//! ox·stride + kx − pad_w)` with bounds checks, which is exactly the
//! SAME-vs-VALID difference (VALID geometries simply never go out of
//! bounds).

/// Spatial geometry of one conv layer (per sample; batch is a
/// separate argument everywhere so one geometry serves any batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvGeom {
    /// Input spatial dims and channels (NHWC map is `h × w × cin`).
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    /// Output spatial dims (`oh × ow` positions per sample).
    pub oh: usize,
    pub ow: usize,
    /// Square kernel side.
    pub kside: usize,
    /// Spatial stride (both axes).
    pub stride: usize,
    /// Top / left zero-padding.  Bottom/right padding is implicit:
    /// kernels bounds-check `oy·stride + ky − pad_h` against `[0, h)`.
    pub pad_h: usize,
    pub pad_w: usize,
}

impl ConvGeom {
    /// SAME-padded conv: `out = ceil(in / stride)`, pad split with the
    /// extra row/column on the bottom/right (TensorFlow convention; at
    /// stride 1 with an odd kernel this is the symmetric
    /// `pad = (kside − 1)/2`).  Panics on an even kernel — the naive
    /// engines reject those earlier, at plan-build time.
    pub fn same(h: usize, w: usize, cin: usize, kside: usize, stride: usize) -> ConvGeom {
        assert!(
            kside % 2 == 1 && kside > 0,
            "SAME conv requires an odd kernel side, got {kside} \
             (pad = (kside-1)/2 would be asymmetric)"
        );
        assert!(stride >= 1, "conv stride must be positive");
        let oh = h.div_ceil(stride);
        let ow = w.div_ceil(stride);
        let pad_h = ((oh - 1) * stride + kside).saturating_sub(h) / 2;
        let pad_w = ((ow - 1) * stride + kside).saturating_sub(w) / 2;
        ConvGeom { h, w, cin, oh, ow, kside, stride, pad_h, pad_w }
    }

    /// Stride-1 SAME — the geometry the pre-PR-4 pipeline hardcoded.
    pub fn same1(h: usize, w: usize, cin: usize, kside: usize) -> ConvGeom {
        ConvGeom::same(h, w, cin, kside, 1)
    }

    /// VALID (unpadded) conv: `out = (in − kside)/stride + 1`.
    pub fn valid(h: usize, w: usize, cin: usize, kside: usize, stride: usize) -> ConvGeom {
        assert!(kside >= 1, "conv kernel side must be positive");
        assert!(stride >= 1, "conv stride must be positive");
        assert!(
            kside <= h && kside <= w,
            "VALID conv kernel {kside} exceeds input {h}x{w}"
        );
        let oh = (h - kside) / stride + 1;
        let ow = (w - kside) / stride + 1;
        ConvGeom { h, w, cin, oh, ow, kside, stride, pad_h: 0, pad_w: 0 }
    }

    /// im2col contraction width `k = kside² · cin`.
    #[inline]
    pub fn k(&self) -> usize {
        self.kside * self.kside * self.cin
    }

    /// im2col rows for a batch: `b · oh · ow`.
    #[inline]
    pub fn rows(&self, b: usize) -> usize {
        b * self.oh * self.ow
    }

    /// Input map length for a batch: `b · h · w · cin`.
    #[inline]
    pub fn in_len(&self, b: usize) -> usize {
        b * self.h * self.w * self.cin
    }

    /// Any padding taps at all?  VALID (and SAME geometries whose
    /// kernel never overhangs, e.g. 1×1) contribute no out-of-bounds
    /// taps, so the pad corrections are no-ops.
    #[inline]
    pub fn padded(&self) -> bool {
        self.pad_h > 0 || self.pad_w > 0
    }

    /// True for the stride-1 spatial-preserving case (SAME, s = 1):
    /// output positions coincide with input positions.
    #[inline]
    pub fn unit(&self) -> bool {
        self.stride == 1 && self.oh == self.h && self.ow == self.w
    }
}

/// Half-open output range `[lo, hi)` of positions (along one axis)
/// whose tap `kt` lands in bounds: `0 ≤ o·stride + kt − pad < n`.
#[inline]
pub(crate) fn tap_out_range(
    o: usize,
    n: usize,
    pad: usize,
    kt: usize,
    stride: usize,
) -> (usize, usize) {
    let lo = if kt >= pad { 0 } else { (pad - kt).div_ceil(stride) };
    let hi = if n + pad <= kt { 0 } else { ((n + pad - kt - 1) / stride + 1).min(o) };
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_stride1_matches_legacy_pad() {
        for kside in [1usize, 3, 5, 7] {
            let g = ConvGeom::same1(16, 12, 3, kside);
            assert_eq!((g.oh, g.ow), (16, 12));
            assert_eq!(g.pad_h, (kside - 1) / 2);
            assert_eq!(g.pad_w, (kside - 1) / 2);
            assert!(g.unit());
        }
    }

    #[test]
    fn same_strided_ceil_dims() {
        // ResNet stem: 224, k7, s2 -> 112, total pad 5, top pad 2
        let g = ConvGeom::same(224, 224, 3, 7, 2);
        assert_eq!((g.oh, g.ow), (112, 112));
        assert_eq!(g.pad_h, 2);
        // stage entry: 16, k3, s2 -> 8, total pad 1, top pad 0
        let g = ConvGeom::same(16, 16, 64, 3, 2);
        assert_eq!((g.oh, g.ow), (8, 8));
        assert_eq!(g.pad_h, 0);
        // odd input: 7, k3, s2 -> 4, total pad (3*2+3)-7 = 2, top 1
        let g = ConvGeom::same(7, 7, 8, 3, 2);
        assert_eq!(g.oh, 4);
        assert_eq!(g.pad_h, 1);
        // k1 s2 never pads
        let g = ConvGeom::same(5, 5, 2, 1, 2);
        assert_eq!(g.oh, 3);
        assert!(!g.padded());
    }

    #[test]
    fn valid_dims() {
        // FINN CNV: 32 -(3x3 valid)-> 30
        let g = ConvGeom::valid(32, 32, 3, 3, 1);
        assert_eq!((g.oh, g.ow), (30, 30));
        assert!(!g.padded());
        let g = ConvGeom::valid(9, 9, 1, 3, 2);
        assert_eq!(g.oh, 4);
    }

    #[test]
    #[should_panic(expected = "odd kernel side")]
    fn same_rejects_even_kernel() {
        ConvGeom::same(8, 8, 3, 2, 1);
    }

    #[test]
    #[should_panic(expected = "exceeds input")]
    fn valid_rejects_oversized_kernel() {
        ConvGeom::valid(4, 4, 3, 5, 1);
    }

    #[test]
    fn tap_ranges_brute_force() {
        // tap_out_range equals the brute-force scan for every
        // (n, o, pad, kt, stride) in a dense grid
        for stride in 1..=3usize {
            for n in 1..=9usize {
                for pad in 0..=3usize {
                    for o in 1..=9usize {
                        for kt in 0..=6usize {
                            let (lo, hi) = tap_out_range(o, n, pad, kt, stride);
                            for ot in 0..o {
                                let s = ot * stride + kt;
                                let inb = s >= pad && s - pad < n;
                                let claimed = ot >= lo && ot < hi;
                                assert_eq!(
                                    inb, claimed,
                                    "n{n} o{o} pad{pad} kt{kt} s{stride} @ {ot}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}
