//! Per-shape kernel autotuner for the tiled XNOR GEMM.
//!
//! At the first use of an (m-class, k-words, n, panels, threads) shape
//! class under `--tune=auto`, every candidate [`KernelCfg`] — the SIMD
//! 1×4 / 1×8 / 2×4 micro-kernels, the scalar 4×4 block at several
//! K-word tiles, the interleaved [`BPanels`] panel kernel when panels
//! are packed, and a second-phase row-band sweep for the parallel
//! driver — is microbenched **on the caller's real buffers** and the
//! fastest is cached in a process-global registry.  All candidates
//! compute identical integer popcounts (bit-exact against
//! `xnor_gemm_naive`), so tuning can only change speed, never results.
//!
//! Tuning happens strictly at warmup: a registry hit is a read-lock +
//! hash lookup with no allocation, so the zero-alloc steady state of
//! the training/serving engines is untouched (the one-time insert at
//! first use lands in the same warmup step that grows the arenas).
//!
//! The default mode is [`Mode::Fixed`]: exactly the pre-tuner fixed
//! dispatch, bit-for-bit and timing-deterministic — CI and tests run
//! fixed unless they opt in.  `bnn-edge tune` pre-warms a cache
//! offline and `--tune-cache PATH` persists/loads it as JSON; entries
//! record the SIMD level and are dropped on load when the host's
//! detected level differs (tile choices do not transfer across ISAs).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{OnceLock, RwLock};
use std::time::Instant;

use super::gemm::{self, BPanels, KernelCfg, MicroKernel};
use super::pool::Pool;
use super::{simd, BitMatrix};
use crate::util::json::Json;

/// Tuning mode, process-global (see [`set_mode`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Always dispatch [`KernelCfg::fixed`] — the deterministic
    /// pre-tuner behavior.  The default.
    Fixed,
    /// Microbench per shape class on first use, then replay the cached
    /// winner.
    Auto,
}

static MODE: AtomicU8 = AtomicU8::new(0); // 0 = Fixed, 1 = Auto

pub fn set_mode(m: Mode) {
    MODE.store(matches!(m, Mode::Auto) as u8, Ordering::Relaxed);
}

pub fn mode() -> Mode {
    if MODE.load(Ordering::Relaxed) == 0 {
        Mode::Fixed
    } else {
        Mode::Auto
    }
}

/// Parse a `--tune` argument: `fixed` | `auto`.
pub fn parse_mode(s: &str) -> Option<Mode> {
    match s {
        "fixed" => Some(Mode::Fixed),
        "auto" => Some(Mode::Auto),
        _ => None,
    }
}

/// Shape class key.  M (the batch/rows side) is bucketed to the next
/// power of two: microbatch splits and a partial last batch land in
/// the class tuned at warmup instead of re-tuning mid-epoch, and the
/// kernel choice is insensitive to M within a 2× band (it only sets
/// the band count).  K and N are exact — they are weight dimensions,
/// fixed per layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    pub m_class: usize,
    pub k_words: usize,
    pub n: usize,
    pub panels: bool,
    pub threads: usize,
}

/// M bucket: next power of two (minimum 1).
pub fn m_class(m: usize) -> usize {
    m.max(1).next_power_of_two()
}

impl ShapeKey {
    pub fn of(m: usize, k_words: usize, n: usize, panels: bool, threads: usize) -> ShapeKey {
        ShapeKey { m_class: m_class(m), k_words, n, panels, threads }
    }
}

fn registry() -> &'static RwLock<HashMap<ShapeKey, KernelCfg>> {
    static R: OnceLock<RwLock<HashMap<ShapeKey, KernelCfg>>> = OnceLock::new();
    R.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Number of cached shape classes.
pub fn len() -> usize {
    registry().read().unwrap().len()
}

/// Drop every cached choice (tests / re-tuning).
pub fn clear() {
    registry().write().unwrap().clear();
}

/// Cached choice for a shape class, if tuned.
pub fn lookup(key: &ShapeKey) -> Option<KernelCfg> {
    registry().read().unwrap().get(key).copied()
}

/// Snapshot of the registry, sorted by key (stable listing order for
/// `bnn-edge tune` and the cache file).
pub fn entries() -> Vec<(ShapeKey, KernelCfg)> {
    let reg = registry().read().unwrap();
    let mut rows: Vec<(ShapeKey, KernelCfg)> = reg.iter().map(|(k, v)| (*k, *v)).collect();
    drop(reg);
    rows.sort_by_key(|(k, _)| (k.m_class, k.k_words, k.n, k.panels, k.threads));
    rows
}

/// The config the tiled backend will dispatch for this GEMM right
/// now, without tuning anything — [`KernelCfg::fixed`] in fixed mode
/// or on a registry miss.  Benches use this to label rows.
pub fn current_config(m: usize, k_words: usize, n: usize, panels: bool, threads: usize) -> KernelCfg {
    if mode() == Mode::Fixed {
        return KernelCfg::fixed();
    }
    lookup(&ShapeKey::of(m, k_words, n, panels, threads)).unwrap_or_else(KernelCfg::fixed)
}

/// Resolve the kernel config for one GEMM call.  Fixed mode and
/// registry hits return without touching the operands; a miss in auto
/// mode microbenches the candidates on (`a`, `b_t`, `bp`, `out`)
/// themselves — `out` holds a valid product afterwards (every
/// candidate computes it), and the only allocation is the registry
/// insert.
pub fn config_for(
    a: &BitMatrix,
    b_t: &BitMatrix,
    bp: Option<&BPanels>,
    out: &mut [f32],
    pool: &Pool,
) -> KernelCfg {
    if mode() == Mode::Fixed {
        return KernelCfg::fixed();
    }
    let key = ShapeKey::of(a.rows, b_t.words_per_row, b_t.rows, bp.is_some(), pool.threads());
    if let Some(cfg) = lookup(&key) {
        return cfg;
    }
    let cfg = tune_shape(a, b_t, bp, out, pool);
    registry().write().unwrap().insert(key, cfg);
    cfg
}

/// Candidate micro-kernel configs for phase 1 (band_rows = 0).
fn candidates(panels: bool, out: &mut Vec<KernelCfg>) {
    out.clear();
    let kc = |micro, kc_words| KernelCfg { micro, kc_words, band_rows: 0 };
    if simd::level() == simd::Level::Scalar {
        // no-SIMD tier: only the K tile is worth sweeping
        for w in [32, 128, 512] {
            out.push(kc(MicroKernel::Scalar4x4, w));
        }
    } else {
        out.push(kc(MicroKernel::Simd1x4, 128));
        out.push(kc(MicroKernel::Simd1x8, 128));
        out.push(kc(MicroKernel::Simd2x4, 128));
        out.push(kc(MicroKernel::Scalar4x4, 128));
        if panels {
            out.push(kc(MicroKernel::Panel8, 128));
        }
    }
}

/// Best-of-N wall time of one config on the real operands (one warmup
/// run, then the minimum of `TRIALS` timed runs — min is the standard
/// robust estimator for microbenches on a shared machine).
fn bench_cfg(
    cfg: KernelCfg,
    a: &BitMatrix,
    b_t: &BitMatrix,
    bp: Option<&BPanels>,
    out: &mut [f32],
    pool: &Pool,
) -> f64 {
    const TRIALS: usize = 2;
    gemm::xnor_gemm_with(cfg, a, b_t, bp, out, pool);
    let mut best = f64::INFINITY;
    for _ in 0..TRIALS {
        let t0 = Instant::now();
        gemm::xnor_gemm_with(cfg, a, b_t, bp, out, pool);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Two-phase microbench: pick the micro-kernel with the default band
/// split, then sweep row-band granularities for the winner (bands
/// only matter with >1 worker).  ~10–20 GEMM runs total, once per
/// shape class per process (or zero with a pre-warmed `--tune-cache`).
fn tune_shape(
    a: &BitMatrix,
    b_t: &BitMatrix,
    bp: Option<&BPanels>,
    out: &mut [f32],
    pool: &Pool,
) -> KernelCfg {
    let mut cands = Vec::new();
    candidates(bp.is_some(), &mut cands);
    let mut best = KernelCfg::fixed();
    let mut best_t = f64::INFINITY;
    for &cfg in &cands {
        let t = bench_cfg(cfg, a, b_t, bp, out, pool);
        if t < best_t {
            best_t = t;
            best = cfg;
        }
    }
    if pool.threads() > 1 && a.rows > 1 {
        for band_rows in [8usize, 32] {
            if band_rows >= a.rows {
                continue;
            }
            let cfg = KernelCfg { band_rows, ..best };
            let t = bench_cfg(cfg, a, b_t, bp, out, pool);
            if t < best_t {
                best_t = t;
                best = cfg;
            }
        }
    }
    best
}

// ---------------------------------------------------------------- JSON cache

/// Serialize the registry:
/// `{"level": "<simd>", "entries": [{m_class, k_words, n, panels,
/// threads, micro, kc_words, band_rows}, ...]}` — rows sorted by key
/// so repeated saves of the same registry are byte-identical.
pub fn save_cache(path: &str) -> std::io::Result<usize> {
    let rows = entries();
    let mut entries = Vec::with_capacity(rows.len());
    for (k, c) in &rows {
        let mut e = Json::obj();
        e.set("m_class", Json::from(k.m_class));
        e.set("k_words", Json::from(k.k_words));
        e.set("n", Json::from(k.n));
        e.set("panels", Json::from(k.panels));
        e.set("threads", Json::from(k.threads));
        e.set("micro", Json::from(c.micro.name()));
        e.set("kc_words", Json::from(c.kc_words));
        e.set("band_rows", Json::from(c.band_rows));
        entries.push(e);
    }
    let mut root = Json::obj();
    root.set("level", Json::from(simd::label()));
    root.set("entries", Json::Arr(entries));
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, root.to_string_pretty())?;
    Ok(rows.len())
}

/// Load a cache file into the registry (merging over existing
/// entries).  Returns the number of entries installed; a file written
/// on a host with a different detected SIMD level installs nothing —
/// tile choices do not transfer across ISAs.
pub fn load_cache(path: &str) -> anyhow::Result<usize> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("tune cache {path}: {e}"))?;
    let root = Json::parse(&text)?;
    if root.req("level")?.as_str()? != simd::label() {
        return Ok(0);
    }
    let mut n = 0;
    let mut reg = registry().write().unwrap();
    for e in root.req("entries")?.as_arr()? {
        let micro = MicroKernel::parse(e.req("micro")?.as_str()?)
            .ok_or_else(|| anyhow::anyhow!("unknown micro-kernel in tune cache"))?;
        let key = ShapeKey {
            m_class: e.req("m_class")?.as_usize()?,
            k_words: e.req("k_words")?.as_usize()?,
            n: e.req("n")?.as_usize()?,
            panels: e.req("panels")?.as_bool()?,
            threads: e.req("threads")?.as_usize()?,
        };
        let cfg = KernelCfg {
            micro,
            kc_words: e.req("kc_words")?.as_usize()?.max(1),
            band_rows: e.req("band_rows")?.as_usize()?,
        };
        reg.insert(key, cfg);
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::sync::Mutex;

    /// Tests here flip the process-global mode; serialize them and
    /// always restore Fixed (other tests assume the default).
    fn mode_lock() -> &'static Mutex<()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        L.get_or_init(|| Mutex::new(()))
    }

    fn rand_ops(g: &mut Pcg32, m: usize, k: usize, n: usize) -> (BitMatrix, BitMatrix) {
        let a = BitMatrix::pack(m, k, &g.normal_vec(m * k));
        let b_t = BitMatrix::pack(n, k, &g.normal_vec(n * k));
        (a, b_t)
    }

    #[test]
    fn fixed_mode_never_tunes() {
        let _g = mode_lock().lock().unwrap();
        set_mode(Mode::Fixed);
        let mut g = Pcg32::new(11);
        let (a, b_t) = rand_ops(&mut g, 5, 130, 7);
        let mut out = vec![0.0f32; 5 * 7];
        let before = len();
        let cfg = config_for(&a, &b_t, None, &mut out, &Pool::serial());
        assert_eq!(cfg, KernelCfg::fixed());
        assert_eq!(len(), before, "fixed mode must not insert registry entries");
    }

    #[test]
    fn auto_mode_caches_and_replays_one_choice() {
        let _g = mode_lock().lock().unwrap();
        set_mode(Mode::Auto);
        let mut g = Pcg32::new(12);
        let (a, b_t) = rand_ops(&mut g, 9, 200, 17);
        let panels = BPanels::pack(&b_t);
        let mut out = vec![0.0f32; 9 * 17];
        let pool = Pool::new(2);
        let key = ShapeKey::of(9, b_t.words_per_row, 17, true, pool.threads());
        registry().write().unwrap().remove(&key);
        let cfg = config_for(&a, &b_t, Some(&panels), &mut out, &pool);
        // the microbench leaves a correct product behind
        let mut want = vec![0.0f32; 9 * 17];
        gemm::xnor_gemm_naive(&a, &b_t, &mut want);
        assert_eq!(out, want);
        // replay: same key → same cached choice, registry stable
        assert_eq!(lookup(&key), Some(cfg));
        let n_before = len();
        let again = config_for(&a, &b_t, Some(&panels), &mut out, &pool);
        assert_eq!(again, cfg);
        assert_eq!(len(), n_before);
        // a partial "last batch" (m=7 < 9, same power-of-two bucket
        // boundary 16) shares the class — no re-tune mid-epoch
        assert_eq!(m_class(9), m_class(16));
        set_mode(Mode::Fixed);
    }

    #[test]
    fn cache_roundtrips_and_filters_by_level() {
        let _g = mode_lock().lock().unwrap();
        set_mode(Mode::Auto);
        let mut g = Pcg32::new(13);
        let (a, b_t) = rand_ops(&mut g, 4, 64, 5);
        let mut out = vec![0.0f32; 4 * 5];
        let _ = config_for(&a, &b_t, None, &mut out, &Pool::serial());
        set_mode(Mode::Fixed);

        let dir = std::env::temp_dir().join(format!("bnn_tune_{}", std::process::id()));
        let path = dir.join("tune.json").to_string_lossy().into_owned();
        let saved = save_cache(&path).unwrap();
        assert!(saved >= 1);
        // byte-identical on re-save (sorted rows)
        let t1 = std::fs::read_to_string(&path).unwrap();
        save_cache(&path).unwrap();
        assert_eq!(t1, std::fs::read_to_string(&path).unwrap());

        clear();
        assert_eq!(len(), 0);
        let loaded = load_cache(&path).unwrap();
        assert_eq!(loaded, saved);
        assert_eq!(len(), saved);

        // a cache from a different SIMD level installs nothing
        let foreign = t1.replace(simd::label(), "not-a-real-level");
        let fpath = dir.join("foreign.json").to_string_lossy().into_owned();
        std::fs::write(&fpath, foreign).unwrap();
        clear();
        assert_eq!(load_cache(&fpath).unwrap(), 0);
        assert_eq!(len(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mode_parse() {
        assert_eq!(parse_mode("fixed"), Some(Mode::Fixed));
        assert_eq!(parse_mode("auto"), Some(Mode::Auto));
        assert_eq!(parse_mode("fast"), None);
        // default is fixed (bit-reproducible CI)
        assert_eq!(parse_mode("fixed").unwrap(), Mode::Fixed);
    }
}
