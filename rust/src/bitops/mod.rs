//! Bit-packing substrate: the XNOR-popcount GEMM of BNN training.
//!
//! Binary tensors are packed 64 values/word (bit = 1 ⇔ +1).  The dot
//! product of two ±1 vectors of length k is
//!
//! ```text
//! dot = k − 2·popcount(a XOR b)
//! ```
//!
//! — one `xor` + one `popcnt` per 64 elements, the arithmetic the
//! paper's inference-side literature (FINN et al.) builds on and what
//! our proposed-scheme naive engine uses for both storage (32× smaller
//! activations) and compute.
//!
//! The kernel stack (see [`gemm`]) has three tiers selected by
//! [`Backend`]: the paper's naïve prototype, the 1×4 blocked "CBLAS"
//! path of Fig. 7, and the tiled kernel — SIMD XOR-popcount panels
//! (AVX2 `vpshufb` / NEON `vcnt`, runtime-dispatched via [`simd`])
//! with a scalar 4×4 fallback — row-parallel over the persistent
//! worker [`Pool`].  The tiled tier's micro-kernel, K tile and band
//! split are chosen per shape class by the [`tune`] autotuner
//! (deterministic fixed dispatch by default, `--tune=auto` to
//! microbench; wide layers stream B through interleaved [`BPanels`]).
//! Packing, unpacking and transposition are all
//! word-level (branch-free pack, 64×64 bit-block transpose) so the
//! non-GEMM overheads stay negligible next to the popcount stream;
//! [`PackedWeightCache`] lets the training engines pack each layer's
//! binarized weights once per step instead of once per matmul, and
//! [`im2col_packed`] signs and packs conv patches straight into row
//! panels so the binary conv path never materializes an f32 im2col
//! buffer.  All conv kernels take a [`ConvGeom`] — stride, padding
//! and independent input/output spatial dims — so stride-1 SAME,
//! strided SAME and VALID convs run the same packed pipeline.
//!
//! The conv **backward** is fused the same way: [`conv_dx_streaming`]
//! computes `col2im(∂Y·Ŵᵀ)` tap-by-tap (one rows×cin panel, never the
//! rows×k²·Cin `dcols` buffer) and [`packed_at_gemm_f32`] contracts
//! `X̂ᵀ·∂Y` straight from the packed activation panel (no f32 unpack,
//! no transpose), with [`subtract_pad_dw_contrib`] restoring zero-pad
//! dW semantics for the standard engine.

pub mod backend;
pub mod cache;
pub mod gemm;
pub mod geom;
pub mod im2col;
pub mod pool;
pub mod simd;
pub mod tune;

pub use backend::Backend;
pub use cache::PackedWeightCache;
pub use geom::ConvGeom;
pub use gemm::{
    gemm_f32_at, packed_at_gemm_f32, xnor_gemm, xnor_gemm_naive, xnor_gemm_parallel,
    xnor_gemm_tiled, xnor_gemm_with, BPanels, KernelCfg, MicroKernel,
};
pub use im2col::{
    col2im_tap_scatter, conv_dx_streaming, conv_dx_streaming_into, im2col_packed,
    im2col_packed_into, subtract_pad_contrib, subtract_pad_contrib_with,
    subtract_pad_dw_contrib, subtract_pad_dw_contrib_with,
};
pub use pool::{sweep_stats, Pool, SweepStats};

/// A bit-packed ±1 matrix, row-major, rows padded to whole u64 words.
/// Bit set ⇔ +1; zero-padded tail bits are corrected for in the GEMM.
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    pub words_per_row: usize,
    pub data: Vec<u64>,
}

/// Transpose a 64×64 bit block in place, word-level (Hacker's
/// Delight 7-3, mirrored for our LSB-first column convention):
/// log₂64 = 6 passes of masked swap instead of 4096 bit probes.
#[inline]
fn transpose64(a: &mut [u64; 64]) {
    let mut j: usize = 32;
    let mut m: u64 = 0xFFFF_FFFF_0000_0000;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            let t = (a[k] ^ (a[k + j] << j)) & m;
            a[k] ^= t;
            a[k + j] ^= t >> j;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m >> j;
    }
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> BitMatrix {
        let wpr = cols.div_ceil(64);
        BitMatrix { rows, cols, words_per_row: wpr, data: vec![0; rows * wpr] }
    }

    /// Pack the signs of an f32 row-major matrix (x ≥ 0 ⇔ +1, the
    /// paper's sgn with sgn(0) = +1).  Branch-free: each output word
    /// is assembled from 64 sign tests in registers and stored once.
    pub fn pack(rows: usize, cols: usize, xs: &[f32]) -> BitMatrix {
        let mut m = BitMatrix::zeros(rows, cols);
        BitMatrix::pack_into(rows, cols, xs, &mut m);
        m
    }

    /// [`BitMatrix::pack`] into caller-owned storage: `out` is
    /// reshaped (its word buffer reused — no allocation when the
    /// capacity suffices) and every word including the zero tail is
    /// overwritten, so recycled dirty storage is fine.  The
    /// steady-state engines route all per-step packing through this.
    pub fn pack_into(rows: usize, cols: usize, xs: &[f32], out: &mut BitMatrix) {
        assert_eq!(xs.len(), rows * cols);
        out.reshape(rows, cols);
        let wpr = out.words_per_row;
        for r in 0..rows {
            let row = &xs[r * cols..(r + 1) * cols];
            let words = &mut out.data[r * wpr..(r + 1) * wpr];
            for (w, chunk) in words.iter_mut().zip(row.chunks(64)) {
                let mut acc = 0u64;
                for (b, &v) in chunk.iter().enumerate() {
                    acc |= ((v >= 0.0) as u64) << b;
                }
                *w = acc;
            }
        }
    }

    /// Re-dimension in place, reusing the word buffer when it is
    /// large enough.  Word contents after a grow are unspecified;
    /// every packing routine that accepts recycled storage overwrites
    /// (or pre-zeros) all words.
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        let wpr = cols.div_ceil(64);
        self.rows = rows;
        self.cols = cols;
        self.words_per_row = wpr;
        self.data.resize(rows * wpr, 0);
    }

    /// Unpack to ±1 f32.
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        self.unpack_into(&mut out);
        out
    }

    /// Unpack into a caller-owned buffer (every cell written, recycled
    /// dirty storage fine).
    pub fn unpack_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows * self.cols);
        for r in 0..self.rows {
            let base = r * self.words_per_row;
            let orow = &mut out[r * self.cols..(r + 1) * self.cols];
            for (c, o) in orow.iter_mut().enumerate() {
                *o = if self.data[base + (c >> 6)] >> (c & 63) & 1 == 1 { 1.0 } else { -1.0 };
            }
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        if self.data[r * self.words_per_row + (c >> 6)] >> (c & 63) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Pack the signs of an f16-bit-pattern matrix (k rows × n cols,
    /// row-major) directly into the *transposed* (n × k) layout the
    /// XNOR GEMM wants — no f32 materialization, no separate
    /// transpose pass (§Perf: saves ~30% of the proposed forward).
    /// Sign convention matches `pack`: x >= 0 ⇔ +1, and -0.0 ⇔ +1.
    ///
    /// Word-level: 64×64 tiles are read row-major (input-sequential)
    /// while 64 output words accumulate branch-free in registers and
    /// are stored once each — no per-bit read-modify-write of the
    /// output array.
    pub fn pack_f16_t(f16_bits: &[u16], k: usize, n: usize) -> BitMatrix {
        let mut m = BitMatrix::zeros(n, k);
        BitMatrix::pack_f16_t_into(f16_bits, k, n, &mut m);
        m
    }

    /// [`BitMatrix::pack_f16_t`] into caller-owned storage (see
    /// [`BitMatrix::pack_into`]; all words are overwritten).
    pub fn pack_f16_t_into(f16_bits: &[u16], k: usize, n: usize, m: &mut BitMatrix) {
        assert_eq!(f16_bits.len(), k * n);
        m.reshape(n, k);
        let wpr = m.words_per_row;
        let mut j0 = 0;
        while j0 < n {
            let jb = 64.min(n - j0);
            for wi in 0..wpr {
                let k0 = wi * 64;
                let kb = 64.min(k - k0);
                let mut words = [0u64; 64];
                for t in 0..kb {
                    let row = &f16_bits[(k0 + t) * n + j0..(k0 + t) * n + j0 + jb];
                    for (jj, w) in words[..jb].iter_mut().enumerate() {
                        let h = row[jj];
                        // +1 unless strictly negative (sign set, nonzero)
                        let nonneg = (h >> 15 == 0) | (h & 0x7fff == 0);
                        *w |= (nonneg as u64) << t;
                    }
                }
                for (jj, &w) in words[..jb].iter().enumerate() {
                    m.data[(j0 + jj) * wpr + wi] = w;
                }
            }
            j0 += 64;
        }
    }

    /// Transpose (used to lay out W column-major for the GEMM):
    /// 64×64 word-level block transpose, O(rows·cols/64) word ops
    /// instead of the old O(rows·cols) bit-by-bit scatter.  Padding
    /// bits stay zero (gathered blocks are zero-padded), preserving
    /// the GEMM's exact-tail invariant.
    pub fn transpose(&self) -> BitMatrix {
        let mut t = BitMatrix::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// [`BitMatrix::transpose`] into caller-owned storage (see
    /// [`BitMatrix::pack_into`]; every destination word is written).
    pub fn transpose_into(&self, t: &mut BitMatrix) {
        t.reshape(self.cols, self.rows);
        let twpr = t.words_per_row;
        let mut blk = [0u64; 64];
        let mut rb = 0;
        while rb < self.rows {
            let rn = 64.min(self.rows - rb);
            let tw = rb >> 6; // destination word index for these rows
            for cb in 0..self.words_per_row {
                for (i, b) in blk.iter_mut().enumerate() {
                    *b = if i < rn { self.data[(rb + i) * self.words_per_row + cb] } else { 0 };
                }
                transpose64(&mut blk);
                let c0 = cb << 6;
                let cn = 64.min(self.cols - c0);
                for (j, &w) in blk[..cn].iter().enumerate() {
                    t.data[(c0 + j) * twpr + tw] = w;
                }
            }
            rb += 64;
        }
    }

    /// Heap bytes (what the tracking allocator will see).
    pub fn heap_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// Pack a boolean mask (true ⇔ keep) — STE / pooling masks, 1 bit each.
#[derive(Clone, Debug)]
pub struct BitMask {
    pub len: usize,
    pub data: Vec<u64>,
}

impl BitMask {
    pub fn from_bools<I: IntoIterator<Item = bool>>(len: usize, it: I) -> BitMask {
        let mut m = BitMask { len, data: vec![0; len.div_ceil(64)] };
        m.fill_from_bools(it);
        m
    }

    /// Re-fill an existing (recycled) mask in place.  The word buffer
    /// is rewritten wholesale — each word is assembled in a register
    /// and stored once — so dirty recycled storage is fine; `len`
    /// must match the mask's current length.
    pub fn fill_from_bools<I: IntoIterator<Item = bool>>(&mut self, it: I) {
        let mut it = it.into_iter();
        for w in self.data.iter_mut() {
            let mut acc = 0u64;
            for b in 0..64 {
                match it.next() {
                    Some(true) => acc |= 1 << b,
                    Some(false) => {}
                    None => break,
                }
            }
            *w = acc;
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.data[i >> 6] |= 1 << (i & 63);
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.data[i >> 6] >> (i & 63) & 1 == 1
    }

    pub fn count_ones(&self) -> usize {
        self.data.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn heap_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
    use crate::util::rng::Pcg32;

    #[test]
    fn pack_unpack_roundtrip() {
        let mut g = Pcg32::new(1);
        for (r, c) in [(1, 1), (3, 64), (5, 65), (7, 130), (16, 100)] {
            let xs = g.normal_vec(r * c);
            let m = BitMatrix::pack(r, c, &xs);
            let u = m.unpack();
            for i in 0..xs.len() {
                assert_eq!(u[i], if xs[i] >= 0.0 { 1.0 } else { -1.0 });
            }
        }
    }

    #[test]
    fn sign_zero_is_plus_one() {
        // NB: -0.0 >= 0.0 is true in IEEE, so both zeros pack to +1 —
        // matching jnp.where(x >= 0, 1, -1).
        let m = BitMatrix::pack(1, 3, &[0.0, -0.0, -1.0]);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.get(0, 2), -1.0);
    }

    #[test]
    fn pack_padding_bits_are_zero() {
        // the GEMM's exactness relies on zero tail bits even for
        // all-positive inputs
        let m = BitMatrix::pack(3, 70, &vec![1.0; 3 * 70]);
        for r in 0..3 {
            let last = m.row_words(r)[m.words_per_row - 1];
            assert_eq!(last >> (70 % 64), 0, "row {r}");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut g = Pcg32::new(2);
        let xs = g.normal_vec(9 * 70);
        let m = BitMatrix::pack(9, 70, &xs);
        assert_eq!(m.transpose().transpose(), m);
        let t = m.transpose();
        for r in 0..9 {
            for c in 0..70 {
                assert_eq!(m.get(r, c), t.get(c, r));
            }
        }
    }

    #[test]
    fn block_transpose_matches_scalar_all_shapes() {
        // exercise every tail case of the 64×64 blocking: exact
        // multiples, single row/col, both dims crossing one block
        let mut g = Pcg32::new(8);
        for (r, c) in [
            (1, 1),
            (1, 200),
            (64, 1),
            (64, 64),
            (65, 63),
            (100, 64),
            (128, 200),
            (130, 129),
        ] {
            let xs = g.normal_vec(r * c);
            let m = BitMatrix::pack(r, c, &xs);
            let t = m.transpose();
            assert_eq!(t.rows, c);
            assert_eq!(t.cols, r);
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(m.get(i, j), t.get(j, i), "{r}x{c} @ ({i},{j})");
                }
            }
            // padding bits of the transpose stay zero
            if t.cols % 64 != 0 {
                for i in 0..t.rows {
                    let last = t.row_words(i)[t.words_per_row - 1];
                    assert_eq!(last >> (t.cols % 64), 0, "{r}x{c} t-row {i}");
                }
            }
        }
    }

    #[test]
    fn pack_f16_t_matches_pack_then_transpose() {
        let mut g = Pcg32::new(9);
        for (k, n) in [(1, 1), (3, 5), (64, 64), (65, 70), (130, 33), (200, 129)] {
            let xs = g.normal_vec(k * n);
            let bits: Vec<u16> = xs.iter().map(|&v| f32_to_f16_bits(v)).collect();
            let direct = BitMatrix::pack_f16_t(&bits, k, n);
            // reference over the f16-roundtripped values (f16 may round
            // a tiny negative to -0.0, which packs as +1 in both paths)
            let rt: Vec<f32> = bits.iter().map(|&h| f16_bits_to_f32(h)).collect();
            let via_f32 = BitMatrix::pack(k, n, &rt).transpose();
            assert_eq!(direct, via_f32, "{k}x{n}");
        }
        // -0.0 in f16 packs as +1 (sign bit set, magnitude zero)
        let neg0 = BitMatrix::pack_f16_t(&[0x8000u16], 1, 1);
        assert_eq!(neg0.get(0, 0), 1.0);
    }

    #[test]
    fn storage_is_32x_smaller() {
        let m = BitMatrix::pack(100, 1024, &vec![1.0; 100 * 1024]);
        assert_eq!(m.heap_bytes(), 100 * 1024 / 8);
        assert_eq!(100 * 1024 * 4 / m.heap_bytes(), 32);
    }

    #[test]
    fn bitmask_basics() {
        let m = BitMask::from_bools(130, (0..130).map(|i| i % 3 == 0));
        assert!(m.get(0) && m.get(3) && !m.get(1));
        assert_eq!(m.count_ones(), (0..130).filter(|i| i % 3 == 0).count());
    }
}
