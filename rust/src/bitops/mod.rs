//! Bit-packing substrate: the XNOR-popcount GEMM of BNN training.
//!
//! Binary tensors are packed 64 values/word (bit = 1 ⇔ +1).  The dot
//! product of two ±1 vectors of length k is
//!
//! ```text
//! dot = k − 2·popcount(a XOR b)
//! ```
//!
//! — one `xor` + one `popcnt` per 64 elements, the arithmetic the
//! paper's inference-side literature (FINN et al.) builds on and what
//! our proposed-scheme naive engine uses for both storage (32× smaller
//! activations) and compute.  The blocked variant is the "CBLAS"
//! accelerated path of Fig. 7; `xnor_gemm_naive` is the paper's naïve
//! prototype.

pub mod gemm;

pub use gemm::{xnor_gemm, xnor_gemm_naive};

/// A bit-packed ±1 matrix, row-major, rows padded to whole u64 words.
/// Bit set ⇔ +1; zero-padded tail bits are corrected for in the GEMM.
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    pub words_per_row: usize,
    pub data: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> BitMatrix {
        let wpr = cols.div_ceil(64);
        BitMatrix { rows, cols, words_per_row: wpr, data: vec![0; rows * wpr] }
    }

    /// Pack the signs of an f32 row-major matrix (x ≥ 0 ⇔ +1, the
    /// paper's sgn with sgn(0) = +1).
    pub fn pack(rows: usize, cols: usize, xs: &[f32]) -> BitMatrix {
        assert_eq!(xs.len(), rows * cols);
        let mut m = BitMatrix::zeros(rows, cols);
        for r in 0..rows {
            let row = &xs[r * cols..(r + 1) * cols];
            let base = r * m.words_per_row;
            for (c, &v) in row.iter().enumerate() {
                if v >= 0.0 {
                    m.data[base + (c >> 6)] |= 1u64 << (c & 63);
                }
            }
        }
        m
    }

    /// Unpack to ±1 f32.
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = vec![-1.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            let base = r * self.words_per_row;
            for c in 0..self.cols {
                if self.data[base + (c >> 6)] >> (c & 63) & 1 == 1 {
                    out[r * self.cols + c] = 1.0;
                }
            }
        }
        out
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        if self.data[r * self.words_per_row + (c >> 6)] >> (c & 63) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        &self.data[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Pack the signs of an f16-bit-pattern matrix (k rows × n cols,
    /// row-major) directly into the *transposed* (n × k) layout the
    /// XNOR GEMM wants — no f32 materialization, no separate
    /// transpose pass (§Perf: saves ~30% of the proposed forward).
    /// Sign convention matches `pack`: x >= 0 ⇔ +1, and -0.0 ⇔ +1.
    pub fn pack_f16_t(f16_bits: &[u16], k: usize, n: usize) -> BitMatrix {
        assert_eq!(f16_bits.len(), k * n);
        let mut m = BitMatrix::zeros(n, k);
        for kk in 0..k {
            let row = &f16_bits[kk * n..(kk + 1) * n];
            for (j, &h) in row.iter().enumerate() {
                // +1 unless strictly negative (sign bit set, nonzero)
                if h >> 15 == 0 || h & 0x7fff == 0 {
                    m.data[j * m.words_per_row + (kk >> 6)] |= 1u64 << (kk & 63);
                }
            }
        }
        m
    }

    /// Transpose (used to lay out W column-major for the GEMM).
    pub fn transpose(&self) -> BitMatrix {
        let mut t = BitMatrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            let base = r * self.words_per_row;
            for c in 0..self.cols {
                if self.data[base + (c >> 6)] >> (c & 63) & 1 == 1 {
                    t.data[c * t.words_per_row + (r >> 6)] |= 1u64 << (r & 63);
                }
            }
        }
        t
    }

    /// Heap bytes (what the tracking allocator will see).
    pub fn heap_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// Pack a boolean mask (true ⇔ keep) — STE / pooling masks, 1 bit each.
#[derive(Clone, Debug)]
pub struct BitMask {
    pub len: usize,
    pub data: Vec<u64>,
}

impl BitMask {
    pub fn from_bools<I: IntoIterator<Item = bool>>(len: usize, it: I) -> BitMask {
        let mut m = BitMask { len, data: vec![0; len.div_ceil(64)] };
        for (i, b) in it.into_iter().enumerate() {
            if b {
                m.data[i >> 6] |= 1 << (i & 63);
            }
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.data[i >> 6] >> (i & 63) & 1 == 1
    }

    pub fn count_ones(&self) -> usize {
        self.data.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn heap_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn pack_unpack_roundtrip() {
        let mut g = Pcg32::new(1);
        for (r, c) in [(1, 1), (3, 64), (5, 65), (7, 130), (16, 100)] {
            let xs = g.normal_vec(r * c);
            let m = BitMatrix::pack(r, c, &xs);
            let u = m.unpack();
            for i in 0..xs.len() {
                assert_eq!(u[i], if xs[i] >= 0.0 { 1.0 } else { -1.0 });
            }
        }
    }

    #[test]
    fn sign_zero_is_plus_one() {
        // NB: -0.0 >= 0.0 is true in IEEE, so both zeros pack to +1 —
        // matching jnp.where(x >= 0, 1, -1).
        let m = BitMatrix::pack(1, 3, &[0.0, -0.0, -1.0]);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.get(0, 2), -1.0);
    }

    #[test]
    fn transpose_involution() {
        let mut g = Pcg32::new(2);
        let xs = g.normal_vec(9 * 70);
        let m = BitMatrix::pack(9, 70, &xs);
        assert_eq!(m.transpose().transpose(), m);
        let t = m.transpose();
        for r in 0..9 {
            for c in 0..70 {
                assert_eq!(m.get(r, c), t.get(c, r));
            }
        }
    }

    #[test]
    fn storage_is_32x_smaller() {
        let m = BitMatrix::pack(100, 1024, &vec![1.0; 100 * 1024]);
        assert_eq!(m.heap_bytes(), 100 * 1024 / 8);
        assert_eq!(100 * 1024 * 4 / m.heap_bytes(), 32);
    }

    #[test]
    fn bitmask_basics() {
        let m = BitMask::from_bools(130, (0..130).map(|i| i % 3 == 0));
        assert!(m.get(0) && m.get(3) && !m.get(1));
        assert_eq!(m.count_ones(), (0..130).filter(|i| i % 3 == 0).count());
    }
}
