//! Kernel dispatch: one enum selects which GEMM tier every engine,
//! bench and CLI entry point runs.
//!
//! - [`Backend::Naive`]   — triple-loop kernels, minimal buffers (the
//!   paper's naïve prototype).
//! - [`Backend::Blocked`] — 1×4 register-blocked XNOR kernel + cache-
//!   blocked f32 GEMM (the original "CBLAS" path of Fig. 7).
//! - [`Backend::Tiled`]   — the fast tier: SIMD XOR-popcount panels
//!   (AVX2/NEON via [`super::simd`]) falling back to the scalar 4×4
//!   MR×NR micro-kernel with K-word tiling, row-parallel over the
//!   persistent worker [`Pool`] (`threads = 1` is the pure
//!   single-core kernel).
//!
//! The enum is `Copy` and carries its thread count, so engines stash
//! one and dispatch per matmul with zero setup cost.  Thread counts
//! come from config/CLI (`--engine tiled --threads N`, `0` = auto).

use anyhow::{bail, Result};

use super::gemm::BPanels;
use super::{gemm, tune, BitMatrix, Pool};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Naive,
    Blocked,
    Tiled { threads: usize },
}

impl Backend {
    /// Parse a backend name; `threads` applies to `tiled` (0 = auto,
    /// resolved immediately so the choice is recorded deterministically).
    pub fn parse(s: &str, threads: usize) -> Result<Backend> {
        Ok(match s {
            "naive" => Backend::Naive,
            "blocked" => Backend::Blocked,
            "tiled" => Backend::Tiled { threads: Pool::resolve(threads) },
            _ => bail!("unknown backend '{s}' (naive|blocked|tiled)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Naive => "naive",
            Backend::Blocked => "blocked",
            Backend::Tiled { .. } => "tiled",
        }
    }

    /// Worker count this backend will use (1 for the serial tiers).
    pub fn threads(&self) -> usize {
        match self {
            Backend::Tiled { threads } => Pool::resolve(*threads),
            _ => 1,
        }
    }

    /// Worker pool for the fused non-GEMM stages (bit-im2col): the
    /// persistent shared pool for `Tiled`, inline for serial tiers.
    pub fn pool(&self) -> Pool {
        match self {
            Backend::Tiled { threads } => Pool::new(*threads),
            _ => Pool::serial(),
        }
    }

    /// Display label, e.g. `tiled(4)`.
    pub fn label(&self) -> String {
        match self {
            Backend::Tiled { .. } => format!("tiled({})", self.threads()),
            _ => self.name().to_string(),
        }
    }

    /// Packed ±1 GEMM: out (m×n) = a (m×k) @ b (k×n), `b_t` packed
    /// transposed.  All tiers are bit-exact.
    pub fn xnor_gemm(&self, a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
        self.xnor_gemm_packed(a, b_t, None, out);
    }

    /// [`Backend::xnor_gemm`] with optional pre-packed B panels.  The
    /// `Tiled` tier routes through the autotuner ([`tune::config_for`]):
    /// fixed mode / registry hits cost one atomic load + hash lookup,
    /// a first-use miss under `--tune=auto` microbenches on these very
    /// buffers.  `Naive` and `Blocked` stay untouched reference tiers
    /// (panels ignored); every path is bit-exact, so the tier and the
    /// tuner only ever change speed.
    pub fn xnor_gemm_packed(
        &self,
        a: &BitMatrix,
        b_t: &BitMatrix,
        bp: Option<&BPanels>,
        out: &mut [f32],
    ) {
        match self {
            Backend::Naive => gemm::xnor_gemm_naive(a, b_t, out),
            Backend::Blocked => gemm::xnor_gemm(a, b_t, out),
            Backend::Tiled { threads } => {
                let pool = Pool::new(*threads);
                let cfg = tune::config_for(a, b_t, bp, out, &pool);
                gemm::xnor_gemm_with(cfg, a, b_t, bp, out, &pool);
            }
        }
    }

    /// Dense f32 GEMM: out = a (m×k) @ b (k×n).
    pub fn gemm_f32(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
        match self {
            Backend::Naive => gemm::gemm_f32_naive(m, k, n, a, b, out),
            Backend::Blocked => gemm::gemm_f32(m, k, n, a, b, out),
            Backend::Tiled { threads } => {
                gemm::gemm_f32_parallel(m, k, n, a, b, out, &Pool::new(*threads))
            }
        }
    }

    /// Dense f32 GEMM, accumulating: out += a (m×k) @ b (k×n).  Same
    /// ascending-k per-cell order as [`Backend::gemm_f32`] within each
    /// tier, so a k-partition summed tap-by-tap is bit-identical to
    /// one full-k call (the fused first-conv path relies on this).
    pub fn gemm_f32_acc(
        &self,
        m: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
    ) {
        match self {
            Backend::Naive | Backend::Blocked => gemm::gemm_f32_acc(m, k, n, a, b, out),
            Backend::Tiled { threads } => {
                gemm::gemm_f32_acc_parallel(m, k, n, a, b, out, &Pool::new(*threads))
            }
        }
    }

    /// Packed-A real GEMM of the conv/dense backward's dW: out (k×n)
    /// = Âᵀ @ B, `a` the bit-packed (rows×k) ±1 activations, `b` the
    /// dense (rows×n) ∂Y.  Row-banded over the tier's pool on `Tiled`;
    /// bit-identical across tiers and thread counts.
    pub fn packed_at_gemm_f32(&self, a: &BitMatrix, b: &[f32], n: usize, out: &mut [f32]) {
        gemm::packed_at_gemm_f32(a, b, n, out, &self.pool());
    }

    /// f32 AᵀB GEMM without materializing Aᵀ: out (k×n) = aᵀ (rows×k)
    /// @ b (rows×n) — the reference backward's transpose-free dW.
    pub fn gemm_f32_at(
        &self,
        rows: usize,
        k: usize,
        n: usize,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
    ) {
        gemm::gemm_f32_at(rows, k, n, a, b, out, &self.pool());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn parse_and_labels() {
        assert_eq!(Backend::parse("naive", 0).unwrap(), Backend::Naive);
        assert_eq!(Backend::parse("blocked", 7).unwrap(), Backend::Blocked);
        match Backend::parse("tiled", 3).unwrap() {
            Backend::Tiled { threads } => assert_eq!(threads, 3),
            other => panic!("{other:?}"),
        }
        // auto thread count resolves to something positive
        assert!(Backend::parse("tiled", 0).unwrap().threads() >= 1);
        // fused-stage pool matches the tier's parallelism
        assert_eq!(Backend::Tiled { threads: 3 }.pool().threads(), 3);
        assert_eq!(Backend::Blocked.pool().threads(), 1);
        assert!(Backend::parse("gpu", 0).is_err());
        assert_eq!(Backend::parse("tiled", 2).unwrap().label(), "tiled(2)");
        assert_eq!(Backend::Blocked.label(), "blocked");
    }

    #[test]
    fn all_backends_agree() {
        let mut g = Pcg32::new(11);
        let (m, k, n) = (5, 130, 7);
        let a = g.normal_vec(m * k);
        let bt = g.normal_vec(n * k); // transposed layout
        let ap = BitMatrix::pack(m, k, &a);
        let btp = BitMatrix::pack(n, k, &bt);
        let mut want = vec![0.0; m * n];
        Backend::Naive.xnor_gemm(&ap, &btp, &mut want);
        let panels = BPanels::pack(&btp);
        for be in [Backend::Blocked, Backend::Tiled { threads: 1 }, Backend::Tiled { threads: 3 }]
        {
            let mut got = vec![0.0; m * n];
            be.xnor_gemm(&ap, &btp, &mut got);
            assert_eq!(got, want, "{}", be.label());
            got.fill(9.0);
            be.xnor_gemm_packed(&ap, &btp, Some(&panels), &mut got);
            assert_eq!(got, want, "{} packed", be.label());
        }

        let b = g.normal_vec(k * n);
        let mut fw = vec![0.0; m * n];
        Backend::Naive.gemm_f32(m, k, n, &a, &b, &mut fw);
        for be in [Backend::Blocked, Backend::Tiled { threads: 2 }] {
            let mut got = vec![0.0; m * n];
            be.gemm_f32(m, k, n, &a, &b, &mut got);
            for i in 0..fw.len() {
                assert!((got[i] - fw[i]).abs() < 1e-3, "{} @ {i}", be.label());
            }
        }

        // accumulating variant adds on top of what's there, every tier
        for be in [Backend::Naive, Backend::Blocked, Backend::Tiled { threads: 2 }] {
            let mut got = vec![1.5; m * n];
            be.gemm_f32_acc(m, k, n, &a, &b, &mut got);
            for i in 0..fw.len() {
                assert!((got[i] - 1.5 - fw[i]).abs() < 1e-3, "{} acc @ {i}", be.label());
            }
        }
    }
}
