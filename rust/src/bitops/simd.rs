//! Runtime-dispatched XOR-popcount inner kernels.
//!
//! The popcount stream is the whole cost of the XNOR GEMM, and scalar
//! `count_ones` (`popcnt`) retires one word per instruction.  The
//! vector kernels here count 4 words (AVX2) or 2 words (NEON) per
//! step:
//!
//! - **AVX2** — the Mula `vpshufb` nibble-LUT: two table lookups give
//!   per-byte popcounts of `a ^ b`, `vpsadbw` folds them into u64
//!   lanes, so the accumulators can never overflow regardless of K.
//! - **NEON** — `vcnt` gives per-byte popcounts directly; a
//!   pairwise-widen chain folds them to u64 lanes.
//! - **Scalar** — `u64::count_ones`, the reference every other level
//!   is bit-exact against (popcounts are integers: any organization
//!   yields identical results).
//!
//! Dispatch is detected once (`is_x86_feature_detected!("avx2")` /
//! `cfg(target_arch = "aarch64")`, cached in an atomic) and branched
//! per kernel call — nanoseconds next to a K-word popcount sweep.
//! The NEON path is compile-checked by CI's `aarch64-unknown-linux-gnu`
//! cross job so it cannot rot on x86 dev machines.

use std::sync::atomic::{AtomicU8, Ordering};

/// Detected instruction tier for the popcount kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    Scalar,
    Avx2,
    Neon,
}

/// Cached runtime detection (first call probes, later calls load).
pub fn level() -> Level {
    static CACHE: AtomicU8 = AtomicU8::new(0);
    match CACHE.load(Ordering::Relaxed) {
        1 => Level::Scalar,
        2 => Level::Avx2,
        3 => Level::Neon,
        _ => {
            let l = detect();
            let code = match l {
                Level::Scalar => 1,
                Level::Avx2 => 2,
                Level::Neon => 3,
            };
            CACHE.store(code, Ordering::Relaxed);
            l
        }
    }
}

/// Human-readable tier (bench prints / README dispatch table).
pub fn label() -> &'static str {
    match level() {
        Level::Scalar => "scalar",
        Level::Avx2 => "avx2",
        Level::Neon => "neon",
    }
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Level {
    if std::is_x86_feature_detected!("avx2") {
        Level::Avx2
    } else {
        Level::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Level {
    Level::Neon // baseline on aarch64, no runtime probe needed
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Level {
    Level::Scalar
}

/// Σ_w popcount(a[w] ^ b[w]) — dispatched.  Slices must have equal
/// length (the packed K axis of both operands).
#[inline]
pub fn xor_popcount(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::xor_popcount_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::xor_popcount_neon(a, b) },
        _ => xor_popcount_scalar(a, b),
    }
}

/// Four mismatch counts of one packed A row against a 4-row B panel —
/// dispatched.  Loads each A word once per panel (the 1×4 reuse the
/// blocked kernels exploit), XORs it against all four B rows.
#[inline]
pub fn xor_popcount_1x4(a: &[u64], b0: &[u64], b1: &[u64], b2: &[u64], b3: &[u64]) -> [u64; 4] {
    debug_assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len()
    );
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::xor_popcount_1x4_avx2(a, b0, b1, b2, b3) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::xor_popcount_1x4_neon(a, b0, b1, b2, b3) },
        _ => xor_popcount_1x4_scalar(a, b0, b1, b2, b3),
    }
}

/// Eight mismatch counts of one packed A row against an 8-row B panel
/// — dispatched.  Same reuse idea as 1×4 with twice the B fan-out:
/// each A word is loaded once and XORed against eight B rows, the
/// widest panel before accumulator pressure costs more than the loads
/// save.  The autotuner picks between 1×4 / 1×8 / 2×4 per shape.
#[inline]
pub fn xor_popcount_1x8(a: &[u64], b: [&[u64]; 8]) -> [u64; 8] {
    debug_assert!(b.iter().all(|r| r.len() == a.len()));
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::xor_popcount_1x8_avx2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::xor_popcount_1x8_neon(a, b) },
        _ => xor_popcount_1x8_scalar(a, b),
    }
}

/// Eight mismatch counts of a 2-row A block against a 4-row B panel —
/// dispatched.  Loads each B word once per pair of A rows (the 2×4
/// register block), trading A reuse for B reuse; wins on tall-M
/// shapes where the A panel stays cache-hot.
#[inline]
pub fn xor_popcount_2x4(a0: &[u64], a1: &[u64], b: [&[u64]; 4]) -> [u64; 8] {
    debug_assert!(a0.len() == a1.len() && b.iter().all(|r| r.len() == a0.len()));
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::xor_popcount_2x4_avx2(a0, a1, b) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::xor_popcount_2x4_neon(a0, a1, b) },
        _ => xor_popcount_2x4_scalar(a0, a1, b),
    }
}

/// Eight mismatch counts of one packed A row against an *interleaved*
/// 8-column B panel — dispatched.  `panel[w * 8 + l]` holds word `w`
/// of panel column `l` (see `gemm::BPanels`), so the whole inner loop
/// is one contiguous forward stream over `panel`: 8 B words per 64
/// bytes of sequential reads, where the strided row layout costs 8
/// scattered cache lines at large N.
#[inline]
pub fn xor_popcount_p8(a: &[u64], panel: &[u64]) -> [u64; 8] {
    debug_assert_eq!(panel.len(), a.len() * 8);
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::xor_popcount_p8_avx2(a, panel) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::xor_popcount_p8_neon(a, panel) },
        _ => xor_popcount_p8_scalar(a, panel),
    }
}

/// Σ_w popcount(a[w]) — dispatched.  The federated vote tally's
/// inner kernel: after the word transpose, one weight's votes are a
/// contiguous word run, and this is all that remains of counting
/// them.
#[inline]
pub fn popcount(a: &[u64]) -> u64 {
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::popcount_avx2(a) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::popcount_neon(a) },
        _ => popcount_scalar(a),
    }
}

/// Scalar reference (also the fallback tier).
#[inline]
pub fn popcount_scalar(a: &[u64]) -> u64 {
    a.iter().map(|&x| x.count_ones() as u64).sum()
}

/// Scalar reference (also the fallback tier).
#[inline]
pub fn xor_popcount_scalar(a: &[u64], b: &[u64]) -> u64 {
    a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones() as u64).sum()
}

/// Scalar reference for the 1×4 panel kernel.
#[inline]
pub fn xor_popcount_1x4_scalar(
    a: &[u64],
    b0: &[u64],
    b1: &[u64],
    b2: &[u64],
    b3: &[u64],
) -> [u64; 4] {
    let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
    for w in 0..a.len() {
        let aw = a[w];
        c0 += (aw ^ b0[w]).count_ones() as u64;
        c1 += (aw ^ b1[w]).count_ones() as u64;
        c2 += (aw ^ b2[w]).count_ones() as u64;
        c3 += (aw ^ b3[w]).count_ones() as u64;
    }
    [c0, c1, c2, c3]
}

/// Scalar reference for the 1×8 panel kernel.
#[inline]
pub fn xor_popcount_1x8_scalar(a: &[u64], b: [&[u64]; 8]) -> [u64; 8] {
    let mut c = [0u64; 8];
    for w in 0..a.len() {
        let aw = a[w];
        for (j, row) in b.iter().enumerate() {
            c[j] += (aw ^ row[w]).count_ones() as u64;
        }
    }
    c
}

/// Scalar reference for the interleaved-panel kernel.
#[inline]
pub fn xor_popcount_p8_scalar(a: &[u64], panel: &[u64]) -> [u64; 8] {
    let mut c = [0u64; 8];
    for (w, &aw) in a.iter().enumerate() {
        let pw = &panel[w * 8..w * 8 + 8];
        for l in 0..8 {
            c[l] += (aw ^ pw[l]).count_ones() as u64;
        }
    }
    c
}

/// Scalar reference for the 2×4 panel kernel.  Output layout:
/// `[a0^b0..a0^b3, a1^b0..a1^b3]`.
#[inline]
pub fn xor_popcount_2x4_scalar(a0: &[u64], a1: &[u64], b: [&[u64]; 4]) -> [u64; 8] {
    let mut c = [0u64; 8];
    for w in 0..a0.len() {
        let (x0, x1) = (a0[w], a1[w]);
        for (j, row) in b.iter().enumerate() {
            let bw = row[w];
            c[j] += (x0 ^ bw).count_ones() as u64;
            c[4 + j] += (x1 ^ bw).count_ones() as u64;
        }
    }
    c
}

// ------------------------------------------------------- f32 row ops
//
// The packed conv *backward* streams f32 rows: the streaming-col2im
// scatter adds tap panels into the dX map, and the packed-A dW GEMM
// adds/subtracts ∂Y rows into weight-gradient rows selected by X̂
// bits.  These elementwise kernels are the whole inner loop there.
// Every level is bit-exact (elementwise add/sub/mul never
// reassociates, and axpy is mul-then-add — no FMA — so vector and
// scalar round identically).

/// dst[i] += src[i] — dispatched.
#[inline]
pub fn add_assign_f32(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::add_assign_avx2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::add_assign_neon(dst, src) },
        _ => add_assign_f32_scalar(dst, src),
    }
}

/// dst[i] -= src[i] — dispatched.
#[inline]
pub fn sub_assign_f32(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::sub_assign_avx2(dst, src) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::sub_assign_neon(dst, src) },
        _ => sub_assign_f32_scalar(dst, src),
    }
}

/// dst[i] += a * src[i] — dispatched (mul-then-add, never fused).
#[inline]
pub fn axpy_f32(dst: &mut [f32], a: f32, src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    match level() {
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { x86::axpy_avx2(dst, a, src) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::axpy_neon(dst, a, src) },
        _ => axpy_f32_scalar(dst, a, src),
    }
}

/// Scalar reference (also the fallback tier).
#[inline]
pub fn add_assign_f32_scalar(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Scalar reference (also the fallback tier).
#[inline]
pub fn sub_assign_f32_scalar(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d -= s;
    }
}

/// Scalar reference (also the fallback tier).
#[inline]
pub fn axpy_f32_scalar(dst: &mut [f32], a: f32, src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += a * s;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Per-byte popcount of a 256-bit vector (Mula's vpshufb LUT).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_bytes(x: __m256i, lut: __m256i, mask: __m256i) -> __m256i {
        unsafe {
            let lo = _mm256_and_si256(x, mask);
            let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), mask);
            _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
        }
    }

    /// Popcounts of the nibbles 0..=15, twice (one per 128-bit lane).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn nibble_lut() -> __m256i {
        unsafe {
            _mm256_setr_epi8(
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            )
        }
    }

    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn sum_lanes_u64(v: __m256i) -> u64 {
        let mut lanes = [0u64; 4];
        unsafe { _mm256_storeu_si256(lanes.as_mut_ptr().cast(), v) };
        lanes.iter().sum()
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn popcount_avx2(a: &[u64]) -> u64 {
        unsafe {
            let lut = nibble_lut();
            let mask = _mm256_set1_epi8(0x0f);
            let zero = _mm256_setzero_si256();
            let mut acc = zero;
            let n4 = a.len() & !3;
            let mut w = 0;
            while w < n4 {
                let va = _mm256_loadu_si256(a.as_ptr().add(w).cast());
                let cnt = popcnt_bytes(va, lut, mask);
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
                w += 4;
            }
            let mut total = sum_lanes_u64(acc);
            while w < a.len() {
                total += a[w].count_ones() as u64;
                w += 1;
            }
            total
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcount_avx2(a: &[u64], b: &[u64]) -> u64 {
        unsafe {
            let lut = nibble_lut();
            let mask = _mm256_set1_epi8(0x0f);
            let zero = _mm256_setzero_si256();
            let mut acc = zero;
            let n4 = a.len() & !3;
            let mut w = 0;
            while w < n4 {
                let va = _mm256_loadu_si256(a.as_ptr().add(w).cast());
                let vb = _mm256_loadu_si256(b.as_ptr().add(w).cast());
                let cnt = popcnt_bytes(_mm256_xor_si256(va, vb), lut, mask);
                acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
                w += 4;
            }
            let mut total = sum_lanes_u64(acc);
            while w < a.len() {
                total += (a[w] ^ b[w]).count_ones() as u64;
                w += 1;
            }
            total
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcount_1x4_avx2(
        a: &[u64],
        b0: &[u64],
        b1: &[u64],
        b2: &[u64],
        b3: &[u64],
    ) -> [u64; 4] {
        unsafe {
            let lut = nibble_lut();
            let mask = _mm256_set1_epi8(0x0f);
            let zero = _mm256_setzero_si256();
            let (mut s0, mut s1, mut s2, mut s3) = (zero, zero, zero, zero);
            let n4 = a.len() & !3;
            let mut w = 0;
            while w < n4 {
                let va = _mm256_loadu_si256(a.as_ptr().add(w).cast());
                let v0 = _mm256_loadu_si256(b0.as_ptr().add(w).cast());
                let v1 = _mm256_loadu_si256(b1.as_ptr().add(w).cast());
                let v2 = _mm256_loadu_si256(b2.as_ptr().add(w).cast());
                let v3 = _mm256_loadu_si256(b3.as_ptr().add(w).cast());
                let c0 = popcnt_bytes(_mm256_xor_si256(va, v0), lut, mask);
                let c1 = popcnt_bytes(_mm256_xor_si256(va, v1), lut, mask);
                let c2 = popcnt_bytes(_mm256_xor_si256(va, v2), lut, mask);
                let c3 = popcnt_bytes(_mm256_xor_si256(va, v3), lut, mask);
                s0 = _mm256_add_epi64(s0, _mm256_sad_epu8(c0, zero));
                s1 = _mm256_add_epi64(s1, _mm256_sad_epu8(c1, zero));
                s2 = _mm256_add_epi64(s2, _mm256_sad_epu8(c2, zero));
                s3 = _mm256_add_epi64(s3, _mm256_sad_epu8(c3, zero));
                w += 4;
            }
            let mut out =
                [sum_lanes_u64(s0), sum_lanes_u64(s1), sum_lanes_u64(s2), sum_lanes_u64(s3)];
            while w < a.len() {
                let aw = a[w];
                out[0] += (aw ^ b0[w]).count_ones() as u64;
                out[1] += (aw ^ b1[w]).count_ones() as u64;
                out[2] += (aw ^ b2[w]).count_ones() as u64;
                out[3] += (aw ^ b3[w]).count_ones() as u64;
                w += 1;
            }
            out
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcount_1x8_avx2(a: &[u64], b: [&[u64]; 8]) -> [u64; 8] {
        unsafe {
            let lut = nibble_lut();
            let mask = _mm256_set1_epi8(0x0f);
            let zero = _mm256_setzero_si256();
            let mut acc = [zero; 8];
            let n4 = a.len() & !3;
            let mut w = 0;
            while w < n4 {
                let va = _mm256_loadu_si256(a.as_ptr().add(w).cast());
                for j in 0..8 {
                    let vb = _mm256_loadu_si256(b[j].as_ptr().add(w).cast());
                    let cnt = popcnt_bytes(_mm256_xor_si256(va, vb), lut, mask);
                    acc[j] = _mm256_add_epi64(acc[j], _mm256_sad_epu8(cnt, zero));
                }
                w += 4;
            }
            let mut out = [0u64; 8];
            for j in 0..8 {
                out[j] = sum_lanes_u64(acc[j]);
            }
            while w < a.len() {
                let aw = a[w];
                for j in 0..8 {
                    out[j] += (aw ^ b[j][w]).count_ones() as u64;
                }
                w += 1;
            }
            out
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    /// `panel.len()` must be `a.len() * 8`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcount_p8_avx2(a: &[u64], panel: &[u64]) -> [u64; 8] {
        unsafe {
            let lut = nibble_lut();
            let mask = _mm256_set1_epi8(0x0f);
            let zero = _mm256_setzero_si256();
            // each vpsadbw u64 lane IS one panel column: 2 vectors hold
            // all 8 per-column accumulators
            let (mut s0, mut s1) = (zero, zero);
            for (w, &aw) in a.iter().enumerate() {
                let va = _mm256_set1_epi64x(aw as i64);
                let p0 = _mm256_loadu_si256(panel.as_ptr().add(w * 8).cast());
                let p1 = _mm256_loadu_si256(panel.as_ptr().add(w * 8 + 4).cast());
                let c0 = popcnt_bytes(_mm256_xor_si256(va, p0), lut, mask);
                let c1 = popcnt_bytes(_mm256_xor_si256(va, p1), lut, mask);
                s0 = _mm256_add_epi64(s0, _mm256_sad_epu8(c0, zero));
                s1 = _mm256_add_epi64(s1, _mm256_sad_epu8(c1, zero));
            }
            let mut out = [0u64; 8];
            _mm256_storeu_si256(out.as_mut_ptr().cast(), s0);
            _mm256_storeu_si256(out.as_mut_ptr().add(4).cast(), s1);
            out
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn xor_popcount_2x4_avx2(a0: &[u64], a1: &[u64], b: [&[u64]; 4]) -> [u64; 8] {
        unsafe {
            let lut = nibble_lut();
            let mask = _mm256_set1_epi8(0x0f);
            let zero = _mm256_setzero_si256();
            let mut acc = [zero; 8];
            let n4 = a0.len() & !3;
            let mut w = 0;
            while w < n4 {
                let v0 = _mm256_loadu_si256(a0.as_ptr().add(w).cast());
                let v1 = _mm256_loadu_si256(a1.as_ptr().add(w).cast());
                for j in 0..4 {
                    let vb = _mm256_loadu_si256(b[j].as_ptr().add(w).cast());
                    let c0 = popcnt_bytes(_mm256_xor_si256(v0, vb), lut, mask);
                    let c1 = popcnt_bytes(_mm256_xor_si256(v1, vb), lut, mask);
                    acc[j] = _mm256_add_epi64(acc[j], _mm256_sad_epu8(c0, zero));
                    acc[4 + j] = _mm256_add_epi64(acc[4 + j], _mm256_sad_epu8(c1, zero));
                }
                w += 4;
            }
            let mut out = [0u64; 8];
            for j in 0..8 {
                out[j] = sum_lanes_u64(acc[j]);
            }
            while w < a0.len() {
                let (x0, x1) = (a0[w], a1[w]);
                for j in 0..4 {
                    let bw = b[j][w];
                    out[j] += (x0 ^ bw).count_ones() as u64;
                    out[4 + j] += (x1 ^ bw).count_ones() as u64;
                }
                w += 1;
            }
            out
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_avx2(dst: &mut [f32], src: &[f32]) {
        unsafe {
            let n8 = dst.len() & !7;
            let mut i = 0;
            while i < n8 {
                let d = _mm256_loadu_ps(dst.as_ptr().add(i));
                let s = _mm256_loadu_ps(src.as_ptr().add(i));
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, s));
                i += 8;
            }
            while i < dst.len() {
                dst[i] += src[i];
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sub_assign_avx2(dst: &mut [f32], src: &[f32]) {
        unsafe {
            let n8 = dst.len() & !7;
            let mut i = 0;
            while i < n8 {
                let d = _mm256_loadu_ps(dst.as_ptr().add(i));
                let s = _mm256_loadu_ps(src.as_ptr().add(i));
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_sub_ps(d, s));
                i += 8;
            }
            while i < dst.len() {
                dst[i] -= src[i];
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (see [`super::level`]).
    /// Mul-then-add (no FMA) so rounding matches the scalar path.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(dst: &mut [f32], a: f32, src: &[f32]) {
        unsafe {
            let va = _mm256_set1_ps(a);
            let n8 = dst.len() & !7;
            let mut i = 0;
            while i < n8 {
                let d = _mm256_loadu_ps(dst.as_ptr().add(i));
                let s = _mm256_loadu_ps(src.as_ptr().add(i));
                let p = _mm256_mul_ps(va, s);
                _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, p));
                i += 8;
            }
            while i < dst.len() {
                dst[i] += a * src[i];
                i += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// u64-lane popcount of a 128-bit XOR: vcnt bytes, widen pairwise.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn popcnt_words(x: uint64x2_t) -> uint64x2_t {
        unsafe { vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(x))))) }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    #[target_feature(enable = "neon")]
    pub unsafe fn popcount_neon(a: &[u64]) -> u64 {
        unsafe {
            let mut acc = vdupq_n_u64(0);
            let n2 = a.len() & !1;
            let mut w = 0;
            while w < n2 {
                let va = vld1q_u64(a.as_ptr().add(w));
                acc = vaddq_u64(acc, popcnt_words(va));
                w += 2;
            }
            let mut total = vaddvq_u64(acc);
            if w < a.len() {
                total += a[w].count_ones() as u64;
            }
            total
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_popcount_neon(a: &[u64], b: &[u64]) -> u64 {
        unsafe {
            let mut acc = vdupq_n_u64(0);
            let n2 = a.len() & !1;
            let mut w = 0;
            while w < n2 {
                let va = vld1q_u64(a.as_ptr().add(w));
                let vb = vld1q_u64(b.as_ptr().add(w));
                acc = vaddq_u64(acc, popcnt_words(veorq_u64(va, vb)));
                w += 2;
            }
            let mut total = vaddvq_u64(acc);
            if w < a.len() {
                total += (a[w] ^ b[w]).count_ones() as u64;
            }
            total
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_popcount_1x4_neon(
        a: &[u64],
        b0: &[u64],
        b1: &[u64],
        b2: &[u64],
        b3: &[u64],
    ) -> [u64; 4] {
        unsafe {
            let (mut s0, mut s1, mut s2, mut s3) =
                (vdupq_n_u64(0), vdupq_n_u64(0), vdupq_n_u64(0), vdupq_n_u64(0));
            let n2 = a.len() & !1;
            let mut w = 0;
            while w < n2 {
                let va = vld1q_u64(a.as_ptr().add(w));
                s0 = vaddq_u64(s0, popcnt_words(veorq_u64(va, vld1q_u64(b0.as_ptr().add(w)))));
                s1 = vaddq_u64(s1, popcnt_words(veorq_u64(va, vld1q_u64(b1.as_ptr().add(w)))));
                s2 = vaddq_u64(s2, popcnt_words(veorq_u64(va, vld1q_u64(b2.as_ptr().add(w)))));
                s3 = vaddq_u64(s3, popcnt_words(veorq_u64(va, vld1q_u64(b3.as_ptr().add(w)))));
                w += 2;
            }
            let mut out = [vaddvq_u64(s0), vaddvq_u64(s1), vaddvq_u64(s2), vaddvq_u64(s3)];
            if w < a.len() {
                let aw = a[w];
                out[0] += (aw ^ b0[w]).count_ones() as u64;
                out[1] += (aw ^ b1[w]).count_ones() as u64;
                out[2] += (aw ^ b2[w]).count_ones() as u64;
                out[3] += (aw ^ b3[w]).count_ones() as u64;
            }
            out
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_popcount_1x8_neon(a: &[u64], b: [&[u64]; 8]) -> [u64; 8] {
        unsafe {
            let mut acc = [vdupq_n_u64(0); 8];
            let n2 = a.len() & !1;
            let mut w = 0;
            while w < n2 {
                let va = vld1q_u64(a.as_ptr().add(w));
                for j in 0..8 {
                    let vb = vld1q_u64(b[j].as_ptr().add(w));
                    acc[j] = vaddq_u64(acc[j], popcnt_words(veorq_u64(va, vb)));
                }
                w += 2;
            }
            let mut out = [0u64; 8];
            for j in 0..8 {
                out[j] = vaddvq_u64(acc[j]);
            }
            if w < a.len() {
                let aw = a[w];
                for j in 0..8 {
                    out[j] += (aw ^ b[j][w]).count_ones() as u64;
                }
            }
            out
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    /// `panel.len()` must be `a.len() * 8`.
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_popcount_p8_neon(a: &[u64], panel: &[u64]) -> [u64; 8] {
        unsafe {
            // each 128-bit accumulator lane IS one panel column
            let mut acc = [vdupq_n_u64(0); 4];
            for (w, &aw) in a.iter().enumerate() {
                let va = vdupq_n_u64(aw);
                for v in 0..4 {
                    let p = vld1q_u64(panel.as_ptr().add(w * 8 + v * 2));
                    acc[v] = vaddq_u64(acc[v], popcnt_words(veorq_u64(va, p)));
                }
            }
            let mut out = [0u64; 8];
            for v in 0..4 {
                vst1q_u64(out.as_mut_ptr().add(v * 2), acc[v]);
            }
            out
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    #[target_feature(enable = "neon")]
    pub unsafe fn xor_popcount_2x4_neon(a0: &[u64], a1: &[u64], b: [&[u64]; 4]) -> [u64; 8] {
        unsafe {
            let mut acc = [vdupq_n_u64(0); 8];
            let n2 = a0.len() & !1;
            let mut w = 0;
            while w < n2 {
                let v0 = vld1q_u64(a0.as_ptr().add(w));
                let v1 = vld1q_u64(a1.as_ptr().add(w));
                for j in 0..4 {
                    let vb = vld1q_u64(b[j].as_ptr().add(w));
                    acc[j] = vaddq_u64(acc[j], popcnt_words(veorq_u64(v0, vb)));
                    acc[4 + j] = vaddq_u64(acc[4 + j], popcnt_words(veorq_u64(v1, vb)));
                }
                w += 2;
            }
            let mut out = [0u64; 8];
            for j in 0..8 {
                out[j] = vaddvq_u64(acc[j]);
            }
            if w < a0.len() {
                let (x0, x1) = (a0[w], a1[w]);
                for j in 0..4 {
                    let bw = b[j][w];
                    out[j] += (x0 ^ bw).count_ones() as u64;
                    out[4 + j] += (x1 ^ bw).count_ones() as u64;
                }
            }
            out
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign_neon(dst: &mut [f32], src: &[f32]) {
        unsafe {
            let n4 = dst.len() & !3;
            let mut i = 0;
            while i < n4 {
                let d = vld1q_f32(dst.as_ptr().add(i));
                let s = vld1q_f32(src.as_ptr().add(i));
                vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(d, s));
                i += 4;
            }
            while i < dst.len() {
                dst[i] += src[i];
                i += 1;
            }
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    #[target_feature(enable = "neon")]
    pub unsafe fn sub_assign_neon(dst: &mut [f32], src: &[f32]) {
        unsafe {
            let n4 = dst.len() & !3;
            let mut i = 0;
            while i < n4 {
                let d = vld1q_f32(dst.as_ptr().add(i));
                let s = vld1q_f32(src.as_ptr().add(i));
                vst1q_f32(dst.as_mut_ptr().add(i), vsubq_f32(d, s));
                i += 4;
            }
            while i < dst.len() {
                dst[i] -= src[i];
                i += 1;
            }
        }
    }

    /// # Safety
    /// NEON is baseline on aarch64; caller dispatches via [`super::level`].
    /// vmulq + vaddq (not vfmaq) so rounding matches the scalar path.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_neon(dst: &mut [f32], a: f32, src: &[f32]) {
        unsafe {
            let va = vdupq_n_f32(a);
            let n4 = dst.len() & !3;
            let mut i = 0;
            while i < n4 {
                let d = vld1q_f32(dst.as_ptr().add(i));
                let s = vld1q_f32(src.as_ptr().add(i));
                vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(d, vmulq_f32(va, s)));
                i += 4;
            }
            while i < dst.len() {
                dst[i] += a * src[i];
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn words(g: &mut Pcg32, n: usize) -> Vec<u64> {
        (0..n).map(|_| g.next_u64()).collect()
    }

    #[test]
    fn level_is_cached_and_consistent() {
        let l = level();
        assert_eq!(level(), l);
        assert!(!label().is_empty());
        #[cfg(not(target_arch = "x86_64"))]
        assert_ne!(l, Level::Avx2);
        #[cfg(not(target_arch = "aarch64"))]
        assert_ne!(l, Level::Neon);
    }

    #[test]
    fn dispatched_matches_scalar_all_lengths() {
        // lengths crossing every vector-width remainder case (0..=9
        // words covers AVX2's 4-word and NEON's 2-word strides)
        let mut g = Pcg32::new(31);
        for len in 0..=9usize {
            for _ in 0..20 {
                let a = words(&mut g, len);
                let b = words(&mut g, len);
                assert_eq!(xor_popcount(&a, &b), xor_popcount_scalar(&a, &b), "len {len}");
            }
        }
        for len in [63, 64, 65, 127, 128, 129, 500] {
            let a = words(&mut g, len);
            let b = words(&mut g, len);
            assert_eq!(xor_popcount(&a, &b), xor_popcount_scalar(&a, &b), "len {len}");
        }
    }

    #[test]
    fn dispatched_1x4_matches_scalar() {
        let mut g = Pcg32::new(32);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 129] {
            let a = words(&mut g, len);
            let bs: Vec<Vec<u64>> = (0..4).map(|_| words(&mut g, len)).collect();
            let want = xor_popcount_1x4_scalar(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            let got = xor_popcount_1x4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            assert_eq!(got, want, "len {len}");
            // cross-check one lane against the 1x1 kernel
            assert_eq!(got[2], xor_popcount(&a, &bs[2]), "len {len}");
        }
    }

    #[test]
    fn dispatched_1x8_matches_scalar() {
        let mut g = Pcg32::new(35);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 129] {
            let a = words(&mut g, len);
            let bs: Vec<Vec<u64>> = (0..8).map(|_| words(&mut g, len)).collect();
            let panel: [&[u64]; 8] = std::array::from_fn(|j| bs[j].as_slice());
            let want = xor_popcount_1x8_scalar(&a, panel);
            let got = xor_popcount_1x8(&a, panel);
            assert_eq!(got, want, "len {len}");
            // cross-check lanes against the 1x1 kernel
            for j in 0..8 {
                assert_eq!(got[j], xor_popcount(&a, &bs[j]), "len {len} lane {j}");
            }
        }
    }

    #[test]
    fn dispatched_2x4_matches_scalar() {
        let mut g = Pcg32::new(36);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 129] {
            let a0 = words(&mut g, len);
            let a1 = words(&mut g, len);
            let bs: Vec<Vec<u64>> = (0..4).map(|_| words(&mut g, len)).collect();
            let panel: [&[u64]; 4] = std::array::from_fn(|j| bs[j].as_slice());
            let want = xor_popcount_2x4_scalar(&a0, &a1, panel);
            let got = xor_popcount_2x4(&a0, &a1, panel);
            assert_eq!(got, want, "len {len}");
            for j in 0..4 {
                assert_eq!(got[j], xor_popcount(&a0, &bs[j]), "len {len} lane {j}");
                assert_eq!(got[4 + j], xor_popcount(&a1, &bs[j]), "len {len} lane {j}");
            }
        }
    }

    #[test]
    fn dispatched_p8_matches_scalar_and_rowwise() {
        let mut g = Pcg32::new(37);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 64, 129] {
            let a = words(&mut g, len);
            let bs: Vec<Vec<u64>> = (0..8).map(|_| words(&mut g, len)).collect();
            // interleave: panel[w*8 + l] = bs[l][w]
            let mut panel = vec![0u64; len * 8];
            for w in 0..len {
                for (l, row) in bs.iter().enumerate() {
                    panel[w * 8 + l] = row[w];
                }
            }
            let want = xor_popcount_p8_scalar(&a, &panel);
            let got = xor_popcount_p8(&a, &panel);
            assert_eq!(got, want, "len {len}");
            for (l, row) in bs.iter().enumerate() {
                assert_eq!(got[l], xor_popcount(&a, row), "len {len} lane {l}");
            }
        }
    }

    #[test]
    fn popcount_matches_scalar_all_lengths() {
        let mut g = Pcg32::new(34);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 16, 63, 64, 65, 129, 500] {
            let a = words(&mut g, len);
            assert_eq!(popcount(&a), popcount_scalar(&a), "len {len}");
            // cross-check against the XOR kernel with a zero operand
            let z = vec![0u64; len];
            assert_eq!(popcount(&a), xor_popcount(&a, &z), "len {len}");
        }
        assert_eq!(popcount(&[u64::MAX; 5]), 320);
        assert_eq!(popcount(&[0u64; 9]), 0);
    }

    #[test]
    fn extremes() {
        let a = vec![u64::MAX; 5];
        let z = vec![0u64; 5];
        assert_eq!(xor_popcount(&a, &z), 320);
        assert_eq!(xor_popcount(&a, &a), 0);
        assert_eq!(xor_popcount_1x4(&a, &z, &a, &z, &a), [320, 0, 320, 0]);
    }

    #[test]
    fn f32_row_ops_match_scalar_all_lengths() {
        // bit-exact across SIMD levels: elementwise add/sub and
        // mul-then-add axpy round identically in vector and scalar
        // form — lengths cross AVX2's 8-lane and NEON's 4-lane strides
        let mut g = Pcg32::new(33);
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 33, 128, 257] {
            let src = g.normal_vec(len);
            let base = g.normal_vec(len);
            let a = g.normal();

            let mut want = base.clone();
            add_assign_f32_scalar(&mut want, &src);
            let mut got = base.clone();
            add_assign_f32(&mut got, &src);
            assert_eq!(got, want, "add len {len}");

            let mut want = base.clone();
            sub_assign_f32_scalar(&mut want, &src);
            let mut got = base.clone();
            sub_assign_f32(&mut got, &src);
            assert_eq!(got, want, "sub len {len}");

            let mut want = base.clone();
            axpy_f32_scalar(&mut want, a, &src);
            let mut got = base.clone();
            axpy_f32(&mut got, a, &src);
            assert_eq!(got, want, "axpy len {len}");
        }
    }

    #[test]
    fn f32_row_ops_basics() {
        let mut d = vec![1.0f32, 2.0, 3.0];
        add_assign_f32(&mut d, &[10.0, 20.0, 30.0]);
        assert_eq!(d, vec![11.0, 22.0, 33.0]);
        sub_assign_f32(&mut d, &[1.0, 2.0, 3.0]);
        assert_eq!(d, vec![10.0, 20.0, 30.0]);
        axpy_f32(&mut d, -0.5, &[2.0, 2.0, 2.0]);
        assert_eq!(d, vec![9.0, 19.0, 29.0]);
    }
}
