//! Fused binary im2col: sign-pack conv patches straight into
//! [`BitMatrix`] row panels — for *any* [`ConvGeom`] (stride-1 SAME,
//! strided SAME, VALID).
//!
//! The pre-fusion binary conv *forward* materialized a full f32
//! im2col buffer (`B·OH·OW × k²·Cin × 4` bytes — the hottest
//! transient of the forward pass) and then bit-packed it in a second
//! pass.  The paper's central claim is that binary activations alone
//! need be retained; [`im2col_packed`] realizes that on the forward
//! compute path too: each output row's patch is signed and packed
//! directly from the NHWC activation map, 32× less transient memory
//! and one pass instead of three, threaded over output rows via the
//! persistent [`Pool`].
//!
//! Geometry convention (see [`ConvGeom`]): output position `(oy, ox)`
//! reads input `(oy·stride + ky − pad_h, ox·stride + kx − pad_w)`;
//! out-of-bounds taps are the SAME zero-padding (VALID geometries
//! never go out of bounds, so all pad machinery degenerates away).
//!
//! Padding taps pack as **+1** — the f32 reference writes `0.0` into
//! the cols buffer and `BitMatrix::pack` maps `0.0 ≥ 0` to bit-set —
//! so `im2col_packed(x) == BitMatrix::pack(im2col(x))` bit for bit
//! (the property tests pin this).  That is exactly what the proposed
//! engine's binary conv consumes.  For the *standard* engine, whose
//! f32 conv treats padding as a true zero, [`subtract_pad_contrib`]
//! applies the masked padding edge correction: with pad bits fixed at
//! +1, `y_zero_pad = y_xnor − Σ_{oob taps} Σ_cin ŵ`, a weight-only
//! term subtracted on the border output positions
//! (O(border·k²·Cout), weight scan O(k·Cout/64) word-popcounts).

use super::geom::tap_out_range;
use super::{simd, Backend, BitMatrix, ConvGeom, Pool};

/// OR `vals.len()` sign bits (`v ≥ 0` ⇔ set, the paper's sgn with
/// sgn(0) = +1) into `words` starting at bit offset `bit`, assembling
/// whole words in registers across word boundaries.
#[inline]
fn set_sign_bits(words: &mut [u64], mut bit: usize, vals: &[f32]) {
    let mut i = 0;
    while i < vals.len() {
        let word = bit >> 6;
        let off = bit & 63;
        let take = (64 - off).min(vals.len() - i);
        let mut acc = 0u64;
        for (j, &v) in vals[i..i + take].iter().enumerate() {
            acc |= ((v >= 0.0) as u64) << j;
        }
        words[word] |= acc << off;
        i += take;
        bit += take;
    }
}

/// OR `n` set bits into `words` starting at bit offset `bit` (the
/// +1-packed padding taps).
#[inline]
fn set_ones(words: &mut [u64], mut bit: usize, mut n: usize) {
    while n > 0 {
        let word = bit >> 6;
        let off = bit & 63;
        let take = (64 - off).min(n);
        let mask = if take == 64 { u64::MAX } else { ((1u64 << take) - 1) << off };
        words[word] |= mask;
        bit += take;
        n -= take;
    }
}

/// Pack one patch row: output position (`bi`, `oy`, `ox`) of the conv
/// geometry `g` over the NHWC map `x`.
#[inline]
fn pack_patch(x: &[f32], words: &mut [u64], bi: usize, oy: usize, ox: usize, g: &ConvGeom) {
    let cin = g.cin;
    let mut bit = 0usize;
    for ky in 0..g.kside {
        let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
        let row_ok = sy >= 0 && sy < g.h as isize;
        for kx in 0..g.kside {
            let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
            if row_ok && sx >= 0 && sx < g.w as isize {
                let src = ((bi * g.h + sy as usize) * g.w + sx as usize) * cin;
                set_sign_bits(words, bit, &x[src..src + cin]);
            } else {
                set_ones(words, bit, cin);
            }
            bit += cin;
        }
    }
}

/// Fused sign-pack im2col for conv geometry `g` over the NHWC map `x`
/// (`b`×`h`×`w`×`cin`): returns the packed (B·OH·OW × k²·Cin) patch
/// matrix, bit-identical to `BitMatrix::pack(rows, k, &im2col(x, ..))`
/// — without ever materializing the f32 cols buffer.  Threaded over
/// output rows via `pool` (each worker owns a disjoint band of packed
/// rows).
pub fn im2col_packed(x: &[f32], b: usize, g: ConvGeom, pool: &Pool) -> BitMatrix {
    let mut m = BitMatrix::zeros(g.rows(b), g.k());
    im2col_packed_into(x, b, g, pool, &mut m);
    m
}

/// [`im2col_packed`] into caller-owned storage: `out` is reshaped
/// (word buffer reused, no allocation when capacity suffices) and
/// re-zeroed before packing (patch packing ORs bits into the words).
/// The steady-state engines route every per-step bit-im2col through
/// this with an arena-recycled panel.
pub fn im2col_packed_into(x: &[f32], b: usize, g: ConvGeom, pool: &Pool, out: &mut BitMatrix) {
    assert_eq!(x.len(), g.in_len(b), "NHWC shape mismatch");
    let k = g.k();
    let rows = g.rows(b);
    out.reshape(rows, k);
    out.data.fill(0);
    let wpr = out.words_per_row;
    let per_sample = g.oh * g.ow;
    pool.run_rows(rows, wpr, &mut out.data, |r0, band| {
        for (i, words) in band.chunks_mut(wpr).enumerate() {
            let r = r0 + i;
            let bi = r / per_sample;
            let rem = r % per_sample;
            pack_patch(x, words, bi, rem / g.ow, rem % g.ow, &g);
        }
    });
}

/// Popcount of the bit range `[start, end)` of a packed row.
fn count_bit_range(words: &[u64], start: usize, end: usize) -> u32 {
    debug_assert!(start <= end);
    if start == end {
        return 0;
    }
    let (sw, sb) = (start >> 6, start & 63);
    let (ew, eb) = (end >> 6, end & 63);
    if sw == ew {
        // same word: end > start so 0 < eb - sb < 64
        let mask = ((1u64 << (eb - sb)) - 1) << sb;
        return (words[sw] & mask).count_ones();
    }
    let mut c = (words[sw] >> sb).count_ones();
    for w in &words[sw + 1..ew] {
        c += w.count_ones();
    }
    if eb > 0 {
        c += (words[ew] << (64 - eb)).count_ones();
    }
    c
}

/// Is output position (`oy`, `ox`) interior — i.e. every tap of its
/// kernel window lands inside the input map?
#[inline]
fn interior(oy: usize, ox: usize, g: &ConvGeom) -> bool {
    let y0 = oy * g.stride;
    let x0 = ox * g.stride;
    y0 >= g.pad_h
        && y0 + g.kside - g.pad_h <= g.h
        && x0 >= g.pad_w
        && x0 + g.kside - g.pad_w <= g.w
}

/// Masked padding correction for the fused XNOR conv of the standard
/// engine: `im2col_packed` fixes out-of-bounds taps at +1, so with
/// packed transposed weights `wt` (Cout × k²·Cin) the XNOR product
/// overshoots the zero-padded truth by the padded taps' weight sums.
/// Subtracts, per border output position, `T[tap] = Σ_cin ŵ[tap]` for
/// each out-of-bounds tap; interior positions are untouched.  `y` is
/// the (B·OH·OW × Cout) conv output in place.  No-op for unpadded
/// (VALID / 1×1) geometries.
pub fn subtract_pad_contrib(y: &mut [f32], wt: &BitMatrix, b: usize, g: ConvGeom) {
    if !same_overhangs(&g) {
        return;
    }
    let mut t = vec![0.0f32; g.kside * g.kside * wt.rows];
    subtract_pad_contrib_with(y, wt, b, g, &mut t);
}

/// [`subtract_pad_contrib`] with caller-owned scratch: `scratch` is
/// the (k² × cout) per-tap weight-sum table, fully overwritten, so
/// arena-recycled dirty storage is fine.  Still a no-op (scratch
/// untouched) for unpadded geometries.
pub fn subtract_pad_contrib_with(
    y: &mut [f32],
    wt: &BitMatrix,
    b: usize,
    g: ConvGeom,
    scratch: &mut [f32],
) {
    // a geometry can overhang bottom/right even with zero top/left pad
    // only via SAME-stride interplay; cheapest exact test is below per
    // position, but fully unpadded geometries never overhang at all
    if !same_overhangs(&g) {
        return;
    }
    let cout = wt.rows;
    let kk = g.kside * g.kside;
    let cin = g.cin;
    debug_assert_eq!(wt.cols, kk * cin);
    debug_assert_eq!(y.len(), g.rows(b) * cout);
    // per-tap channel-summed ±1 weights: T[tap][j] = 2·ones − cin
    let t = scratch;
    assert_eq!(t.len(), kk * cout, "pad-contrib scratch mismatch");
    for j in 0..cout {
        let rw = wt.row_words(j);
        for tap in 0..kk {
            let ones = count_bit_range(rw, tap * cin, (tap + 1) * cin);
            t[tap * cout + j] = (2 * ones as i64 - cin as i64) as f32;
        }
    }
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                if interior(oy, ox, &g) {
                    continue;
                }
                let o = ((bi * g.oh + oy) * g.ow + ox) * cout;
                let orow = &mut y[o..o + cout];
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    let y_oob = sy < 0 || sy >= g.h as isize;
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if y_oob || sx < 0 || sx >= g.w as isize {
                            let trow = &t[(ky * g.kside + kx) * cout..][..cout];
                            for (yv, &tv) in orow.iter_mut().zip(trow) {
                                *yv -= tv;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Can any tap of this geometry fall out of bounds?  Checks the four
/// extreme window corners (top-left of position (0,0), bottom-right of
/// position (oh−1, ow−1)).
#[inline]
fn same_overhangs(g: &ConvGeom) -> bool {
    g.pad_h > 0
        || g.pad_w > 0
        || (g.oh - 1) * g.stride + g.kside > g.h + g.pad_h
        || (g.ow - 1) * g.stride + g.kside > g.w + g.pad_w
}

/// Scatter-add one conv tap's (B·OH·OW × cin) panel into the NHWC
/// input gradient map — the streaming col2im inner step.  Output
/// position (bi, oy, ox) contributes its panel row to input position
/// (bi, oy·stride + ky − pad_h, ox·stride + kx − pad_w); out-of-bounds
/// taps are skipped (zero-padding contributes no input gradient).  At
/// stride 1 rows contiguous in x shift together, so each (bi, oy)
/// line is one vector add; strided geometries add per position.
pub fn col2im_tap_scatter(
    dx: &mut [f32],
    panel: &[f32],
    b: usize,
    g: ConvGeom,
    ky: usize,
    kx: usize,
) {
    debug_assert_eq!(dx.len(), g.in_len(b));
    debug_assert_eq!(panel.len(), g.rows(b) * g.cin);
    debug_assert!(ky < g.kside && kx < g.kside);
    let cin = g.cin;
    let s = g.stride;
    let (ylo, yhi) = tap_out_range(g.oh, g.h, g.pad_h, ky, s);
    let (xlo, xhi) = tap_out_range(g.ow, g.w, g.pad_w, kx, s);
    if ylo >= yhi || xlo >= xhi {
        return;
    }
    if s == 1 {
        let run = (xhi - xlo) * cin; // contiguous in x on both sides
        let sx = xlo + kx - g.pad_w;
        for bi in 0..b {
            for oy in ylo..yhi {
                let sy = oy + ky - g.pad_h;
                let src = ((bi * g.oh + oy) * g.ow + xlo) * cin;
                let dst = ((bi * g.h + sy) * g.w + sx) * cin;
                simd::add_assign_f32(&mut dx[dst..dst + run], &panel[src..src + run]);
            }
        }
    } else {
        for bi in 0..b {
            for oy in ylo..yhi {
                let sy = oy * s + ky - g.pad_h;
                for ox in xlo..xhi {
                    let sx = ox * s + kx - g.pad_w;
                    let src = ((bi * g.oh + oy) * g.ow + ox) * cin;
                    let dst = ((bi * g.h + sy) * g.w + sx) * cin;
                    simd::add_assign_f32(&mut dx[dst..dst + cin], &panel[src..src + cin]);
                }
            }
        }
    }
}

/// Streaming col2im-fused dX for the conv backward of geometry `g`:
/// `dx = col2im(∂Y · Ŵᵀ)` computed **tap-by-tap** — per (ky, kx) a
/// (B·OH·OW × cin) panel `∂Y · Ŵᵀ[tap]` (the backend's f32 GEMM,
/// row-banded over the worker pool on the tiled tier) is
/// scatter-added straight into `dx` via [`col2im_tap_scatter`].
///
/// The full (B·OH·OW × k²·Cin) `dcols` patch-gradient buffer — the
/// backward's dominant f32 transient — never exists; the peak
/// transient is one panel (k²× smaller) plus the (Cout × cin) f32 tap
/// weights unpacked from the packed Ŵᵀ.  Equal to
/// `col2im(gemm(∂Y, Ŵᵀ))` up to f32 summation order (taps accumulate
/// tap-major instead of row-major), and identical across backends and
/// thread counts (bands never split a reduction).
pub fn conv_dx_streaming(
    dy: &[f32],
    wt: &BitMatrix,
    b: usize,
    g: ConvGeom,
    backend: Backend,
) -> Vec<f32> {
    let mut dx = vec![0.0f32; g.in_len(b)];
    let mut panel = vec![0.0f32; g.rows(b) * g.cin];
    let mut wtap = vec![0.0f32; wt.rows * g.cin];
    conv_dx_streaming_into(dy, wt, b, g, backend, &mut dx, &mut panel, &mut wtap);
    dx
}

/// [`conv_dx_streaming`] into caller-owned buffers: `dx` must be
/// **zeroed** (`g.in_len(b)` — taps scatter-add into it), while
/// `panel` (rows × cin) and `wtap` (cout × cin) are pure scratch that
/// is fully overwritten per tap, so arena-recycled dirty storage is
/// fine for both.
#[allow(clippy::too_many_arguments)]
pub fn conv_dx_streaming_into(
    dy: &[f32],
    wt: &BitMatrix,
    b: usize,
    g: ConvGeom,
    backend: Backend,
    dx: &mut [f32],
    panel: &mut [f32],
    wtap: &mut [f32],
) {
    let cout = wt.rows;
    let rows = g.rows(b);
    assert_eq!(dy.len(), rows * cout, "dY shape mismatch");
    assert_eq!(wt.cols, g.k(), "Ŵᵀ shape mismatch");
    let cin = g.cin;
    assert_eq!(dx.len(), g.in_len(b), "dX shape mismatch");
    assert_eq!(panel.len(), rows * cin, "panel scratch mismatch");
    assert_eq!(wtap.len(), cout * cin, "wtap scratch mismatch");
    for ky in 0..g.kside {
        for kx in 0..g.kside {
            let tap = ky * g.kside + kx;
            // unpack this tap's (cout × cin) ±1 weight slice from the
            // packed Ŵᵀ row words — never the full (cout × k) f32
            for j in 0..cout {
                let words = wt.row_words(j);
                let row = &mut wtap[j * cin..(j + 1) * cin];
                for (ci, v) in row.iter_mut().enumerate() {
                    let c = tap * cin + ci;
                    *v = if words[c >> 6] >> (c & 63) & 1 == 1 { 1.0 } else { -1.0 };
                }
            }
            backend.gemm_f32(rows, cout, cin, dy, wtap, panel);
            col2im_tap_scatter(dx, panel, b, g, ky, kx);
        }
    }
}

/// Gather one conv tap's (B·OH·OW × cin) f32 input panel from the
/// NHWC map `x`: panel row (bi, oy, ox) is
/// `x[bi, oy·stride + ky − pad_h, ox·stride + kx − pad_w, :]`, zeroed
/// where the tap reads padding — exactly the tap's cin-column slice of
/// the f32 im2col matrix, without that matrix existing.  `panel` is
/// fully overwritten (zero-filled first), so recycled dirty storage is
/// fine.  The adjoint of [`col2im_tap_scatter`] (same `tap_out_range`
/// bounds, same stride-1 contiguous-run fast path).
pub fn gather_tap_f32(
    x: &[f32],
    b: usize,
    g: ConvGeom,
    ky: usize,
    kx: usize,
    panel: &mut [f32],
) {
    debug_assert_eq!(x.len(), g.in_len(b));
    debug_assert_eq!(panel.len(), g.rows(b) * g.cin);
    debug_assert!(ky < g.kside && kx < g.kside);
    panel.fill(0.0);
    let cin = g.cin;
    let s = g.stride;
    let (ylo, yhi) = tap_out_range(g.oh, g.h, g.pad_h, ky, s);
    let (xlo, xhi) = tap_out_range(g.ow, g.w, g.pad_w, kx, s);
    if ylo >= yhi || xlo >= xhi {
        return;
    }
    if s == 1 {
        let run = (xhi - xlo) * cin; // contiguous in x on both sides
        let sx = xlo + kx - g.pad_w;
        for bi in 0..b {
            for oy in ylo..yhi {
                let sy = oy + ky - g.pad_h;
                let dst = ((bi * g.oh + oy) * g.ow + xlo) * cin;
                let src = ((bi * g.h + sy) * g.w + sx) * cin;
                panel[dst..dst + run].copy_from_slice(&x[src..src + run]);
            }
        }
    } else {
        for bi in 0..b {
            for oy in ylo..yhi {
                let sy = oy * s + ky - g.pad_h;
                for ox in xlo..xhi {
                    let sx = ox * s + kx - g.pad_w;
                    let dst = ((bi * g.oh + oy) * g.ow + ox) * cin;
                    let src = ((bi * g.h + sy) * g.w + sx) * cin;
                    panel[dst..dst + cin].copy_from_slice(&x[src..src + cin]);
                }
            }
        }
    }
}

/// One element of the f32 im2col matrix computed straight from the
/// geometry (row `r`, column `c = tap·cin + ci`): the naive tier's
/// row-at-a-time contractions read patches through this instead of
/// materializing the rows×k cols buffer.  Out-of-bounds taps return
/// the zero-padding `0.0`.
#[inline]
pub fn im2col_at(x: &[f32], g: &ConvGeom, r: usize, c: usize) -> f32 {
    let cin = g.cin;
    let tap = c / cin;
    let ci = c % cin;
    let (ky, kx) = (tap / g.kside, tap % g.kside);
    let per_sample = g.oh * g.ow;
    let bi = r / per_sample;
    let rem = r % per_sample;
    let (oy, ox) = (rem / g.ow, rem % g.ow);
    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
    let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
    if sy < 0 || sy >= g.h as isize || sx < 0 || sx >= g.w as isize {
        return 0.0;
    }
    x[((bi * g.h + sy as usize) * g.w + sx as usize) * cin + ci]
}

/// Fused real-input conv **forward**: `y = im2col(x) @ w` streamed
/// tap-by-tap — per (ky, kx) the (B·OH·OW × cin) input panel is
/// gathered ([`gather_tap_f32`]) and accumulated against the tap's
/// contiguous (cin × cout) rows of `w` via the backend's accumulating
/// GEMM.  The (B·OH·OW × k²·Cin) f32 cols buffer — the first layer's
/// last unfused transient — never exists; peak scratch is one panel
/// (k²× smaller).
///
/// **Bit-identical** to `gemm_f32(rows, k, cout, im2col(x), w)` on the
/// same backend at the same thread count: every per-cell sum runs in
/// ascending-k order on both sides (taps ascend = k ascends, the
/// blocked kernels never reorder within a cell, M bands split
/// identically), and zero-padding contributes the same exact `+0.0`
/// terms.  `y` and `panel` are fully overwritten.
pub fn conv_fwd_first_streaming_into(
    x: &[f32],
    w: &[f32],
    b: usize,
    g: ConvGeom,
    cout: usize,
    backend: Backend,
    y: &mut [f32],
    panel: &mut [f32],
) {
    let rows = g.rows(b);
    let cin = g.cin;
    assert_eq!(x.len(), g.in_len(b), "NHWC shape mismatch");
    assert_eq!(w.len(), g.k() * cout, "W shape mismatch");
    assert_eq!(y.len(), rows * cout, "Y shape mismatch");
    assert_eq!(panel.len(), rows * cin, "panel scratch mismatch");
    y.fill(0.0);
    for ky in 0..g.kside {
        for kx in 0..g.kside {
            let tap = ky * g.kside + kx;
            gather_tap_f32(x, b, g, ky, kx, panel);
            let wtap = &w[tap * cin * cout..(tap + 1) * cin * cout];
            backend.gemm_f32_acc(rows, cin, cout, panel, wtap, y);
        }
    }
}

/// Fused real-input conv **dW**: `dw = im2col(x)ᵀ · ∂Y` streamed
/// tap-by-tap — each tap's gathered panel contracts via the backend's
/// transpose-free AᵀB GEMM straight into its own contiguous (cin ×
/// cout) slice of `dw`.  Mirrors [`conv_fwd_first_streaming_into`] in
/// the backward direction, killing the same rows×k cols transient.
///
/// **Bit-identical** to `gemm_f32_at(rows, k, cout, im2col(x), dy,
/// dw)`: tap slices partition the k output axis (never the row
/// reduction), each cell accumulates in ascending row order on both
/// sides, and zero pad entries take the same skip path.  `dw` and
/// `panel` are fully overwritten.
#[allow(clippy::too_many_arguments)]
pub fn conv_dw_first_streaming_into(
    x: &[f32],
    dy: &[f32],
    b: usize,
    g: ConvGeom,
    cout: usize,
    backend: Backend,
    dw: &mut [f32],
    panel: &mut [f32],
) {
    let rows = g.rows(b);
    let cin = g.cin;
    assert_eq!(x.len(), g.in_len(b), "NHWC shape mismatch");
    assert_eq!(dy.len(), rows * cout, "dY shape mismatch");
    assert_eq!(dw.len(), g.k() * cout, "dW shape mismatch");
    assert_eq!(panel.len(), rows * cin, "panel scratch mismatch");
    for ky in 0..g.kside {
        for kx in 0..g.kside {
            let tap = ky * g.kside + kx;
            gather_tap_f32(x, b, g, ky, kx, panel);
            let dst = &mut dw[tap * cin * cout..(tap + 1) * cin * cout];
            backend.gemm_f32_at(rows, cin, cout, panel, dy, dst);
        }
    }
}

/// Masked padding correction for the packed-activation dW of the
/// standard engine: `im2col_packed` fixes out-of-bounds taps at +1,
/// so `X̂ᵀ·∂Y` overshoots the zero-padded truth by the border rows'
/// ∂Y sums.  For each tap, `B[tap][j] = Σ_{r: tap OOB at r} ∂Y[r][j]`
/// is accumulated over border output positions only, then subtracted
/// from all `cin` dW rows of that tap.  O(border·k²·Cout +
/// k²·Cin·Cout) — weight-scale work, no rows×k anything.  No-op for
/// unpadded geometries.
pub fn subtract_pad_dw_contrib(
    dw: &mut [f32],
    dy: &[f32],
    b: usize,
    g: ConvGeom,
    cout: usize,
) {
    if !same_overhangs(&g) {
        return;
    }
    let mut bs = vec![0.0f32; g.kside * g.kside * cout];
    subtract_pad_dw_contrib_with(dw, dy, b, g, cout, &mut bs);
}

/// [`subtract_pad_dw_contrib`] with caller-owned scratch: `scratch`
/// is the (k² × cout) border-∂Y sum table (re-zeroed here, recycled
/// dirty storage fine).  No-op for unpadded geometries.
pub fn subtract_pad_dw_contrib_with(
    dw: &mut [f32],
    dy: &[f32],
    b: usize,
    g: ConvGeom,
    cout: usize,
    scratch: &mut [f32],
) {
    if !same_overhangs(&g) {
        return;
    }
    let kk = g.kside * g.kside;
    debug_assert_eq!(dw.len(), kk * g.cin * cout);
    debug_assert_eq!(dy.len(), g.rows(b) * cout);
    // border ∂Y sums per tap
    let bs = scratch;
    assert_eq!(bs.len(), kk * cout, "pad-dW scratch mismatch");
    bs.fill(0.0);
    for bi in 0..b {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                if interior(oy, ox, &g) {
                    continue;
                }
                let dyr = &dy[((bi * g.oh + oy) * g.ow + ox) * cout..][..cout];
                for ky in 0..g.kside {
                    let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                    let y_oob = sy < 0 || sy >= g.h as isize;
                    for kx in 0..g.kside {
                        let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                        if y_oob || sx < 0 || sx >= g.w as isize {
                            let brow = &mut bs[(ky * g.kside + kx) * cout..][..cout];
                            simd::add_assign_f32(brow, dyr);
                        }
                    }
                }
            }
        }
    }
    for tap in 0..kk {
        let brow = &bs[tap * cout..(tap + 1) * cout];
        for ci in 0..g.cin {
            let drow = &mut dw[(tap * g.cin + ci) * cout..][..cout];
            simd::sub_assign_f32(drow, brow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::gemm::{gemm_f32, packed_at_gemm_f32, xnor_gemm_naive};
    use crate::util::rng::Pcg32;

    /// f32 reference im2col for any geometry (mirrors `naive::im2col`,
    /// kept local so the substrate test has no engine dependency).
    fn im2col_ref(x: &[f32], b: usize, g: &ConvGeom) -> Vec<f32> {
        let k = g.k();
        let mut cols = vec![0.0f32; g.rows(b) * k];
        for bi in 0..b {
            for oy in 0..g.oh {
                for ox in 0..g.ow {
                    let mut idx = ((bi * g.oh + oy) * g.ow + ox) * k;
                    for ky in 0..g.kside {
                        let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                        for kx in 0..g.kside {
                            let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                            if sy >= 0 && sy < g.h as isize && sx >= 0 && sx < g.w as isize {
                                let src =
                                    ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                                cols[idx..idx + g.cin].copy_from_slice(&x[src..src + g.cin]);
                            }
                            idx += g.cin;
                        }
                    }
                }
            }
        }
        cols
    }

    /// f32 reference col2im for any geometry.
    fn col2im_ref(dcols: &[f32], b: usize, g: &ConvGeom) -> Vec<f32> {
        let k = g.k();
        let mut dx = vec![0.0f32; g.in_len(b)];
        for bi in 0..b {
            for oy in 0..g.oh {
                for ox in 0..g.ow {
                    let mut idx = ((bi * g.oh + oy) * g.ow + ox) * k;
                    for ky in 0..g.kside {
                        let sy = (oy * g.stride + ky) as isize - g.pad_h as isize;
                        for kx in 0..g.kside {
                            let sx = (ox * g.stride + kx) as isize - g.pad_w as isize;
                            if sy >= 0 && sy < g.h as isize && sx >= 0 && sx < g.w as isize {
                                let dst =
                                    ((bi * g.h + sy as usize) * g.w + sx as usize) * g.cin;
                                for ci in 0..g.cin {
                                    dx[dst + ci] += dcols[idx + ci];
                                }
                            }
                            idx += g.cin;
                        }
                    }
                }
            }
        }
        dx
    }

    /// (b, geometry) sweep: stride-1 SAME (the legacy cases, word-grid
    /// offenders included), strided SAME, and strided/unit VALID.
    fn geometries() -> Vec<(usize, ConvGeom)> {
        vec![
            // legacy stride-1 SAME
            (1, ConvGeom::same1(4, 4, 1, 1)),
            (1, ConvGeom::same1(5, 5, 3, 3)),
            (2, ConvGeom::same1(4, 4, 5, 3)),
            (1, ConvGeom::same1(6, 6, 33, 3)),
            (3, ConvGeom::same1(5, 5, 2, 5)),
            (1, ConvGeom::same1(7, 7, 13, 5)),
            (2, ConvGeom::same1(3, 3, 64, 1)),
            (1, ConvGeom::same1(4, 4, 70, 3)),
            // strided SAME (even + odd input, ResNet-stem-like k7)
            (2, ConvGeom::same(8, 8, 3, 3, 2)),
            (1, ConvGeom::same(7, 7, 5, 3, 2)),
            (1, ConvGeom::same(9, 9, 2, 7, 2)),
            (2, ConvGeom::same(6, 8, 4, 5, 2)),
            (1, ConvGeom::same(8, 8, 33, 1, 2)),
            // VALID, unit + strided (FINN-CNV-like)
            (2, ConvGeom::valid(6, 6, 3, 3, 1)),
            (1, ConvGeom::valid(8, 8, 17, 3, 2)),
            (1, ConvGeom::valid(7, 5, 2, 5, 1)),
            (2, ConvGeom::valid(9, 9, 4, 2, 3)), // even kernel OK for VALID
        ]
    }

    fn noisy_map(g: &mut Pcg32, n: usize) -> Vec<f32> {
        // include exact zeros: sgn(0) = +1 must match the reference
        g.normal_vec(n)
            .into_iter()
            .enumerate()
            .map(|(i, v)| if i % 17 == 0 { 0.0 } else { v })
            .collect()
    }

    #[test]
    fn fused_matches_im2col_then_pack() {
        let mut rng = Pcg32::new(41);
        for (b, g) in geometries() {
            let x = noisy_map(&mut rng, g.in_len(b));
            let want = BitMatrix::pack(g.rows(b), g.k(), &im2col_ref(&x, b, &g));
            for threads in [1, 2, 4] {
                let got = im2col_packed(&x, b, g, &Pool::new(threads));
                assert_eq!(got, want, "{g:?} b{b} t{threads}");
            }
        }
    }

    #[test]
    fn fused_padding_bits_stay_zero() {
        // tail bits beyond k must stay clear (GEMM exact-tail invariant)
        let mut rng = Pcg32::new(42);
        for (b, g) in geometries() {
            let k = g.k();
            if k % 64 == 0 {
                continue;
            }
            let x = noisy_map(&mut rng, g.in_len(b));
            let m = im2col_packed(&x, b, g, &Pool::serial());
            for r in 0..m.rows {
                let last = m.row_words(r)[m.words_per_row - 1];
                assert_eq!(last >> (k % 64), 0, "{g:?} row {r}");
            }
        }
    }

    #[test]
    fn count_bit_range_matches_bit_probes() {
        let mut g = Pcg32::new(43);
        let words: Vec<u64> = (0..6).map(|_| g.next_u64()).collect();
        let bits = words.len() * 64;
        for start in (0..bits).step_by(7) {
            for end in (start..=bits).step_by(13) {
                let want: u32 =
                    (start..end).map(|c| (words[c >> 6] >> (c & 63) & 1) as u32).sum();
                assert_eq!(count_bit_range(&words, start, end), want, "{start}..{end}");
            }
        }
        assert_eq!(count_bit_range(&words, 5, 5), 0);
        assert_eq!(count_bit_range(&words, 0, 64), words[0].count_ones());
    }

    #[test]
    fn xnor_with_pad_correction_equals_zero_pad_conv() {
        // fused packed conv + correction == f32 zero-padded conv of
        // the signed activations (both sides exact integers) — across
        // SAME/VALID, stride 1/2/3
        let mut rng = Pcg32::new(44);
        for (b, g) in geometries() {
            let k = g.k();
            let rows = g.rows(b);
            let cout = 5;
            let x = noisy_map(&mut rng, g.in_len(b));
            let wf = rng.normal_vec(k * cout);
            // zero-pad reference: im2col of sign(x) (pads stay 0.0)
            // against sign(w), f32 GEMM
            let xs: Vec<f32> =
                x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let cols = im2col_ref(&xs, b, &g);
            let ws: Vec<f32> =
                wf.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let mut want = vec![0.0f32; rows * cout];
            gemm_f32(rows, k, cout, &cols, &ws, &mut want);
            // fused path: packed patches (+1 pads) × packed Ŵᵀ, then
            // the masked edge correction
            let xhat = im2col_packed(&x, b, g, &Pool::serial());
            let mut wt_f = vec![0.0f32; cout * k];
            for kk in 0..k {
                for j in 0..cout {
                    wt_f[j * k + kk] = wf[kk * cout + j];
                }
            }
            let wt = BitMatrix::pack(cout, k, &wt_f);
            let mut got = vec![0.0f32; rows * cout];
            xnor_gemm_naive(&xhat, &wt, &mut got);
            subtract_pad_contrib(&mut got, &wt, b, g);
            assert_eq!(got, want, "{g:?} b{b}");
        }
    }

    #[test]
    fn unpadded_geometries_need_no_correction() {
        let mut rng = Pcg32::new(45);
        for g in [
            ConvGeom::same1(3, 3, 64, 1),
            ConvGeom::valid(6, 6, 5, 3, 1),
            ConvGeom::valid(9, 9, 2, 3, 2),
            ConvGeom::same(8, 8, 3, 1, 2),
        ] {
            let b = 2;
            let cout = 4;
            let wt = BitMatrix::pack(cout, g.k(), &rng.normal_vec(cout * g.k()));
            let mut y = vec![1.5f32; g.rows(b) * cout];
            let before = y.clone();
            subtract_pad_contrib(&mut y, &wt, b, g);
            assert_eq!(y, before, "{g:?}");
            let dy = rng.normal_vec(g.rows(b) * cout);
            let mut dw = vec![0.25f32; g.k() * cout];
            let dbefore = dw.clone();
            subtract_pad_dw_contrib(&mut dw, &dy, b, g, cout);
            assert_eq!(dw, dbefore, "{g:?}");
        }
    }

    #[test]
    fn tap_scatter_sums_to_col2im() {
        // Σ_taps scatter(panel_tap(c)) == col2im(c) (f32 reorder only)
        let mut rng = Pcg32::new(46);
        for (b, g) in geometries() {
            let k = g.k();
            let rows = g.rows(b);
            let c = rng.normal_vec(rows * k);
            let want = col2im_ref(&c, b, &g);
            let mut got = vec![0.0f32; g.in_len(b)];
            let mut panel = vec![0.0f32; rows * g.cin];
            for ky in 0..g.kside {
                for kx in 0..g.kside {
                    let tap = ky * g.kside + kx;
                    for r in 0..rows {
                        panel[r * g.cin..(r + 1) * g.cin].copy_from_slice(
                            &c[r * k + tap * g.cin..r * k + (tap + 1) * g.cin],
                        );
                    }
                    col2im_tap_scatter(&mut got, &panel, b, g, ky, kx);
                }
            }
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                    "{g:?} b{b} @ {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn streaming_dx_matches_gemm_col2im_reference() {
        // conv_dx_streaming == col2im(∂Y · Ŵᵀ) within f32 reorder, on
        // every backend tier and thread count — and it is identical
        // across tiers (same kernels, bands never split a reduction)
        let mut rng = Pcg32::new(47);
        for (b, g) in geometries() {
            let k = g.k();
            let rows = g.rows(b);
            let cout = 5;
            let dy = rng.normal_vec(rows * cout);
            let wt = BitMatrix::pack(cout, k, &rng.normal_vec(cout * k));
            let wt_f = wt.unpack();
            let mut dcols = vec![0.0f32; rows * k];
            gemm_f32(rows, cout, k, &dy, &wt_f, &mut dcols);
            let want = col2im_ref(&dcols, b, &g);
            let first = conv_dx_streaming(&dy, &wt, b, g, Backend::Blocked);
            for i in 0..want.len() {
                assert!(
                    (first[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                    "{g:?} b{b} @ {i}: {} vs {}",
                    first[i],
                    want[i]
                );
            }
            for threads in [1, 2, 4] {
                let got = conv_dx_streaming(&dy, &wt, b, g, Backend::Tiled { threads });
                assert_eq!(got, first, "{g:?} b{b} t{threads}");
            }
        }
    }

    #[test]
    fn packed_dw_with_pad_correction_equals_zero_pad_reference() {
        // im2col_packed(x)ᵀ·∂Y (pads +1) + correction == zero-padded
        // colsᵀ·∂Y — the standard engine's fused dW semantics, across
        // SAME/VALID and strides
        let mut rng = Pcg32::new(48);
        for (b, g) in geometries() {
            let k = g.k();
            let rows = g.rows(b);
            let cout = 4;
            let x = noisy_map(&mut rng, g.in_len(b));
            let dy = rng.normal_vec(rows * cout);
            // reference: zero-pad im2col of sign(x), transposed GEMM
            let xs: Vec<f32> =
                x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let cols = im2col_ref(&xs, b, &g);
            let mut colst = vec![0.0f32; k * rows];
            for r in 0..rows {
                for kk in 0..k {
                    colst[kk * rows + r] = cols[r * k + kk];
                }
            }
            let mut want = vec![0.0f32; k * cout];
            gemm_f32(k, rows, cout, &colst, &dy, &mut want);
            // fused: packed panel, packed-A GEMM, border correction
            let xh = im2col_packed(&x, b, g, &Pool::serial());
            let mut got = vec![0.0f32; k * cout];
            packed_at_gemm_f32(&xh, &dy, cout, &mut got, &Pool::serial());
            subtract_pad_dw_contrib(&mut got, &dy, b, g, cout);
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                    "{g:?} b{b} @ {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn gather_tap_matches_im2col_column_slice() {
        let mut rng = Pcg32::new(49);
        for (b, g) in geometries() {
            let x = noisy_map(&mut rng, g.in_len(b));
            let cols = im2col_ref(&x, b, &g);
            let rows = g.rows(b);
            let k = g.k();
            let mut panel = vec![7.0f32; rows * g.cin]; // dirty recycled
            for ky in 0..g.kside {
                for kx in 0..g.kside {
                    let tap = ky * g.kside + kx;
                    gather_tap_f32(&x, b, g, ky, kx, &mut panel);
                    for r in 0..rows {
                        assert_eq!(
                            &panel[r * g.cin..(r + 1) * g.cin],
                            &cols[r * k + tap * g.cin..r * k + (tap + 1) * g.cin],
                            "{g:?} b{b} tap({ky},{kx}) row {r}"
                        );
                    }
                }
            }
            // single-element reads agree too (naive-tier path)
            for r in (0..rows).step_by(3) {
                for c in (0..k).step_by(5) {
                    assert_eq!(im2col_at(&x, &g, r, c), cols[r * k + c], "{g:?} ({r},{c})");
                }
            }
        }
    }

    #[test]
    fn fused_first_conv_forward_is_bit_identical() {
        // tap-streamed forward == im2col + one full-k GEMM, assert_eq
        // (not tolerance): per-cell sums run in the same ascending-k
        // order on every backend tier and thread count
        let mut rng = Pcg32::new(50);
        for (b, g) in geometries() {
            let rows = g.rows(b);
            let k = g.k();
            let cout = 5;
            let x = noisy_map(&mut rng, g.in_len(b));
            let w = rng.normal_vec(k * cout);
            let cols = im2col_ref(&x, b, &g);
            for backend in [
                Backend::Naive,
                Backend::Blocked,
                Backend::Tiled { threads: 1 },
                Backend::Tiled { threads: 3 },
            ] {
                let mut want = vec![0.0f32; rows * cout];
                backend.gemm_f32(rows, k, cout, &cols, &w, &mut want);
                let mut got = vec![9.0f32; rows * cout]; // dirty recycled
                let mut panel = vec![9.0f32; rows * g.cin];
                conv_fwd_first_streaming_into(&x, &w, b, g, cout, backend, &mut got, &mut panel);
                if matches!(backend, Backend::Naive) {
                    // the naive tier's full-k reference uses a
                    // different (ijk) loop; fused still matches to
                    // rounding there and exactly on the blocked tiers
                    for i in 0..want.len() {
                        assert!(
                            (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                            "{g:?} b{b} naive @ {i}"
                        );
                    }
                } else {
                    assert_eq!(got, want, "{g:?} b{b} {}", backend.label());
                }
            }
        }
    }

    #[test]
    fn fused_first_conv_dw_is_bit_identical() {
        let mut rng = Pcg32::new(51);
        for (b, g) in geometries() {
            let rows = g.rows(b);
            let k = g.k();
            let cout = 4;
            let x = noisy_map(&mut rng, g.in_len(b));
            let dy = rng.normal_vec(rows * cout);
            let cols = im2col_ref(&x, b, &g);
            for backend in [
                Backend::Naive,
                Backend::Blocked,
                Backend::Tiled { threads: 1 },
                Backend::Tiled { threads: 3 },
            ] {
                let mut want = vec![0.0f32; k * cout];
                backend.gemm_f32_at(rows, k, cout, &cols, &dy, &mut want);
                let mut got = vec![8.0f32; k * cout]; // dirty recycled
                let mut panel = vec![8.0f32; rows * g.cin];
                conv_dw_first_streaming_into(&x, &dy, b, g, cout, backend, &mut got, &mut panel);
                assert_eq!(got, want, "{g:?} b{b} {}", backend.label());
            }
        }
    }

    #[test]
    #[should_panic(expected = "odd kernel side")]
    fn even_kside_rejected_by_same_geometry() {
        // SAME geometries (what the packed im2col consumes from the
        // engines) still refuse even kernels at construction
        ConvGeom::same1(4, 4, 2, 2);
    }
}
