//! Fused binary im2col: sign-pack conv patches straight into
//! [`BitMatrix`] row panels.
//!
//! The pre-fusion binary conv *forward* materialized a full f32
//! im2col buffer (`B·H·W × k²·Cin × 4` bytes — the hottest transient
//! of the forward pass) and then bit-packed it in a second pass.  The
//! paper's central claim is that binary activations alone need be
//! retained; [`im2col_packed`] realizes that on the forward compute
//! path too: each output row's patch is signed and packed directly
//! from the NHWC activation map, 32× less transient memory and one
//! pass instead of three, threaded over output rows via the
//! persistent [`Pool`].  (The conv *backward* still materializes
//! rows × k f32 buffers — dX patch gradients, and the standard
//! engine's dW im2col — so the step-level peak is governed by the
//! backward until that lever lands; see ROADMAP perf notes.)
//!
//! Padding convention: SAME zero-padding taps pack as **+1** — the
//! f32 reference wrote `0.0` into the cols buffer and
//! `BitMatrix::pack` maps `0.0 ≥ 0` to bit-set — so
//! `im2col_packed(x) == BitMatrix::pack(im2col(x))` bit for bit (the
//! property tests pin this).  That is exactly what the proposed
//! engine's binary conv consumed all along.  For the *standard*
//! engine, whose f32 conv treats padding as a true zero,
//! [`subtract_pad_contrib`] applies the masked SAME-padding edge
//! correction: with pad bits fixed at +1,
//! `y_zero_pad = y_xnor − Σ_{oob taps} Σ_cin ŵ`, a weight-only term
//! subtracted on the border output columns (O(border·k²·Cout), weight
//! scan O(k·Cout/64) word-popcounts).

use super::{BitMatrix, Pool};

/// OR `vals.len()` sign bits (`v ≥ 0` ⇔ set, the paper's sgn with
/// sgn(0) = +1) into `words` starting at bit offset `bit`, assembling
/// whole words in registers across word boundaries.
#[inline]
fn set_sign_bits(words: &mut [u64], mut bit: usize, vals: &[f32]) {
    let mut i = 0;
    while i < vals.len() {
        let word = bit >> 6;
        let off = bit & 63;
        let take = (64 - off).min(vals.len() - i);
        let mut acc = 0u64;
        for (j, &v) in vals[i..i + take].iter().enumerate() {
            acc |= ((v >= 0.0) as u64) << j;
        }
        words[word] |= acc << off;
        i += take;
        bit += take;
    }
}

/// OR `n` set bits into `words` starting at bit offset `bit` (the
/// +1-packed SAME-padding taps).
#[inline]
fn set_ones(words: &mut [u64], mut bit: usize, mut n: usize) {
    while n > 0 {
        let word = bit >> 6;
        let off = bit & 63;
        let take = (64 - off).min(n);
        let mask = if take == 64 { u64::MAX } else { ((1u64 << take) - 1) << off };
        words[word] |= mask;
        bit += take;
        n -= take;
    }
}

/// Pack one patch row: output position (`bi`, `y`, `x0`) of a
/// stride-1 SAME `kside`×`kside` conv over the NHWC map `x`.
#[allow(clippy::too_many_arguments)]
#[inline]
fn pack_patch(
    x: &[f32],
    words: &mut [u64],
    bi: usize,
    y: usize,
    x0: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
    pad: usize,
) {
    let mut bit = 0usize;
    for ky in 0..kside {
        let sy = y as isize + ky as isize - pad as isize;
        let row_ok = sy >= 0 && sy < h as isize;
        for kx in 0..kside {
            let sx = x0 as isize + kx as isize - pad as isize;
            if row_ok && sx >= 0 && sx < w as isize {
                let src = ((bi * h + sy as usize) * w + sx as usize) * cin;
                set_sign_bits(words, bit, &x[src..src + cin]);
            } else {
                set_ones(words, bit, cin);
            }
            bit += cin;
        }
    }
}

/// Fused sign-pack im2col for a stride-1 SAME `kside`×`kside` conv
/// over the NHWC map `x` (`b`×`h`×`w`×`cin`): returns the packed
/// (B·H·W × k²·Cin) patch matrix, bit-identical to
/// `BitMatrix::pack(b*h*w, k, &im2col(x, ..))` — without ever
/// materializing the f32 cols buffer.  Threaded over output rows via
/// `pool` (each worker owns a disjoint band of packed rows).
pub fn im2col_packed(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
    pool: &Pool,
) -> BitMatrix {
    assert_eq!(x.len(), b * h * w * cin, "NHWC shape mismatch");
    let k = kside * kside * cin;
    let rows = b * h * w;
    let mut m = BitMatrix::zeros(rows, k);
    let wpr = m.words_per_row;
    let pad = (kside - 1) / 2;
    pool.run_rows(rows, wpr, &mut m.data, |r0, band| {
        for (i, words) in band.chunks_mut(wpr).enumerate() {
            let r = r0 + i;
            let bi = r / (h * w);
            let rem = r % (h * w);
            pack_patch(x, words, bi, rem / w, rem % w, h, w, cin, kside, pad);
        }
    });
    m
}

/// Popcount of the bit range `[start, end)` of a packed row.
fn count_bit_range(words: &[u64], start: usize, end: usize) -> u32 {
    debug_assert!(start <= end);
    if start == end {
        return 0;
    }
    let (sw, sb) = (start >> 6, start & 63);
    let (ew, eb) = (end >> 6, end & 63);
    if sw == ew {
        // same word: end > start so 0 < eb - sb < 64
        let mask = ((1u64 << (eb - sb)) - 1) << sb;
        return (words[sw] & mask).count_ones();
    }
    let mut c = (words[sw] >> sb).count_ones();
    for w in &words[sw + 1..ew] {
        c += w.count_ones();
    }
    if eb > 0 {
        c += (words[ew] << (64 - eb)).count_ones();
    }
    c
}

/// Masked SAME-padding correction for the fused XNOR conv of the
/// standard engine: `im2col_packed` fixes out-of-bounds taps at +1,
/// so with packed transposed weights `wt` (Cout × k²·Cin) the XNOR
/// product overshoots the zero-padded truth by the padded taps'
/// weight sums.  Subtracts, per border output position, `T[tap] =
/// Σ_cin ŵ[tap]` for each out-of-bounds tap; interior positions are
/// untouched.  `y` is the (B·H·W × Cout) conv output in place.
pub fn subtract_pad_contrib(
    y: &mut [f32],
    wt: &BitMatrix,
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
) {
    let pad = (kside - 1) / 2;
    if pad == 0 {
        return; // 1×1 taps never leave the map
    }
    let cout = wt.rows;
    let kk = kside * kside;
    debug_assert_eq!(wt.cols, kk * cin);
    debug_assert_eq!(y.len(), b * h * w * cout);
    // per-tap channel-summed ±1 weights: T[tap][j] = 2·ones − cin
    let mut t = vec![0.0f32; kk * cout];
    for j in 0..cout {
        let rw = wt.row_words(j);
        for tap in 0..kk {
            let ones = count_bit_range(rw, tap * cin, (tap + 1) * cin);
            t[tap * cout + j] = (2 * ones as i64 - cin as i64) as f32;
        }
    }
    for bi in 0..b {
        for yy in 0..h {
            for xx in 0..w {
                // interior positions have no out-of-bounds taps
                if yy >= pad && yy + pad < h && xx >= pad && xx + pad < w {
                    continue;
                }
                let o = ((bi * h + yy) * w + xx) * cout;
                let orow = &mut y[o..o + cout];
                for ky in 0..kside {
                    let sy = yy as isize + ky as isize - pad as isize;
                    let y_oob = sy < 0 || sy >= h as isize;
                    for kx in 0..kside {
                        let sx = xx as isize + kx as isize - pad as isize;
                        if y_oob || sx < 0 || sx >= w as isize {
                            let trow = &t[(ky * kside + kx) * cout..][..cout];
                            for (yv, &tv) in orow.iter_mut().zip(trow) {
                                *yv -= tv;
                            }
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::gemm::{gemm_f32, xnor_gemm_naive};
    use crate::util::rng::Pcg32;

    /// f32 reference im2col (mirrors `naive::im2col`, kept local so
    /// the substrate test has no engine dependency).
    fn im2col_ref(x: &[f32], b: usize, h: usize, w: usize, cin: usize, kside: usize) -> Vec<f32> {
        let k = kside * kside * cin;
        let pad = (kside - 1) / 2;
        let mut cols = vec![0.0f32; b * h * w * k];
        for bi in 0..b {
            for y in 0..h {
                for x0 in 0..w {
                    let mut idx = ((bi * h + y) * w + x0) * k;
                    for ky in 0..kside {
                        let sy = y as isize + ky as isize - pad as isize;
                        for kx in 0..kside {
                            let sx = x0 as isize + kx as isize - pad as isize;
                            if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                                let src = ((bi * h + sy as usize) * w + sx as usize) * cin;
                                cols[idx..idx + cin].copy_from_slice(&x[src..src + cin]);
                            }
                            idx += cin;
                        }
                    }
                }
            }
        }
        cols
    }

    fn geometries() -> Vec<(usize, usize, usize, usize, usize)> {
        // (b, h, w, cin, kside): kside 1/3/5, patch widths off the
        // word grid (45, 297, 630 bits), batch 1/3
        vec![
            (1, 4, 4, 1, 1),
            (1, 5, 5, 3, 3),
            (2, 4, 4, 5, 3),
            (1, 6, 6, 33, 3),
            (3, 5, 5, 2, 5),
            (1, 7, 7, 13, 5),
            (2, 3, 3, 64, 1),
            (1, 4, 4, 70, 3),
        ]
    }

    fn noisy_map(g: &mut Pcg32, n: usize) -> Vec<f32> {
        // include exact zeros: sgn(0) = +1 must match the reference
        g.normal_vec(n)
            .into_iter()
            .enumerate()
            .map(|(i, v)| if i % 17 == 0 { 0.0 } else { v })
            .collect()
    }

    #[test]
    fn fused_matches_im2col_then_pack() {
        let mut g = Pcg32::new(41);
        for (b, h, w, cin, kside) in geometries() {
            let x = noisy_map(&mut g, b * h * w * cin);
            let k = kside * kside * cin;
            let want = BitMatrix::pack(b * h * w, k, &im2col_ref(&x, b, h, w, cin, kside));
            for threads in [1, 2, 4] {
                let got = im2col_packed(&x, b, h, w, cin, kside, &Pool::new(threads));
                assert_eq!(got, want, "b{b} {h}x{w}x{cin} k{kside} t{threads}");
            }
        }
    }

    #[test]
    fn fused_padding_bits_stay_zero() {
        // tail bits beyond k must stay clear (GEMM exact-tail invariant)
        let mut g = Pcg32::new(42);
        for (b, h, w, cin, kside) in geometries() {
            let k = kside * kside * cin;
            if k % 64 == 0 {
                continue;
            }
            let x = noisy_map(&mut g, b * h * w * cin);
            let m = im2col_packed(&x, b, h, w, cin, kside, &Pool::serial());
            for r in 0..m.rows {
                let last = m.row_words(r)[m.words_per_row - 1];
                assert_eq!(last >> (k % 64), 0, "row {r}");
            }
        }
    }

    #[test]
    fn count_bit_range_matches_bit_probes() {
        let mut g = Pcg32::new(43);
        let words: Vec<u64> = (0..6).map(|_| g.next_u64()).collect();
        let bits = words.len() * 64;
        for start in (0..bits).step_by(7) {
            for end in (start..=bits).step_by(13) {
                let want: u32 =
                    (start..end).map(|c| (words[c >> 6] >> (c & 63) & 1) as u32).sum();
                assert_eq!(count_bit_range(&words, start, end), want, "{start}..{end}");
            }
        }
        assert_eq!(count_bit_range(&words, 5, 5), 0);
        assert_eq!(count_bit_range(&words, 0, 64), words[0].count_ones());
    }

    #[test]
    fn xnor_with_pad_correction_equals_zero_pad_conv() {
        // fused packed conv + correction == f32 zero-padded conv of
        // the signed activations (both sides exact integers)
        let mut g = Pcg32::new(44);
        for (b, h, w, cin, kside) in geometries() {
            let k = kside * kside * cin;
            let rows = b * h * w;
            let cout = 5;
            let x = noisy_map(&mut g, b * h * w * cin);
            let wf = g.normal_vec(k * cout);
            // zero-pad reference: im2col of sign(x) (pads stay 0.0)
            // against sign(w), f32 GEMM
            let xs: Vec<f32> =
                x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let cols = im2col_ref(&xs, b, h, w, cin, kside);
            let ws: Vec<f32> =
                wf.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let mut want = vec![0.0f32; rows * cout];
            gemm_f32(rows, k, cout, &cols, &ws, &mut want);
            // fused path: packed patches (+1 pads) × packed Ŵᵀ, then
            // the masked edge correction
            let xhat = im2col_packed(&x, b, h, w, cin, kside, &Pool::serial());
            let mut wt_f = vec![0.0f32; cout * k];
            for kk in 0..k {
                for j in 0..cout {
                    wt_f[j * k + kk] = wf[kk * cout + j];
                }
            }
            let wt = BitMatrix::pack(cout, k, &wt_f);
            let mut got = vec![0.0f32; rows * cout];
            xnor_gemm_naive(&xhat, &wt, &mut got);
            subtract_pad_contrib(&mut got, &wt, b, h, w, cin, kside);
            assert_eq!(got, want, "b{b} {h}x{w}x{cin} k{kside}");
        }
    }

    #[test]
    fn kside1_needs_no_correction() {
        let mut g = Pcg32::new(45);
        let (b, h, w, cin) = (2, 3, 3, 64);
        let x = g.normal_vec(b * h * w * cin);
        let wt = BitMatrix::pack(4, cin, &g.normal_vec(4 * cin));
        let mut y = vec![1.5f32; b * h * w * 4];
        let before = y.clone();
        subtract_pad_contrib(&mut y, &wt, b, h, w, cin, 1);
        assert_eq!(y, before);
    }
}
