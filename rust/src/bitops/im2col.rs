//! Fused binary im2col: sign-pack conv patches straight into
//! [`BitMatrix`] row panels.
//!
//! The pre-fusion binary conv *forward* materialized a full f32
//! im2col buffer (`B·H·W × k²·Cin × 4` bytes — the hottest transient
//! of the forward pass) and then bit-packed it in a second pass.  The
//! paper's central claim is that binary activations alone need be
//! retained; [`im2col_packed`] realizes that on the forward compute
//! path too: each output row's patch is signed and packed directly
//! from the NHWC activation map, 32× less transient memory and one
//! pass instead of three, threaded over output rows via the
//! persistent [`Pool`].  (The conv *backward* still materializes
//! rows × k f32 buffers — dX patch gradients, and the standard
//! engine's dW im2col — so the step-level peak is governed by the
//! backward until that lever lands; see ROADMAP perf notes.)
//!
//! Padding convention: SAME zero-padding taps pack as **+1** — the
//! f32 reference wrote `0.0` into the cols buffer and
//! `BitMatrix::pack` maps `0.0 ≥ 0` to bit-set — so
//! `im2col_packed(x) == BitMatrix::pack(im2col(x))` bit for bit (the
//! property tests pin this).  That is exactly what the proposed
//! engine's binary conv consumed all along.  For the *standard*
//! engine, whose f32 conv treats padding as a true zero,
//! [`subtract_pad_contrib`] applies the masked SAME-padding edge
//! correction: with pad bits fixed at +1,
//! `y_zero_pad = y_xnor − Σ_{oob taps} Σ_cin ŵ`, a weight-only term
//! subtracted on the border output columns (O(border·k²·Cout), weight
//! scan O(k·Cout/64) word-popcounts).

use super::{simd, Backend, BitMatrix, Pool};

/// SAME im2col geometry is only symmetric for odd kernels:
/// `pad = (kside-1)/2` silently under-pads the right/bottom for even
/// `kside`.  Every conv entry point asserts this; the engines reject
/// even kernels earlier, at plan-build time (`naive::Plan`).
#[inline]
pub(crate) fn assert_odd_kside(kside: usize) {
    assert!(
        kside % 2 == 1 && kside > 0,
        "SAME conv requires an odd kernel side, got {kside} \
         (pad = (kside-1)/2 would be asymmetric)"
    );
}

/// OR `vals.len()` sign bits (`v ≥ 0` ⇔ set, the paper's sgn with
/// sgn(0) = +1) into `words` starting at bit offset `bit`, assembling
/// whole words in registers across word boundaries.
#[inline]
fn set_sign_bits(words: &mut [u64], mut bit: usize, vals: &[f32]) {
    let mut i = 0;
    while i < vals.len() {
        let word = bit >> 6;
        let off = bit & 63;
        let take = (64 - off).min(vals.len() - i);
        let mut acc = 0u64;
        for (j, &v) in vals[i..i + take].iter().enumerate() {
            acc |= ((v >= 0.0) as u64) << j;
        }
        words[word] |= acc << off;
        i += take;
        bit += take;
    }
}

/// OR `n` set bits into `words` starting at bit offset `bit` (the
/// +1-packed SAME-padding taps).
#[inline]
fn set_ones(words: &mut [u64], mut bit: usize, mut n: usize) {
    while n > 0 {
        let word = bit >> 6;
        let off = bit & 63;
        let take = (64 - off).min(n);
        let mask = if take == 64 { u64::MAX } else { ((1u64 << take) - 1) << off };
        words[word] |= mask;
        bit += take;
        n -= take;
    }
}

/// Pack one patch row: output position (`bi`, `y`, `x0`) of a
/// stride-1 SAME `kside`×`kside` conv over the NHWC map `x`.
#[allow(clippy::too_many_arguments)]
#[inline]
fn pack_patch(
    x: &[f32],
    words: &mut [u64],
    bi: usize,
    y: usize,
    x0: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
    pad: usize,
) {
    let mut bit = 0usize;
    for ky in 0..kside {
        let sy = y as isize + ky as isize - pad as isize;
        let row_ok = sy >= 0 && sy < h as isize;
        for kx in 0..kside {
            let sx = x0 as isize + kx as isize - pad as isize;
            if row_ok && sx >= 0 && sx < w as isize {
                let src = ((bi * h + sy as usize) * w + sx as usize) * cin;
                set_sign_bits(words, bit, &x[src..src + cin]);
            } else {
                set_ones(words, bit, cin);
            }
            bit += cin;
        }
    }
}

/// Fused sign-pack im2col for a stride-1 SAME `kside`×`kside` conv
/// over the NHWC map `x` (`b`×`h`×`w`×`cin`): returns the packed
/// (B·H·W × k²·Cin) patch matrix, bit-identical to
/// `BitMatrix::pack(b*h*w, k, &im2col(x, ..))` — without ever
/// materializing the f32 cols buffer.  Threaded over output rows via
/// `pool` (each worker owns a disjoint band of packed rows).
pub fn im2col_packed(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
    pool: &Pool,
) -> BitMatrix {
    assert_odd_kside(kside);
    assert_eq!(x.len(), b * h * w * cin, "NHWC shape mismatch");
    let k = kside * kside * cin;
    let rows = b * h * w;
    let mut m = BitMatrix::zeros(rows, k);
    let wpr = m.words_per_row;
    let pad = (kside - 1) / 2;
    pool.run_rows(rows, wpr, &mut m.data, |r0, band| {
        for (i, words) in band.chunks_mut(wpr).enumerate() {
            let r = r0 + i;
            let bi = r / (h * w);
            let rem = r % (h * w);
            pack_patch(x, words, bi, rem / w, rem % w, h, w, cin, kside, pad);
        }
    });
    m
}

/// Popcount of the bit range `[start, end)` of a packed row.
fn count_bit_range(words: &[u64], start: usize, end: usize) -> u32 {
    debug_assert!(start <= end);
    if start == end {
        return 0;
    }
    let (sw, sb) = (start >> 6, start & 63);
    let (ew, eb) = (end >> 6, end & 63);
    if sw == ew {
        // same word: end > start so 0 < eb - sb < 64
        let mask = ((1u64 << (eb - sb)) - 1) << sb;
        return (words[sw] & mask).count_ones();
    }
    let mut c = (words[sw] >> sb).count_ones();
    for w in &words[sw + 1..ew] {
        c += w.count_ones();
    }
    if eb > 0 {
        c += (words[ew] << (64 - eb)).count_ones();
    }
    c
}

/// Masked SAME-padding correction for the fused XNOR conv of the
/// standard engine: `im2col_packed` fixes out-of-bounds taps at +1,
/// so with packed transposed weights `wt` (Cout × k²·Cin) the XNOR
/// product overshoots the zero-padded truth by the padded taps'
/// weight sums.  Subtracts, per border output position, `T[tap] =
/// Σ_cin ŵ[tap]` for each out-of-bounds tap; interior positions are
/// untouched.  `y` is the (B·H·W × Cout) conv output in place.
pub fn subtract_pad_contrib(
    y: &mut [f32],
    wt: &BitMatrix,
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
) {
    assert_odd_kside(kside);
    let pad = (kside - 1) / 2;
    if pad == 0 {
        return; // 1×1 taps never leave the map
    }
    let cout = wt.rows;
    let kk = kside * kside;
    debug_assert_eq!(wt.cols, kk * cin);
    debug_assert_eq!(y.len(), b * h * w * cout);
    // per-tap channel-summed ±1 weights: T[tap][j] = 2·ones − cin
    let mut t = vec![0.0f32; kk * cout];
    for j in 0..cout {
        let rw = wt.row_words(j);
        for tap in 0..kk {
            let ones = count_bit_range(rw, tap * cin, (tap + 1) * cin);
            t[tap * cout + j] = (2 * ones as i64 - cin as i64) as f32;
        }
    }
    for bi in 0..b {
        for yy in 0..h {
            for xx in 0..w {
                // interior positions have no out-of-bounds taps
                if yy >= pad && yy + pad < h && xx >= pad && xx + pad < w {
                    continue;
                }
                let o = ((bi * h + yy) * w + xx) * cout;
                let orow = &mut y[o..o + cout];
                for ky in 0..kside {
                    let sy = yy as isize + ky as isize - pad as isize;
                    let y_oob = sy < 0 || sy >= h as isize;
                    for kx in 0..kside {
                        let sx = xx as isize + kx as isize - pad as isize;
                        if y_oob || sx < 0 || sx >= w as isize {
                            let trow = &t[(ky * kside + kx) * cout..][..cout];
                            for (yv, &tv) in orow.iter_mut().zip(trow) {
                                *yv -= tv;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Scatter-add one conv tap's (B·H·W × cin) panel into the NHWC input
/// gradient map — the streaming col2im inner step.  Output position
/// (bi, y, x) contributes its panel row to input position
/// (bi, y + ky − pad, x + kx − pad); out-of-bounds taps are skipped
/// (zero-padding contributes no input gradient).  Rows contiguous in
/// `x` shift together, so each (bi, y) line is one vector add.
#[allow(clippy::too_many_arguments)]
pub fn col2im_tap_scatter(
    dx: &mut [f32],
    panel: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
    ky: usize,
    kx: usize,
) {
    assert_odd_kside(kside);
    debug_assert_eq!(dx.len(), b * h * w * cin);
    debug_assert_eq!(panel.len(), b * h * w * cin);
    debug_assert!(ky < kside && kx < kside);
    let pad = (kside - 1) / 2;
    let oy = ky as isize - pad as isize; // sy = y + oy
    let ox = kx as isize - pad as isize; // sx = x + ox
    // valid output range: sy ∈ [0, h), sx ∈ [0, w)
    let ylo = (-oy).max(0) as usize;
    let yhi = ((h as isize - oy).min(h as isize)).max(0) as usize;
    let xlo = (-ox).max(0) as usize;
    let xhi = ((w as isize - ox).min(w as isize)).max(0) as usize;
    if ylo >= yhi || xlo >= xhi {
        return;
    }
    let run = (xhi - xlo) * cin; // contiguous in x on both sides
    for bi in 0..b {
        for y in ylo..yhi {
            let sy = (y as isize + oy) as usize;
            let sx = (xlo as isize + ox) as usize;
            let src = ((bi * h + y) * w + xlo) * cin;
            let dst = ((bi * h + sy) * w + sx) * cin;
            simd::add_assign_f32(&mut dx[dst..dst + run], &panel[src..src + run]);
        }
    }
}

/// Streaming col2im-fused dX for the stride-1 SAME conv backward:
/// `dx = col2im(∂Y · Ŵᵀ)` computed **tap-by-tap** — per (ky, kx) a
/// (B·H·W × cin) panel `∂Y · Ŵᵀ[tap]` (the backend's f32 GEMM,
/// row-banded over the worker pool on the tiled tier) is scatter-added
/// straight into `dx` via [`col2im_tap_scatter`].
///
/// The full (B·H·W × k²·Cin) `dcols` patch-gradient buffer — the
/// backward's dominant f32 transient — never exists; the peak
/// transient is one panel (k²× smaller) plus the (Cout × cin) f32 tap
/// weights unpacked from the packed Ŵᵀ.  Equal to
/// `col2im(gemm(∂Y, Ŵᵀ))` up to f32 summation order (taps accumulate
/// tap-major instead of row-major), and identical across backends and
/// thread counts (bands never split a reduction).
#[allow(clippy::too_many_arguments)]
pub fn conv_dx_streaming(
    dy: &[f32],
    wt: &BitMatrix,
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    kside: usize,
    backend: Backend,
) -> Vec<f32> {
    assert_odd_kside(kside);
    let cout = wt.rows;
    let rows = b * h * w;
    assert_eq!(dy.len(), rows * cout, "dY shape mismatch");
    assert_eq!(wt.cols, kside * kside * cin, "Ŵᵀ shape mismatch");
    let mut dx = vec![0.0f32; b * h * w * cin];
    let mut panel = vec![0.0f32; rows * cin];
    let mut wtap = vec![0.0f32; cout * cin];
    for ky in 0..kside {
        for kx in 0..kside {
            let tap = ky * kside + kx;
            // unpack this tap's (cout × cin) ±1 weight slice from the
            // packed Ŵᵀ row words — never the full (cout × k) f32
            for j in 0..cout {
                let words = wt.row_words(j);
                let row = &mut wtap[j * cin..(j + 1) * cin];
                for (ci, v) in row.iter_mut().enumerate() {
                    let c = tap * cin + ci;
                    *v = if words[c >> 6] >> (c & 63) & 1 == 1 { 1.0 } else { -1.0 };
                }
            }
            backend.gemm_f32(rows, cout, cin, dy, &wtap, &mut panel);
            col2im_tap_scatter(&mut dx, &panel, b, h, w, cin, kside, ky, kx);
        }
    }
    dx
}

/// Masked SAME-padding correction for the packed-activation dW of the
/// standard engine: `im2col_packed` fixes out-of-bounds taps at +1,
/// so `X̂ᵀ·∂Y` overshoots the zero-padded truth by the border rows'
/// ∂Y sums.  For each tap, `B[tap][j] = Σ_{r: tap OOB at r} ∂Y[r][j]`
/// is accumulated over border output positions only, then subtracted
/// from all `cin` dW rows of that tap.  O(border·k²·Cout + k²·Cin·Cout)
/// — weight-scale work, no rows×k anything.
#[allow(clippy::too_many_arguments)]
pub fn subtract_pad_dw_contrib(
    dw: &mut [f32],
    dy: &[f32],
    b: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    kside: usize,
) {
    assert_odd_kside(kside);
    let pad = (kside - 1) / 2;
    if pad == 0 {
        return; // 1×1 taps never leave the map
    }
    let kk = kside * kside;
    debug_assert_eq!(dw.len(), kk * cin * cout);
    debug_assert_eq!(dy.len(), b * h * w * cout);
    // border ∂Y sums per tap
    let mut bs = vec![0.0f32; kk * cout];
    for bi in 0..b {
        for yy in 0..h {
            for xx in 0..w {
                // interior positions have no out-of-bounds taps
                if yy >= pad && yy + pad < h && xx >= pad && xx + pad < w {
                    continue;
                }
                let dyr = &dy[((bi * h + yy) * w + xx) * cout..][..cout];
                for ky in 0..kside {
                    let sy = yy as isize + ky as isize - pad as isize;
                    let y_oob = sy < 0 || sy >= h as isize;
                    for kx in 0..kside {
                        let sx = xx as isize + kx as isize - pad as isize;
                        if y_oob || sx < 0 || sx >= w as isize {
                            let brow = &mut bs[(ky * kside + kx) * cout..][..cout];
                            simd::add_assign_f32(brow, dyr);
                        }
                    }
                }
            }
        }
    }
    for tap in 0..kk {
        let brow = &bs[tap * cout..(tap + 1) * cout];
        for ci in 0..cin {
            let drow = &mut dw[(tap * cin + ci) * cout..][..cout];
            simd::sub_assign_f32(drow, brow);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::gemm::{gemm_f32, xnor_gemm_naive};
    use crate::util::rng::Pcg32;

    /// f32 reference im2col (mirrors `naive::im2col`, kept local so
    /// the substrate test has no engine dependency).
    fn im2col_ref(x: &[f32], b: usize, h: usize, w: usize, cin: usize, kside: usize) -> Vec<f32> {
        let k = kside * kside * cin;
        let pad = (kside - 1) / 2;
        let mut cols = vec![0.0f32; b * h * w * k];
        for bi in 0..b {
            for y in 0..h {
                for x0 in 0..w {
                    let mut idx = ((bi * h + y) * w + x0) * k;
                    for ky in 0..kside {
                        let sy = y as isize + ky as isize - pad as isize;
                        for kx in 0..kside {
                            let sx = x0 as isize + kx as isize - pad as isize;
                            if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                                let src = ((bi * h + sy as usize) * w + sx as usize) * cin;
                                cols[idx..idx + cin].copy_from_slice(&x[src..src + cin]);
                            }
                            idx += cin;
                        }
                    }
                }
            }
        }
        cols
    }

    fn geometries() -> Vec<(usize, usize, usize, usize, usize)> {
        // (b, h, w, cin, kside): kside 1/3/5, patch widths off the
        // word grid (45, 297, 630 bits), batch 1/3
        vec![
            (1, 4, 4, 1, 1),
            (1, 5, 5, 3, 3),
            (2, 4, 4, 5, 3),
            (1, 6, 6, 33, 3),
            (3, 5, 5, 2, 5),
            (1, 7, 7, 13, 5),
            (2, 3, 3, 64, 1),
            (1, 4, 4, 70, 3),
        ]
    }

    fn noisy_map(g: &mut Pcg32, n: usize) -> Vec<f32> {
        // include exact zeros: sgn(0) = +1 must match the reference
        g.normal_vec(n)
            .into_iter()
            .enumerate()
            .map(|(i, v)| if i % 17 == 0 { 0.0 } else { v })
            .collect()
    }

    #[test]
    fn fused_matches_im2col_then_pack() {
        let mut g = Pcg32::new(41);
        for (b, h, w, cin, kside) in geometries() {
            let x = noisy_map(&mut g, b * h * w * cin);
            let k = kside * kside * cin;
            let want = BitMatrix::pack(b * h * w, k, &im2col_ref(&x, b, h, w, cin, kside));
            for threads in [1, 2, 4] {
                let got = im2col_packed(&x, b, h, w, cin, kside, &Pool::new(threads));
                assert_eq!(got, want, "b{b} {h}x{w}x{cin} k{kside} t{threads}");
            }
        }
    }

    #[test]
    fn fused_padding_bits_stay_zero() {
        // tail bits beyond k must stay clear (GEMM exact-tail invariant)
        let mut g = Pcg32::new(42);
        for (b, h, w, cin, kside) in geometries() {
            let k = kside * kside * cin;
            if k % 64 == 0 {
                continue;
            }
            let x = noisy_map(&mut g, b * h * w * cin);
            let m = im2col_packed(&x, b, h, w, cin, kside, &Pool::serial());
            for r in 0..m.rows {
                let last = m.row_words(r)[m.words_per_row - 1];
                assert_eq!(last >> (k % 64), 0, "row {r}");
            }
        }
    }

    #[test]
    fn count_bit_range_matches_bit_probes() {
        let mut g = Pcg32::new(43);
        let words: Vec<u64> = (0..6).map(|_| g.next_u64()).collect();
        let bits = words.len() * 64;
        for start in (0..bits).step_by(7) {
            for end in (start..=bits).step_by(13) {
                let want: u32 =
                    (start..end).map(|c| (words[c >> 6] >> (c & 63) & 1) as u32).sum();
                assert_eq!(count_bit_range(&words, start, end), want, "{start}..{end}");
            }
        }
        assert_eq!(count_bit_range(&words, 5, 5), 0);
        assert_eq!(count_bit_range(&words, 0, 64), words[0].count_ones());
    }

    #[test]
    fn xnor_with_pad_correction_equals_zero_pad_conv() {
        // fused packed conv + correction == f32 zero-padded conv of
        // the signed activations (both sides exact integers)
        let mut g = Pcg32::new(44);
        for (b, h, w, cin, kside) in geometries() {
            let k = kside * kside * cin;
            let rows = b * h * w;
            let cout = 5;
            let x = noisy_map(&mut g, b * h * w * cin);
            let wf = g.normal_vec(k * cout);
            // zero-pad reference: im2col of sign(x) (pads stay 0.0)
            // against sign(w), f32 GEMM
            let xs: Vec<f32> =
                x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let cols = im2col_ref(&xs, b, h, w, cin, kside);
            let ws: Vec<f32> =
                wf.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let mut want = vec![0.0f32; rows * cout];
            gemm_f32(rows, k, cout, &cols, &ws, &mut want);
            // fused path: packed patches (+1 pads) × packed Ŵᵀ, then
            // the masked edge correction
            let xhat = im2col_packed(&x, b, h, w, cin, kside, &Pool::serial());
            let mut wt_f = vec![0.0f32; cout * k];
            for kk in 0..k {
                for j in 0..cout {
                    wt_f[j * k + kk] = wf[kk * cout + j];
                }
            }
            let wt = BitMatrix::pack(cout, k, &wt_f);
            let mut got = vec![0.0f32; rows * cout];
            xnor_gemm_naive(&xhat, &wt, &mut got);
            subtract_pad_contrib(&mut got, &wt, b, h, w, cin, kside);
            assert_eq!(got, want, "b{b} {h}x{w}x{cin} k{kside}");
        }
    }

    #[test]
    fn kside1_needs_no_correction() {
        let mut g = Pcg32::new(45);
        let (b, h, w, cin) = (2, 3, 3, 64);
        let x = g.normal_vec(b * h * w * cin);
        let wt = BitMatrix::pack(4, cin, &g.normal_vec(4 * cin));
        let mut y = vec![1.5f32; b * h * w * 4];
        let before = y.clone();
        subtract_pad_contrib(&mut y, &wt, b, h, w, cin, 1);
        assert_eq!(y, before);
    }

    /// f32 reference col2im (mirrors `naive::col2im`, local so the
    /// substrate tests have no engine dependency).
    fn col2im_ref(
        dcols: &[f32],
        b: usize,
        h: usize,
        w: usize,
        cin: usize,
        kside: usize,
    ) -> Vec<f32> {
        let k = kside * kside * cin;
        let pad = (kside - 1) / 2;
        let mut dx = vec![0.0f32; b * h * w * cin];
        for bi in 0..b {
            for y in 0..h {
                for x0 in 0..w {
                    let mut idx = ((bi * h + y) * w + x0) * k;
                    for ky in 0..kside {
                        let sy = y as isize + ky as isize - pad as isize;
                        for kx in 0..kside {
                            let sx = x0 as isize + kx as isize - pad as isize;
                            if sy >= 0 && sy < h as isize && sx >= 0 && sx < w as isize {
                                let dst = ((bi * h + sy as usize) * w + sx as usize) * cin;
                                for ci in 0..cin {
                                    dx[dst + ci] += dcols[idx + ci];
                                }
                            }
                            idx += cin;
                        }
                    }
                }
            }
        }
        dx
    }

    #[test]
    fn tap_scatter_sums_to_col2im() {
        // Σ_taps scatter(panel_tap(c)) == col2im(c) (f32 reorder only)
        let mut g = Pcg32::new(46);
        for (b, h, w, cin, kside) in geometries() {
            let k = kside * kside * cin;
            let rows = b * h * w;
            let c = g.normal_vec(rows * k);
            let want = col2im_ref(&c, b, h, w, cin, kside);
            let mut got = vec![0.0f32; b * h * w * cin];
            let mut panel = vec![0.0f32; rows * cin];
            for ky in 0..kside {
                for kx in 0..kside {
                    let tap = ky * kside + kx;
                    for r in 0..rows {
                        panel[r * cin..(r + 1) * cin]
                            .copy_from_slice(&c[r * k + tap * cin..r * k + (tap + 1) * cin]);
                    }
                    col2im_tap_scatter(&mut got, &panel, b, h, w, cin, kside, ky, kx);
                }
            }
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                    "b{b} {h}x{w}x{cin} k{kside} @ {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn streaming_dx_matches_gemm_col2im_reference() {
        // conv_dx_streaming == col2im(∂Y · Ŵᵀ) within f32 reorder, on
        // every backend tier and thread count — and it is identical
        // across tiers (same kernels, bands never split a reduction)
        let mut g = Pcg32::new(47);
        for (b, h, w, cin, kside) in geometries() {
            let k = kside * kside * cin;
            let rows = b * h * w;
            let cout = 5;
            let dy = g.normal_vec(rows * cout);
            let wt = BitMatrix::pack(cout, k, &g.normal_vec(cout * k));
            let wt_f = wt.unpack();
            let mut dcols = vec![0.0f32; rows * k];
            gemm_f32(rows, cout, k, &dy, &wt_f, &mut dcols);
            let want = col2im_ref(&dcols, b, h, w, cin, kside);
            let first = conv_dx_streaming(&dy, &wt, b, h, w, cin, kside, Backend::Blocked);
            for i in 0..want.len() {
                assert!(
                    (first[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                    "b{b} {h}x{w}x{cin} k{kside} @ {i}: {} vs {}",
                    first[i],
                    want[i]
                );
            }
            for threads in [1, 2, 4] {
                let got = conv_dx_streaming(
                    &dy,
                    &wt,
                    b,
                    h,
                    w,
                    cin,
                    kside,
                    Backend::Tiled { threads },
                );
                assert_eq!(got, first, "b{b} {h}x{w}x{cin} k{kside} t{threads}");
            }
        }
    }

    #[test]
    fn packed_dw_with_pad_correction_equals_zero_pad_reference() {
        // im2col_packed(x)ᵀ·∂Y (pads +1) + correction == zero-padded
        // colsᵀ·∂Y — the standard engine's fused dW semantics
        use crate::bitops::gemm::packed_at_gemm_f32;
        let mut g = Pcg32::new(48);
        for (b, h, w, cin, kside) in geometries() {
            let k = kside * kside * cin;
            let rows = b * h * w;
            let cout = 4;
            let x = noisy_map(&mut g, b * h * w * cin);
            let dy = g.normal_vec(rows * cout);
            // reference: zero-pad im2col of sign(x), transposed GEMM
            let xs: Vec<f32> =
                x.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
            let cols = im2col_ref(&xs, b, h, w, cin, kside);
            let mut colst = vec![0.0f32; k * rows];
            for r in 0..rows {
                for kk in 0..k {
                    colst[kk * rows + r] = cols[r * k + kk];
                }
            }
            let mut want = vec![0.0f32; k * cout];
            gemm_f32(k, rows, cout, &colst, &dy, &mut want);
            // fused: packed panel, packed-A GEMM, border correction
            let xh = im2col_packed(&x, b, h, w, cin, kside, &Pool::serial());
            let mut got = vec![0.0f32; k * cout];
            packed_at_gemm_f32(&xh, &dy, cout, &mut got, &Pool::serial());
            subtract_pad_dw_contrib(&mut got, &dy, b, h, w, cin, cout, kside);
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                    "b{b} {h}x{w}x{cin} k{kside} @ {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "odd kernel side")]
    fn even_kside_rejected_by_packed_im2col() {
        let x = vec![0.0f32; 4 * 4 * 2];
        im2col_packed(&x, 1, 4, 4, 2, 2, &Pool::serial());
    }
}
