//! XNOR-popcount GEMM kernels.
//!
//! Three tiers (the backend dispatch in [`super::Backend`]):
//!
//! - `xnor_gemm_naive` — straight triple loop over packed words: the
//!   paper's naïve C++ prototype equivalent.
//! - `xnor_gemm` — register-blocked 1×4 micro-kernel over the packed
//!   K axis: the original "CBLAS-accelerated" path of Fig. 7.
//! - `xnor_gemm_tiled` / `xnor_gemm_parallel` — the tiled tier, plus a
//!   row-banded multi-threaded driver over [`super::Pool`].  Its band
//!   kernel dispatches on [`super::simd::level`]: with AVX2/NEON
//!   available it runs 1×4 column panels over the vectorized
//!   XOR-popcount kernels of [`super::simd`]; otherwise it falls back
//!   to the scalar 4×4 MR×NR micro-kernel with K-word tiling (each
//!   4-row A panel × 4-row B panel stays L1-resident while 16 popcount
//!   accumulators stay hot).
//!
//! All variants compute `out[m][n] = Σ_k a[m,k]·b[k,n]` over ±1 values
//! where `b_t` is the transposed packed B (rows = N, cols = K).  Zero
//! tail bits in both operands XOR to 0, so `k − 2·popcount(xor)` is
//! exact with no padding correction — every kernel here (every SIMD
//! level included: popcounts are exact integers) is bit-exact against
//! `xnor_gemm_naive` (tests below + rust/tests/property.rs).

use super::{simd, BitMatrix, Pool};

/// Register block sizes of the tiled micro-kernel.
const MR: usize = 4;
const NR: usize = 4;
/// K-tile in packed words: a 4-row B panel of 128 words is 4 KiB
/// (L1-resident), and 128·64 = 8192 bits bounds each u32 partial
/// accumulator far below overflow regardless of total K.
const KC_WORDS: usize = 128;

/// Naive packed GEMM: out (m×n) f32 = a (m×k ±1) @ b (k×n ±1),
/// with `b_t` packed transposed (n rows of k bits).
pub fn xnor_gemm_naive(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n, k) = (a.rows, b_t.rows, a.cols);
    assert_eq!(out.len(), m * n);
    // Zero-padded tail bits XOR to 0 in both operands (a "match"),
    // so dot = k_padded - 2*mismatch - pad = k - 2*mismatch exactly.
    for i in 0..m {
        let ar = a.row_words(i);
        for j in 0..n {
            let br = b_t.row_words(j);
            let mut mismatch = 0u32;
            for w in 0..ar.len() {
                mismatch += (ar[w] ^ br[w]).count_ones();
            }
            out[i * n + j] = (k as i64 - 2 * mismatch as i64) as f32;
        }
    }
}

/// One output row via the 1×4 N-unrolled kernel (also the M-remainder
/// path of the tiled kernel).
#[inline]
fn xnor_row_1x4(ar: &[u64], b_t: &BitMatrix, orow: &mut [f32], k: usize) {
    let n = b_t.rows;
    let kw = b_t.words_per_row;
    let n4 = n - n % 4;
    let kk = k as i64;
    let mut j = 0;
    while j < n4 {
        let b0 = &b_t.data[j * kw..(j + 1) * kw];
        let b1 = &b_t.data[(j + 1) * kw..(j + 2) * kw];
        let b2 = &b_t.data[(j + 2) * kw..(j + 3) * kw];
        let b3 = &b_t.data[(j + 3) * kw..(j + 4) * kw];
        let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
        for w in 0..kw {
            let aw = ar[w];
            c0 += (aw ^ b0[w]).count_ones() as u64;
            c1 += (aw ^ b1[w]).count_ones() as u64;
            c2 += (aw ^ b2[w]).count_ones() as u64;
            c3 += (aw ^ b3[w]).count_ones() as u64;
        }
        orow[j] = (kk - 2 * c0 as i64) as f32;
        orow[j + 1] = (kk - 2 * c1 as i64) as f32;
        orow[j + 2] = (kk - 2 * c2 as i64) as f32;
        orow[j + 3] = (kk - 2 * c3 as i64) as f32;
        j += 4;
    }
    while j < n {
        let br = b_t.row_words(j);
        let mut c = 0u64;
        for w in 0..kw {
            c += (ar[w] ^ br[w]).count_ones() as u64;
        }
        orow[j] = (kk - 2 * c as i64) as f32;
        j += 1;
    }
}

/// Blocked packed GEMM: 1×4 N-unrolled micro-kernel; ~3-4× the naive
/// throughput at BinaryNet sizes (see benches/perf log).
pub fn xnor_gemm(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n, k) = (a.rows, b_t.rows, a.cols);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        xnor_row_1x4(a.row_words(i), b_t, &mut out[i * n..(i + 1) * n], k);
    }
}

/// Band kernel of the tiled path: rows `row0..row0 + band.len()/n`
/// of the output.  Dispatches once per band on the detected SIMD
/// level; both paths are bit-exact (integer popcounts).
fn xnor_band(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    if simd::level() == simd::Level::Scalar {
        xnor_band_scalar(a, b_t, row0, band);
    } else {
        xnor_band_simd(a, b_t, row0, band);
    }
}

/// SIMD band kernel: 1×4 column panels over the vectorized
/// XOR-popcount kernels.  No KC tiling needed — the vector kernels
/// fold byte counts into 64-bit lanes, which cannot overflow.
fn xnor_band_simd(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    let n = b_t.rows;
    if n == 0 || band.is_empty() {
        return;
    }
    let kw = b_t.words_per_row;
    let kk = a.cols as i64;
    let br = band.len() / n;
    let bdata = &b_t.data;
    let n4 = n - n % 4;
    for i in 0..br {
        let ar = a.row_words(row0 + i);
        let orow = &mut band[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n4 {
            let b0 = &bdata[j * kw..(j + 1) * kw];
            let b1 = &bdata[(j + 1) * kw..(j + 2) * kw];
            let b2 = &bdata[(j + 2) * kw..(j + 3) * kw];
            let b3 = &bdata[(j + 3) * kw..(j + 4) * kw];
            let c = simd::xor_popcount_1x4(ar, b0, b1, b2, b3);
            orow[j] = (kk - 2 * c[0] as i64) as f32;
            orow[j + 1] = (kk - 2 * c[1] as i64) as f32;
            orow[j + 2] = (kk - 2 * c[2] as i64) as f32;
            orow[j + 3] = (kk - 2 * c[3] as i64) as f32;
            j += 4;
        }
        while j < n {
            let c = simd::xor_popcount(ar, b_t.row_words(j));
            orow[j] = (kk - 2 * c as i64) as f32;
            j += 1;
        }
    }
}

/// Scalar band kernel: 4×4 register blocks, K in `KC_WORDS` tiles.
fn xnor_band_scalar(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    let n = b_t.rows;
    if n == 0 || band.is_empty() {
        return;
    }
    let k = a.cols;
    let kw = a.words_per_row;
    let kk = k as i64;
    let br = band.len() / n;
    let bdata = &b_t.data;
    let m4 = br - br % MR;
    let n4 = n - n % NR;

    let mut i = 0;
    while i < m4 {
        let a0 = a.row_words(row0 + i);
        let a1 = a.row_words(row0 + i + 1);
        let a2 = a.row_words(row0 + i + 2);
        let a3 = a.row_words(row0 + i + 3);
        let mut j = 0;
        while j < n4 {
            let b0 = &bdata[j * kw..(j + 1) * kw];
            let b1 = &bdata[(j + 1) * kw..(j + 2) * kw];
            let b2 = &bdata[(j + 2) * kw..(j + 3) * kw];
            let b3 = &bdata[(j + 3) * kw..(j + 4) * kw];
            // 16 mismatch totals; partials per K tile stay u32
            let mut c = [[0u64; NR]; MR];
            let mut w0 = 0;
            while w0 < kw {
                let we = (w0 + KC_WORDS).min(kw);
                let mut p = [[0u32; NR]; MR];
                for w in w0..we {
                    let (aw0, aw1, aw2, aw3) = (a0[w], a1[w], a2[w], a3[w]);
                    let (bw0, bw1, bw2, bw3) = (b0[w], b1[w], b2[w], b3[w]);
                    p[0][0] += (aw0 ^ bw0).count_ones();
                    p[0][1] += (aw0 ^ bw1).count_ones();
                    p[0][2] += (aw0 ^ bw2).count_ones();
                    p[0][3] += (aw0 ^ bw3).count_ones();
                    p[1][0] += (aw1 ^ bw0).count_ones();
                    p[1][1] += (aw1 ^ bw1).count_ones();
                    p[1][2] += (aw1 ^ bw2).count_ones();
                    p[1][3] += (aw1 ^ bw3).count_ones();
                    p[2][0] += (aw2 ^ bw0).count_ones();
                    p[2][1] += (aw2 ^ bw1).count_ones();
                    p[2][2] += (aw2 ^ bw2).count_ones();
                    p[2][3] += (aw2 ^ bw3).count_ones();
                    p[3][0] += (aw3 ^ bw0).count_ones();
                    p[3][1] += (aw3 ^ bw1).count_ones();
                    p[3][2] += (aw3 ^ bw2).count_ones();
                    p[3][3] += (aw3 ^ bw3).count_ones();
                }
                for ii in 0..MR {
                    for jj in 0..NR {
                        c[ii][jj] += p[ii][jj] as u64;
                    }
                }
                w0 = we;
            }
            for (ii, crow) in c.iter().enumerate() {
                let o = (i + ii) * n + j;
                for (jj, &cv) in crow.iter().enumerate() {
                    band[o + jj] = (kk - 2 * cv as i64) as f32;
                }
            }
            j += NR;
        }
        // N remainder: 4 rows × 1 column
        while j < n {
            let bj = b_t.row_words(j);
            let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
            for w in 0..kw {
                let bw = bj[w];
                c0 += (a0[w] ^ bw).count_ones() as u64;
                c1 += (a1[w] ^ bw).count_ones() as u64;
                c2 += (a2[w] ^ bw).count_ones() as u64;
                c3 += (a3[w] ^ bw).count_ones() as u64;
            }
            band[i * n + j] = (kk - 2 * c0 as i64) as f32;
            band[(i + 1) * n + j] = (kk - 2 * c1 as i64) as f32;
            band[(i + 2) * n + j] = (kk - 2 * c2 as i64) as f32;
            band[(i + 3) * n + j] = (kk - 2 * c3 as i64) as f32;
            j += 1;
        }
        i += MR;
    }
    // M remainder: 1×4 row kernel
    while i < br {
        xnor_row_1x4(a.row_words(row0 + i), b_t, &mut band[i * n..(i + 1) * n], k);
        i += 1;
    }
}

/// Tiled packed GEMM, single-threaded: the band kernel alone (SIMD
/// where detected, scalar 4×4 otherwise).
pub fn xnor_gemm_tiled(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    assert_eq!(out.len(), a.rows * b_t.rows);
    xnor_band(a, b_t, 0, out);
}

/// Forced-scalar tiled GEMM: the 4×4 micro-kernel regardless of the
/// detected SIMD level.  Reference path for the SIMD bit-exactness
/// property tests (and a fair "PR-1 kernel" baseline in benches).
pub fn xnor_gemm_tiled_scalar(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    assert_eq!(out.len(), a.rows * b_t.rows);
    xnor_band_scalar(a, b_t, 0, out);
}

/// Tiled packed GEMM, row-parallel over `pool`: each worker owns a
/// contiguous output band and runs the dispatched band kernel on it.
pub fn xnor_gemm_parallel(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32], pool: &Pool) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n) = (a.rows, b_t.rows);
    assert_eq!(out.len(), m * n);
    pool.run_rows(m, n, out, |row0, band| xnor_band(a, b_t, row0, band));
}

/// f32 reference GEMM (the standard engine's compute): out = a @ b,
/// both dense row-major.  Simple ikj loop — cache-friendly enough for
/// the mini models; the blocked variant below is the accelerated path.
pub fn gemm_f32_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Cache-blocked f32 GEMM (the "CBLAS" stand-in for the standard
/// engine): ikj with 64×256 K×N tiling.
pub fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    const KB: usize = 64;
    const NB: usize = 256;
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KB).min(k);
        let mut n0 = 0;
        while n0 < n {
            let nend = (n0 + NB).min(n);
            for i in 0..m {
                let orow = &mut out[i * n + n0..i * n + nend];
                for kk in k0..kend {
                    let av = a[i * k + kk];
                    let brow = &b[kk * n + n0..kk * n + nend];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            n0 = nend;
        }
        k0 = kend;
    }
}

/// Row-parallel tiled f32 GEMM: each worker runs the cache-blocked
/// kernel on a contiguous M band (disjoint slices of `a` and `out`).
pub fn gemm_f32_parallel(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    pool: &Pool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    pool.run_rows(m, n, out, |row0, band| {
        let rows = band.len() / n.max(1);
        gemm_f32(rows, k, n, &a[row0 * k..(row0 + rows) * k], b, band);
    });
}

/// Bit mask selecting bits `[start, end)` of one u64 word
/// (`0 ≤ start < end ≤ 64`).
#[inline]
fn word_range_mask(start: usize, end: usize) -> u64 {
    debug_assert!(start < end && end <= 64);
    let hi = if end == 64 { u64::MAX } else { (1u64 << end) - 1 };
    hi & (u64::MAX << start)
}

/// Packed-A real GEMM: out (k×n) = Âᵀ @ B, where Â is the bit-packed
/// ±1 (rows × k) matrix and B is dense f32 (rows × n) —
/// `out[kk][j] = Σ_r Â[r][kk]·b[r][j]`.
///
/// This is the conv/dense backward's dW contraction (X̂ᵀ·∂Y) computed
/// straight from the packed activation panel: no (rows × k) f32
/// unpack, no (k × rows) transpose — the buffers that used to bound
/// the backward's transient peak.  Row-outer per band: each ∂Y row is
/// added to the band's out rows with set bits and subtracted from
/// those with clear bits, so every out cell accumulates in ascending
/// row order — **bit-identical** to densifying Âᵀ and running
/// [`gemm_f32`]/[`gemm_f32_naive`], at any thread count (bands split
/// the k axis, never the reduction axis).
pub fn packed_at_gemm_f32(a: &BitMatrix, b: &[f32], n: usize, out: &mut [f32], pool: &Pool) {
    let (rows, k) = (a.rows, a.cols);
    assert_eq!(b.len(), rows * n, "B shape mismatch");
    assert_eq!(out.len(), k * n, "out shape mismatch");
    if k == 0 || n == 0 {
        return;
    }
    pool.run_rows(k, n, out, |kk0, band| {
        band.fill(0.0);
        let kk1 = kk0 + band.len() / n;
        for r in 0..rows {
            let brow = &b[r * n..(r + 1) * n];
            let words = a.row_words(r);
            let (w0, wlast) = (kk0 >> 6, (kk1 - 1) >> 6);
            for w in w0..=wlast {
                let lo = (w << 6).max(kk0);
                let hi = ((w << 6) + 64).min(kk1);
                let mask = word_range_mask(lo - (w << 6), hi - (w << 6));
                let mut set = words[w] & mask;
                let mut clear = !words[w] & mask;
                while set != 0 {
                    let kk = (w << 6) + set.trailing_zeros() as usize;
                    let orow = &mut band[(kk - kk0) * n..(kk - kk0 + 1) * n];
                    simd::add_assign_f32(orow, brow);
                    set &= set - 1;
                }
                while clear != 0 {
                    let kk = (w << 6) + clear.trailing_zeros() as usize;
                    let orow = &mut band[(kk - kk0) * n..(kk - kk0 + 1) * n];
                    simd::sub_assign_f32(orow, brow);
                    clear &= clear - 1;
                }
            }
        }
    });
}

/// f32 AᵀB GEMM without materializing Aᵀ: out (k×n) = aᵀ (rows×k) @ b
/// (rows×n).  Replaces the `transpose(a)` + [`gemm_f32`] pair of the
/// pre-fusion backward (one whole rows×k transient gone); row-outer,
/// so each out cell accumulates in ascending row order — bit-identical
/// to the transpose+GEMM path at any thread count.  ±1 entries take
/// the exact add/sub path (the engines' signed activations).
pub fn gemm_f32_at(
    rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    pool: &Pool,
) {
    assert_eq!(a.len(), rows * k);
    assert_eq!(b.len(), rows * n);
    assert_eq!(out.len(), k * n);
    if k == 0 || n == 0 {
        return;
    }
    pool.run_rows(k, n, out, |kk0, band| {
        band.fill(0.0);
        let kks = band.len() / n;
        for r in 0..rows {
            let arow = &a[r * k + kk0..r * k + kk0 + kks];
            let brow = &b[r * n..(r + 1) * n];
            for (kkl, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut band[kkl * n..(kkl + 1) * n];
                if av == 1.0 {
                    simd::add_assign_f32(orow, brow);
                } else if av == -1.0 {
                    simd::sub_assign_f32(orow, brow);
                } else {
                    simd::axpy_f32(orow, av, brow);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn ref_pm1(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let sgn = |x: f32| if x >= 0.0 { 1.0 } else { -1.0f32 };
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += sgn(a[i * k + kk]) * sgn(b[kk * n + j]);
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn pack_b_t(k: usize, n: usize, b: &[f32]) -> BitMatrix {
        // transpose b (k×n) into (n×k) then pack
        let mut bt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        BitMatrix::pack(n, k, &bt)
    }

    #[test]
    fn xnor_matches_reference_odd_shapes() {
        let mut g = Pcg32::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 64, 5), (4, 65, 7), (5, 200, 9), (8, 127, 4)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let want = ref_pm1(m, k, n, &a, &b);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let mut naive = vec![0.0; m * n];
            let mut blocked = vec![0.0; m * n];
            xnor_gemm_naive(&ap, &btp, &mut naive);
            xnor_gemm(&ap, &btp, &mut blocked);
            assert_eq!(naive, want, "naive {m}x{k}x{n}");
            assert_eq!(blocked, want, "blocked {m}x{k}x{n}");
        }
    }

    #[test]
    fn tiled_and_parallel_bit_exact_vs_naive() {
        // odd shapes: K not a multiple of 64, M/N below the 4×4 tile,
        // single row/col, K crossing the KC_WORDS tile boundary
        let mut g = Pcg32::new(7);
        for (m, k, n) in [
            (1, 1, 1),
            (1, 65, 1),
            (2, 63, 3),
            (3, 64, 4),
            (4, 100, 4),
            (5, 127, 9),
            (7, 130, 6),
            (8, 8256, 5), // kw = 129 > KC_WORDS: exercises the K tiling
            (13, 200, 17),
            (70, 130, 70), // 4900 output cells: crosses the pool's
                           // MIN_PARALLEL_CELLS, so threads really band
        ] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let mut naive = vec![0.0; m * n];
            xnor_gemm_naive(&ap, &btp, &mut naive);
            let mut tiled = vec![0.0; m * n];
            xnor_gemm_tiled(&ap, &btp, &mut tiled);
            assert_eq!(tiled, naive, "tiled {m}x{k}x{n}");
            for threads in [1, 2, 4] {
                let mut par = vec![0.0; m * n];
                xnor_gemm_parallel(&ap, &btp, &mut par, &Pool::new(threads));
                assert_eq!(par, naive, "parallel t={threads} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn simd_and_scalar_bands_bit_exact() {
        // the dispatched tiled kernel (vectorized where the host has
        // AVX2/NEON) against the forced-scalar 4×4 micro-kernel, on
        // shapes hitting every panel/word remainder
        let mut g = Pcg32::new(17);
        for (m, k, n) in [
            (1, 1, 1),
            (2, 63, 3),
            (4, 64, 4),
            (5, 129, 9),
            (7, 257, 6),
            (8, 8256, 5),
            (13, 200, 17),
        ] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let mut scalar = vec![0.0; m * n];
            xnor_gemm_tiled_scalar(&ap, &btp, &mut scalar);
            let mut dispatched = vec![0.0; m * n];
            xnor_gemm_tiled(&ap, &btp, &mut dispatched);
            assert_eq!(dispatched, scalar, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn xnor_extremes() {
        // all +1 . all +1 = k; all +1 . all -1 = -k — on every kernel
        let k = 70;
        let a = BitMatrix::pack(1, k, &vec![1.0; k]);
        let bp = BitMatrix::pack(1, k, &vec![1.0; k]);
        let bn = BitMatrix::pack(1, k, &vec![-1.0; k]);
        let mut out = vec![0.0; 1];
        for f in [
            xnor_gemm as fn(&BitMatrix, &BitMatrix, &mut [f32]),
            xnor_gemm_naive,
            xnor_gemm_tiled,
        ] {
            f(&a, &bp, &mut out);
            assert_eq!(out[0], k as f32);
            f(&a, &bn, &mut out);
            assert_eq!(out[0], -(k as f32));
        }
    }

    #[test]
    fn f32_gemms_agree() {
        let mut g = Pcg32::new(4);
        for (m, k, n) in [(3, 5, 7), (16, 64, 33), (10, 100, 257)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let mut x = vec![0.0; m * n];
            let mut y = vec![0.0; m * n];
            gemm_f32_naive(m, k, n, &a, &b, &mut x);
            gemm_f32(m, k, n, &a, &b, &mut y);
            for i in 0..x.len() {
                assert!((x[i] - y[i]).abs() < 1e-3, "{i}: {} vs {}", x[i], y[i]);
            }
            // the parallel path splits only along M, so each band is
            // the blocked kernel verbatim: results are bit-identical
            for threads in [1, 2, 4] {
                let mut z = vec![0.0; m * n];
                gemm_f32_parallel(m, k, n, &a, &b, &mut z, &Pool::new(threads));
                assert_eq!(y, z, "parallel t={threads} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn word_range_mask_cases() {
        assert_eq!(word_range_mask(0, 64), u64::MAX);
        assert_eq!(word_range_mask(0, 1), 1);
        assert_eq!(word_range_mask(63, 64), 1u64 << 63);
        assert_eq!(word_range_mask(4, 8), 0b1111_0000);
        assert_eq!(word_range_mask(0, 64).count_ones(), 64);
        for s in 0..64 {
            for e in (s + 1)..=64 {
                assert_eq!(word_range_mask(s, e).count_ones() as usize, e - s, "{s}..{e}");
            }
        }
    }

    fn transpose_ref(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = a[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn packed_at_gemm_bit_identical_to_densified_reference() {
        // the dW kernel's exactness claim: identical to unpacking Âᵀ
        // and running the dense f32 GEMM — odd shapes (k off the word
        // grid, k below/above one word, single row/col) and every
        // thread count (bands split k, not the reduction)
        let mut g = Pcg32::new(51);
        for (rows, k, n) in [
            (1, 1, 1),
            (3, 63, 4),
            (5, 64, 3),
            (7, 65, 5),
            (16, 130, 9),
            (33, 200, 17),
            (64, 70, 70), // 4900 cells: crosses MIN_PARALLEL_CELLS
        ] {
            let av = g.normal_vec(rows * k);
            let b = g.normal_vec(rows * n);
            let a = BitMatrix::pack(rows, k, &av);
            let at = transpose_ref(&a.unpack(), rows, k); // (k × rows) ±1
            let mut want = vec![0.0f32; k * n];
            gemm_f32(k, rows, n, &at, &b, &mut want);
            for threads in [1, 2, 4] {
                let mut got = vec![0.0f32; k * n];
                packed_at_gemm_f32(&a, &b, n, &mut got, &Pool::new(threads));
                assert_eq!(got, want, "t={threads} ({rows},{k},{n})");
            }
        }
    }

    #[test]
    fn gemm_f32_at_bit_identical_to_transpose_then_gemm() {
        let mut g = Pcg32::new(52);
        for (rows, k, n) in [(1, 1, 1), (4, 7, 3), (16, 64, 33), (10, 100, 9), (70, 70, 70)] {
            // mix dense values with exact ±1/0 entries (the signed
            // activation fast paths)
            let a: Vec<f32> = g
                .normal_vec(rows * k)
                .into_iter()
                .enumerate()
                .map(|(i, v)| match i % 5 {
                    0 => 1.0,
                    1 => -1.0,
                    2 => 0.0,
                    _ => v,
                })
                .collect();
            let b = g.normal_vec(rows * n);
            let at = transpose_ref(&a, rows, k);
            let mut want = vec![0.0f32; k * n];
            gemm_f32(k, rows, n, &at, &b, &mut want);
            for threads in [1, 2, 4] {
                let mut got = vec![0.0f32; k * n];
                gemm_f32_at(rows, k, n, &a, &b, &mut got, &Pool::new(threads));
                assert_eq!(got, want, "t={threads} ({rows},{k},{n})");
            }
        }
    }

    #[test]
    fn gemm_identity() {
        // A @ I = A
        let m = 4;
        let k = 8;
        let mut g = Pcg32::new(5);
        let a = g.normal_vec(m * k);
        let mut eye = vec![0.0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        let mut out = vec![0.0; m * k];
        gemm_f32(m, k, k, &a, &eye, &mut out);
        for i in 0..a.len() {
            assert!((out[i] - a[i]).abs() < 1e-6);
        }
    }
}
