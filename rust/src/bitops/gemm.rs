//! XNOR-popcount GEMM kernels.
//!
//! Three tiers (the backend dispatch in [`super::Backend`]):
//!
//! - `xnor_gemm_naive` — straight triple loop over packed words: the
//!   paper's naïve C++ prototype equivalent.
//! - `xnor_gemm` — register-blocked 1×4 micro-kernel over the packed
//!   K axis: the original "CBLAS-accelerated" path of Fig. 7.
//! - `xnor_gemm_tiled` / `xnor_gemm_parallel` — the tiled tier, plus a
//!   row-banded multi-threaded driver over [`super::Pool`].  Its band
//!   kernel dispatches on [`super::simd::level`]: with AVX2/NEON
//!   available it runs 1×4 column panels over the vectorized
//!   XOR-popcount kernels of [`super::simd`]; otherwise it falls back
//!   to the scalar 4×4 MR×NR micro-kernel with K-word tiling (each
//!   4-row A panel × 4-row B panel stays L1-resident while 16 popcount
//!   accumulators stay hot).
//!
//! All variants compute `out[m][n] = Σ_k a[m,k]·b[k,n]` over ±1 values
//! where `b_t` is the transposed packed B (rows = N, cols = K).  Zero
//! tail bits in both operands XOR to 0, so `k − 2·popcount(xor)` is
//! exact with no padding correction — every kernel here (every SIMD
//! level included: popcounts are exact integers) is bit-exact against
//! `xnor_gemm_naive` (tests below + rust/tests/property.rs).

use super::{simd, BitMatrix, Pool};

/// Register block sizes of the tiled micro-kernel.
const MR: usize = 4;
const NR: usize = 4;
/// K-tile in packed words: a 4-row B panel of 128 words is 4 KiB
/// (L1-resident), and 128·64 = 8192 bits bounds each u32 partial
/// accumulator far below overflow regardless of total K.
const KC_WORDS: usize = 128;

/// Micro-kernel variant of the tiled band kernel — the autotuner's
/// main candidate axis (`bitops::tune`).  Every variant computes the
/// identical integer popcounts, so all are bit-exact against
/// [`xnor_gemm_naive`]; they differ only in register blocking and
/// B-operand layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MicroKernel {
    /// Scalar 4×4 MR×NR register block with K-word tiling
    /// ([`KernelCfg::kc_words`]) — the no-SIMD tier.
    Scalar4x4,
    /// 1 A row × 4 B rows over the vectorized XOR-popcount (the
    /// pre-tuner fixed SIMD kernel).
    Simd1x4,
    /// 1 A row × 8 B rows: twice the B fan-out per A load.
    Simd1x8,
    /// 2 A rows × 4 B rows: B reuse across an A pair.
    Simd2x4,
    /// 1 A row × one interleaved 8-column [`BPanels`] panel: the
    /// inner loop streams B contiguously (large-N layouts).  Falls
    /// back to the fixed kernel when no panels were packed.
    Panel8,
}

impl MicroKernel {
    pub fn name(self) -> &'static str {
        match self {
            MicroKernel::Scalar4x4 => "scalar4x4",
            MicroKernel::Simd1x4 => "simd1x4",
            MicroKernel::Simd1x8 => "simd1x8",
            MicroKernel::Simd2x4 => "simd2x4",
            MicroKernel::Panel8 => "panel8",
        }
    }

    pub fn parse(s: &str) -> Option<MicroKernel> {
        Some(match s {
            "scalar4x4" => MicroKernel::Scalar4x4,
            "simd1x4" => MicroKernel::Simd1x4,
            "simd1x8" => MicroKernel::Simd1x8,
            "simd2x4" => MicroKernel::Simd2x4,
            "panel8" => MicroKernel::Panel8,
            _ => return None,
        })
    }
}

/// One tuned kernel configuration: which micro-kernel, its K tile (the
/// scalar block's word depth), and the parallel driver's row-band
/// granularity (0 = one even band per worker).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelCfg {
    pub micro: MicroKernel,
    pub kc_words: usize,
    pub band_rows: usize,
}

impl KernelCfg {
    /// The deterministic pre-tuner configuration (`--tune=fixed`):
    /// exactly the dispatch the fixed-tile kernels always ran — SIMD
    /// 1×4 panels where the host has AVX2/NEON, the scalar 4×4
    /// micro-kernel with the default K tile otherwise.
    pub fn fixed() -> KernelCfg {
        let micro = if simd::level() == simd::Level::Scalar {
            MicroKernel::Scalar4x4
        } else {
            MicroKernel::Simd1x4
        };
        KernelCfg { micro, kc_words: KC_WORDS, band_rows: 0 }
    }

    /// Compact display form, e.g. `simd1x8/kc128/band0`.
    pub fn label(&self) -> String {
        format!("{}/kc{}/band{}", self.micro.name(), self.kc_words, self.band_rows)
    }
}

/// Naive packed GEMM: out (m×n) f32 = a (m×k ±1) @ b (k×n ±1),
/// with `b_t` packed transposed (n rows of k bits).
pub fn xnor_gemm_naive(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n, k) = (a.rows, b_t.rows, a.cols);
    assert_eq!(out.len(), m * n);
    // Zero-padded tail bits XOR to 0 in both operands (a "match"),
    // so dot = k_padded - 2*mismatch - pad = k - 2*mismatch exactly.
    for i in 0..m {
        let ar = a.row_words(i);
        for j in 0..n {
            let br = b_t.row_words(j);
            let mut mismatch = 0u32;
            for w in 0..ar.len() {
                mismatch += (ar[w] ^ br[w]).count_ones();
            }
            out[i * n + j] = (k as i64 - 2 * mismatch as i64) as f32;
        }
    }
}

/// One output row via the 1×4 N-unrolled kernel (also the M-remainder
/// path of the tiled kernel).
#[inline]
fn xnor_row_1x4(ar: &[u64], b_t: &BitMatrix, orow: &mut [f32], k: usize) {
    let n = b_t.rows;
    let kw = b_t.words_per_row;
    let n4 = n - n % 4;
    let kk = k as i64;
    let mut j = 0;
    while j < n4 {
        let b0 = &b_t.data[j * kw..(j + 1) * kw];
        let b1 = &b_t.data[(j + 1) * kw..(j + 2) * kw];
        let b2 = &b_t.data[(j + 2) * kw..(j + 3) * kw];
        let b3 = &b_t.data[(j + 3) * kw..(j + 4) * kw];
        let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
        for w in 0..kw {
            let aw = ar[w];
            c0 += (aw ^ b0[w]).count_ones() as u64;
            c1 += (aw ^ b1[w]).count_ones() as u64;
            c2 += (aw ^ b2[w]).count_ones() as u64;
            c3 += (aw ^ b3[w]).count_ones() as u64;
        }
        orow[j] = (kk - 2 * c0 as i64) as f32;
        orow[j + 1] = (kk - 2 * c1 as i64) as f32;
        orow[j + 2] = (kk - 2 * c2 as i64) as f32;
        orow[j + 3] = (kk - 2 * c3 as i64) as f32;
        j += 4;
    }
    while j < n {
        let br = b_t.row_words(j);
        let mut c = 0u64;
        for w in 0..kw {
            c += (ar[w] ^ br[w]).count_ones() as u64;
        }
        orow[j] = (kk - 2 * c as i64) as f32;
        j += 1;
    }
}

/// Blocked packed GEMM: 1×4 N-unrolled micro-kernel; ~3-4× the naive
/// throughput at BinaryNet sizes (see benches/perf log).
pub fn xnor_gemm(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n, k) = (a.rows, b_t.rows, a.cols);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        xnor_row_1x4(a.row_words(i), b_t, &mut out[i * n..(i + 1) * n], k);
    }
}

/// Band kernel of the tiled path: rows `row0..row0 + band.len()/n`
/// of the output.  Dispatches once per band on the detected SIMD
/// level; both paths are bit-exact (integer popcounts).
fn xnor_band(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    if simd::level() == simd::Level::Scalar {
        xnor_band_scalar(a, b_t, row0, band);
    } else {
        xnor_band_simd(a, b_t, row0, band);
    }
}

/// SIMD band kernel: 1×4 column panels over the vectorized
/// XOR-popcount kernels.  No KC tiling needed — the vector kernels
/// fold byte counts into 64-bit lanes, which cannot overflow.
fn xnor_band_simd(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    let n = b_t.rows;
    if n == 0 || band.is_empty() {
        return;
    }
    let kw = b_t.words_per_row;
    let kk = a.cols as i64;
    let br = band.len() / n;
    let bdata = &b_t.data;
    let n4 = n - n % 4;
    for i in 0..br {
        let ar = a.row_words(row0 + i);
        let orow = &mut band[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n4 {
            let b0 = &bdata[j * kw..(j + 1) * kw];
            let b1 = &bdata[(j + 1) * kw..(j + 2) * kw];
            let b2 = &bdata[(j + 2) * kw..(j + 3) * kw];
            let b3 = &bdata[(j + 3) * kw..(j + 4) * kw];
            let c = simd::xor_popcount_1x4(ar, b0, b1, b2, b3);
            orow[j] = (kk - 2 * c[0] as i64) as f32;
            orow[j + 1] = (kk - 2 * c[1] as i64) as f32;
            orow[j + 2] = (kk - 2 * c[2] as i64) as f32;
            orow[j + 3] = (kk - 2 * c[3] as i64) as f32;
            j += 4;
        }
        while j < n {
            let c = simd::xor_popcount(ar, b_t.row_words(j));
            orow[j] = (kk - 2 * c as i64) as f32;
            j += 1;
        }
    }
}

/// Scalar band kernel: 4×4 register blocks, K in `KC_WORDS` tiles.
fn xnor_band_scalar(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    xnor_band_scalar_kc(a, b_t, row0, band, KC_WORDS);
}

/// Scalar band kernel with an explicit K tile (the autotuner's
/// `kc_words` axis).  `kc_words · 64` bounds each u32 partial; any
/// tile ≤ 2²⁶ words is overflow-safe.
fn xnor_band_scalar_kc(
    a: &BitMatrix,
    b_t: &BitMatrix,
    row0: usize,
    band: &mut [f32],
    kc_words: usize,
) {
    let n = b_t.rows;
    if n == 0 || band.is_empty() {
        return;
    }
    let kc_words = kc_words.max(1);
    let k = a.cols;
    let kw = a.words_per_row;
    let kk = k as i64;
    let br = band.len() / n;
    let bdata = &b_t.data;
    let m4 = br - br % MR;
    let n4 = n - n % NR;

    let mut i = 0;
    while i < m4 {
        let a0 = a.row_words(row0 + i);
        let a1 = a.row_words(row0 + i + 1);
        let a2 = a.row_words(row0 + i + 2);
        let a3 = a.row_words(row0 + i + 3);
        let mut j = 0;
        while j < n4 {
            let b0 = &bdata[j * kw..(j + 1) * kw];
            let b1 = &bdata[(j + 1) * kw..(j + 2) * kw];
            let b2 = &bdata[(j + 2) * kw..(j + 3) * kw];
            let b3 = &bdata[(j + 3) * kw..(j + 4) * kw];
            // 16 mismatch totals; partials per K tile stay u32
            let mut c = [[0u64; NR]; MR];
            let mut w0 = 0;
            while w0 < kw {
                let we = (w0 + kc_words).min(kw);
                let mut p = [[0u32; NR]; MR];
                for w in w0..we {
                    let (aw0, aw1, aw2, aw3) = (a0[w], a1[w], a2[w], a3[w]);
                    let (bw0, bw1, bw2, bw3) = (b0[w], b1[w], b2[w], b3[w]);
                    p[0][0] += (aw0 ^ bw0).count_ones();
                    p[0][1] += (aw0 ^ bw1).count_ones();
                    p[0][2] += (aw0 ^ bw2).count_ones();
                    p[0][3] += (aw0 ^ bw3).count_ones();
                    p[1][0] += (aw1 ^ bw0).count_ones();
                    p[1][1] += (aw1 ^ bw1).count_ones();
                    p[1][2] += (aw1 ^ bw2).count_ones();
                    p[1][3] += (aw1 ^ bw3).count_ones();
                    p[2][0] += (aw2 ^ bw0).count_ones();
                    p[2][1] += (aw2 ^ bw1).count_ones();
                    p[2][2] += (aw2 ^ bw2).count_ones();
                    p[2][3] += (aw2 ^ bw3).count_ones();
                    p[3][0] += (aw3 ^ bw0).count_ones();
                    p[3][1] += (aw3 ^ bw1).count_ones();
                    p[3][2] += (aw3 ^ bw2).count_ones();
                    p[3][3] += (aw3 ^ bw3).count_ones();
                }
                for ii in 0..MR {
                    for jj in 0..NR {
                        c[ii][jj] += p[ii][jj] as u64;
                    }
                }
                w0 = we;
            }
            for (ii, crow) in c.iter().enumerate() {
                let o = (i + ii) * n + j;
                for (jj, &cv) in crow.iter().enumerate() {
                    band[o + jj] = (kk - 2 * cv as i64) as f32;
                }
            }
            j += NR;
        }
        // N remainder: 4 rows × 1 column
        while j < n {
            let bj = b_t.row_words(j);
            let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
            for w in 0..kw {
                let bw = bj[w];
                c0 += (a0[w] ^ bw).count_ones() as u64;
                c1 += (a1[w] ^ bw).count_ones() as u64;
                c2 += (a2[w] ^ bw).count_ones() as u64;
                c3 += (a3[w] ^ bw).count_ones() as u64;
            }
            band[i * n + j] = (kk - 2 * c0 as i64) as f32;
            band[(i + 1) * n + j] = (kk - 2 * c1 as i64) as f32;
            band[(i + 2) * n + j] = (kk - 2 * c2 as i64) as f32;
            band[(i + 3) * n + j] = (kk - 2 * c3 as i64) as f32;
            j += 1;
        }
        i += MR;
    }
    // M remainder: 1×4 row kernel
    while i < br {
        xnor_row_1x4(a.row_words(row0 + i), b_t, &mut band[i * n..(i + 1) * n], k);
        i += 1;
    }
}

/// One output row over the vectorized 1×4 kernel — the shared M/N
/// remainder path of the wider SIMD band kernels.
#[inline]
fn xnor_row_simd(ar: &[u64], b_t: &BitMatrix, orow: &mut [f32], kk: i64) {
    let n = b_t.rows;
    let kw = b_t.words_per_row;
    let bdata = &b_t.data;
    let n4 = n - n % 4;
    let mut j = 0;
    while j < n4 {
        let b0 = &bdata[j * kw..(j + 1) * kw];
        let b1 = &bdata[(j + 1) * kw..(j + 2) * kw];
        let b2 = &bdata[(j + 2) * kw..(j + 3) * kw];
        let b3 = &bdata[(j + 3) * kw..(j + 4) * kw];
        let c = simd::xor_popcount_1x4(ar, b0, b1, b2, b3);
        for l in 0..4 {
            orow[j + l] = (kk - 2 * c[l] as i64) as f32;
        }
        j += 4;
    }
    while j < n {
        let c = simd::xor_popcount(ar, b_t.row_words(j));
        orow[j] = (kk - 2 * c as i64) as f32;
        j += 1;
    }
}

/// SIMD band kernel, 1×8 panels: twice the B fan-out per A load of
/// the 1×4 kernel (autotuner candidate).
fn xnor_band_simd_1x8(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    let n = b_t.rows;
    if n == 0 || band.is_empty() {
        return;
    }
    let kw = b_t.words_per_row;
    let kk = a.cols as i64;
    let br = band.len() / n;
    let bdata = &b_t.data;
    let n8 = n - n % 8;
    for i in 0..br {
        let ar = a.row_words(row0 + i);
        let orow = &mut band[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n8 {
            let panel: [&[u64]; 8] =
                std::array::from_fn(|l| &bdata[(j + l) * kw..(j + l + 1) * kw]);
            let c = simd::xor_popcount_1x8(ar, panel);
            for l in 0..8 {
                orow[j + l] = (kk - 2 * c[l] as i64) as f32;
            }
            j += 8;
        }
        if j + 4 <= n {
            let b0 = &bdata[j * kw..(j + 1) * kw];
            let b1 = &bdata[(j + 1) * kw..(j + 2) * kw];
            let b2 = &bdata[(j + 2) * kw..(j + 3) * kw];
            let b3 = &bdata[(j + 3) * kw..(j + 4) * kw];
            let c = simd::xor_popcount_1x4(ar, b0, b1, b2, b3);
            for l in 0..4 {
                orow[j + l] = (kk - 2 * c[l] as i64) as f32;
            }
            j += 4;
        }
        while j < n {
            let c = simd::xor_popcount(ar, b_t.row_words(j));
            orow[j] = (kk - 2 * c as i64) as f32;
            j += 1;
        }
    }
}

/// SIMD band kernel, 2×4 blocks: each B panel load serves two A rows
/// (autotuner candidate for tall-M shapes).
fn xnor_band_simd_2x4(a: &BitMatrix, b_t: &BitMatrix, row0: usize, band: &mut [f32]) {
    let n = b_t.rows;
    if n == 0 || band.is_empty() {
        return;
    }
    let kw = b_t.words_per_row;
    let kk = a.cols as i64;
    let br = band.len() / n;
    let bdata = &b_t.data;
    let m2 = br - br % 2;
    let n4 = n - n % 4;
    let mut i = 0;
    while i < m2 {
        let a0 = a.row_words(row0 + i);
        let a1 = a.row_words(row0 + i + 1);
        let mut j = 0;
        while j < n4 {
            let panel: [&[u64]; 4] =
                std::array::from_fn(|l| &bdata[(j + l) * kw..(j + l + 1) * kw]);
            let c = simd::xor_popcount_2x4(a0, a1, panel);
            for l in 0..4 {
                band[i * n + j + l] = (kk - 2 * c[l] as i64) as f32;
                band[(i + 1) * n + j + l] = (kk - 2 * c[4 + l] as i64) as f32;
            }
            j += 4;
        }
        while j < n {
            let bj = b_t.row_words(j);
            band[i * n + j] = (kk - 2 * simd::xor_popcount(a0, bj) as i64) as f32;
            band[(i + 1) * n + j] = (kk - 2 * simd::xor_popcount(a1, bj) as i64) as f32;
            j += 1;
        }
        i += 2;
    }
    while i < br {
        xnor_row_simd(a.row_words(row0 + i), b_t, &mut band[i * n..(i + 1) * n], kk);
        i += 1;
    }
}

/// B packed into interleaved 8-column panels: `data[(p·wpr + w)·8 + l]`
/// holds word `w` of column `p·8 + l` of Ŵᵀ/Bᵀ.  The panel band
/// kernel's inner loop then streams `data` strictly forward — at
/// BinaryNet fc widths (N = 1024–4096) the row-major `b_t` walk
/// touches N scattered K-word rows per A row, while the panel walk is
/// one sequential pass.  Missing tail columns are zero-filled (their
/// counts are computed and discarded, never written).
///
/// Panels are packed once per weight update (cached in
/// `PackedWeightCache`) and rebuilt in place — steady state stays
/// allocation-free.
#[derive(Debug, Clone, Default)]
pub struct BPanels {
    pub n: usize,
    pub wpr: usize,
    pub data: Vec<u64>,
}

impl BPanels {
    /// Panel width (columns per panel).
    pub const NR: usize = 8;

    /// Word count of the panel store for an (n × wpr-word) `b_t` —
    /// the `memmodel` mirror of [`Self::heap_bytes`].
    pub fn words_for(n: usize, wpr: usize) -> usize {
        n.div_ceil(Self::NR) * wpr * Self::NR
    }

    pub fn pack(b_t: &BitMatrix) -> BPanels {
        let mut p = BPanels::default();
        p.pack_into(b_t);
        p
    }

    /// Re-pack in place; allocates only if the shape grew (repacking
    /// the same weight shape every update is allocation-free).
    pub fn pack_into(&mut self, b_t: &BitMatrix) {
        let (n, wpr) = (b_t.rows, b_t.words_per_row);
        self.n = n;
        self.wpr = wpr;
        self.data.resize(Self::words_for(n, wpr), 0);
        for p in 0..n.div_ceil(Self::NR) {
            let base = p * wpr * Self::NR;
            for l in 0..Self::NR {
                let col = p * Self::NR + l;
                if col >= n {
                    for w in 0..wpr {
                        self.data[base + w * Self::NR + l] = 0;
                    }
                } else {
                    let row = b_t.row_words(col);
                    for w in 0..wpr {
                        self.data[base + w * Self::NR + l] = row[w];
                    }
                }
            }
        }
    }

    pub fn heap_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// Panel band kernel: one interleaved panel sweep per A row.
fn xnor_band_panels(a: &BitMatrix, bp: &BPanels, row0: usize, band: &mut [f32]) {
    let n = bp.n;
    if n == 0 || band.is_empty() {
        return;
    }
    let nr = BPanels::NR;
    let pw = bp.wpr * nr; // words per panel
    let kk = a.cols as i64;
    let br = band.len() / n;
    for i in 0..br {
        let ar = a.row_words(row0 + i);
        let orow = &mut band[i * n..(i + 1) * n];
        for p in 0..n.div_ceil(nr) {
            let c = simd::xor_popcount_p8(ar, &bp.data[p * pw..(p + 1) * pw]);
            let cols = nr.min(n - p * nr);
            for l in 0..cols {
                orow[p * nr + l] = (kk - 2 * c[l] as i64) as f32;
            }
        }
    }
}

/// Band kernel dispatched by an explicit [`KernelCfg`] (the tuned
/// path).  `Panel8` without packed panels falls back to the fixed
/// dispatch — every arm is bit-exact, so the choice is purely perf.
fn xnor_band_cfg(
    cfg: KernelCfg,
    a: &BitMatrix,
    b_t: &BitMatrix,
    bp: Option<&BPanels>,
    row0: usize,
    band: &mut [f32],
) {
    match cfg.micro {
        MicroKernel::Scalar4x4 => xnor_band_scalar_kc(a, b_t, row0, band, cfg.kc_words),
        MicroKernel::Simd1x4 => xnor_band_simd(a, b_t, row0, band),
        MicroKernel::Simd1x8 => xnor_band_simd_1x8(a, b_t, row0, band),
        MicroKernel::Simd2x4 => xnor_band_simd_2x4(a, b_t, row0, band),
        MicroKernel::Panel8 => match bp {
            Some(p) => xnor_band_panels(a, p, row0, band),
            None => xnor_band(a, b_t, row0, band),
        },
    }
}

/// Tiled packed GEMM under an explicit tuned configuration: the
/// micro-kernel, K tile, and row-band granularity of `cfg`, with
/// optional pre-packed B panels.  Bands split only M, so the result
/// is bit-exact against [`xnor_gemm_naive`] for every `cfg` at every
/// thread count (rust/tests/property.rs sweeps the full space).
pub fn xnor_gemm_with(
    cfg: KernelCfg,
    a: &BitMatrix,
    b_t: &BitMatrix,
    bp: Option<&BPanels>,
    out: &mut [f32],
    pool: &Pool,
) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n) = (a.rows, b_t.rows);
    assert_eq!(out.len(), m * n);
    if let Some(p) = bp {
        assert_eq!((p.n, p.wpr), (n, b_t.words_per_row), "panel shape mismatch");
    }
    pool.run_rows_chunk(m, n, cfg.band_rows, out, |row0, band| {
        xnor_band_cfg(cfg, a, b_t, bp, row0, band)
    });
}

/// Tiled packed GEMM, single-threaded: the band kernel alone (SIMD
/// where detected, scalar 4×4 otherwise).
pub fn xnor_gemm_tiled(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    assert_eq!(out.len(), a.rows * b_t.rows);
    xnor_band(a, b_t, 0, out);
}

/// Forced-scalar tiled GEMM: the 4×4 micro-kernel regardless of the
/// detected SIMD level.  Reference path for the SIMD bit-exactness
/// property tests (and a fair "PR-1 kernel" baseline in benches).
pub fn xnor_gemm_tiled_scalar(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    assert_eq!(out.len(), a.rows * b_t.rows);
    xnor_band_scalar(a, b_t, 0, out);
}

/// Tiled packed GEMM, row-parallel over `pool`: each worker owns a
/// contiguous output band and runs the dispatched band kernel on it.
pub fn xnor_gemm_parallel(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32], pool: &Pool) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n) = (a.rows, b_t.rows);
    assert_eq!(out.len(), m * n);
    pool.run_rows(m, n, out, |row0, band| xnor_band(a, b_t, row0, band));
}

/// f32 reference GEMM (the standard engine's compute): out = a @ b,
/// both dense row-major.  Simple ikj loop — cache-friendly enough for
/// the mini models; the blocked variant below is the accelerated path.
pub fn gemm_f32_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Cache-blocked f32 GEMM (the "CBLAS" stand-in for the standard
/// engine): ikj with 64×256 K×N tiling.
pub fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    const KB: usize = 64;
    const NB: usize = 256;
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KB).min(k);
        let mut n0 = 0;
        while n0 < n {
            let nend = (n0 + NB).min(n);
            for i in 0..m {
                let orow = &mut out[i * n + n0..i * n + nend];
                for kk in k0..kend {
                    let av = a[i * k + kk];
                    let brow = &b[kk * n + n0..kk * n + nend];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            n0 = nend;
        }
        k0 = kend;
    }
}

/// Row-parallel tiled f32 GEMM: each worker runs the cache-blocked
/// kernel on a contiguous M band (disjoint slices of `a` and `out`).
pub fn gemm_f32_parallel(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    pool: &Pool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    pool.run_rows(m, n, out, |row0, band| {
        let rows = band.len() / n.max(1);
        gemm_f32(rows, k, n, &a[row0 * k..(row0 + rows) * k], b, band);
    });
}

/// [`gemm_f32`] without the zero fill: out += a @ b.  Each out cell
/// accumulates in ascending-k order exactly as the blocked kernel
/// does, so summing a k-partition tap by tap (the fused first conv)
/// is **bit-identical** to one full-k [`gemm_f32`] call over the
/// concatenated operands.
pub fn gemm_f32_acc(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    const KB: usize = 64;
    const NB: usize = 256;
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KB).min(k);
        let mut n0 = 0;
        while n0 < n {
            let nend = (n0 + NB).min(n);
            for i in 0..m {
                let orow = &mut out[i * n + n0..i * n + nend];
                for kk in k0..kend {
                    let av = a[i * k + kk];
                    let brow = &b[kk * n + n0..kk * n + nend];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            n0 = nend;
        }
        k0 = kend;
    }
}

/// Row-parallel [`gemm_f32_acc`]: bands split only M, so results are
/// bit-identical to the serial accumulate at any thread count.
pub fn gemm_f32_acc_parallel(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    pool: &Pool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    pool.run_rows(m, n, out, |row0, band| {
        let rows = band.len() / n.max(1);
        gemm_f32_acc(rows, k, n, &a[row0 * k..(row0 + rows) * k], b, band);
    });
}

/// Bit mask selecting bits `[start, end)` of one u64 word
/// (`0 ≤ start < end ≤ 64`).
#[inline]
fn word_range_mask(start: usize, end: usize) -> u64 {
    debug_assert!(start < end && end <= 64);
    let hi = if end == 64 { u64::MAX } else { (1u64 << end) - 1 };
    hi & (u64::MAX << start)
}

/// Packed-A real GEMM: out (k×n) = Âᵀ @ B, where Â is the bit-packed
/// ±1 (rows × k) matrix and B is dense f32 (rows × n) —
/// `out[kk][j] = Σ_r Â[r][kk]·b[r][j]`.
///
/// This is the conv/dense backward's dW contraction (X̂ᵀ·∂Y) computed
/// straight from the packed activation panel: no (rows × k) f32
/// unpack, no (k × rows) transpose — the buffers that used to bound
/// the backward's transient peak.  Row-outer per band: each ∂Y row is
/// added to the band's out rows with set bits and subtracted from
/// those with clear bits, so every out cell accumulates in ascending
/// row order — **bit-identical** to densifying Âᵀ and running
/// [`gemm_f32`]/[`gemm_f32_naive`], at any thread count (bands split
/// the k axis, never the reduction axis).
pub fn packed_at_gemm_f32(a: &BitMatrix, b: &[f32], n: usize, out: &mut [f32], pool: &Pool) {
    let (rows, k) = (a.rows, a.cols);
    assert_eq!(b.len(), rows * n, "B shape mismatch");
    assert_eq!(out.len(), k * n, "out shape mismatch");
    if k == 0 || n == 0 {
        return;
    }
    pool.run_rows(k, n, out, |kk0, band| {
        band.fill(0.0);
        let kk1 = kk0 + band.len() / n;
        for r in 0..rows {
            let brow = &b[r * n..(r + 1) * n];
            let words = a.row_words(r);
            let (w0, wlast) = (kk0 >> 6, (kk1 - 1) >> 6);
            for w in w0..=wlast {
                let lo = (w << 6).max(kk0);
                let hi = ((w << 6) + 64).min(kk1);
                let mask = word_range_mask(lo - (w << 6), hi - (w << 6));
                let mut set = words[w] & mask;
                let mut clear = !words[w] & mask;
                while set != 0 {
                    let kk = (w << 6) + set.trailing_zeros() as usize;
                    let orow = &mut band[(kk - kk0) * n..(kk - kk0 + 1) * n];
                    simd::add_assign_f32(orow, brow);
                    set &= set - 1;
                }
                while clear != 0 {
                    let kk = (w << 6) + clear.trailing_zeros() as usize;
                    let orow = &mut band[(kk - kk0) * n..(kk - kk0 + 1) * n];
                    simd::sub_assign_f32(orow, brow);
                    clear &= clear - 1;
                }
            }
        }
    });
}

/// f32 AᵀB GEMM without materializing Aᵀ: out (k×n) = aᵀ (rows×k) @ b
/// (rows×n).  Replaces the `transpose(a)` + [`gemm_f32`] pair of the
/// pre-fusion backward (one whole rows×k transient gone); row-outer,
/// so each out cell accumulates in ascending row order — bit-identical
/// to the transpose+GEMM path at any thread count.  ±1 entries take
/// the exact add/sub path (the engines' signed activations).
pub fn gemm_f32_at(
    rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    pool: &Pool,
) {
    assert_eq!(a.len(), rows * k);
    assert_eq!(b.len(), rows * n);
    assert_eq!(out.len(), k * n);
    if k == 0 || n == 0 {
        return;
    }
    pool.run_rows(k, n, out, |kk0, band| {
        band.fill(0.0);
        let kks = band.len() / n;
        for r in 0..rows {
            let arow = &a[r * k + kk0..r * k + kk0 + kks];
            let brow = &b[r * n..(r + 1) * n];
            for (kkl, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut band[kkl * n..(kkl + 1) * n];
                if av == 1.0 {
                    simd::add_assign_f32(orow, brow);
                } else if av == -1.0 {
                    simd::sub_assign_f32(orow, brow);
                } else {
                    simd::axpy_f32(orow, av, brow);
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn ref_pm1(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let sgn = |x: f32| if x >= 0.0 { 1.0 } else { -1.0f32 };
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += sgn(a[i * k + kk]) * sgn(b[kk * n + j]);
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn pack_b_t(k: usize, n: usize, b: &[f32]) -> BitMatrix {
        // transpose b (k×n) into (n×k) then pack
        let mut bt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        BitMatrix::pack(n, k, &bt)
    }

    #[test]
    fn xnor_matches_reference_odd_shapes() {
        let mut g = Pcg32::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 64, 5), (4, 65, 7), (5, 200, 9), (8, 127, 4)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let want = ref_pm1(m, k, n, &a, &b);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let mut naive = vec![0.0; m * n];
            let mut blocked = vec![0.0; m * n];
            xnor_gemm_naive(&ap, &btp, &mut naive);
            xnor_gemm(&ap, &btp, &mut blocked);
            assert_eq!(naive, want, "naive {m}x{k}x{n}");
            assert_eq!(blocked, want, "blocked {m}x{k}x{n}");
        }
    }

    #[test]
    fn tiled_and_parallel_bit_exact_vs_naive() {
        // odd shapes: K not a multiple of 64, M/N below the 4×4 tile,
        // single row/col, K crossing the KC_WORDS tile boundary
        let mut g = Pcg32::new(7);
        for (m, k, n) in [
            (1, 1, 1),
            (1, 65, 1),
            (2, 63, 3),
            (3, 64, 4),
            (4, 100, 4),
            (5, 127, 9),
            (7, 130, 6),
            (8, 8256, 5), // kw = 129 > KC_WORDS: exercises the K tiling
            (13, 200, 17),
            (70, 130, 70), // 4900 output cells: crosses the pool's
                           // MIN_PARALLEL_CELLS, so threads really band
        ] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let mut naive = vec![0.0; m * n];
            xnor_gemm_naive(&ap, &btp, &mut naive);
            let mut tiled = vec![0.0; m * n];
            xnor_gemm_tiled(&ap, &btp, &mut tiled);
            assert_eq!(tiled, naive, "tiled {m}x{k}x{n}");
            for threads in [1, 2, 4] {
                let mut par = vec![0.0; m * n];
                xnor_gemm_parallel(&ap, &btp, &mut par, &Pool::new(threads));
                assert_eq!(par, naive, "parallel t={threads} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn simd_and_scalar_bands_bit_exact() {
        // the dispatched tiled kernel (vectorized where the host has
        // AVX2/NEON) against the forced-scalar 4×4 micro-kernel, on
        // shapes hitting every panel/word remainder
        let mut g = Pcg32::new(17);
        for (m, k, n) in [
            (1, 1, 1),
            (2, 63, 3),
            (4, 64, 4),
            (5, 129, 9),
            (7, 257, 6),
            (8, 8256, 5),
            (13, 200, 17),
        ] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let mut scalar = vec![0.0; m * n];
            xnor_gemm_tiled_scalar(&ap, &btp, &mut scalar);
            let mut dispatched = vec![0.0; m * n];
            xnor_gemm_tiled(&ap, &btp, &mut dispatched);
            assert_eq!(dispatched, scalar, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn every_kernel_cfg_bit_exact_vs_naive() {
        // the autotuner's whole candidate space — every micro-kernel
        // (panels packed and not), K tiles, band granularities — on
        // shapes hitting panel/word/row remainders
        let mut g = Pcg32::new(23);
        let micros = [
            MicroKernel::Scalar4x4,
            MicroKernel::Simd1x4,
            MicroKernel::Simd1x8,
            MicroKernel::Simd2x4,
            MicroKernel::Panel8,
        ];
        for (m, k, n) in [(1, 1, 1), (3, 63, 5), (5, 129, 9), (7, 200, 17), (70, 130, 70)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let panels = BPanels::pack(&btp);
            let mut naive = vec![0.0; m * n];
            xnor_gemm_naive(&ap, &btp, &mut naive);
            for micro in micros {
                for kc in [1usize, 2, 128] {
                    for band_rows in [0usize, 1, 3] {
                        let cfg = KernelCfg { micro, kc_words: kc, band_rows };
                        for (bp, tag) in [(None, "flat"), (Some(&panels), "panels")] {
                            for threads in [1, 4] {
                                let mut out = vec![0.0; m * n];
                                xnor_gemm_with(cfg, &ap, &btp, bp, &mut out, &Pool::new(threads));
                                assert_eq!(
                                    out, naive,
                                    "{} {tag} t={threads} {m}x{k}x{n}",
                                    cfg.label()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn b_panels_pack_into_reuses_storage() {
        let mut g = Pcg32::new(24);
        let (k, n) = (130, 19);
        let bt = BitMatrix::pack(n, k, &g.normal_vec(n * k));
        let mut p = BPanels::pack(&bt);
        assert_eq!(p.data.len(), BPanels::words_for(n, bt.words_per_row));
        assert_eq!(p.heap_bytes(), p.data.len() * 8);
        let ptr = p.data.as_ptr();
        let bt2 = BitMatrix::pack(n, k, &g.normal_vec(n * k));
        p.pack_into(&bt2);
        assert_eq!(ptr, p.data.as_ptr(), "same-shape repack must not reallocate");
        // repacked panels compute the new matrix
        let a = BitMatrix::pack(4, k, &g.normal_vec(4 * k));
        let mut want = vec![0.0; 4 * n];
        xnor_gemm_naive(&a, &bt2, &mut want);
        let cfg = KernelCfg { micro: MicroKernel::Panel8, kc_words: 128, band_rows: 0 };
        let mut got = vec![0.0; 4 * n];
        xnor_gemm_with(cfg, &a, &bt2, Some(&p), &mut got, &Pool::serial());
        assert_eq!(got, want);
    }

    #[test]
    fn gemm_f32_acc_tap_partition_is_bit_identical() {
        // accumulate k in uneven chunks == one full-k call, exactly
        // (the fused first conv's correctness claim)
        let mut g = Pcg32::new(25);
        for (m, k, n) in [(3, 11, 7), (8, 64, 33), (5, 100, 9)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let mut want = vec![0.0f32; m * n];
            gemm_f32(m, k, n, &a, &b, &mut want);
            for chunk in [1usize, 3, 64] {
                for threads in [1usize, 4] {
                    let pool = Pool::new(threads);
                    let mut got = vec![0.0f32; m * n];
                    let mut k0 = 0;
                    while k0 < k {
                        let kc = chunk.min(k - k0);
                        // gather the a column block (what the tap
                        // panel gather does)
                        let mut ablk = vec![0.0f32; m * kc];
                        for i in 0..m {
                            ablk[i * kc..(i + 1) * kc]
                                .copy_from_slice(&a[i * k + k0..i * k + k0 + kc]);
                        }
                        gemm_f32_acc_parallel(
                            m,
                            kc,
                            n,
                            &ablk,
                            &b[k0 * n..(k0 + kc) * n],
                            &mut got,
                            &pool,
                        );
                        k0 += kc;
                    }
                    assert_eq!(got, want, "chunk={chunk} t={threads} {m}x{k}x{n}");
                }
            }
        }
    }

    #[test]
    fn xnor_extremes() {
        // all +1 . all +1 = k; all +1 . all -1 = -k — on every kernel
        let k = 70;
        let a = BitMatrix::pack(1, k, &vec![1.0; k]);
        let bp = BitMatrix::pack(1, k, &vec![1.0; k]);
        let bn = BitMatrix::pack(1, k, &vec![-1.0; k]);
        let mut out = vec![0.0; 1];
        for f in [
            xnor_gemm as fn(&BitMatrix, &BitMatrix, &mut [f32]),
            xnor_gemm_naive,
            xnor_gemm_tiled,
        ] {
            f(&a, &bp, &mut out);
            assert_eq!(out[0], k as f32);
            f(&a, &bn, &mut out);
            assert_eq!(out[0], -(k as f32));
        }
    }

    #[test]
    fn f32_gemms_agree() {
        let mut g = Pcg32::new(4);
        for (m, k, n) in [(3, 5, 7), (16, 64, 33), (10, 100, 257)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let mut x = vec![0.0; m * n];
            let mut y = vec![0.0; m * n];
            gemm_f32_naive(m, k, n, &a, &b, &mut x);
            gemm_f32(m, k, n, &a, &b, &mut y);
            for i in 0..x.len() {
                assert!((x[i] - y[i]).abs() < 1e-3, "{i}: {} vs {}", x[i], y[i]);
            }
            // the parallel path splits only along M, so each band is
            // the blocked kernel verbatim: results are bit-identical
            for threads in [1, 2, 4] {
                let mut z = vec![0.0; m * n];
                gemm_f32_parallel(m, k, n, &a, &b, &mut z, &Pool::new(threads));
                assert_eq!(y, z, "parallel t={threads} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn word_range_mask_cases() {
        assert_eq!(word_range_mask(0, 64), u64::MAX);
        assert_eq!(word_range_mask(0, 1), 1);
        assert_eq!(word_range_mask(63, 64), 1u64 << 63);
        assert_eq!(word_range_mask(4, 8), 0b1111_0000);
        assert_eq!(word_range_mask(0, 64).count_ones(), 64);
        for s in 0..64 {
            for e in (s + 1)..=64 {
                assert_eq!(word_range_mask(s, e).count_ones() as usize, e - s, "{s}..{e}");
            }
        }
    }

    fn transpose_ref(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = a[r * cols + c];
            }
        }
        t
    }

    #[test]
    fn packed_at_gemm_bit_identical_to_densified_reference() {
        // the dW kernel's exactness claim: identical to unpacking Âᵀ
        // and running the dense f32 GEMM — odd shapes (k off the word
        // grid, k below/above one word, single row/col) and every
        // thread count (bands split k, not the reduction)
        let mut g = Pcg32::new(51);
        for (rows, k, n) in [
            (1, 1, 1),
            (3, 63, 4),
            (5, 64, 3),
            (7, 65, 5),
            (16, 130, 9),
            (33, 200, 17),
            (64, 70, 70), // 4900 cells: crosses MIN_PARALLEL_CELLS
        ] {
            let av = g.normal_vec(rows * k);
            let b = g.normal_vec(rows * n);
            let a = BitMatrix::pack(rows, k, &av);
            let at = transpose_ref(&a.unpack(), rows, k); // (k × rows) ±1
            let mut want = vec![0.0f32; k * n];
            gemm_f32(k, rows, n, &at, &b, &mut want);
            for threads in [1, 2, 4] {
                let mut got = vec![0.0f32; k * n];
                packed_at_gemm_f32(&a, &b, n, &mut got, &Pool::new(threads));
                assert_eq!(got, want, "t={threads} ({rows},{k},{n})");
            }
        }
    }

    #[test]
    fn gemm_f32_at_bit_identical_to_transpose_then_gemm() {
        let mut g = Pcg32::new(52);
        for (rows, k, n) in [(1, 1, 1), (4, 7, 3), (16, 64, 33), (10, 100, 9), (70, 70, 70)] {
            // mix dense values with exact ±1/0 entries (the signed
            // activation fast paths)
            let a: Vec<f32> = g
                .normal_vec(rows * k)
                .into_iter()
                .enumerate()
                .map(|(i, v)| match i % 5 {
                    0 => 1.0,
                    1 => -1.0,
                    2 => 0.0,
                    _ => v,
                })
                .collect();
            let b = g.normal_vec(rows * n);
            let at = transpose_ref(&a, rows, k);
            let mut want = vec![0.0f32; k * n];
            gemm_f32(k, rows, n, &at, &b, &mut want);
            for threads in [1, 2, 4] {
                let mut got = vec![0.0f32; k * n];
                gemm_f32_at(rows, k, n, &a, &b, &mut got, &Pool::new(threads));
                assert_eq!(got, want, "t={threads} ({rows},{k},{n})");
            }
        }
    }

    #[test]
    fn gemm_identity() {
        // A @ I = A
        let m = 4;
        let k = 8;
        let mut g = Pcg32::new(5);
        let a = g.normal_vec(m * k);
        let mut eye = vec![0.0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        let mut out = vec![0.0; m * k];
        gemm_f32(m, k, k, &a, &eye, &mut out);
        for i in 0..a.len() {
            assert!((out[i] - a[i]).abs() < 1e-6);
        }
    }
}
