//! XNOR-popcount GEMM kernels.
//!
//! `xnor_gemm_naive` — straight triple loop over packed words: the
//! paper's naïve C++ prototype equivalent.
//!
//! `xnor_gemm` — register-blocked 1×4 micro-kernel over the packed K
//! axis: the "CBLAS-accelerated" path of Fig. 7 (memory-for-speed:
//! it wants `b` pre-transposed, which the engine caches per step).
//!
//! Both compute `out[m][n] = Σ_k a[m,k]·b[k,n]` over ±1 values where
//! `b_t` is the transposed packed B (rows = N, cols = K).  Zero tail
//! bits in both operands XOR to 0, so `k − 2·popcount(xor)` is exact
//! with no padding correction.

use super::BitMatrix;

/// Naive packed GEMM: out (m×n) f32 = a (m×k ±1) @ b (k×n ±1),
/// with `b_t` packed transposed (n rows of k bits).
pub fn xnor_gemm_naive(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n, k) = (a.rows, b_t.rows, a.cols);
    assert_eq!(out.len(), m * n);
    // Zero-padded tail bits XOR to 0 in both operands (a "match"),
    // so dot = k_padded - 2*mismatch - pad = k - 2*mismatch exactly.
    for i in 0..m {
        let ar = a.row_words(i);
        for j in 0..n {
            let br = b_t.row_words(j);
            let mut mismatch = 0u32;
            for w in 0..ar.len() {
                mismatch += (ar[w] ^ br[w]).count_ones();
            }
            out[i * n + j] = (k as i64 - 2 * mismatch as i64) as f32;
        }
    }
}

/// Blocked packed GEMM: 1×4 N-unrolled micro-kernel; ~3-4× the naive
/// throughput at BinaryNet sizes (see benches/perf log).
pub fn xnor_gemm(a: &BitMatrix, b_t: &BitMatrix, out: &mut [f32]) {
    assert_eq!(a.cols, b_t.cols, "K mismatch");
    let (m, n, k) = (a.rows, b_t.rows, a.cols);
    assert_eq!(out.len(), m * n);
    let kw = a.words_per_row;
    let n4 = n - n % 4;

    for i in 0..m {
        let ar = a.row_words(i);
        let orow = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        while j < n4 {
            let b0 = &b_t.data[j * kw..(j + 1) * kw];
            let b1 = &b_t.data[(j + 1) * kw..(j + 2) * kw];
            let b2 = &b_t.data[(j + 2) * kw..(j + 3) * kw];
            let b3 = &b_t.data[(j + 3) * kw..(j + 4) * kw];
            let (mut c0, mut c1, mut c2, mut c3) = (0u64, 0u64, 0u64, 0u64);
            for w in 0..kw {
                let aw = ar[w];
                c0 += (aw ^ b0[w]).count_ones() as u64;
                c1 += (aw ^ b1[w]).count_ones() as u64;
                c2 += (aw ^ b2[w]).count_ones() as u64;
                c3 += (aw ^ b3[w]).count_ones() as u64;
            }
            let kk = k as i64;
            orow[j] = (kk - 2 * c0 as i64) as f32;
            orow[j + 1] = (kk - 2 * c1 as i64) as f32;
            orow[j + 2] = (kk - 2 * c2 as i64) as f32;
            orow[j + 3] = (kk - 2 * c3 as i64) as f32;
            j += 4;
        }
        while j < n {
            let br = b_t.row_words(j);
            let mut c = 0u64;
            for w in 0..kw {
                c += (ar[w] ^ br[w]).count_ones() as u64;
            }
            orow[j] = (k as i64 - 2 * c as i64) as f32;
            j += 1;
        }
    }
}

/// f32 reference GEMM (the standard engine's compute): out = a @ b,
/// both dense row-major.  Simple ikj loop — cache-friendly enough for
/// the mini models; the blocked variant below is the accelerated path.
pub fn gemm_f32_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
}

/// Cache-blocked f32 GEMM (the "CBLAS" stand-in for the standard
/// engine): ikj with 64×256 K×N tiling.
pub fn gemm_f32(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    const KB: usize = 64;
    const NB: usize = 256;
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    let mut k0 = 0;
    while k0 < k {
        let kend = (k0 + KB).min(k);
        let mut n0 = 0;
        while n0 < n {
            let nend = (n0 + NB).min(n);
            for i in 0..m {
                let orow = &mut out[i * n + n0..i * n + nend];
                for kk in k0..kend {
                    let av = a[i * k + kk];
                    let brow = &b[kk * n + n0..kk * n + nend];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
            n0 = nend;
        }
        k0 = kend;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn ref_pm1(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let sgn = |x: f32| if x >= 0.0 { 1.0 } else { -1.0f32 };
        let mut out = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += sgn(a[i * k + kk]) * sgn(b[kk * n + j]);
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    fn pack_b_t(k: usize, n: usize, b: &[f32]) -> BitMatrix {
        // transpose b (k×n) into (n×k) then pack
        let mut bt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        BitMatrix::pack(n, k, &bt)
    }

    #[test]
    fn xnor_matches_reference_odd_shapes() {
        let mut g = Pcg32::new(3);
        for (m, k, n) in [(1, 1, 1), (3, 64, 5), (4, 65, 7), (5, 200, 9), (8, 127, 4)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let want = ref_pm1(m, k, n, &a, &b);
            let ap = BitMatrix::pack(m, k, &a);
            let btp = pack_b_t(k, n, &b);
            let mut naive = vec![0.0; m * n];
            let mut blocked = vec![0.0; m * n];
            xnor_gemm_naive(&ap, &btp, &mut naive);
            xnor_gemm(&ap, &btp, &mut blocked);
            assert_eq!(naive, want, "naive {m}x{k}x{n}");
            assert_eq!(blocked, want, "blocked {m}x{k}x{n}");
        }
    }

    #[test]
    fn xnor_extremes() {
        // all +1 . all +1 = k; all +1 . all -1 = -k
        let k = 70;
        let a = BitMatrix::pack(1, k, &vec![1.0; k]);
        let bp = BitMatrix::pack(1, k, &vec![1.0; k]);
        let bn = BitMatrix::pack(1, k, &vec![-1.0; k]);
        let mut out = vec![0.0; 1];
        xnor_gemm(&a, &bp, &mut out);
        assert_eq!(out[0], k as f32);
        xnor_gemm(&a, &bn, &mut out);
        assert_eq!(out[0], -(k as f32));
    }

    #[test]
    fn f32_gemms_agree() {
        let mut g = Pcg32::new(4);
        for (m, k, n) in [(3, 5, 7), (16, 64, 33), (10, 100, 257)] {
            let a = g.normal_vec(m * k);
            let b = g.normal_vec(k * n);
            let mut x = vec![0.0; m * n];
            let mut y = vec![0.0; m * n];
            gemm_f32_naive(m, k, n, &a, &b, &mut x);
            gemm_f32(m, k, n, &a, &b, &mut y);
            for i in 0..x.len() {
                assert!((x[i] - y[i]).abs() < 1e-3, "{i}: {} vs {}", x[i], y[i]);
            }
        }
    }

    #[test]
    fn gemm_identity() {
        // A @ I = A
        let m = 4;
        let k = 8;
        let mut g = Pcg32::new(5);
        let a = g.normal_vec(m * k);
        let mut eye = vec![0.0f32; k * k];
        for i in 0..k {
            eye[i * k + i] = 1.0;
        }
        let mut out = vec![0.0; m * k];
        gemm_f32(m, k, k, &a, &eye, &mut out);
        for i in 0..a.len() {
            assert!((out[i] - a[i]).abs() < 1e-6);
        }
    }
}
