//! Variable representation & lifetime analysis (paper Sec. 4, Table 2).
//!
//! Prices every variable class of a training step under a
//! [`DtypeConfig`], honoring the two lifetime classes:
//!
//! - **retained** variables must stay live across the forward /
//!   backward / update phases → summed over all layers
//!   (X, W, ∂W, β/∂β, µ·σ (or ψ·ω), momenta, pooling masks);
//! - **transient** variables live only during one layer's fwd or bwd →
//!   only the *largest* layer counts (Y/∂X share one buffer — equal
//!   size, non-overlapping lifetimes — and ∂Y is its own buffer).
//!
//! Reproduces Table 2 to the MiB and every memory column of Tables
//! 4/5/6 and Figs. 2/6.

use crate::models::{Graph, LayerKind};
use crate::util::MIB;

/// Storage data types of the paper's Table 1/2 rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    Bool,
}

impl Dtype {
    /// Bytes per element.  `Bool` is 1 bit — the paper's modeled
    /// memory for binary tensors divides by 32 vs f32 — expressed in
    /// fractional bytes.
    pub fn bits(self) -> f64 {
        match self {
            Dtype::F32 => 32.0,
            Dtype::F16 => 16.0,
            Dtype::Bool => 1.0,
        }
    }

    pub fn bytes(self) -> f64 {
        self.bits() / 8.0
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "float32",
            Dtype::F16 => "float16",
            Dtype::Bool => "bool",
        }
    }
}

/// Optimizer choice — determines momenta inventory (Table 5 shows the
/// optimizer changing the standard-training total).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    /// Adam: two momenta (m, v) per parameter; ∂W retained.
    Adam,
    /// SGD with momentum: one velocity per parameter; ∂W retained.
    Sgd,
    /// Bop: one gradient EMA per weight, updated in place as gradients
    /// are produced, so ∂W is never retained (hence Table 5's
    /// 405.83 = 512.81 − 53.49 (one momentum) − 53.49 (∂W)).
    Bop,
}

impl Optimizer {
    pub fn parse(s: &str) -> Option<Optimizer> {
        match s {
            "adam" => Some(Optimizer::Adam),
            "sgd" => Some(Optimizer::Sgd),
            "bop" => Some(Optimizer::Bop),
            _ => None,
        }
    }

    pub fn momenta_per_weight(self) -> f64 {
        match self {
            Optimizer::Adam => 2.0,
            Optimizer::Sgd | Optimizer::Bop => 1.0,
        }
    }

    pub fn retains_dw(self) -> bool {
        !matches!(self, Optimizer::Bop)
    }
}

/// Per-variable-class storage dtypes (one row of Table 1).
#[derive(Clone, Copy, Debug)]
pub struct DtypeConfig {
    /// Retained activations X (the Fig. 1 red dependency).
    pub x: Dtype,
    /// Transient Y / ∂X (shared buffer) and ∂Y.
    pub y_grads: Dtype,
    /// Batch-norm statistics µ,σ (or µ,ψ,ω).
    pub stats: Dtype,
    /// Latent weights W.
    pub w: Dtype,
    /// Weight gradients ∂W.
    pub dw: Dtype,
    /// β and ∂β.
    pub beta: Dtype,
    /// Optimizer momenta.
    pub momenta: Dtype,
    /// Max-pool argmax masks.
    pub masks: Dtype,
}

impl DtypeConfig {
    /// Courbariaux & Bengio's standard flow: everything float32.
    pub fn standard() -> DtypeConfig {
        DtypeConfig {
            x: Dtype::F32,
            y_grads: Dtype::F32,
            stats: Dtype::F32,
            w: Dtype::F32,
            dw: Dtype::F32,
            beta: Dtype::F32,
            momenta: Dtype::F32,
            masks: Dtype::F32,
        }
    }

    /// The paper's proposed flow (Alg. 2 / Table 2 right half).
    pub fn proposed() -> DtypeConfig {
        DtypeConfig {
            x: Dtype::Bool,
            y_grads: Dtype::F16,
            stats: Dtype::F16,
            w: Dtype::F16,
            dw: Dtype::Bool,
            beta: Dtype::F16,
            momenta: Dtype::F16,
            masks: Dtype::Bool,
        }
    }

    /// Table 5 ablation rows.  `standard`/`f16`/`boolgrad_l2`/
    /// `boolgrad_l1`/`proposed` — mirrors
    /// `python/compile/layers.py::TrainConfig::ablation`.
    pub fn ablation(name: &str) -> Option<DtypeConfig> {
        Some(match name {
            "standard" => DtypeConfig::standard(),
            "f16" => DtypeConfig {
                x: Dtype::F16,
                y_grads: Dtype::F16,
                stats: Dtype::F16,
                w: Dtype::F16,
                dw: Dtype::F16,
                beta: Dtype::F16,
                momenta: Dtype::F16,
                masks: Dtype::F16,
            },
            // bool ∂W, f16 grads, but l2 BN still retains f16 X
            "boolgrad_l2" | "boolgrad_l1" => DtypeConfig {
                dw: Dtype::Bool,
                ..DtypeConfig::ablation("f16").unwrap()
            },
            "proposed" => DtypeConfig::proposed(),
            _ => return None,
        })
    }

    /// Table 6 single-approximation rows (applied to `standard`).
    pub fn table6(name: &str) -> Option<DtypeConfig> {
        Some(match name {
            "none" | "standard" => DtypeConfig::standard(),
            // TPU bfloat16 ~ f16 for sizing purposes (both 16 bit)
            "bf16" | "f16" => DtypeConfig::ablation("f16").unwrap(),
            "boolgrad" => DtypeConfig {
                dw: Dtype::Bool,
                ..DtypeConfig::standard()
            },
            "l1_bn" => DtypeConfig::standard(), // math change, no dtype change
            // proposed BN alone: binary X + bool masks, rest f32
            "prop_bn" => DtypeConfig {
                x: Dtype::Bool,
                masks: Dtype::Bool,
                ..DtypeConfig::standard()
            },
            "proposed" => DtypeConfig::proposed(),
            _ => return None,
        })
    }
}

/// One priced row of Table 2.
#[derive(Clone, Debug)]
pub struct VarRow {
    pub name: &'static str,
    pub dtype: Dtype,
    pub bytes: f64,
    /// false = must be retained across phases; true = transient
    /// (rebuildable / max-over-layers).
    pub transient: bool,
}

/// The full memory breakdown for one training configuration.
#[derive(Clone, Debug)]
pub struct Breakdown {
    pub model: String,
    pub batch: usize,
    pub rows: Vec<VarRow>,
}

impl Breakdown {
    pub fn total_bytes(&self) -> f64 {
        self.rows.iter().map(|r| r.bytes).sum()
    }

    pub fn total_mib(&self) -> f64 {
        self.total_bytes() / MIB
    }

    pub fn row(&self, name: &str) -> Option<&VarRow> {
        self.rows.iter().find(|r| r.name == name)
    }
}

/// Price a training step: the paper's Table 2 computation.
pub fn breakdown(
    graph: &Graph,
    batch: usize,
    cfg: &DtypeConfig,
    opt: Optimizer,
) -> Breakdown {
    let b = batch as f64;
    let w = graph.total_weights() as f64;
    let ch = graph.total_channels() as f64;
    let x = graph.retained_act_elems() as f64 * b;
    let y = graph.max_y_elems() as f64 * b;
    let masks = graph.pool_mask_elems() as f64 * b;
    // residual skips stay f32 (the accuracy-critical high-precision
    // path); zero for non-residual models
    let skip = graph.residual_skip_elems() as f64 * b;

    // Bop's weights are inherently binary (no latent weights); once a
    // reduced-precision scheme is in play they are stored packed —
    // Table 5's Bop/proposed row (82.45 MiB) prices W at 1 bit.  The
    // all-f32 standard convention keeps them in f32 containers
    // (matching the paper's 405.83).
    let w_dtype = if matches!(opt, Optimizer::Bop) && cfg.w != Dtype::F32 {
        Dtype::Bool
    } else {
        cfg.w
    };
    let mut rows = vec![
        VarRow { name: "X", dtype: cfg.x, bytes: x * cfg.x.bytes(), transient: false },
        VarRow {
            name: "dX/Y",
            dtype: cfg.y_grads,
            bytes: y * cfg.y_grads.bytes(),
            transient: true,
        },
        VarRow {
            name: "mu/sigma",
            dtype: cfg.stats,
            bytes: 2.0 * ch * cfg.stats.bytes(),
            transient: false,
        },
        VarRow {
            name: "dY",
            dtype: cfg.y_grads,
            bytes: y * cfg.y_grads.bytes(),
            transient: true,
        },
        VarRow { name: "W", dtype: w_dtype, bytes: w * w_dtype.bytes(), transient: false },
    ];
    if opt.retains_dw() {
        rows.push(VarRow {
            name: "dW",
            dtype: cfg.dw,
            bytes: w * cfg.dw.bytes(),
            transient: false,
        });
    }
    rows.push(VarRow {
        name: "beta/dbeta",
        dtype: cfg.beta,
        bytes: 2.0 * ch * cfg.beta.bytes(),
        transient: false,
    });
    rows.push(VarRow {
        name: "momenta",
        dtype: cfg.momenta,
        bytes: opt.momenta_per_weight() * (w + ch) * cfg.momenta.bytes(),
        transient: false,
    });
    if masks > 0.0 {
        rows.push(VarRow {
            name: "pool masks",
            dtype: cfg.masks,
            bytes: masks * cfg.masks.bytes(),
            transient: false,
        });
    }
    if skip > 0.0 {
        rows.push(VarRow {
            name: "residual skips",
            dtype: Dtype::F32,
            bytes: skip * Dtype::F32.bytes(),
            transient: true,
        });
    }
    Breakdown { model: graph.name.clone(), batch, rows }
}

/// Peak transient im2col footprint of the binary conv **forward**
/// GEMM path (max over non-first conv layers; the real-input first
/// layer streams its f32 im2col tap-by-tap and is priced by
/// [`first_conv_transient`]).
///
/// Pre-fusion (PR 1) the accelerated engines' forward materialized a
/// f32 cols buffer of B·H·W × k²·Cin and bit-packed it in a second
/// pass — both live at the pack.  The fused `bitops::im2col_packed`
/// packs patches directly: `f32_bytes` drops to exactly zero and
/// only the 1-bit panel remains (~33× less for word-aligned K).
/// Scope: this models the forward im2col only — the conv *backward*
/// still allocates rows × k f32 buffers (dX patch gradients; the
/// standard engine's dW im2col), so the whole-step peak transient is
/// unchanged until that lever lands.  `memtrack`-measured
/// counterpart: rust/tests/memtrack_conv.rs.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConvColsTransient {
    /// f32 cols buffer bytes (0 on the fused path).
    pub f32_bytes: f64,
    /// Bit-packed patch panel bytes (rows padded to whole u64 words).
    pub packed_bytes: f64,
}

impl ConvColsTransient {
    pub fn total(&self) -> f64 {
        self.f32_bytes + self.packed_bytes
    }
}

/// Model the binary conv path's transient im2col memory, pre-fusion
/// (`fused = false`: f32 cols + packed panel) or fused
/// (`fused = true`: packed panel only, zero f32 bytes).  Rows are the
/// conv's *output* positions (`h_out · w_out · batch` — what the
/// fused packed pipeline allocates for strided/VALID geometry too).
pub fn conv_cols_transient(graph: &Graph, batch: usize, fused: bool) -> ConvColsTransient {
    let mut best = ConvColsTransient::default();
    for n in &graph.nodes {
        if n.kind != LayerKind::Conv || n.first {
            continue;
        }
        let (pos, k, _) = n.gemm; // pos = h_out · w_out
        let rows = (pos * batch) as f64;
        let cand = ConvColsTransient {
            f32_bytes: if fused { 0.0 } else { rows * k as f64 * 4.0 },
            packed_bytes: rows * (k.div_ceil(64) * 8) as f64,
        };
        if cand.total() > best.total() {
            best = cand;
        }
    }
    best
}

/// Peak transient footprint of the binary conv **backward** (max over
/// non-first conv layers) — the step-level twin of
/// [`ConvColsTransient`], which covers the forward only.
///
/// Pre-fusion (PR 2) the accelerated backward held three rows × k f32
/// buffers live at its peak: the dX patch gradients `dcols` plus the
/// standard engine's dW `im2col` cols and their transpose (all scoped
/// to the end of the layer arm).  The fused backward streams dX
/// tap-by-tap (one rows × Cin panel) and contracts dW straight from a
/// re-packed 1-bit patch panel: `dcols_f32_bytes` and
/// `dw_cols_f32_bytes` drop to exactly zero, and with the forward
/// already fused this is what moves the whole-step peak.
/// `memtrack`-measured counterpart: rust/tests/memtrack_conv.rs.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConvBackwardTransient {
    /// dX patch-gradient buffer (rows × k f32; 0 on the fused path).
    pub dcols_f32_bytes: f64,
    /// dW im2col cols + transpose (2 × rows × k f32; 0 fused).
    pub dw_cols_f32_bytes: f64,
    /// Streaming per-tap panel (rows × Cin f32; fused path only).
    pub panel_f32_bytes: f64,
    /// Bit-packed patch panel for dW (fused path only).
    pub packed_bytes: f64,
}

impl ConvBackwardTransient {
    pub fn total(&self) -> f64 {
        self.dcols_f32_bytes + self.dw_cols_f32_bytes + self.panel_f32_bytes + self.packed_bytes
    }
}

/// Model the binary conv backward's transient memory, pre-fusion
/// (`fused = false`: dcols + dW cols + colsᵀ, all f32) or fused
/// (`fused = true`: one rows × Cin panel + the 1-bit packed panel).
pub fn conv_backward_transient(
    graph: &Graph,
    batch: usize,
    fused: bool,
) -> ConvBackwardTransient {
    let mut best = ConvBackwardTransient::default();
    for n in &graph.nodes {
        if n.kind != LayerKind::Conv || n.first {
            continue;
        }
        let (pos, k, _) = n.gemm; // pos = h_out · w_out
        let rows = (pos * batch) as f64;
        // exact Cin from the recorded node geometry (the old
        // in_elems/pos fallback overestimated strided convs by
        // stride² — it priced input positions as if they were output
        // positions); the streaming dX panel is rows × Cin
        let cin = n
            .geom
            .map(|g| g.c_in as f64)
            .unwrap_or((n.in_elems / pos) as f64);
        let cand = if fused {
            ConvBackwardTransient {
                dcols_f32_bytes: 0.0,
                dw_cols_f32_bytes: 0.0,
                panel_f32_bytes: rows * cin * 4.0,
                packed_bytes: rows * (k.div_ceil(64) * 8) as f64,
            }
        } else {
            ConvBackwardTransient {
                dcols_f32_bytes: rows * k as f64 * 4.0,
                dw_cols_f32_bytes: 2.0 * rows * k as f64 * 4.0,
                panel_f32_bytes: 0.0,
                packed_bytes: 0.0,
            }
        };
        if cand.total() > best.total() {
            best = cand;
        }
    }
    best
}

/// Peak transient footprint of the **real-input first conv** (f32
/// activations — the one layer the binary panels never cover), per
/// direction.
///
/// Pre-fusion (PR 10) both engines materialized a rows × k²·Cin f32
/// `cols` buffer for the first layer's forward GEMM and again for
/// its ∂W contraction.  The fused path streams the f32 im2col
/// tap-by-tap through one rows × Cin panel (the adjoint of the
/// streaming dX): `cols_f32_bytes` drops to exactly zero in both
/// directions and the panel is all that remains — a kside² cut.
/// `memtrack`-measured counterpart: rust/tests/memtrack_conv.rs.
#[derive(Clone, Copy, Debug, Default)]
pub struct FirstConvTransient {
    /// rows × k f32 im2col cols (0 on the fused path).
    pub cols_f32_bytes: f64,
    /// Streaming per-tap panel (rows × Cin f32; fused path only).
    pub panel_f32_bytes: f64,
}

impl FirstConvTransient {
    pub fn total(&self) -> f64 {
        self.cols_f32_bytes + self.panel_f32_bytes
    }
}

/// Model the first conv's transient im2col memory, pre-fusion
/// (`fused = false`: the rows × k f32 cols buffer) or fused
/// (`fused = true`: one rows × Cin f32 panel).  The same shape
/// appears once in forward and once in the ∂W contraction, so the
/// model prices a single direction.
pub fn first_conv_transient(graph: &Graph, batch: usize, fused: bool) -> FirstConvTransient {
    let mut best = FirstConvTransient::default();
    for n in &graph.nodes {
        if n.kind != LayerKind::Conv || !n.first {
            continue;
        }
        let (pos, k, _) = n.gemm; // pos = h_out · w_out
        let rows = (pos * batch) as f64;
        let cin = n
            .geom
            .map(|g| g.c_in as f64)
            .unwrap_or((n.in_elems / pos) as f64);
        let cand = if fused {
            FirstConvTransient { cols_f32_bytes: 0.0, panel_f32_bytes: rows * cin * 4.0 }
        } else {
            FirstConvTransient { cols_f32_bytes: rows * k as f64 * 4.0, panel_f32_bytes: 0.0 }
        };
        if cand.total() > best.total() {
            best = cand;
        }
    }
    best
}

/// Reduction factor standard/proposed (the paper's Δ columns).
pub fn reduction(graph: &Graph, batch: usize, opt: Optimizer) -> f64 {
    let std = breakdown(graph, batch, &DtypeConfig::standard(), opt);
    let prop = breakdown(graph, batch, &DtypeConfig::proposed(), opt);
    std.total_bytes() / prop.total_bytes()
}

/// Planned steady-state footprint of one training step on the
/// pure-Rust engines (accelerated tiers): persistent engine state
/// plus the step arena's scheduled pool.
///
/// Unlike [`breakdown`] (the paper's coarse Table-2 classes), this is
/// the *engine-exact* envelope: `state_bytes` mirrors the trainers'
/// `state_bytes()` accounting (weights, β, momenta, gradient
/// accumulators, packed-weight cache after one step) and
/// `arena_bytes` is the compiled schedule's slot-table total
/// (`naive::schedule::compile_step(..).arena_bytes()`) — the same
/// slot table the engine's arena installs, so planned == measured
/// **exactly**; CI and `memtrack_step.rs` assert equality with no
/// tolerance band.
#[derive(Clone, Copy, Debug)]
pub struct StepEnvelope {
    pub state_bytes: f64,
    pub arena_bytes: f64,
}

impl StepEnvelope {
    pub fn total_bytes(&self) -> f64 {
        self.state_bytes + self.arena_bytes
    }

    pub fn total_mib(&self) -> f64 {
        self.total_bytes() / MIB
    }
}

/// Price one training step of `algo` ("standard" | "proposed") at
/// logical `batch` executed in `microbatch`-sized chunks (0 = whole
/// batch).  Peak training memory is set by the microbatch: the arena
/// term scales with `microbatch`, the state term is batch-free — the
/// decoupling the microbatch accumulation work exists to provide.
pub fn step_envelope(
    graph: &Graph,
    algo: &str,
    opt: Optimizer,
    batch: usize,
    microbatch: usize,
) -> anyhow::Result<StepEnvelope> {
    let plan = crate::naive::Plan::from_graph(graph)?;
    let micro = if microbatch == 0 { batch } else { microbatch };
    if micro == 0 || batch % micro != 0 {
        anyhow::bail!("microbatch {micro} must divide batch {batch}");
    }
    let chunks = batch / micro;
    let momenta = opt.momenta_per_weight();
    let mut state = 0.0f64;
    // the accelerated-tier schedule (naive = false) — the tiers the
    // envelope has always modeled
    let arena =
        crate::naive::schedule::compile_step(&plan, algo, false, micro, chunks)?.arena_bytes()
            as f64;
    match algo {
        "standard" => {
            for l in plan.layers.iter().filter(|l| l.weight_len() > 0) {
                let (w, ch) = (l.weight_len() as f64, l.channels() as f64);
                let (k, n) = (l.fan_in(), l.channels());
                // W + β + momenta + the retained ∂W/∂β accumulators,
                // all f32
                state += 4.0 * (w + ch) + momenta * 4.0 * (w + ch) + 4.0 * (w + ch);
                // packed-weight cache after one step: first layers
                // pack Ŵ only; the binary layers derive Ŵᵀ too
                let first = matches!(
                    l,
                    crate::naive::LayerPlan::Dense { first: true, .. }
                        | crate::naive::LayerPlan::Conv { first: true, .. }
                );
                state += (k * n.div_ceil(64) * 8) as f64;
                if !first {
                    state += (n * k.div_ceil(64) * 8) as f64;
                    // interleaved B panels cached next to Ŵᵀ on wide
                    // layers (the tuner's panel kernel operand)
                    if crate::bitops::cache::panels_worthwhile(n) {
                        state +=
                            (crate::bitops::BPanels::words_for(n, k.div_ceil(64)) * 8) as f64;
                    }
                }
            }
        }
        "proposed" => {
            for l in plan.layers.iter().filter(|l| l.weight_len() > 0) {
                let (w, ch) = (l.weight_len() as f64, l.channels() as f64);
                let (k, n) = (l.fan_in(), l.channels());
                // f16 W + β + momenta; f32 ∂β accumulator; the f32 ∂W
                // accumulator only exists when chunks > 1
                state += 2.0 * (w + ch) + momenta * 2.0 * (w + ch) + 4.0 * ch;
                if chunks > 1 {
                    state += 4.0 * w;
                }
                // packed Ŵᵀ cache (binary layers only; first layers
                // never pack)
                let first = matches!(
                    l,
                    crate::naive::LayerPlan::Dense { first: true, .. }
                        | crate::naive::LayerPlan::Conv { first: true, .. }
                );
                if !first {
                    state += (n * k.div_ceil(64) * 8) as f64;
                    // interleaved B panels cached next to Ŵᵀ on wide
                    // layers (the tuner's panel kernel operand)
                    if crate::bitops::cache::panels_worthwhile(n) {
                        state +=
                            (crate::bitops::BPanels::words_for(n, k.div_ceil(64)) * 8) as f64;
                    }
                }
            }
        }
        _ => anyhow::bail!("step_envelope: unknown algo '{algo}' (standard|proposed)"),
    }
    Ok(StepEnvelope { state_bytes: state, arena_bytes: arena })
}

/// Modeled steady-state footprint of a `serve::PackedInferEngine`:
/// the immutable packed snapshot plus the warmed forward-only scratch
/// arena.  Both terms are exact — CI and the serve bench diff them
/// against the measured `state_bytes()` / `arena_bytes()`.
#[derive(Clone, Copy, Debug)]
pub struct ServeEnvelope {
    /// Packed Ŵ + Ŵᵀ + f32 β per matmul layer.
    pub snapshot_bytes: usize,
    /// Scratch arena at its post-warmup fixed point (covers every
    /// batch size ≤ `max_batch`).
    pub arena_bytes: usize,
}

impl ServeEnvelope {
    pub fn total_bytes(&self) -> usize {
        self.snapshot_bytes + self.arena_bytes
    }

    pub fn total_mib(&self) -> f64 {
        self.total_bytes() as f64 / MIB
    }
}

/// Price the inference-serving footprint of `algo` at `max_batch`
/// (accelerated tiers — the ones serving runs on).
pub fn serve_envelope(
    graph: &Graph,
    algo: &str,
    max_batch: usize,
) -> anyhow::Result<ServeEnvelope> {
    let plan = crate::naive::Plan::from_graph(graph)?;
    if max_batch == 0 {
        anyhow::bail!("serve_envelope: max_batch must be positive");
    }
    let mut snapshot = 0usize;
    for l in plan.layers.iter().filter(|l| l.weight_len() > 0) {
        let (k, n) = (l.fan_in(), l.channels());
        // packed w (k×n) + packed wt (n×k) + f32 β
        snapshot += k * n.div_ceil(64) * 8 + n * k.div_ceil(64) * 8 + n * 4;
    }
    // the serve schedule's colored slot table == the engine's
    // installed arena, exactly (accelerated tiers)
    let arena = crate::naive::schedule::compile_serve(&plan, algo, false, max_batch)?.arena_bytes();
    Ok(ServeEnvelope { snapshot_bytes: snapshot, arena_bytes: arena })
}

/// One tenant's load declaration for [`fleet_envelope`]: which model,
/// which algorithm, and which of the two schedules (train, serve) it
/// co-hosts on the multi-tenant runtime.
pub struct TenantLoad<'a> {
    pub graph: &'a Graph,
    pub algo: &'a str,
    pub opt: Optimizer,
    /// `(batch, microbatch)` when the tenant trains (microbatch 0 =
    /// whole batch).
    pub train: Option<(usize, usize)>,
    /// `max_batch` when the tenant serves.
    pub serve: Option<usize>,
}

/// Planned steady-state footprint of one tenant: its train and/or
/// serve envelope plus the runtime's per-tenant staging buffers.
#[derive(Clone, Copy, Debug)]
pub struct TenantEnvelope {
    pub train: Option<StepEnvelope>,
    pub serve: Option<ServeEnvelope>,
    /// The multi-tenant lane's gather/scatter staging for this
    /// tenant: `max_batch × (input_elems + classes)` f32 (serving
    /// tenants only — training batches arrive pre-staged).
    pub staging_bytes: usize,
}

impl TenantEnvelope {
    pub fn total_bytes(&self) -> f64 {
        self.train.map(|e| e.total_bytes()).unwrap_or(0.0)
            + self.serve.map(|e| e.total_bytes()).unwrap_or(0) as f64
            + self.staging_bytes as f64
    }
}

/// The whole fleet's planned envelope: the **exact sum** of the
/// per-tenant schedule folds.  Same `assert_eq!` discipline as the
/// single-tenant envelopes — the multi-tenant runtime adds no hidden
/// per-tenant overhead, so planned == measured with no tolerance
/// band (rust/tests/multi_tenant.rs and `BENCH_multi.json` pin it).
#[derive(Clone, Debug)]
pub struct FleetEnvelope {
    pub tenants: Vec<TenantEnvelope>,
}

impl FleetEnvelope {
    pub fn total_bytes(&self) -> f64 {
        self.tenants.iter().map(|t| t.total_bytes()).sum()
    }

    pub fn total_mib(&self) -> f64 {
        self.total_bytes() / MIB
    }
}

/// Price a multi-tenant fleet (accelerated tiers).  A pure fold over
/// each tenant's compiled schedules; nothing is shared between
/// tenants except the process-global worker pool (which owns no
/// per-tenant memory), so the fleet envelope is exactly the sum of
/// its parts.
pub fn fleet_envelope(loads: &[TenantLoad]) -> anyhow::Result<FleetEnvelope> {
    let mut tenants = Vec::with_capacity(loads.len());
    for l in loads {
        let train = match l.train {
            Some((b, m)) => Some(step_envelope(l.graph, l.algo, l.opt, b, m)?),
            None => None,
        };
        let serve = match l.serve {
            Some(mb) => Some(serve_envelope(l.graph, l.algo, mb)?),
            None => None,
        };
        let staging = l
            .serve
            .map(|mb| mb * (l.graph.input_elems + l.graph.classes) * 4)
            .unwrap_or(0);
        tenants.push(TenantEnvelope { train, serve, staging_bytes: staging });
    }
    Ok(FleetEnvelope { tenants })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    #[test]
    fn serve_envelope_matches_measured_engine() {
        use crate::naive::{build_engine, Accel, Plan, StepEngine};
        use crate::serve::{InferAlgo, PackedInferEngine, WeightSnapshot};
        use std::sync::Arc;
        for (m, algo, ia) in [
            ("cnv_mini", "standard", InferAlgo::Standard),
            ("mlp_mini", "proposed", InferAlgo::Proposed),
        ] {
            let graph = lower(&get(m).unwrap()).unwrap();
            let plan = Plan::from_graph(&graph).unwrap();
            let tr = build_engine(algo, &graph, 2, "adam", Accel::Blocked, 5).unwrap();
            let snap = Arc::new(WeightSnapshot::pack(&plan, &tr.weights_snapshot(), 0).unwrap());
            let env = serve_envelope(&graph, algo, 4).unwrap();
            assert_eq!(env.snapshot_bytes, snap.heap_bytes(), "{m} snapshot model drifted");
            let mut eng =
                PackedInferEngine::new(&graph, ia, Accel::Blocked, 4, snap).unwrap();
            eng.warmup().unwrap();
            assert_eq!(env.arena_bytes, eng.arena_bytes(), "{m} arena model drifted");
            assert!(env.total_bytes() > 0 && env.total_mib() > 0.0);
            // serving is far lighter than training the same model
            let step = step_envelope(&graph, algo, Optimizer::Adam, 4, 0).unwrap();
            assert!((env.total_bytes() as f64) < step.total_bytes(), "{m}");
        }
    }

    #[test]
    fn fleet_envelope_is_sum_of_parts() {
        let mlp = lower(&get("mlp_mini").unwrap()).unwrap();
        let cnv = lower(&get("cnv_mini").unwrap()).unwrap();
        let loads = [
            TenantLoad {
                graph: &mlp,
                algo: "proposed",
                opt: Optimizer::Adam,
                train: Some((16, 0)),
                serve: Some(8),
            },
            TenantLoad {
                graph: &cnv,
                algo: "standard",
                opt: Optimizer::Adam,
                train: None,
                serve: Some(4),
            },
        ];
        let fleet = fleet_envelope(&loads).unwrap();
        assert_eq!(fleet.tenants.len(), 2);
        let t0 = &fleet.tenants[0];
        let step = step_envelope(&mlp, "proposed", Optimizer::Adam, 16, 0).unwrap();
        let serve = serve_envelope(&mlp, "proposed", 8).unwrap();
        assert_eq!(t0.train.unwrap().total_bytes(), step.total_bytes());
        assert_eq!(t0.serve.unwrap().total_bytes(), serve.total_bytes());
        assert_eq!(t0.staging_bytes, 8 * (mlp.input_elems + mlp.classes) * 4);
        let t1 = &fleet.tenants[1];
        assert!(t1.train.is_none());
        assert_eq!(t1.staging_bytes, 4 * (cnv.input_elems + cnv.classes) * 4);
        let total: f64 = fleet.tenants.iter().map(|t| t.total_bytes()).sum();
        assert_eq!(fleet.total_bytes(), total);
        assert!(fleet.total_mib() > 0.0);
    }

    fn binarynet_b100(cfg: &DtypeConfig) -> Breakdown {
        let g = lower(&get("binarynet").unwrap()).unwrap();
        breakdown(&g, 100, cfg, Optimizer::Adam)
    }

    #[test]
    fn table2_standard_rows() {
        // Paper Table 2, left half (float32, Adam, B=100)
        let b = binarynet_b100(&DtypeConfig::standard());
        let mib = |n: &str| b.row(n).unwrap().bytes / MIB;
        assert!((mib("X") - 111.33).abs() < 0.2, "{}", mib("X"));
        assert!((mib("dX/Y") - 50.0).abs() < 0.05);
        assert!((mib("dY") - 50.0).abs() < 0.05);
        assert!((mib("W") - 53.49).abs() < 0.05);
        assert!((mib("dW") - 53.49).abs() < 0.05);
        assert!((mib("momenta") - 106.98).abs() < 0.1);
        assert!((mib("pool masks") - 87.46).abs() < 0.1);
        assert!((b.total_mib() - 512.81).abs() < 1.0, "{}", b.total_mib());
    }

    #[test]
    fn table2_proposed_rows() {
        // Paper Table 2, right half
        let b = binarynet_b100(&DtypeConfig::proposed());
        let mib = |n: &str| b.row(n).unwrap().bytes / MIB;
        assert!((mib("X") - 3.48).abs() < 0.02, "{}", mib("X"));
        assert!((mib("dX/Y") - 25.0).abs() < 0.05);
        assert!((mib("W") - 26.74).abs() < 0.05);
        assert!((mib("dW") - 1.67).abs() < 0.02);
        assert!((mib("momenta") - 53.49).abs() < 0.1);
        assert!((mib("pool masks") - 2.73).abs() < 0.02);
        assert!((b.total_mib() - 138.15).abs() < 0.5, "{}", b.total_mib());
    }

    #[test]
    fn table2_reduction_factor() {
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let r = reduction(&g, 100, Optimizer::Adam);
        assert!((r - 3.71).abs() < 0.02, "{r}");
    }

    #[test]
    fn table4_memory_columns() {
        // (model, std MiB, prop MiB, factor)
        let cases = [
            ("mlp", 7.40, 2.65, 2.78),
            ("cnv", 134.05, 32.16, 4.17),
            ("binarynet", 512.81, 138.15, 3.71),
        ];
        // Tolerance note (EXPERIMENTS.md): BinaryNet matches Table 2
        // row-exactly; for MLP/CNV the paper's tool counts a small
        // extra per-layer buffer (~5%) we do not model — bands below.
        for (m, std_mib, prop_mib, fac) in cases {
            let g = lower(&get(m).unwrap()).unwrap();
            let s = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam);
            let p = breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Adam);
            assert!(
                (s.total_mib() - std_mib).abs() / std_mib < 0.08,
                "{m} std {} want {std_mib}",
                s.total_mib()
            );
            assert!(
                (p.total_mib() - prop_mib).abs() / prop_mib < 0.10,
                "{m} prop {} want {prop_mib}",
                p.total_mib()
            );
            let r = s.total_mib() / p.total_mib();
            assert!((r - fac).abs() < 0.4, "{m} factor {r} want {fac}");
        }
    }

    #[test]
    fn table5_optimizer_totals() {
        // standard-training totals per optimizer (Table 5 col 'MiB')
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let std = DtypeConfig::standard();
        let adam = breakdown(&g, 100, &std, Optimizer::Adam).total_mib();
        let sgd = breakdown(&g, 100, &std, Optimizer::Sgd).total_mib();
        let bop = breakdown(&g, 100, &std, Optimizer::Bop).total_mib();
        assert!((adam - 512.81).abs() < 1.0, "{adam}");
        assert!((sgd - 459.32).abs() < 1.0, "{sgd}");
        assert!((bop - 405.83).abs() < 1.0, "{bop}");
    }

    #[test]
    fn f16_halves_everything() {
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let s = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam);
        let h = breakdown(
            &g,
            100,
            &DtypeConfig::ablation("f16").unwrap(),
            Optimizer::Adam,
        );
        let r = s.total_bytes() / h.total_bytes();
        assert!((r - 2.0).abs() < 1e-6, "{r}");
    }

    #[test]
    fn batch_scaling_transients_grow_weights_dont() {
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let cfg = DtypeConfig::standard();
        let b1 = breakdown(&g, 100, &cfg, Optimizer::Adam);
        let b2 = breakdown(&g, 200, &cfg, Optimizer::Adam);
        assert_eq!(b1.row("W").unwrap().bytes, b2.row("W").unwrap().bytes);
        assert!((b2.row("X").unwrap().bytes / b1.row("X").unwrap().bytes - 2.0).abs() < 1e-9);
    }

    #[test]
    fn batch_headroom_about_10x() {
        // Fig. 2 claim: proposed at ~10x batch fits in standard's
        // envelope (evaluated at B=50, Fig. 2's operating region;
        // headroom shrinks as fixed W/momenta amortize at large B).
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let std50 =
            breakdown(&g, 50, &DtypeConfig::standard(), Optimizer::Adam).total_bytes();
        let mut b = 50;
        while breakdown(&g, b + 10, &DtypeConfig::proposed(), Optimizer::Adam)
            .total_bytes()
            <= std50
        {
            b += 10;
        }
        let headroom = b as f64 / 50.0;
        assert!((8.0..14.0).contains(&headroom), "headroom {headroom}");
    }

    #[test]
    fn table6_resnete_reduction() {
        // Table 6: proposed vs none = 3.78x at B=4096 (modeled; the
        // paper's TPU totals differ in absolute GiB because of the
        // non-binary stem dominating — we assert the factor banding)
        let g = lower(&get("resnete18").unwrap()).unwrap();
        let s = breakdown(&g, 4096, &DtypeConfig::standard(), Optimizer::Adam);
        let p = breakdown(&g, 4096, &DtypeConfig::proposed(), Optimizer::Adam);
        let r = s.total_bytes() / p.total_bytes();
        assert!((2.5..6.0).contains(&r), "reduction {r}");
        // tens of GiB at this scale, as in the paper
        assert!(s.total_bytes() / crate::util::GIB > 20.0);
    }

    #[test]
    fn bop_proposed_packs_weights() {
        // Table 5: Bop + proposed = 82.45 MiB (binary weights stored
        // packed); our decomposition lands in the same band
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let b = breakdown(&g, 100, &DtypeConfig::proposed(), Optimizer::Bop);
        let w = b.row("W").unwrap();
        assert_eq!(w.dtype, Dtype::Bool);
        assert!((b.total_mib() - 82.45).abs() < 3.0, "{}", b.total_mib());
        // standard stays f32-containered (405.83)
        let s = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Bop);
        assert_eq!(s.row("W").unwrap().dtype, Dtype::F32);
    }

    #[test]
    fn fused_im2col_drops_modeled_conv_transient_33x() {
        // BinaryNet's binary convs have K ∈ {1152, 2304, 4608}, all
        // word-aligned, so pre-fusion (f32 cols + packed panel) vs
        // fused (panel only) is exactly (32x + x) / x = 33
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let pre = conv_cols_transient(&g, 100, false);
        let post = conv_cols_transient(&g, 100, true);
        assert_eq!(post.f32_bytes, 0.0);
        assert!(pre.f32_bytes > 0.0);
        // peak layer: conv2, 32*32 positions x K=1152 at B=100
        let rows = 100.0 * 1024.0;
        assert_eq!(pre.f32_bytes, rows * 1152.0 * 4.0);
        assert_eq!(post.packed_bytes, rows * (1152.0 / 8.0));
        let ratio = pre.total() / post.total();
        assert!((ratio - 33.0).abs() < 1e-9, "{ratio}");
        // the eliminated buffer is the dominant conv transient: bigger
        // than the modeled dX/Y row of the proposed config
        let bd = binarynet_b100(&DtypeConfig::proposed());
        assert!(pre.f32_bytes > bd.row("dX/Y").unwrap().bytes);
    }

    #[test]
    fn fused_backward_drops_modeled_conv_step_transient() {
        // the conv backward was the step-peak holder after PR 2 (the
        // forward was already fused): pre-fusion it held dcols + cols
        // + colsᵀ = 3 rows×k f32 buffers at peak, the fused path one
        // rows×Cin panel + a 1-bit packed panel.  On BinaryNet conv
        // shapes the modeled drop is ≥3× (the acceptance bar; actual
        // factor is far larger), which — with the forward transient
        // already 33× down — finally moves the *step-level* peak.
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let pre = conv_backward_transient(&g, 100, false);
        let post = conv_backward_transient(&g, 100, true);
        assert_eq!(post.dcols_f32_bytes, 0.0);
        assert_eq!(post.dw_cols_f32_bytes, 0.0);
        assert!(pre.dcols_f32_bytes > 0.0);
        // peak layer: conv2, 32·32 positions × K=1152 at B=100
        let rows = 100.0 * 1024.0;
        assert_eq!(pre.dcols_f32_bytes, rows * 1152.0 * 4.0);
        assert_eq!(pre.dw_cols_f32_bytes, 2.0 * rows * 1152.0 * 4.0);
        assert_eq!(post.panel_f32_bytes, rows * 128.0 * 4.0);
        assert_eq!(post.packed_bytes, rows * (1152.0 / 8.0));
        let ratio = pre.total() / post.total();
        assert!(ratio >= 3.0, "modeled backward drop only {ratio:.2}x");
        // the backward was the bigger of the two phases pre-fusion:
        // dropping it moves the step peak, not just a phase peak
        let fwd_pre = conv_cols_transient(&g, 100, false);
        assert!(pre.total() > fwd_pre.total());
        let step_pre = pre.total().max(conv_cols_transient(&g, 100, true).total());
        let step_post = post.total().max(conv_cols_transient(&g, 100, true).total());
        assert!(step_pre / step_post >= 3.0, "{}", step_pre / step_post);
    }

    #[test]
    fn fused_backward_transient_zero_f32_for_every_model() {
        use crate::models::names;
        for m in names() {
            let g = lower(&get(m).unwrap()).unwrap();
            let t = conv_backward_transient(&g, 64, true);
            assert_eq!(t.dcols_f32_bytes, 0.0, "{m}");
            assert_eq!(t.dw_cols_f32_bytes, 0.0, "{m}");
            if m.starts_with("mlp") {
                assert_eq!(t.total(), 0.0, "{m}");
            }
        }
    }

    #[test]
    fn fused_conv_transient_zero_f32_for_every_model() {
        use crate::models::names;
        for m in names() {
            let g = lower(&get(m).unwrap()).unwrap();
            let t = conv_cols_transient(&g, 64, true);
            assert_eq!(t.f32_bytes, 0.0, "{m}");
            // models without binary convs (mlp) model zero transient
            if m.starts_with("mlp") {
                assert_eq!(t.total(), 0.0, "{m}");
            }
        }
    }

    #[test]
    fn strided_conv_transients_use_output_geometry() {
        // resnete18's stage-entry convs are strided: rows must be
        // h_out·w_out·batch and the dX panel must price the exact Cin
        // (not in_elems/out_positions, which is stride²·Cin)
        let g = lower(&get("resnete18").unwrap()).unwrap();
        let entry = g
            .nodes
            .iter()
            .find(|n| {
                n.kind == LayerKind::Conv && n.geom.map(|gg| gg.stride) == Some(2) && !n.first
            })
            .unwrap();
        let gg = entry.geom.unwrap();
        assert_eq!(gg.h, 2 * gg.oh);
        assert_eq!(entry.gemm.0, gg.oh * gg.ow);
        assert_ne!(entry.in_elems / entry.gemm.0, gg.c_in); // the old bug
        // peak layers across the model price consistently: rows·Cin·4
        // for the panel, rows·⌈k/64⌉·8 for the packed panel — and the
        // peak candidate must dominate a per-node recomputation
        let t = conv_backward_transient(&g, 16, true);
        assert_eq!(t.dcols_f32_bytes, 0.0);
        let mut max_total = 0.0f64;
        for n in &g.nodes {
            if n.kind != LayerKind::Conv || n.first {
                continue;
            }
            let (pos, k, _) = n.gemm;
            let rows = (pos * 16) as f64;
            let cin = n.geom.unwrap().c_in as f64;
            max_total = max_total.max(rows * cin * 4.0 + rows * (k.div_ceil(64) * 8) as f64);
        }
        assert_eq!(t.total(), max_total);
    }

    #[test]
    fn bop_drops_dw_row() {
        let g = lower(&get("binarynet").unwrap()).unwrap();
        let b = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Bop);
        assert!(b.row("dW").is_none());
    }

    #[test]
    fn step_envelope_matches_measured_steady_state() {
        // the compiled schedule vs the real engines: the arena term
        // is the very slot table the engine installs and the state
        // formula mirrors `state_bytes()` item by item, so planned ==
        // measured with **no tolerance band** (the pre-schedule 10%
        // drift gate is retired).
        use crate::naive::{build_engine_micro, Accel, StepEngine};
        use crate::util::rng::Pcg32;
        for (model, batch, micro) in
            [("cnv_mini", 8usize, 0usize), ("binarynet_mini", 8, 4), ("bireal_mini", 4, 0)]
        {
            let g = lower(&get(model).unwrap()).unwrap();
            for algo in ["standard", "proposed"] {
                let mut e =
                    build_engine_micro(algo, &g, batch, micro, "adam", Accel::Blocked, 1)
                        .unwrap();
                let mut rng = Pcg32::new(9);
                let x = rng.normal_vec(batch * g.input_elems);
                let y: Vec<usize> = (0..batch).map(|i| i % g.classes).collect();
                e.train_step(&x, &y, 0.01).unwrap();
                e.train_step(&x, &y, 0.01).unwrap();
                let env = step_envelope(&g, algo, Optimizer::Adam, batch, micro).unwrap();
                assert_eq!(
                    env.arena_bytes as usize,
                    e.arena_bytes(),
                    "{model}/{algo} micro={micro}: arena model drifted"
                );
                assert_eq!(
                    env.state_bytes as usize,
                    e.state_bytes(),
                    "{model}/{algo} micro={micro}: state model drifted"
                );
            }
        }
    }

    #[test]
    fn step_envelope_decouples_from_logical_batch() {
        // the acceptance claim, modeled: binarynet_mini at B=64 with
        // microbatch 16 prices ≥2× below the full-batch step, because
        // the arena term scales with the microbatch while state does
        // not
        let g = lower(&get("binarynet_mini").unwrap()).unwrap();
        for algo in ["standard", "proposed"] {
            let full = step_envelope(&g, algo, Optimizer::Adam, 64, 0).unwrap();
            let quarter = step_envelope(&g, algo, Optimizer::Adam, 64, 16).unwrap();
            assert!(
                full.total_bytes() / quarter.total_bytes() >= 2.0,
                "{algo}: full {:.0} vs micro {:.0}",
                full.total_bytes(),
                quarter.total_bytes()
            );
            // arena scales ~4x with the 4x microbatch reduction
            assert!(
                full.arena_bytes / quarter.arena_bytes > 2.5,
                "{algo}: arena {:.0} vs {:.0}",
                full.arena_bytes,
                quarter.arena_bytes
            );
            // state is batch-free (up to the accumulating proposed
            // engine's f32 dW carrier)
            assert!(quarter.state_bytes >= full.state_bytes);
        }
        // and chunking leaves the envelope at the microbatch scale:
        // B=64/micro=16 arena ≈ B=16 full-batch arena
        let b16 = step_envelope(&g, "standard", Optimizer::Adam, 16, 0).unwrap();
        let b64m16 = step_envelope(&g, "standard", Optimizer::Adam, 64, 16).unwrap();
        let r = b64m16.arena_bytes / b16.arena_bytes;
        assert!((0.9..1.5).contains(&r), "{r}");
    }

    #[test]
    fn step_envelope_rejects_bad_microbatch() {
        let g = lower(&get("mlp_mini").unwrap()).unwrap();
        assert!(step_envelope(&g, "standard", Optimizer::Adam, 64, 48).is_err());
        assert!(step_envelope(&g, "nope", Optimizer::Adam, 64, 0).is_err());
    }
}
