//! Optimizers + learning-rate schedules for the naive engines.
//!
//! Mirrors `python/compile/train_step.py`: Adam (Kingma & Ba), SGD
//! with momentum 0.9, and Bop (Helwegen et al.) — plus the paper's
//! learning-rate schedules: development-based decay (Wilson et al.,
//! used for the small-scale experiments), fixed step decay (Bethge et
//! al., ImageNet/ResNetE), and cosine decay (Bi-Real-18).
//!
//! State is stored via a [`Store`] so the proposed engine can keep
//! momenta in *actual* f16 (half the measured bytes) while the
//! standard engine keeps f32 — Table 2's "Momenta" row, realized.

use crate::util::f16::F16Vec;

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const SGD_MOMENTUM: f32 = 0.9;
pub const BOP_TAU: f32 = 1e-8;

/// f32-or-f16 storage for optimizer state / latent weights.
#[derive(Clone, Debug)]
pub enum Store {
    F32(Vec<f32>),
    F16(F16Vec),
}

impl Store {
    pub fn zeros(n: usize, half: bool) -> Store {
        if half {
            Store::F16(F16Vec::zeros(n))
        } else {
            Store::F32(vec![0.0; n])
        }
    }

    pub fn from_f32(xs: Vec<f32>, half: bool) -> Store {
        if half {
            Store::F16(F16Vec::from_f32(&xs))
        } else {
            Store::F32(xs)
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Store::F32(v) => v.len(),
            Store::F16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self {
            Store::F32(v) => v[i],
            Store::F16(v) => v.get(i),
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, x: f32) {
        match self {
            Store::F32(v) => v[i] = x,
            Store::F16(v) => v.set(i, x),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            Store::F32(v) => v.clone(),
            Store::F16(v) => v.to_f32(),
        }
    }

    /// Borrow the f32 payload without copying (None for f16 storage).
    /// The standard engine's allocation-free step path reads weights
    /// and β through this.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Store::F32(v) => Some(v),
            Store::F16(_) => None,
        }
    }

    /// Decode into a caller-owned buffer (no allocation): `out.len()`
    /// must equal `self.len()`.
    pub fn write_f32_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.len());
        match self {
            Store::F32(v) => out.copy_from_slice(v),
            Store::F16(v) => v.write_f32_into(out),
        }
    }

    pub fn heap_bytes(&self) -> usize {
        match self {
            Store::F32(v) => v.len() * 4,
            Store::F16(v) => v.len() * 2,
        }
    }
}

/// Per-parameter-group optimizer state.
#[derive(Clone, Debug)]
pub enum OptState {
    Adam { t: f32, m: Store, v: Store },
    Sgd { vel: Store },
    /// Bop: gradient EMA; the parameter itself stays binary.
    Bop { ema: Store },
}

impl OptState {
    pub fn new(kind: &str, n: usize, half: bool) -> OptState {
        match kind {
            "adam" => OptState::Adam {
                t: 0.0,
                m: Store::zeros(n, half),
                v: Store::zeros(n, half),
            },
            "sgd" => OptState::Sgd { vel: Store::zeros(n, half) },
            "bop" => OptState::Bop { ema: Store::zeros(n, half) },
            _ => panic!("unknown optimizer '{kind}'"),
        }
    }

    pub fn heap_bytes(&self) -> usize {
        match self {
            OptState::Adam { m, v, .. } => m.heap_bytes() + v.heap_bytes(),
            OptState::Sgd { vel } => vel.heap_bytes(),
            OptState::Bop { ema } => ema.heap_bytes(),
        }
    }

    /// Advance the step counter (Adam bias correction); call once per
    /// training step before updating groups.
    pub fn tick(&mut self) {
        if let OptState::Adam { t, .. } = self {
            *t += 1.0;
        }
    }

    /// Apply one update to a parameter group.
    ///
    /// * `param` — latent weights (clipped to [-1,1] when `clip`);
    /// * `grad`  — gradient (already attenuated per Alg. 2 line 18 if
    ///   binarized upstream);
    /// * Bop ignores `lr` as a step size and uses it as the EMA
    ///   adaptivity rate γ, flipping signs where `w·ema > τ`.
    pub fn update(&mut self, param: &mut Store, grad: &[f32], lr: f32, clip: bool) {
        assert_eq!(param.len(), grad.len());
        self.update_fn(param, |i| grad[i], lr, clip)
    }

    /// Closure-based update: lets the proposed engine feed bit-packed
    /// binary gradients (Alg. 2's bool ∂Ŵ) without materializing an
    /// f32 gradient buffer.
    pub fn update_fn<G: Fn(usize) -> f32>(
        &mut self,
        param: &mut Store,
        grad: G,
        lr: f32,
        clip: bool,
    ) {
        let grad = |i: usize| grad(i);
        match self {
            OptState::Adam { t, m, v } => {
                debug_assert!(*t >= 1.0, "tick() before update()");
                let bc1 = 1.0 - ADAM_B1.powf(*t);
                let bc2 = 1.0 - ADAM_B2.powf(*t);
                for i in 0..param.len() {
                    let g = grad(i);
                    let mi = ADAM_B1 * m.get(i) + (1.0 - ADAM_B1) * g;
                    let vi = ADAM_B2 * v.get(i) + (1.0 - ADAM_B2) * g * g;
                    m.set(i, mi);
                    v.set(i, vi);
                    let mhat = mi / bc1;
                    let vhat = vi / bc2;
                    let mut p = param.get(i) - lr * mhat / (vhat.sqrt() + ADAM_EPS);
                    if clip {
                        p = p.clamp(-1.0, 1.0);
                    }
                    param.set(i, p);
                }
            }
            OptState::Sgd { vel } => {
                for i in 0..param.len() {
                    let vi = SGD_MOMENTUM * vel.get(i) + grad(i);
                    vel.set(i, vi);
                    let mut p = param.get(i) - lr * vi;
                    if clip {
                        p = p.clamp(-1.0, 1.0);
                    }
                    param.set(i, p);
                }
            }
            OptState::Bop { ema } => {
                let gamma = lr;
                for i in 0..param.len() {
                    let e = (1.0 - gamma) * ema.get(i) + gamma * grad(i);
                    ema.set(i, e);
                    let w = param.get(i);
                    if w * e > BOP_TAU {
                        param.set(i, -w);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------- schedules

/// Learning-rate schedule (paper Sec. 6.1).
#[derive(Clone, Debug)]
pub enum LrSchedule {
    /// Constant.
    Constant { lr: f32 },
    /// Development-based (Wilson et al.): halve when validation
    /// accuracy fails to improve for `patience` evaluations.
    DevBased { lr: f32, patience: usize, factor: f32, best: f32, stale: usize },
    /// Fixed decay: multiply by `factor` at each epoch in `at`.
    StepDecay { lr0: f32, factor: f32, at: Vec<usize> },
    /// Cosine from lr0 to ~0 over `total` epochs (Bi-Real-18).
    Cosine { lr0: f32, total: usize },
}

impl LrSchedule {
    pub fn dev_based(lr: f32) -> LrSchedule {
        LrSchedule::DevBased { lr, patience: 10, factor: 0.5, best: f32::NEG_INFINITY, stale: 0 }
    }

    /// ResNetE-18 schedule: ×0.1 at epochs 70/90/110 (scaled by the
    /// caller for shorter runs).
    pub fn resnete(lr0: f32, at: Vec<usize>) -> LrSchedule {
        LrSchedule::StepDecay { lr0, factor: 0.1, at }
    }

    /// Current lr for `epoch`.
    pub fn lr(&self, epoch: usize) -> f32 {
        match self {
            LrSchedule::Constant { lr } => *lr,
            LrSchedule::DevBased { lr, .. } => *lr,
            LrSchedule::StepDecay { lr0, factor, at } => {
                let hits = at.iter().filter(|&&e| epoch >= e).count() as i32;
                lr0 * factor.powi(hits)
            }
            LrSchedule::Cosine { lr0, total } => {
                let frac = (epoch as f32 / (*total).max(1) as f32).min(1.0);
                0.5 * lr0 * (1.0 + (std::f32::consts::PI * frac).cos())
            }
        }
    }

    /// Feed a validation metric (dev-based decay only).
    pub fn observe(&mut self, val_acc: f32) {
        if let LrSchedule::DevBased { lr, patience, factor, best, stale } = self {
            if val_acc > *best + 1e-4 {
                *best = val_acc;
                *stale = 0;
            } else {
                *stale += 1;
                if *stale >= *patience {
                    *lr *= *factor;
                    *stale = 0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_min(kind: &str, lr: f32, steps: usize) -> f32 {
        // minimize f(w) = (w - 0.3)^2 elementwise
        let mut p = Store::from_f32(vec![-0.9, 0.8, 0.0], false);
        let mut st = OptState::new(kind, 3, false);
        for _ in 0..steps {
            let g: Vec<f32> = (0..3).map(|i| 2.0 * (p.get(i) - 0.3)).collect();
            st.tick();
            st.update(&mut p, &g, lr, false);
        }
        (0..3).map(|i| (p.get(i) - 0.3).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn adam_converges_quadratic() {
        assert!(quad_min("adam", 0.05, 500) < 0.02);
    }

    #[test]
    fn sgd_converges_quadratic() {
        assert!(quad_min("sgd", 0.02, 500) < 0.02);
    }

    #[test]
    fn bop_flips_aligned_weights() {
        // gradient persistently aligned with weight sign -> flip
        let mut p = Store::from_f32(vec![1.0, -1.0], false);
        let mut st = OptState::new("bop", 2, false);
        for _ in 0..50 {
            // positive grad on w0 (aligned with +1), negative on w1
            st.update(&mut p, &[0.5, -0.5], 0.01, false);
        }
        assert_eq!(p.get(0), -1.0, "aligned weight must flip");
        assert_eq!(p.get(1), 1.0);
        // opposing gradient: no flip back and forth each step
        let mut flips = 0;
        let mut last = p.get(0);
        for _ in 0..50 {
            st.update(&mut p, &[0.0, 0.0], 0.01, false);
            if p.get(0) != last {
                flips += 1;
                last = p.get(0);
            }
        }
        assert!(flips <= 1, "zero grad should not oscillate");
    }

    #[test]
    fn clipping_bounds_latent_weights() {
        let mut p = Store::from_f32(vec![0.99], false);
        let mut st = OptState::new("sgd", 1, false);
        for _ in 0..100 {
            st.update(&mut p, &[-5.0], 0.1, true);
        }
        assert!(p.get(0) <= 1.0);
    }

    #[test]
    fn f16_state_halves_bytes() {
        let a = OptState::new("adam", 1000, false);
        let b = OptState::new("adam", 1000, true);
        assert_eq!(a.heap_bytes(), 8000);
        assert_eq!(b.heap_bytes(), 4000);
    }

    #[test]
    fn adam_matches_reference_first_step() {
        // one Adam step with g=1: p -= lr * 1 / (1 + eps) ~ lr
        let mut p = Store::from_f32(vec![0.0], false);
        let mut st = OptState::new("adam", 1, false);
        st.tick();
        st.update(&mut p, &[1.0], 0.001, false);
        assert!((p.get(0) + 0.001).abs() < 1e-6, "{}", p.get(0));
    }

    #[test]
    fn step_decay_schedule() {
        let s = LrSchedule::resnete(0.016, vec![70, 90, 110]);
        assert_eq!(s.lr(0), 0.016);
        assert!((s.lr(70) - 0.0016).abs() < 1e-6);
        assert!((s.lr(95) - 0.00016).abs() < 1e-7);
        assert!((s.lr(119) - 0.000016).abs() < 1e-8);
    }

    #[test]
    fn cosine_schedule_endpoints() {
        let s = LrSchedule::Cosine { lr0: 0.001, total: 80 };
        assert!((s.lr(0) - 0.001).abs() < 1e-9);
        assert!(s.lr(40) < 0.00062);
        assert!(s.lr(80) < 1e-6);
    }

    #[test]
    fn dev_based_decays_on_plateau() {
        let mut s = LrSchedule::dev_based(0.1);
        s.observe(0.5);
        for _ in 0..10 {
            s.observe(0.5); // no improvement
        }
        assert!((s.lr(0) - 0.05).abs() < 1e-6);
        s.observe(0.9); // improvement resets staleness
        for _ in 0..9 {
            s.observe(0.5);
        }
        assert!((s.lr(0) - 0.05).abs() < 1e-6, "not yet");
    }
}
