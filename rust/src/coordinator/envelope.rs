//! Memory envelope: the edge device's budget, enforced up front.
//!
//! The paper's point is that training must *fit* (Raspberry Pi 3B+:
//! 1 GiB, minus OS).  The coordinator refuses runs whose modeled
//! footprint exceeds the envelope and can auto-tune the largest batch
//! that fits — the mechanism behind Fig. 2's "~10× batch at
//! iso-memory" observation.

use anyhow::{anyhow, Result};

use crate::memmodel::{breakdown, DtypeConfig, Optimizer};
use crate::models::Graph;
use crate::util::MIB;

#[derive(Clone, Copy, Debug)]
pub struct MemoryEnvelope {
    pub bytes: f64,
}

impl MemoryEnvelope {
    pub fn mib(mib: f64) -> MemoryEnvelope {
        MemoryEnvelope { bytes: mib * MIB }
    }

    /// Raspberry Pi 3B+: 1 GiB minus ~20% OS overhead (the paper
    /// notes the OS prevents using all of it).
    pub fn raspberry_pi() -> MemoryEnvelope {
        MemoryEnvelope::mib(819.0)
    }

    pub fn admits(&self, modeled_bytes: f64) -> bool {
        modeled_bytes <= self.bytes
    }
}

/// Check a configuration against the envelope; error explains by how
/// much it misses.
pub fn check(
    graph: &Graph,
    batch: usize,
    algo: &str,
    opt: Optimizer,
    env: &MemoryEnvelope,
) -> Result<f64> {
    let cfg = DtypeConfig::ablation(algo)
        .ok_or_else(|| anyhow!("unknown algo '{algo}'"))?;
    let total = breakdown(graph, batch, &cfg, opt).total_bytes();
    if !env.admits(total) {
        return Err(anyhow!(
            "modeled footprint {:.1} MiB exceeds envelope {:.1} MiB \
             (model {}, algo {algo}, B={batch}) — reduce batch or use \
             the proposed scheme",
            total / MIB,
            env.bytes / MIB,
            graph.name
        ));
    }
    Ok(total)
}

/// Largest batch (binary search over [1, 1<<20]) whose modeled
/// footprint fits the envelope; `None` if even B=1 misses.
pub fn fit_batch(
    graph: &Graph,
    algo: &str,
    opt: Optimizer,
    env: &MemoryEnvelope,
) -> Result<Option<usize>> {
    let cfg = DtypeConfig::ablation(algo)
        .ok_or_else(|| anyhow!("unknown algo '{algo}'"))?;
    let fits = |b: usize| env.admits(breakdown(graph, b, &cfg, opt).total_bytes());
    if !fits(1) {
        return Ok(None);
    }
    let (mut lo, mut hi) = (1usize, 1usize << 20);
    if fits(hi) {
        return Ok(Some(hi));
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Ok(Some(lo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    fn graph() -> Graph {
        lower(&get("binarynet").unwrap()).unwrap()
    }

    #[test]
    fn standard_binarynet_misses_pi_at_b100_scaled() {
        // standard @ B=100 is 512.81 MiB -> fits 819; @ B=200 misses
        let g = graph();
        let env = MemoryEnvelope::raspberry_pi();
        assert!(check(&g, 100, "standard", Optimizer::Adam, &env).is_ok());
        assert!(check(&g, 300, "standard", Optimizer::Adam, &env).is_err());
        // proposed fits at 300 easily
        assert!(check(&g, 300, "proposed", Optimizer::Adam, &env).is_ok());
    }

    #[test]
    fn fit_batch_monotone_and_tight() {
        let g = graph();
        // envelope = our own modeled standard footprint at B=100
        let at100 = breakdown(&g, 100, &DtypeConfig::standard(), Optimizer::Adam)
            .total_bytes();
        let env = MemoryEnvelope { bytes: at100 };
        let std = fit_batch(&g, "standard", Optimizer::Adam, &env)
            .unwrap()
            .unwrap();
        let prop = fit_batch(&g, "proposed", Optimizer::Adam, &env)
            .unwrap()
            .unwrap();
        assert_eq!(std, 100);
        assert!(prop > 5 * std, "prop {prop} vs std {std}");
        // tightness: B and B+1 straddle the envelope
        let cfg = DtypeConfig::ablation("proposed").unwrap();
        let at = breakdown(&g, prop, &cfg, Optimizer::Adam).total_bytes();
        let above = breakdown(&g, prop + 1, &cfg, Optimizer::Adam).total_bytes();
        assert!(env.admits(at) && !env.admits(above));
    }

    #[test]
    fn impossible_envelope() {
        let g = graph();
        let env = MemoryEnvelope::mib(10.0);
        assert!(fit_batch(&g, "standard", Optimizer::Adam, &env)
            .unwrap()
            .is_none());
    }
}
