//! Metrics stream: per-step train loss/acc + periodic validation
//! points, with JSONL export (the raw material for Figs. 3/4/5).

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct MetricPoint {
    pub step: usize,
    pub epoch: usize,
    pub train_loss: f32,
    pub train_acc: f32,
    /// Present on evaluation steps only.
    pub val_loss: Option<f32>,
    pub val_acc: Option<f32>,
    pub lr: f32,
    pub wall_s: f64,
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub points: Vec<MetricPoint>,
    pub best_val_acc: f32,
    pub best_val_step: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics { points: Vec::new(), best_val_acc: 0.0, best_val_step: 0 }
    }

    pub fn push(&mut self, p: MetricPoint) {
        if let Some(va) = p.val_acc {
            if va > self.best_val_acc {
                self.best_val_acc = va;
                self.best_val_step = p.step;
            }
        }
        self.points.push(p);
    }

    pub fn last(&self) -> Option<&MetricPoint> {
        self.points.last()
    }

    /// Validation-accuracy curve: (step, acc) pairs (Figs. 3/4/5).
    pub fn val_curve(&self) -> Vec<(usize, f32)> {
        self.points
            .iter()
            .filter_map(|p| p.val_acc.map(|a| (p.step, a)))
            .collect()
    }

    /// Monotone step index invariant (tested + asserted by property
    /// tests): points are pushed in execution order.
    pub fn steps_monotone(&self) -> bool {
        self.points.windows(2).all(|w| w[0].step <= w[1].step)
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for p in &self.points {
            let mut o = Json::obj();
            o.set("step", p.step.into())
                .set("epoch", p.epoch.into())
                .set("train_loss", (p.train_loss as f64).into())
                .set("train_acc", (p.train_acc as f64).into())
                .set("lr", (p.lr as f64).into())
                .set("wall_s", p.wall_s.into());
            if let Some(v) = p.val_loss {
                o.set("val_loss", (v as f64).into());
            }
            if let Some(v) = p.val_acc {
                o.set("val_acc", (v as f64).into());
            }
            out.push_str(&o.to_string());
            out.push('\n');
        }
        out
    }

    pub fn write_jsonl<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_jsonl().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(step: usize, val: Option<f32>) -> MetricPoint {
        MetricPoint {
            step,
            epoch: 0,
            train_loss: 1.0,
            train_acc: 0.5,
            val_loss: val.map(|_| 1.0),
            val_acc: val,
            lr: 0.001,
            wall_s: 0.1,
        }
    }

    #[test]
    fn tracks_best() {
        let mut m = Metrics::new();
        m.push(point(1, Some(0.5)));
        m.push(point(2, Some(0.8)));
        m.push(point(3, Some(0.7)));
        assert_eq!(m.best_val_acc, 0.8);
        assert_eq!(m.best_val_step, 2);
        assert_eq!(m.val_curve().len(), 3);
        assert!(m.steps_monotone());
    }

    #[test]
    fn jsonl_parses_back() {
        let mut m = Metrics::new();
        m.push(point(1, None));
        m.push(point(2, Some(0.9)));
        let jsonl = m.to_jsonl();
        let lines: Vec<&str> = jsonl.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        let j = Json::parse(lines[1]).unwrap();
        assert_eq!(j.req("step").unwrap().as_usize().unwrap(), 2);
        assert!((j.req("val_acc").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-6);
        assert!(Json::parse(lines[0]).unwrap().get("val_acc").is_none());
    }
}
