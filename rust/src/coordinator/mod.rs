//! Training coordinator: the L3 run orchestrator.
//!
//! Owns the end-to-end training loop the paper's experiments need:
//! dataset → engine (AOT-HLO via PJRT, or a pure-Rust naive engine) →
//! per-step metrics → periodic evaluation → dev-based LR scheduling →
//! checkpointing → best-test-accuracy reporting (the paper reports
//! the highest test accuracy achieved in each run).
//!
//! Edge-specific duties:
//! - **memory envelope** enforcement: refuse configurations whose
//!   modeled footprint exceeds the device budget (Raspberry Pi: 1 GiB)
//!   and auto-tune the largest batch that fits (Fig. 2's ~10× claim);
//! - metrics as JSONL for the figure benches (Figs. 3/4/5 curves).

mod envelope;
mod hlo_engine;
mod metrics;
mod runner;

pub use envelope::{fit_batch, MemoryEnvelope};
pub use hlo_engine::HloEngine;
pub use metrics::{MetricPoint, Metrics};
pub use runner::{EngineKind, RunConfig, RunResult, Runner};

use anyhow::Result;

use crate::util::cli::Args;

/// Launcher entrypoint (`bnn-edge <subcommand> ...`).
pub fn cli_main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "memory" => cmd_memory(&args),
        "energy" => cmd_energy(&args),
        "fit-batch" => cmd_fit_batch(&args),
        "artifacts" => cmd_artifacts(&args),
        "datasets" => cmd_datasets(),
        "federated" => crate::federated::cli(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "bnn-edge — low-memory BNN training on the edge (Wang et al. 2021)

USAGE: bnn-edge <command> [flags]

COMMANDS:
  train       run a training job
              --model mlp_mini --algo proposed --optimizer adam
              --dataset syn-mnist64 --batch 64 --epochs 3
              --engine hlo|naive|blocked|tiled [--threads 4]
              [--microbatch 16]  (gradient accumulation: the step
              executes in microbatch-sized chunks, peak memory scales
              with the microbatch; must divide --batch; naive engines)
              [--lr 0.001] [--seed 42]
              [--envelope-mib 1024] [--metrics out.jsonl]
              [--artifacts artifacts]
  memory      print the Table-2 style breakdown
              --model binarynet [--batch 100] [--algo proposed]
              [--optimizer adam]
  energy      print the modeled energy cost per step
              --model binarynet [--batch 100]
  fit-batch   largest batch fitting an envelope
              --model binarynet --envelope-mib 512 [--algo proposed]
  artifacts   list AOT artifacts [--artifacts artifacts]
  datasets    list synthetic datasets
  federated   run the federated edge-fleet demo
              [--workers 4] [--rounds 5] [--local-steps 8]
"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let mut runner = Runner::new(cfg)?;
    let result = runner.run()?;
    println!("{}", result.summary());
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    use crate::memmodel::{breakdown, DtypeConfig, Optimizer};
    let model = args.str_or("model", "binarynet");
    let batch = args.usize_or("batch", 100)?;
    let algo = args.str_or("algo", "proposed");
    let optimizer = Optimizer::parse(&args.str_or("optimizer", "adam"))
        .ok_or_else(|| anyhow::anyhow!("bad optimizer"))?;
    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    let std = breakdown(&graph, batch, &DtypeConfig::standard(), optimizer);
    let cfg = DtypeConfig::ablation(&algo)
        .ok_or_else(|| anyhow::anyhow!("unknown algo '{algo}'"))?;
    let prop = breakdown(&graph, batch, &cfg, optimizer);
    println!("{}", crate::report::table2(&std, &prop));
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    use crate::energy::step_cost;
    use crate::memmodel::DtypeConfig;
    let model = args.str_or("model", "binarynet");
    let batch = args.usize_or("batch", 100)?;
    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    for (name, cfg) in [
        ("standard", DtypeConfig::standard()),
        ("proposed", DtypeConfig::proposed()),
    ] {
        let c = step_cost(&graph, batch, &cfg, 2.0);
        println!(
            "{name:>9}: {:.2} mJ/step  (DRAM {:.1} MiB moved, {:.0}M MACs, {:.0}M pack ops)",
            c.energy_mj(),
            c.dram_bytes / crate::util::MIB,
            c.mac_ops / 1e6,
            c.pack_ops / 1e6
        );
    }
    Ok(())
}

fn cmd_fit_batch(args: &Args) -> Result<()> {
    use crate::memmodel::Optimizer;
    let model = args.str_or("model", "binarynet");
    let algo = args.str_or("algo", "proposed");
    let mib = args.f64_or("envelope-mib", 1024.0)?;
    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    let env = MemoryEnvelope::mib(mib);
    for a in ["standard", &algo] {
        match fit_batch(&graph, a, Optimizer::Adam, &env)? {
            Some(b) => println!("{a:>9}: max batch {b} within {mib} MiB"),
            None => println!("{a:>9}: does not fit at any batch size"),
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let engine = crate::runtime::Engine::cpu(&dir)?;
    for name in engine.available()? {
        println!("{name}");
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    for (name, desc) in crate::data::catalog() {
        println!("{name:<16} {desc}");
    }
    Ok(())
}
