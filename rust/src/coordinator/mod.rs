//! Training coordinator: the L3 run orchestrator.
//!
//! Owns the end-to-end training loop the paper's experiments need:
//! dataset → engine (AOT-HLO via PJRT, or a pure-Rust naive engine) →
//! per-step metrics → periodic evaluation → dev-based LR scheduling →
//! checkpointing → best-test-accuracy reporting (the paper reports
//! the highest test accuracy achieved in each run).
//!
//! Edge-specific duties:
//! - **memory envelope** enforcement: refuse configurations whose
//!   modeled footprint exceeds the device budget (Raspberry Pi: 1 GiB)
//!   and auto-tune the largest batch that fits (Fig. 2's ~10× claim);
//! - metrics as JSONL for the figure benches (Figs. 3/4/5 curves).

mod envelope;
mod hlo_engine;
mod metrics;
mod runner;

pub use envelope::{fit_batch, MemoryEnvelope};
pub use hlo_engine::HloEngine;
pub use metrics::{MetricPoint, Metrics};
pub use runner::{EngineKind, RunConfig, RunResult, Runner};

use anyhow::Result;

use crate::util::cli::Args;

/// Launcher entrypoint (`bnn-edge <subcommand> ...`).
pub fn cli_main() -> Result<()> {
    let args = Args::from_env();
    // global kernel-dispatch flags: --tune=fixed|auto selects the
    // autotuner mode (default fixed: deterministic pre-tuner
    // dispatch), --tune-cache PATH pre-loads a tuned registry and
    // persists any newly tuned shape classes on exit
    let tune_cache = apply_tune_flags(&args)?;
    // `bnn-edge --dump-schedule [model]` is an alias for the
    // `schedule` subcommand (the flag's value, if any, names a model)
    let r = if args.get("dump-schedule").is_some() {
        cmd_schedule(&args)
    } else {
        let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
        match cmd {
            "train" => cmd_train(&args),
            "memory" => cmd_memory(&args),
            "energy" => cmd_energy(&args),
            "fit-batch" => cmd_fit_batch(&args),
            "artifacts" => cmd_artifacts(&args),
            "datasets" => cmd_datasets(),
            "serve" => cmd_serve(&args),
            "multi" => cmd_multi(&args),
            "schedule" => cmd_schedule(&args),
            "tune" => cmd_tune(&args),
            "federated" => crate::federated::cli(&args),
            _ => {
                print_help();
                Ok(())
            }
        }
    };
    save_tune_cache(tune_cache.as_deref());
    r
}

/// Parse `--tune` / `--tune-cache`, set the process-global tuner mode
/// and pre-load the cache file if it exists.  Returns the cache path
/// (to persist on exit) when tuning is on.
fn apply_tune_flags(args: &Args) -> Result<Option<String>> {
    use crate::bitops::tune;
    // the `tune` subcommand is itself the opt-in: it always runs auto
    let tune_cmd = args.positional.first().map(String::as_str) == Some("tune");
    let mode = match args.get("tune") {
        None if tune_cmd => tune::Mode::Auto,
        None => tune::Mode::Fixed,
        Some(s) => tune::parse_mode(s)
            .ok_or_else(|| anyhow::anyhow!("bad --tune '{s}' (fixed|auto)"))?,
    };
    tune::set_mode(mode);
    let path = args.get("tune-cache").map(str::to_string);
    if let Some(p) = &path {
        if mode == tune::Mode::Fixed {
            anyhow::bail!("--tune-cache requires --tune=auto");
        }
        if std::path::Path::new(p).exists() {
            let n = tune::load_cache(p)?;
            eprintln!("tune: loaded {n} shape classes from {p}");
        }
    }
    Ok(path)
}

/// Persist the tuner registry after a run when `--tune-cache` was
/// given (no-op otherwise; errors are non-fatal — the run's results
/// already stand).
fn save_tune_cache(path: Option<&str>) {
    use crate::bitops::tune;
    if let Some(p) = path {
        match tune::save_cache(p) {
            Ok(n) => eprintln!("tune: saved {n} shape classes to {p}"),
            Err(e) => eprintln!("tune: failed to save {p}: {e}"),
        }
    }
}

fn print_help() {
    println!(
        "bnn-edge — low-memory BNN training on the edge (Wang et al. 2021)

USAGE: bnn-edge <command> [flags]

GLOBAL FLAGS (kernel dispatch):
  --tune fixed|auto   per-shape kernel autotuning for the tiled
                      backend (default fixed: deterministic dispatch)
  --tune-cache PATH   with --tune=auto: load a pre-warmed tune cache
                      (JSON) and persist newly tuned shapes on exit

COMMANDS:
  train       run a training job
              --model mlp_mini --algo proposed --optimizer adam
              --dataset syn-mnist64 --batch 64 --epochs 3
              --engine hlo|naive|blocked|tiled [--threads 4]
              [--microbatch 16]  (gradient accumulation: the step
              executes in microbatch-sized chunks, peak memory scales
              with the microbatch; must divide --batch; naive engines)
              [--lr 0.001] [--seed 42]
              [--envelope-mib 1024] [--metrics out.jsonl]
              [--artifacts artifacts]
  memory      print the Table-2 style breakdown
              --model binarynet [--batch 100] [--algo proposed]
              [--optimizer adam]
  energy      print the modeled energy cost per step
              --model binarynet [--batch 100]
  fit-batch   largest batch fitting an envelope
              --model binarynet --envelope-mib 512 [--algo proposed]
  artifacts   list AOT artifacts [--artifacts artifacts]
  datasets    list synthetic datasets
  serve       run the packed-inference serving demo (dynamic batching
              over the forward-only engine; prints throughput + latency
              for serial batch-1 vs batched serving)
              --model mlp_mini --algo proposed
              --engine tiled [--threads 2]
              [--max-batch 8] [--slo-us 200]
              [--clients 4] [--requests 64] [--seed 42]
  multi       run the multi-tenant co-scheduling demo: N models'
              compiled schedules interleaved on one worker pool, with
              live train-and-serve on the first tenant; prints
              co-scheduled vs time-sliced throughput, per-tenant p99,
              and the fleet memory envelope (planned == measured)
              --models mlp_mini,cnv_mini --engine tiled [--threads 2]
              [--lanes 2] [--max-batch 8] [--batch 16]
              [--clients 2] [--requests 100] [--train-steps 8]
              [--publish-every 2] [--seed 42]
  schedule    compile and dump the slot-colored buffer schedule the
              engines execute (JSON, diffable; prints a per-pool slot
              map + coloring savings to stderr)
              --model binarynet_mini [--algo standard|proposed|both]
              [--engine naive|blocked|tiled] [--batch 64]
              [--microbatch 0] [--serve --max-batch 8]
              [--out schedule.json]
              (alias: bnn-edge --dump-schedule [model])
  tune        pre-warm the kernel autotuner: microbench every GEMM
              shape class a model's train step + serving forward touch
              on this host's tiled backend, print the tuned table
              --models binarynet_mini[,cnv_mini] [--algo both]
              [--threads 4] [--batch 64] [--steps 2]
              [--tune-cache tune.json]  (persist for --tune=auto runs)
  federated   run the fault-tolerant federated edge fleet
              [--workers 4] [--rounds 5] [--local-steps 8]
              [--chaos none|hostile] [--chaos-seed 42]
              [--quorum N] [--max-staleness 2] [--deadline-ms 4000]
              [--retry-budget 3] [--backoff 1]
              [--sim] [--shards 8] [--noise-log2 4]
"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let mut runner = Runner::new(cfg)?;
    let result = runner.run()?;
    println!("{}", result.summary());
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    use crate::memmodel::{breakdown, DtypeConfig, Optimizer};
    let model = args.str_or("model", "binarynet");
    let batch = args.usize_or("batch", 100)?;
    let algo = args.str_or("algo", "proposed");
    let optimizer = Optimizer::parse(&args.str_or("optimizer", "adam"))
        .ok_or_else(|| anyhow::anyhow!("bad optimizer"))?;
    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    let std = breakdown(&graph, batch, &DtypeConfig::standard(), optimizer);
    let cfg = DtypeConfig::ablation(&algo)
        .ok_or_else(|| anyhow::anyhow!("unknown algo '{algo}'"))?;
    let prop = breakdown(&graph, batch, &cfg, optimizer);
    println!("{}", crate::report::table2(&std, &prop));
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    use crate::energy::step_cost;
    use crate::memmodel::DtypeConfig;
    let model = args.str_or("model", "binarynet");
    let batch = args.usize_or("batch", 100)?;
    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    for (name, cfg) in [
        ("standard", DtypeConfig::standard()),
        ("proposed", DtypeConfig::proposed()),
    ] {
        let c = step_cost(&graph, batch, &cfg, 2.0);
        println!(
            "{name:>9}: {:.2} mJ/step  (DRAM {:.1} MiB moved, {:.0}M MACs, {:.0}M pack ops)",
            c.energy_mj(),
            c.dram_bytes / crate::util::MIB,
            c.mac_ops / 1e6,
            c.pack_ops / 1e6
        );
    }
    Ok(())
}

fn cmd_fit_batch(args: &Args) -> Result<()> {
    use crate::memmodel::Optimizer;
    let model = args.str_or("model", "binarynet");
    let algo = args.str_or("algo", "proposed");
    let mib = args.f64_or("envelope-mib", 1024.0)?;
    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    let env = MemoryEnvelope::mib(mib);
    for a in ["standard", &algo] {
        match fit_batch(&graph, a, Optimizer::Adam, &env)? {
            Some(b) => println!("{a:>9}: max batch {b} within {mib} MiB"),
            None => println!("{a:>9}: does not fit at any batch size"),
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let engine = crate::runtime::Engine::cpu(&dir)?;
    for name in engine.available()? {
        println!("{name}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::naive::{build_engine, Accel, StepEngine};
    use crate::serve::{BatchServer, InferAlgo, PackedInferEngine, WeightSnapshot};
    use std::sync::Arc;
    use std::time::Instant;

    let model = args.str_or("model", "mlp_mini");
    let algo = InferAlgo::parse(&args.str_or("algo", "proposed"))?;
    let accel = match args.str_or("engine", "tiled").as_str() {
        "naive" => Accel::Naive,
        "blocked" => Accel::Blocked,
        "tiled" => Accel::Tiled(crate::bitops::Pool::resolve(args.threads()?)),
        other => anyhow::bail!("unknown engine '{other}' (naive|blocked|tiled)"),
    };
    let max_batch = args.usize_or("max-batch", 8)?;
    let slo_us = args.usize_or("slo-us", 200)? as u64;
    let clients = args.usize_or("clients", 4)?;
    let requests = args.usize_or("requests", 64)?;
    let seed = args.usize_or("seed", 42)? as u64;

    // weights come from a freshly initialised trainer — in a real
    // deployment `publish` would hand over a trained snapshot
    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    let plan = crate::naive::Plan::from_graph(&graph)?;
    let algo_name = match algo {
        InferAlgo::Standard => "standard",
        InferAlgo::Proposed => "proposed",
    };
    let trainer = build_engine(algo_name, &graph, max_batch.max(1), "adam", accel, seed)?;
    let snap = Arc::new(WeightSnapshot::pack(&plan, &trainer.weights_snapshot(), 0)?);
    drop(trainer);

    let mk = || PackedInferEngine::new(&graph, algo, accel, max_batch, Arc::clone(&snap));
    let ie = plan.input_elems;
    let cl = plan.classes;
    let per_client = requests.div_ceil(clients.max(1));
    let total = per_client * clients.max(1);

    // serial batch-1 baseline: one engine, one request at a time
    let mut serial = mk()?;
    serial.warmup()?;
    let mut rng = crate::util::rng::Pcg32::new(seed);
    let x0 = rng.normal_vec(ie);
    let mut out = vec![0.0f32; cl];
    let t0 = Instant::now();
    for _ in 0..total {
        serial.infer_into(&x0, 1, &mut out)?;
    }
    let serial_s = t0.elapsed().as_secs_f64();
    let serial_qps = total as f64 / serial_s.max(1e-12);

    // dynamic batching: concurrent clients against one BatchServer
    let (batcher, server) = BatchServer::new(mk()?, slo_us, max_batch.max(4) * 4)?;
    let steady = server.steady_state_bytes();
    let h = std::thread::spawn(move || server.run());
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients.max(1) as u64 {
        let b = batcher.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut rng = crate::util::rng::Pcg32::new(seed ^ (0x9e37 + c));
            let mut out = vec![0.0f32; cl];
            let mut lat = Vec::with_capacity(per_client);
            for _ in 0..per_client {
                let x = rng.normal_vec(ie);
                let t = Instant::now();
                b.infer_one(&x, &mut out)?;
                lat.push(t.elapsed().as_secs_f64() * 1e6);
            }
            Ok(lat)
        }));
    }
    let mut lat = Vec::with_capacity(total);
    for h in handles {
        lat.extend(h.join().expect("client panicked")?);
    }
    let batched_s = t0.elapsed().as_secs_f64();
    batcher.shutdown();
    let engine = h.join().expect("server panicked")?;
    let batched_qps = batcher.served() as f64 / batched_s.max(1e-12);

    println!(
        "serve demo: {model} ({algo_name}, {accel:?})  max_batch={max_batch} slo={slo_us}µs \
         clients={clients} requests={total}"
    );
    println!(
        "  snapshot {:.2} MiB + arena {:.2} MiB  (server steady state {:.2} MiB)",
        engine.state_bytes() as f64 / crate::util::MIB,
        engine.arena_bytes() as f64 / crate::util::MIB,
        steady as f64 / crate::util::MIB
    );
    println!("  serial batch-1 : {serial_qps:>10.1} req/s");
    println!(
        "  dynamic batch  : {batched_qps:>10.1} req/s  ({:.2}x)  p50 {:.0}µs  p99 {:.0}µs",
        batched_qps / serial_qps.max(1e-12),
        crate::util::stats::percentile(&lat, 50.0),
        crate::util::stats::percentile(&lat, 99.0)
    );
    Ok(())
}

/// One measured fleet run for `cmd_multi` (co-scheduled or the
/// 1-lane time-sliced baseline).
struct MultiRunStats {
    qps: f64,
    p99_us: Vec<f64>,
    planned_bytes: f64,
    measured_bytes: usize,
    sweeps: u64,
    contended: u64,
    steps: u64,
    published: u64,
    /// Per-tenant serving-snapshot digests after the run (`None` for
    /// train-only tenants) — the bit-identity witness.
    digests: Vec<Option<u64>>,
}

fn run_multi_fleet(
    specs: &[crate::serve::TenantSpec],
    lanes: usize,
    clients: usize,
    requests: usize,
    train_steps: usize,
    seed: u64,
) -> Result<MultiRunStats> {
    use crate::serve::MultiModelServer;
    use std::time::Instant;

    let (client, server) = MultiModelServer::new(specs.to_vec(), lanes)?;
    let planned = server.fleet_envelope()?.total_bytes();
    let sw0 = crate::bitops::sweep_stats();
    let h = std::thread::spawn(move || server.run());

    let per_client = requests.div_ceil(clients);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (tid, spec) in specs.iter().enumerate() {
        if !spec.role.serves() {
            continue;
        }
        let graph = crate::models::lower(&crate::models::get(&spec.model)?)?;
        for c in 0..clients as u64 {
            let cl = client.clone();
            let (ie, ncl) = (graph.input_elems, graph.classes);
            handles.push(std::thread::spawn(move || -> Result<(usize, Vec<f64>)> {
                let mut rng = crate::util::rng::Pcg32::new(seed ^ (tid as u64 * 97 + c + 1));
                let mut out = vec![0.0f32; ncl];
                let mut lat = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let x = rng.normal_vec(ie);
                    let t = Instant::now();
                    cl.infer_one(tid, &x, &mut out)?;
                    lat.push(t.elapsed().as_secs_f64() * 1e6);
                }
                Ok((tid, lat))
            }));
        }
    }
    // live train-and-serve: a feeder drives tenant 0's training loop
    // while its serve engine takes the infer load above
    let feeder = if train_steps > 0 && specs[0].role.trains() {
        let cl = client.clone();
        let graph = crate::models::lower(&crate::models::get(&specs[0].model)?)?;
        let bsz = specs[0].batch;
        Some(std::thread::spawn(move || -> Result<()> {
            let mut rng = crate::util::rng::Pcg32::new(seed ^ 0xfeed);
            for _ in 0..train_steps {
                let x = rng.normal_vec(graph.input_elems * bsz);
                let y: Vec<usize> = (0..bsz).map(|i| (i * 7) % graph.classes).collect();
                cl.train_step(0, &x, &y, 0.01)?;
            }
            Ok(())
        }))
    } else {
        None
    };

    let mut lat_by_tenant: Vec<Vec<f64>> = vec![Vec::new(); specs.len()];
    let mut total = 0usize;
    for h in handles {
        let (tid, lat) = h.join().expect("client panicked")?;
        total += lat.len();
        lat_by_tenant[tid].extend(lat);
    }
    if let Some(f) = feeder {
        f.join().expect("feeder panicked")?;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    client.shutdown();
    let tenants = h.join().expect("server panicked")?;
    let sw1 = crate::bitops::sweep_stats();

    let measured: usize = tenants.iter().map(|t| t.steady_state_bytes()).sum();
    // the fold is exact once a trained tenant's packed caches fill
    // (≥2 steps) — serve-only fleets are exact from the start
    if train_steps == 0 || train_steps >= 2 {
        anyhow::ensure!(
            planned as usize == measured,
            "fleet envelope {planned} bytes != measured {measured} bytes"
        );
    }
    Ok(MultiRunStats {
        qps: total as f64 / wall_s.max(1e-12),
        p99_us: lat_by_tenant
            .iter()
            .map(|l| if l.is_empty() { 0.0 } else { crate::util::stats::percentile(l, 99.0) })
            .collect(),
        planned_bytes: planned,
        measured_bytes: measured,
        sweeps: sw1.sweeps - sw0.sweeps,
        contended: sw1.contended - sw0.contended,
        steps: tenants.iter().map(|t| t.steps()).sum(),
        published: tenants.iter().map(|t| t.published()).sum(),
        digests: tenants
            .iter()
            .map(|t| t.serve_engine().map(|e| e.snapshot().bit_digest()))
            .collect(),
    })
}

fn cmd_multi(args: &Args) -> Result<()> {
    use crate::naive::{schedule, Accel};
    use crate::serve::{TenantRole, TenantSpec};

    let models: Vec<String> = args
        .str_or("models", "mlp_mini,cnv_mini")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if models.is_empty() {
        anyhow::bail!("--models needs at least one model");
    }
    let accel = match args.str_or("engine", "tiled").as_str() {
        "naive" => Accel::Naive,
        "blocked" => Accel::Blocked,
        "tiled" => Accel::Tiled(crate::bitops::Pool::resolve(args.threads()?)),
        other => anyhow::bail!("unknown engine '{other}' (naive|blocked|tiled)"),
    };
    let lanes = args.usize_or("lanes", 2)?.max(1);
    let max_batch = args.usize_or("max-batch", 8)?;
    let batch = args.usize_or("batch", 16)?;
    let clients = args.usize_or("clients", 2)?.max(1);
    let requests = args.usize_or("requests", 100)?;
    let train_steps = args.usize_or("train-steps", 8)?;
    let publish_every = args.usize_or("publish-every", 2)?;
    let seed = args.usize_or("seed", 42)? as u64;

    let specs: Vec<TenantSpec> = models
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let role = if i == 0 && train_steps > 0 {
                TenantRole::TrainServe
            } else {
                TenantRole::Serve
            };
            let mut s = TenantSpec::new(&format!("{m}#{i}"), m, role);
            s.accel = accel;
            s.seed = seed + i as u64;
            s.batch = batch;
            s.max_batch = max_batch;
            s.publish_every = publish_every;
            s.queue_cap = (max_batch * 4).max(32);
            s
        })
        .collect();

    // the compiled schedules each tenant executes
    let naive = matches!(accel, Accel::Naive);
    println!(
        "multi-tenant fleet: {} tenants ({accel:?}), {clients} clients x {requests} reqs/tenant",
        specs.len()
    );
    for s in &specs {
        let graph = crate::models::lower(&crate::models::get(&s.model)?)?;
        let plan = crate::naive::Plan::from_graph(&graph)?;
        if s.role.trains() {
            let sched = schedule::compile_step(&plan, &s.algo, naive, s.batch, 1)?;
            println!("  {:<14} train {}", s.name, sched.summary());
        }
        if s.role.serves() {
            let sched = schedule::compile_serve(&plan, &s.algo, naive, s.max_batch)?;
            println!("  {:<14} serve {}", s.name, sched.summary());
        }
    }

    let cos = run_multi_fleet(&specs, lanes, clients, requests, train_steps, seed)?;
    let sliced = run_multi_fleet(&specs, 1, clients, requests, train_steps, seed)?;
    // same seeds, same training data: the final weights must be
    // bit-identical however the quanta interleaved
    anyhow::ensure!(
        cos.digests == sliced.digests,
        "co-scheduled weights diverged from time-sliced"
    );

    println!(
        "  fleet envelope: planned {:.2} MiB == measured {:.2} MiB",
        cos.planned_bytes / crate::util::MIB,
        cos.measured_bytes as f64 / crate::util::MIB
    );
    println!(
        "  time-sliced  (1 lane) : {:>8.1} req/s           {} steps, {} publishes, {} pool sweeps ({} contended)",
        sliced.qps, sliced.steps, sliced.published, sliced.sweeps, sliced.contended
    );
    println!(
        "  co-scheduled ({lanes} lanes): {:>8.1} req/s  ({:.2}x)  {} steps, {} publishes, {} pool sweeps ({} contended)",
        cos.qps,
        cos.qps / sliced.qps.max(1e-12),
        cos.steps,
        cos.published,
        cos.sweeps,
        cos.contended
    );
    for (i, s) in specs.iter().enumerate() {
        let snap = match cos.digests[i] {
            Some(d) => format!("snapshot {d:016x}"),
            None => "train-only".to_string(),
        };
        println!(
            "    {:<14} p99 {:>7.0}us co-scheduled vs {:>7.0}us time-sliced  {snap}",
            s.name, cos.p99_us[i], sliced.p99_us[i]
        );
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    use crate::naive::schedule;
    use crate::util::json::Json;

    // `--dump-schedule <model>` doubles as the model flag
    let model = match args.get("dump-schedule") {
        Some(v) if !matches!(v, "true" | "1" | "yes") => v.to_string(),
        _ => args.str_or("model", "binarynet_mini"),
    };
    let engine = args.str_or("engine", "blocked");
    let naive = match engine.as_str() {
        "naive" => true,
        "blocked" | "tiled" => false,
        other => anyhow::bail!("unknown engine '{other}' (naive|blocked|tiled)"),
    };
    let batch = args.usize_or("batch", 64)?;
    let micro = match args.usize_or("microbatch", 0)? {
        0 => batch,
        m => m,
    };
    if batch == 0 || batch % micro != 0 {
        anyhow::bail!("--microbatch must divide --batch");
    }
    let serve = args.bool("serve");
    let max_batch = args.usize_or("max-batch", 8)?;
    let algos: Vec<&str> = match args.str_or("algo", "both").as_str() {
        "both" => vec!["standard", "proposed"],
        "standard" => vec!["standard"],
        "proposed" => vec!["proposed"],
        other => anyhow::bail!("unknown algo '{other}' (standard|proposed|both)"),
    };

    let graph = crate::models::lower(&crate::models::get(&model)?)?;
    let plan = crate::naive::Plan::from_graph(&graph)?;

    let mut dump = Json::obj();
    for algo in algos {
        let sched = if serve {
            schedule::compile_serve(&plan, algo, naive, max_batch)?
        } else {
            schedule::compile_step(&plan, algo, naive, micro, batch / micro)?
        };
        eprintln!("{}", sched.summary());
        dump.set(algo, sched.to_json());
    }
    let text = dump.to_string_pretty();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            eprintln!("wrote {path}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    for (name, desc) in crate::data::catalog() {
        println!("{name:<16} {desc}");
    }
    Ok(())
}

/// `bnn-edge tune`: pre-warm the kernel autotuner offline.  Runs a few
/// training steps (and a serving forward) of each requested model so
/// every GEMM shape class the step touches gets microbenched, then
/// prints the tuned table; with `--tune-cache PATH` the launcher
/// persists it for later `--tune=auto` runs to load.
fn cmd_tune(args: &Args) -> Result<()> {
    use crate::bitops::tune;
    use crate::naive::{build_engine, Accel};

    let models: Vec<String> = args
        .str_or("models", &args.str_or("model", "binarynet_mini"))
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let algos: Vec<&str> = match args.str_or("algo", "both").as_str() {
        "both" => vec!["standard", "proposed"],
        "standard" => vec!["standard"],
        "proposed" => vec!["proposed"],
        other => anyhow::bail!("unknown algo '{other}' (standard|proposed|both)"),
    };
    let threads = crate::bitops::Pool::resolve(args.threads()?);
    let accel = Accel::Tiled(threads);
    let batch = args.usize_or("batch", 64)?;
    let steps = args.usize_or("steps", 2)?.max(1);
    let seed = args.usize_or("seed", 42)? as u64;

    for model in &models {
        let graph = crate::models::lower(&crate::models::get(model)?)?;
        let mut rng = crate::util::rng::Pcg32::new(seed);
        let x = rng.normal_vec(graph.input_elems * batch);
        let y: Vec<usize> = (0..batch).map(|i| (i * 7) % graph.classes).collect();
        for algo in &algos {
            let before = tune::len();
            let mut eng = build_engine(algo, &graph, batch, "adam", accel, seed)?;
            for _ in 0..steps {
                eng.train_step(&x, &y, 0.01)?;
            }
            eng.eval(&x, &y)?;
            println!(
                "tuned {model}/{algo} ({threads} threads): {} new shape classes",
                tune::len() - before
            );
        }
    }
    println!("\n{:<30} {:>8} config", "shape class (mclass,kw,n,p,t)", "");
    for (k, c) in tune::entries() {
        println!(
            "  m{:<5} k{:<4} n{:<5} {}{:<2}     {}",
            k.m_class,
            k.k_words,
            k.n,
            if k.panels { "P" } else { "-" },
            k.threads,
            c.label()
        );
    }
    Ok(())
}
