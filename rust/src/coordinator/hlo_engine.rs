//! HLO-backed step engine: drives an AOT train-step executable
//! (compiled once by python/compile/aot.py) through the PJRT runtime.
//!
//! This is the system's primary engine — L1 Pallas kernels and the L2
//! JAX model are baked into the artifact; Rust feeds parameters and
//! batches, and feeds the returned state back in, with Python nowhere
//! on the path.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::naive::StepEngine;
use crate::runtime::{Artifact, Engine, IoKind, Tensor};
use crate::util::rng::Pcg32;

pub struct HloEngine {
    train: Arc<Artifact>,
    eval: Option<Arc<Artifact>>,
    /// params + opt state, fed back every step (manifest order).
    state: Vec<Tensor>,
    n_params: usize,
    loss_idx: usize,
    acc_idx: usize,
}

impl HloEngine {
    /// Load `train_name` (and optionally an eval artifact) and init
    /// parameters with Glorot (same scheme as python init) + zero opt
    /// state.
    pub fn new(
        engine: &Engine,
        train_name: &str,
        eval_name: Option<&str>,
        seed: u64,
    ) -> Result<HloEngine> {
        let train = engine.load(train_name)?;
        let m = &train.manifest;
        if m.kind != "train" {
            bail!("'{train_name}' is not a train artifact");
        }
        let eval = match eval_name {
            Some(n) => Some(engine.load(n)?),
            None => None,
        };
        let mut rng = Pcg32::new(seed);
        let mut state = Vec::new();
        let is_bop = m.optimizer.as_deref() == Some("bop");
        for spec in &m.inputs {
            match spec.kind {
                IoKind::Param => {
                    // weights (rank >= 2) get Glorot; betas zeros
                    if spec.shape.len() >= 2 {
                        let fan_out = *spec.shape.last().unwrap();
                        let fan_in = spec.numel() / fan_out;
                        let mut w = rng.glorot(fan_in, fan_out, spec.numel());
                        if is_bop {
                            for v in w.iter_mut() {
                                *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                            }
                        }
                        state.push(Tensor::new(spec.shape.clone(), w)?);
                    } else {
                        state.push(Tensor::zeros(&spec.shape));
                    }
                }
                IoKind::Opt => state.push(Tensor::zeros(&spec.shape)),
                _ => {}
            }
        }
        let n_params = m.input_indices(IoKind::Param).len();
        let loss_idx = m
            .output_index("loss")
            .ok_or_else(|| anyhow!("no loss output"))?;
        let acc_idx = m
            .output_index("acc")
            .ok_or_else(|| anyhow!("no acc output"))?;
        Ok(HloEngine { train, eval, state, n_params, loss_idx, acc_idx })
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.train.manifest
    }

    /// Batch size of the eval artifact (eval chunking granularity).
    pub fn eval_batch(&self) -> Option<usize> {
        self.eval.as_ref().map(|a| a.manifest.batch)
    }

    fn input_shape_elems(&self) -> usize {
        self.train.manifest.input_shape.iter().product()
    }

    fn xy_tensors(
        batch: usize,
        sample: usize,
        classes: usize,
        shape: &[usize],
        x: &[f32],
        labels: &[usize],
    ) -> Result<(Tensor, Tensor)> {
        if x.len() != batch * sample || labels.len() != batch {
            bail!(
                "batch shapes: x has {} want {}, labels {} want {batch}",
                x.len(),
                batch * sample,
                labels.len()
            );
        }
        let mut xshape = vec![batch];
        xshape.extend_from_slice(shape);
        let xt = Tensor::new(xshape, x.to_vec())?;
        let mut y = vec![0.0f32; batch * classes];
        for (i, &l) in labels.iter().enumerate() {
            y[i * classes + l] = 1.0;
        }
        let yt = Tensor::new(vec![batch, classes], y)?;
        Ok((xt, yt))
    }
}

impl StepEngine for HloEngine {
    fn train_step(&mut self, x: &[f32], labels: &[usize], lr: f32) -> Result<(f32, f32)> {
        let m = &self.train.manifest;
        let (xt, yt) = Self::xy_tensors(
            m.batch,
            self.input_shape_elems(),
            m.classes,
            &m.input_shape,
            x,
            labels,
        )?;
        let mut inputs = self.state.clone();
        inputs.push(xt);
        inputs.push(yt);
        inputs.push(Tensor::scalar(lr));
        let outs = self.train.run(&inputs)?;
        let loss = outs[self.loss_idx].item()?;
        let acc = outs[self.acc_idx].item()?;
        // feed params + opt state back (they precede the metrics)
        let n_state = self.state.len();
        self.state = outs.into_iter().take(n_state).collect();
        Ok((loss, acc))
    }

    fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)> {
        let e = self
            .eval
            .as_ref()
            .ok_or_else(|| anyhow!("no eval artifact loaded"))?;
        let m = &e.manifest;
        let (xt, yt) = Self::xy_tensors(
            m.batch,
            self.input_shape_elems(),
            m.classes,
            &m.input_shape,
            x,
            labels,
        )?;
        let mut inputs: Vec<Tensor> =
            self.state.iter().take(self.n_params).cloned().collect();
        inputs.push(xt);
        inputs.push(yt);
        let outs = e.run(&inputs)?;
        Ok((outs[0].item()?, outs[1].item()?))
    }

    fn state_bytes(&self) -> usize {
        self.state.iter().map(|t| t.len() * 4).sum()
    }

    fn batch(&self) -> usize {
        self.train.manifest.batch
    }

    fn weights_snapshot(&self) -> Vec<Vec<f32>> {
        // weight tensors are the even param slots (w0, beta0, w1, ...)
        self.state
            .iter()
            .take(self.n_params)
            .map(|t| t.data.clone())
            .collect()
    }

    fn load_weights(&mut self, w: &[Vec<f32>]) -> Result<()> {
        if w.len() != self.n_params {
            bail!("snapshot has {} tensors, artifact wants {}", w.len(), self.n_params);
        }
        for (i, src) in w.iter().enumerate() {
            if src.len() != self.state[i].len() {
                bail!("tensor {i} length mismatch");
            }
            self.state[i].data = src.clone();
        }
        Ok(())
    }
}
