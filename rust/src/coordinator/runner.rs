//! The run loop: epochs → shuffled batches → engine step → metrics →
//! periodic eval → LR schedule → checkpoint → best-acc result.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::envelope::{check, MemoryEnvelope};
use super::hlo_engine::HloEngine;
use super::metrics::{MetricPoint, Metrics};
use std::sync::Arc;

use crate::data::{build, Batches, Dataset};
use crate::memmodel::Optimizer;
use crate::naive::{build_engine_micro, Accel, Plan, StepEngine};
use crate::optim::LrSchedule;
use crate::serve::WeightSnapshot;
use crate::util::cli::Args;
use crate::util::rng::Pcg32;

/// Which engine executes steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT HLO via PJRT (the primary path; needs artifacts).
    Hlo,
    /// Pure-Rust engine, direct loops (the naïve prototype).
    Naive,
    /// Pure-Rust engine, blocked GEMM ("CBLAS"-accelerated).
    Blocked,
    /// Pure-Rust engine, 4×4 tiled kernels row-parallel over the
    /// worker pool (`RunConfig::threads`; 0 = auto).
    Tiled,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind> {
        Ok(match s {
            "hlo" => EngineKind::Hlo,
            "naive" => EngineKind::Naive,
            "blocked" => EngineKind::Blocked,
            "tiled" => EngineKind::Tiled,
            _ => bail!("unknown engine '{s}' (hlo|naive|blocked|tiled)"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub algo: String,          // ablation name
    pub optimizer: String,     // adam | sgd | bop
    pub dataset: String,
    pub batch: usize,
    pub epochs: usize,
    pub max_steps: Option<usize>,
    pub lr: f32,
    pub engine: EngineKind,
    /// Worker threads for the tiled engine (0 = auto-detect).
    pub threads: usize,
    /// Microbatch for gradient accumulation on the pure-Rust engines
    /// (0 = whole batch).  Must divide `batch`; the step arena — and
    /// with it peak training memory — is sized by this instead of the
    /// logical batch (`memmodel::step_envelope` prices it).
    pub microbatch: usize,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    pub eval_every_steps: usize,
    pub envelope: Option<MemoryEnvelope>,
    pub artifacts_dir: PathBuf,
    pub metrics_path: Option<PathBuf>,
    pub use_pallas_artifact: bool,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            model: "mlp_mini".into(),
            algo: "proposed".into(),
            optimizer: "adam".into(),
            dataset: "syn-mnist64".into(),
            batch: 64,
            epochs: 3,
            max_steps: None,
            lr: 0.001,
            engine: EngineKind::Hlo,
            threads: 0,
            microbatch: 0,
            seed: 42,
            n_train: 2000,
            n_test: 400,
            eval_every_steps: 20,
            envelope: None,
            artifacts_dir: "artifacts".into(),
            metrics_path: None,
            use_pallas_artifact: false,
        }
    }
}

impl RunConfig {
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let d = RunConfig::default();
        Ok(RunConfig {
            model: args.str_or("model", &d.model),
            algo: args.str_or("algo", &d.algo),
            optimizer: args.str_or("optimizer", &d.optimizer),
            dataset: args.str_or("dataset", &d.dataset),
            batch: args.usize_or("batch", d.batch)?,
            epochs: args.usize_or("epochs", d.epochs)?,
            max_steps: args.get("max-steps").map(|v| v.parse()).transpose()?,
            lr: args.f64_or("lr", d.lr as f64)? as f32,
            engine: EngineKind::parse(&args.str_or("engine", "hlo"))?,
            threads: args.threads()?,
            microbatch: args.usize_or("microbatch", d.microbatch)?,
            seed: args.usize_or("seed", d.seed as usize)? as u64,
            n_train: args.usize_or("n-train", d.n_train)?,
            n_test: args.usize_or("n-test", d.n_test)?,
            eval_every_steps: args.usize_or("eval-every", d.eval_every_steps)?,
            envelope: args
                .get("envelope-mib")
                .map(|v| v.parse::<f64>().map(MemoryEnvelope::mib))
                .transpose()?,
            artifacts_dir: args.str_or("artifacts", "artifacts").into(),
            metrics_path: args.get("metrics").map(PathBuf::from),
            use_pallas_artifact: args.bool("pallas"),
        })
    }

    /// Train artifact name per aot.py's Variant naming.
    pub fn train_artifact(&self) -> String {
        let mut n = format!(
            "{}_{}_{}_b{}",
            self.model, self.algo, self.optimizer, self.batch
        );
        if self.use_pallas_artifact {
            n.push_str("_pallas");
        }
        n
    }

    /// Matching eval artifact, if the set includes one.
    pub fn eval_artifact(&self, available: &[String]) -> Option<String> {
        // prefer algo-exact eval; batch may differ (chunked eval)
        available
            .iter()
            .find(|n| {
                n.starts_with(&format!("{}_{}_b", self.model, self.algo))
                    && n.ends_with("_eval")
            })
            .cloned()
    }
}

#[derive(Debug)]
pub struct RunResult {
    pub config_summary: String,
    pub metrics: Metrics,
    pub best_test_acc: f32,
    pub final_train_loss: f32,
    pub steps: usize,
    pub wall_s: f64,
    pub modeled_mib: Option<f64>,
}

impl RunResult {
    pub fn summary(&self) -> String {
        format!(
            "{}: best test acc {:.2}% | final train loss {:.4} | {} steps in {:.1}s{}",
            self.config_summary,
            self.best_test_acc * 100.0,
            self.final_train_loss,
            self.steps,
            self.wall_s,
            match self.modeled_mib {
                Some(m) => format!(" | modeled {m:.1} MiB"),
                None => String::new(),
            }
        )
    }
}

/// Receives each published [`WeightSnapshot`] — typically
/// `MultiClient::publish` into a co-resident serving tenant, the live
/// train-and-serve wiring of `bnn-edge multi`.
pub type SnapshotSink = Box<dyn FnMut(Arc<WeightSnapshot>) -> Result<()> + Send>;

pub struct Runner {
    cfg: RunConfig,
    dataset: Dataset,
    engine: Box<dyn StepEngine>,
    eval_chunk: usize,
    schedule: LrSchedule,
    modeled_mib: Option<f64>,
    plan: Plan,
    /// `(publish_every_steps, sink)` — see [`Runner::set_snapshot_sink`].
    sink: Option<(usize, SnapshotSink)>,
    published: u64,
    last_pub_step: usize,
}

impl Runner {
    pub fn new(cfg: RunConfig) -> Result<Runner> {
        let dataset = build(&cfg.dataset, cfg.n_train, cfg.n_test, cfg.seed)?;
        let graph = crate::models::lower(&crate::models::get(&cfg.model)?)?;
        if dataset.sample_elems() != graph.input_elems {
            bail!(
                "dataset '{}' ({} elems) does not match model '{}' ({} elems)",
                cfg.dataset,
                dataset.sample_elems(),
                cfg.model,
                graph.input_elems
            );
        }
        // memory envelope gate (modeled; the edge-device admission)
        let modeled_mib = match &cfg.envelope {
            Some(env) => {
                let opt = Optimizer::parse(&cfg.optimizer)
                    .ok_or_else(|| anyhow!("bad optimizer '{}'", cfg.optimizer))?;
                Some(check(&graph, cfg.batch, &cfg.algo, opt, env)? / crate::util::MIB)
            }
            None => None,
        };

        if cfg.microbatch != 0 && cfg.engine == EngineKind::Hlo {
            bail!("--microbatch requires a pure-Rust engine (naive|blocked|tiled)");
        }
        let (engine, eval_chunk): (Box<dyn StepEngine>, usize) = match cfg.engine {
            EngineKind::Hlo => {
                let rt = crate::runtime::Engine::cpu(&cfg.artifacts_dir)?;
                let avail = rt.available()?;
                let train_name = cfg.train_artifact();
                if !avail.contains(&train_name) {
                    bail!(
                        "artifact '{train_name}' not found — run `make artifacts` \
                         (available: {} artifacts)",
                        avail.len()
                    );
                }
                let eval_name = cfg.eval_artifact(&avail);
                let eng =
                    HloEngine::new(&rt, &train_name, eval_name.as_deref(), cfg.seed)?;
                let chunk = eng.eval_batch().unwrap_or(cfg.batch);
                (Box::new(eng), chunk)
            }
            EngineKind::Naive | EngineKind::Blocked | EngineKind::Tiled => {
                let accel = match cfg.engine {
                    EngineKind::Naive => Accel::Naive,
                    EngineKind::Blocked => Accel::Blocked,
                    // resolve 0 = auto once here, not per matmul
                    _ => Accel::Tiled(crate::bitops::Pool::resolve(cfg.threads)),
                };
                let eng = build_engine_micro(
                    &cfg.algo,
                    &graph,
                    cfg.batch,
                    cfg.microbatch,
                    &cfg.optimizer,
                    accel,
                    cfg.seed,
                )?;
                (eng, cfg.batch)
            }
        };

        let schedule = LrSchedule::dev_based(cfg.lr);
        let plan = Plan::from_graph(&graph)?;
        Ok(Runner {
            cfg,
            dataset,
            engine,
            eval_chunk,
            schedule,
            modeled_mib,
            plan,
            sink: None,
            published: 0,
            last_pub_step: 0,
        })
    }

    /// Publish a packed snapshot of the latent weights into `sink`
    /// every `every_steps` training steps (and once more after the
    /// final step — the commit-boundary flush).  Versions are the
    /// publish count, monotone from 1.
    pub fn set_snapshot_sink(&mut self, every_steps: usize, sink: SnapshotSink) {
        assert!(every_steps > 0, "publish interval must be positive");
        self.sink = Some((every_steps, sink));
    }

    /// Snapshots published so far.
    pub fn published(&self) -> u64 {
        self.published
    }

    fn maybe_publish(&mut self, step: usize, force: bool) -> Result<()> {
        let Some((every, sink)) = self.sink.as_mut() else { return Ok(()) };
        if step == self.last_pub_step || (!force && step % *every != 0) {
            return Ok(());
        }
        let v = self.published + 1;
        let snap = Arc::new(WeightSnapshot::pack(&self.plan, &self.engine.weights_snapshot(), v)?);
        sink(snap)?;
        self.published = v;
        self.last_pub_step = step;
        Ok(())
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn engine_mut(&mut self) -> &mut dyn StepEngine {
        self.engine.as_mut()
    }

    /// Evaluate on the test split in eval_chunk-sized pieces.
    pub fn evaluate(&mut self) -> Result<(f32, f32)> {
        let k = self.dataset.sample_elems();
        let chunk = self.eval_chunk;
        let n = (self.dataset.n_test() / chunk) * chunk;
        if n == 0 {
            bail!("test split smaller than eval batch {chunk}");
        }
        let (mut loss, mut acc) = (0.0f64, 0.0f64);
        let mut batches = 0;
        for start in (0..n).step_by(chunk) {
            let x = &self.dataset.test_x[start * k..(start + chunk) * k];
            let y = &self.dataset.test_y[start..start + chunk];
            let (l, a) = self.engine.eval(x, y)?;
            loss += l as f64;
            acc += a as f64;
            batches += 1;
        }
        Ok(((loss / batches as f64) as f32, (acc / batches as f64) as f32))
    }

    pub fn run(&mut self) -> Result<RunResult> {
        let t0 = Instant::now();
        let mut metrics = Metrics::new();
        let mut rng = Pcg32::with_stream(self.cfg.seed, 0x9e3779b97f4a7c15);
        let mut step = 0usize;

        'epochs: for epoch in 0..self.cfg.epochs {
            // materialize the epoch's batches up front so evaluate()
            // (which needs &mut self) can interleave with stepping
            let epoch_batches: Vec<(Vec<f32>, Vec<usize>)> = {
                let mut it = Batches::new(&self.dataset, self.cfg.batch, &mut rng);
                std::iter::from_fn(|| it.next()).collect()
            };
            for (x, y) in epoch_batches {
                let lr = self.schedule.lr(epoch);
                let (loss, acc) = self.engine.train_step(&x, &y, lr)?;
                step += 1;
                self.maybe_publish(step, false)?;
                let eval_now = step % self.cfg.eval_every_steps == 0;
                let (vl, va) = if eval_now {
                    let (l, a) = self.evaluate()?;
                    self.schedule.observe(a);
                    (Some(l), Some(a))
                } else {
                    (None, None)
                };
                metrics.push(MetricPoint {
                    step,
                    epoch,
                    train_loss: loss,
                    train_acc: acc,
                    val_loss: vl,
                    val_acc: va,
                    lr,
                    wall_s: t0.elapsed().as_secs_f64(),
                });
                if let Some(ms) = self.cfg.max_steps {
                    if step >= ms {
                        break 'epochs;
                    }
                }
            }
        }
        // flush the endpoint weights to the sink (commit boundary:
        // whatever serves next must see the final step)
        self.maybe_publish(step, true)?;
        // final eval (ensures best-acc includes the endpoint)
        let (vl, va) = self.evaluate()?;
        metrics.push(MetricPoint {
            step: step + 1,
            epoch: self.cfg.epochs,
            train_loss: metrics.last().map(|p| p.train_loss).unwrap_or(0.0),
            train_acc: metrics.last().map(|p| p.train_acc).unwrap_or(0.0),
            val_loss: Some(vl),
            val_acc: Some(va),
            lr: self.schedule.lr(self.cfg.epochs),
            wall_s: t0.elapsed().as_secs_f64(),
        });

        if let Some(p) = &self.cfg.metrics_path {
            metrics.write_jsonl(p)?;
        }
        let final_train_loss = metrics
            .points
            .iter()
            .rev()
            .find(|p| p.train_loss.is_finite())
            .map(|p| p.train_loss)
            .unwrap_or(f32::NAN);
        Ok(RunResult {
            config_summary: format!(
                "{} {} {} on {} (B={}, {:?})",
                self.cfg.model,
                self.cfg.algo,
                self.cfg.optimizer,
                self.cfg.dataset,
                self.cfg.batch,
                self.cfg.engine
            ),
            best_test_acc: metrics.best_val_acc,
            final_train_loss,
            steps: step,
            wall_s: t0.elapsed().as_secs_f64(),
            metrics,
            modeled_mib: self.modeled_mib,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(engine: EngineKind) -> RunConfig {
        RunConfig {
            engine,
            n_train: 640,
            n_test: 128,
            epochs: 6,
            eval_every_steps: 10,
            batch: 64,
            lr: 0.003,
            ..Default::default()
        }
    }

    #[test]
    fn blocked_runner_end_to_end() {
        let mut r = Runner::new(cfg(EngineKind::Blocked)).unwrap();
        let result = r.run().unwrap();
        assert!(result.steps >= 8, "{}", result.steps);
        assert!(result.best_test_acc > 0.15, "acc {}", result.best_test_acc);
        assert!(result.metrics.steps_monotone());
        // loss went down
        let first = result.metrics.points.first().unwrap().train_loss;
        assert!(result.final_train_loss < first);
    }

    #[test]
    fn tiled_runner_end_to_end() {
        let mut c = cfg(EngineKind::Tiled);
        c.threads = 2;
        let mut r = Runner::new(c).unwrap();
        let result = r.run().unwrap();
        assert!(result.steps >= 8, "{}", result.steps);
        assert!(result.best_test_acc > 0.15, "acc {}", result.best_test_acc);
        assert!(result.metrics.steps_monotone());
    }

    #[test]
    fn snapshot_sink_fires_on_interval_and_final_flush() {
        use std::sync::Mutex;
        let mut c = cfg(EngineKind::Blocked);
        c.max_steps = Some(8);
        let mut r = Runner::new(c).unwrap();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink_seen = Arc::clone(&seen);
        r.set_snapshot_sink(
            3,
            Box::new(move |snap| {
                sink_seen.lock().unwrap().push(snap.version());
                Ok(())
            }),
        );
        let result = r.run().unwrap();
        assert_eq!(result.steps, 8);
        // steps 3 and 6 on the interval, plus the step-8 commit flush
        assert_eq!(*seen.lock().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.published(), 3);
    }

    #[test]
    fn engine_parse_accepts_tiled() {
        assert_eq!(EngineKind::parse("tiled").unwrap(), EngineKind::Tiled);
        assert!(EngineKind::parse("gpu").is_err());
    }

    #[test]
    fn envelope_gates_runs() {
        let mut c = cfg(EngineKind::Blocked);
        c.envelope = Some(MemoryEnvelope::mib(0.01));
        assert!(Runner::new(c).is_err());
        let mut c = cfg(EngineKind::Blocked);
        c.envelope = Some(MemoryEnvelope::mib(100.0));
        let r = Runner::new(c).unwrap();
        assert!(r.modeled_mib.unwrap() < 100.0);
    }

    #[test]
    fn dataset_model_mismatch_rejected() {
        let mut c = cfg(EngineKind::Blocked);
        c.dataset = "syn-cifar16".into(); // 768 elems vs mlp_mini's 64
        assert!(Runner::new(c).is_err());
    }

    #[test]
    fn artifact_names() {
        let c = RunConfig::default();
        assert_eq!(c.train_artifact(), "mlp_mini_proposed_adam_b64");
        let avail = vec![
            "mlp_mini_proposed_b64_eval".to_string(),
            "mlp_mini_standard_b64_eval".to_string(),
        ];
        assert_eq!(
            c.eval_artifact(&avail).unwrap(),
            "mlp_mini_proposed_b64_eval"
        );
    }
}
