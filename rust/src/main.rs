//! bnn-edge launcher (CLI filled in by the coordinator module).
fn main() -> anyhow::Result<()> {
    bnn_edge::coordinator::cli_main()
}
