//! Fault-tolerant federated edge-fleet coordinator.
//!
//! The paper motivates on-device training via federated learning
//! (Sec. 1, refs [13], [14]); this module makes that concrete — and
//! production-shaped.  A leader distributes weight snapshots to a
//! fleet of edge workers, each of which trains the *proposed*
//! low-memory step on its private shard and uplinks a **bit-packed
//! sign update** — 1 bit per weight, the communication-side twin of
//! the paper's binary weight gradients (and of signSGD [9], which the
//! paper cites as the gradient-quantization precedent).
//!
//! Aggregation is a **staleness-weighted majority sign vote** with a
//! fixed step size:
//!
//! ```text
//! w ← clip(w + η_fed · sign(Σ_k ω_k · sign(Δw_k)))   once votes ≥ quorum
//! ```
//!
//! where `ω_k` is an integer discount for admitted-but-stale updates
//! ([`vote_weight`]).  The tally itself is word-level — stack, word
//! transpose, SIMD popcount ([`tally`]) — so a 10³-worker round
//! aggregates in milliseconds rather than a per-bit scalar sweep.
//!
//! The moving parts:
//! - [`fault`] — deterministic seeded chaos: crash/rejoin, stall,
//!   dropped uplinks, corrupt updates ([`FaultPlan`]);
//! - [`async_round`] — bounded-staleness admission, quorum commits,
//!   straggler backoff, quarantine ([`FleetState`]);
//! - [`tally`] — word-level weighted vote counts, associative across
//!   shard leaders ([`LayerVotes`]);
//! - [`sim`] — the virtual-time 10³-worker fleet with shard-leader
//!   threads ([`SimFleet`]);
//! - [`leader`] / [`worker`] — the threaded small-fleet transport and
//!   the round loop both transports share.
//!
//! Invariants (tested here, in rust/tests/federated_chaos.rs, and
//! property-tested in rust/tests/property.rs):
//! - every shard is routed to exactly one worker;
//! - aggregation is permutation-invariant in worker order, and the
//!   word-level tally is bit-exact vs the scalar reference;
//! - two-level (shard leader → root) tallies are bit-identical to
//!   flat ones — counts are associative, sign-majorities are not;
//! - malformed updates are rejected whole on arrival (every layer
//!   validated) and their sender quarantined; rounds commit
//!   all-or-nothing;
//! - below quorum the round stalls (bounded retries), committed
//!   rounds never roll back, weights stay in the unit box;
//! - a seeded hostile chaos schedule replays bit-identically.

pub mod async_round;
pub mod fault;
mod leader;
pub mod sim;
pub mod tally;
mod worker;

pub use async_round::{vote_weight, Admission, AsyncConfig, FleetState, Health, RoundStat};
pub use fault::{Fault, FaultPlan, FaultRates, FaultState};
pub use leader::{CommitSink, FedConfig, FedResult, FleetMode, Leader};
pub use sim::{ShardReport, SimFleet};
pub use tally::{
    count_votes_scalar, count_votes_sharded, count_votes_words, sign_vote_words, LayerVotes,
};
pub use worker::{SignUpdate, WorkerHandle};

use anyhow::Result;

use crate::util::cli::Args;

/// `bnn-edge federated` entrypoint.
pub fn cli(args: &Args) -> Result<()> {
    let workers = args.usize_or("workers", 4)?;
    let mut async_cfg = AsyncConfig::majority(workers);
    async_cfg.quorum = args.usize_or("quorum", async_cfg.quorum)?;
    async_cfg.max_staleness = args.usize_or("max-staleness", async_cfg.max_staleness)?;
    async_cfg.deadline_ms = args.usize_or("deadline-ms", async_cfg.deadline_ms as usize)? as u64;
    async_cfg.retry_budget = args.usize_or("retry-budget", async_cfg.retry_budget)?;
    async_cfg.backoff_base = args.usize_or("backoff", async_cfg.backoff_base)?;
    let seed = args.usize_or("seed", 42)? as u64;
    let chaos_seed = args.usize_or("chaos-seed", seed as usize)? as u64;
    let plan = FaultPlan::parse(&args.str_or("chaos", "none"), chaos_seed)?;
    let sim = args.bool("sim") || workers > FedConfig::SIM_THRESHOLD;
    let mode = if sim {
        FleetMode::Sim {
            shards: args.usize_or("shards", 8)?,
            noise_log2: args.usize_or("noise-log2", 4)? as u32,
        }
    } else {
        FleetMode::Threads
    };
    let cfg = FedConfig {
        workers,
        rounds: args.usize_or("rounds", 5)?,
        local_steps: args.usize_or("local-steps", 8)?,
        batch: args.usize_or("batch", 32)?,
        model: args.str_or("model", "mlp_mini"),
        dataset: args.str_or("dataset", "syn-mnist64"),
        lr: args.f64_or("lr", 0.002)? as f32,
        fed_lr: args.f64_or("fed-lr", 0.01)? as f32,
        seed,
        samples_per_worker: args.usize_or("samples-per-worker", 256)?,
        async_cfg,
        plan,
        mode,
        tally_threads: args.usize_or("threads", 0)?,
    };
    let mut leader = Leader::new(cfg)?;
    let result = leader.run()?;
    for s in &result.round_stats {
        println!(
            "round {:>3}: {} admitted={} (fresh {} stale {}) timeouts={} quarantined={} retries={} loss={:.3} {:.1}ms",
            s.round,
            if s.committed { "commit" } else { "STALL " },
            s.admitted,
            s.fresh,
            s.stale,
            s.timeouts,
            s.quarantined,
            s.retries,
            s.mean_loss,
            s.commit_ms,
        );
    }
    println!("{}", result.summary());
    Ok(())
}

/// Majority sign vote over packed updates: returns ±1 per weight (0
/// on exact tie).  Scalar reference path — [`sign_vote_words`] is the
/// word-level twin, asserted bit-exact against this.  Pure function →
/// trivially permutation-invariant; the tests pin that down anyway.
pub fn sign_vote(updates: &[&crate::bitops::BitMatrix]) -> Vec<i8> {
    let weights = vec![1u32; updates.len()];
    count_votes_scalar(updates, &weights).signs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::BitMatrix;
    use crate::util::rng::Pcg32;

    fn pack(v: &[f32], rows: usize, cols: usize) -> BitMatrix {
        BitMatrix::pack(rows, cols, v)
    }

    #[test]
    fn sign_vote_majority() {
        let a = pack(&[1.0, 1.0, -1.0, -1.0], 2, 2);
        let b = pack(&[1.0, -1.0, -1.0, 1.0], 2, 2);
        let c = pack(&[1.0, -1.0, -1.0, -1.0], 2, 2);
        let v = sign_vote(&[&a, &b, &c]);
        assert_eq!(v, vec![1, -1, -1, -1]);
    }

    #[test]
    fn sign_vote_tie_is_zero() {
        let a = pack(&[1.0, -1.0], 1, 2);
        let b = pack(&[-1.0, 1.0], 1, 2);
        assert_eq!(sign_vote(&[&a, &b]), vec![0, 0]);
    }

    #[test]
    fn sign_vote_permutation_invariant() {
        let mut g = Pcg32::new(1);
        let ms: Vec<BitMatrix> = (0..5)
            .map(|_| pack(&g.normal_vec(24), 4, 6))
            .collect();
        let refs: Vec<&BitMatrix> = ms.iter().collect();
        let base = sign_vote(&refs);
        let perm: Vec<&BitMatrix> = vec![&ms[3], &ms[0], &ms[4], &ms[2], &ms[1]];
        assert_eq!(sign_vote(&perm), base);
    }
}
