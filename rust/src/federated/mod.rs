//! Federated edge-fleet coordinator.
//!
//! The paper motivates on-device training via federated learning
//! (Sec. 1, refs [13], [14]); this module makes that concrete: a
//! leader distributes weight snapshots to a fleet of simulated edge
//! workers (threads), each of which trains the *proposed* low-memory
//! step on its private shard and sends back a **bit-packed sign
//! update** — 1 bit per weight, the communication-side twin of the
//! paper's binary weight gradients (and of signSGD [9], which the
//! paper cites as the gradient-quantization precedent).
//!
//! Aggregation is **majority sign vote** with a fixed step size:
//!
//! ```text
//! w ← clip(w − η_fed · sign(Σ_k sign(Δw_k)))   where votes ≥ quorum
//! ```
//!
//! Invariants (tested here + property-tested in rust/tests/):
//! - every shard is routed to exactly one worker per round;
//! - aggregation is permutation-invariant in worker order;
//! - worker dropout below quorum stalls the round rather than
//!   corrupting state; committed rounds never roll back.

mod leader;
mod worker;

pub use leader::{FedConfig, FedResult, Leader};
pub use worker::{SignUpdate, WorkerHandle};

use anyhow::Result;

use crate::util::cli::Args;

/// `bnn-edge federated` entrypoint.
pub fn cli(args: &Args) -> Result<()> {
    let cfg = FedConfig {
        workers: args.usize_or("workers", 4)?,
        rounds: args.usize_or("rounds", 5)?,
        local_steps: args.usize_or("local-steps", 8)?,
        batch: args.usize_or("batch", 32)?,
        model: args.str_or("model", "mlp_mini"),
        dataset: args.str_or("dataset", "syn-mnist64"),
        lr: args.f64_or("lr", 0.002)? as f32,
        fed_lr: args.f64_or("fed-lr", 0.01)? as f32,
        seed: args.usize_or("seed", 42)? as u64,
        samples_per_worker: args.usize_or("samples-per-worker", 256)?,
        drop_worker: None,
    };
    let mut leader = Leader::new(cfg)?;
    let result = leader.run()?;
    println!("{}", result.summary());
    Ok(())
}

/// Majority sign vote over packed updates: returns ±1 per weight (0 on
/// exact tie).  Pure function → trivially permutation-invariant; the
/// tests pin that down anyway.
pub fn sign_vote(updates: &[&crate::bitops::BitMatrix]) -> Vec<i8> {
    assert!(!updates.is_empty());
    let rows = updates[0].rows;
    let cols = updates[0].cols;
    let n = rows * cols;
    let mut tally = vec![0i32; n];
    for u in updates {
        assert_eq!(u.rows, rows);
        assert_eq!(u.cols, cols);
        for r in 0..rows {
            for c in 0..cols {
                tally[r * cols + c] += if u.get(r, c) > 0.0 { 1 } else { -1 };
            }
        }
    }
    tally
        .into_iter()
        .map(|t| match t.cmp(&0) {
            std::cmp::Ordering::Greater => 1,
            std::cmp::Ordering::Less => -1,
            std::cmp::Ordering::Equal => 0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitops::BitMatrix;
    use crate::util::rng::Pcg32;

    fn pack(v: &[f32], rows: usize, cols: usize) -> BitMatrix {
        BitMatrix::pack(rows, cols, v)
    }

    #[test]
    fn sign_vote_majority() {
        let a = pack(&[1.0, 1.0, -1.0, -1.0], 2, 2);
        let b = pack(&[1.0, -1.0, -1.0, 1.0], 2, 2);
        let c = pack(&[1.0, -1.0, -1.0, -1.0], 2, 2);
        let v = sign_vote(&[&a, &b, &c]);
        assert_eq!(v, vec![1, -1, -1, -1]);
    }

    #[test]
    fn sign_vote_tie_is_zero() {
        let a = pack(&[1.0, -1.0], 1, 2);
        let b = pack(&[-1.0, 1.0], 1, 2);
        assert_eq!(sign_vote(&[&a, &b]), vec![0, 0]);
    }

    #[test]
    fn sign_vote_permutation_invariant() {
        let mut g = Pcg32::new(1);
        let ms: Vec<BitMatrix> = (0..5)
            .map(|_| pack(&g.normal_vec(24), 4, 6))
            .collect();
        let refs: Vec<&BitMatrix> = ms.iter().collect();
        let base = sign_vote(&refs);
        let perm: Vec<&BitMatrix> = vec![&ms[3], &ms[0], &ms[4], &ms[2], &ms[1]];
        assert_eq!(sign_vote(&perm), base);
    }
}
