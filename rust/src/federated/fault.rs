//! Chaos harness: deterministic, seeded fault injection for the fleet.
//!
//! A [`FaultPlan`] is a *pure function* `(worker, round) → Fault` —
//! no mutable schedule state, so two runs with the same seed inject
//! byte-identical fault sequences regardless of thread interleaving
//! (the determinism-under-chaos acceptance test leans on this).  The
//! stateful part — a crash keeps a worker offline for the whole
//! outage window, not just the round the dice landed on — lives in
//! the per-worker [`FaultState`] each consumer owns.
//!
//! Faults model the edge-fleet failure modes the paper's setting
//! implies (devices that flake, lag, and rejoin):
//!
//! - **Crash** — the device goes dark for `outage` rounds, then
//!   rejoins (the leader sees timeouts, marks it a straggler, and
//!   re-admits it with backoff once it answers again).
//! - **Stall** — the update arrives late: `rounds` rounds late in the
//!   simulated fleet (virtual time), after a `millis` sleep in the
//!   threaded fleet (wall time).  Stale-but-admissible updates are
//!   vote-weight-discounted by the leader.
//! - **DropUplink** — local training happens but the update vanishes.
//! - **Corrupt** — the update is malformed (truncated layer shape);
//!   the leader must detect it on arrival and quarantine the sender
//!   without poisoning the round.
//!
//! The leader must survive *every* schedule without corrupting
//! committed state; `rust/tests/federated_chaos.rs` sweeps the matrix.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::rng::Pcg32;

/// One injected fault (or none) for a (worker, round) cell.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    None,
    /// Go dark now, rejoin after `outage` rounds.
    Crash { outage: usize },
    /// Deliver the update late: `rounds` rounds of virtual lateness
    /// (sim fleet) / a `millis` sleep before the uplink (thread fleet).
    Stall { rounds: usize, millis: u64 },
    /// Train, then never send.
    DropUplink,
    /// Send a malformed (truncated-layer) update.
    Corrupt,
    /// Derived, never scheduled directly: inside a crash outage.
    Offline,
}

/// Per-(worker, round) fault probabilities of a seeded plan.
#[derive(Clone, Copy, Debug)]
pub struct FaultRates {
    pub crash: f32,
    /// Rounds a crashed worker stays dark before rejoining.
    pub crash_outage: usize,
    pub stall: f32,
    /// Virtual lateness of a stalled update (sim fleet).
    pub stall_rounds: usize,
    /// Wall-clock lateness of a stalled uplink (thread fleet).
    pub stall_millis: u64,
    pub drop: f32,
    pub corrupt: f32,
}

impl FaultRates {
    /// The hostile mix the chaos smoke + acceptance tests run: all
    /// five failure modes live at once, frequent enough that a 20
    /// round × dozen worker run sees each several times.
    pub fn hostile() -> FaultRates {
        FaultRates {
            crash: 0.03,
            crash_outage: 3,
            stall: 0.08,
            stall_rounds: 1,
            stall_millis: 25,
            drop: 0.05,
            corrupt: 0.015,
        }
    }
}

/// Deterministic fault schedule. See module docs.
#[derive(Clone, Debug)]
pub enum FaultPlan {
    /// No faults ever (the clean schedule).
    None,
    /// Seeded i.i.d. draws per (worker, round) cell.
    Seeded { seed: u64, rates: FaultRates },
    /// Explicit (worker, round) → fault script (targeted tests).
    Scripted(BTreeMap<(usize, usize), Fault>),
}

impl FaultPlan {
    /// Hostile seeded plan (see [`FaultRates::hostile`]).
    pub fn hostile(seed: u64) -> FaultPlan {
        FaultPlan::Seeded { seed, rates: FaultRates::hostile() }
    }

    /// Build from a script of (worker, round, fault) triples.
    pub fn scripted<I: IntoIterator<Item = (usize, usize, Fault)>>(it: I) -> FaultPlan {
        FaultPlan::Scripted(it.into_iter().map(|(w, r, f)| ((w, r), f)).collect())
    }

    /// CLI spec: `none` | `hostile` (seeded from `--chaos-seed`).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        match spec {
            "none" => Ok(FaultPlan::None),
            "hostile" => Ok(FaultPlan::hostile(seed)),
            other => bail!("unknown chaos spec '{other}' (none|hostile)"),
        }
    }

    /// The scheduled fault for one (worker, round) cell — pure; crash
    /// windows are applied by [`FaultState::effective`].
    pub fn action(&self, worker: usize, round: usize) -> Fault {
        match self {
            FaultPlan::None => Fault::None,
            FaultPlan::Scripted(map) => {
                map.get(&(worker, round)).copied().unwrap_or(Fault::None)
            }
            FaultPlan::Seeded { seed, rates } => {
                // One independent PCG stream per cell: the draw is a
                // pure function of (seed, worker, round), so arrival
                // order / thread interleaving cannot perturb it.
                let stream = ((worker as u64) << 32) | round as u64;
                let mut g = Pcg32::with_stream(seed ^ 0xC4A0_5FA1, stream);
                let p = g.next_f32();
                let mut lo = 0.0f32;
                if p < lo + rates.crash {
                    return Fault::Crash { outage: rates.crash_outage.max(1) };
                }
                lo += rates.crash;
                if p < lo + rates.stall {
                    return Fault::Stall {
                        rounds: rates.stall_rounds,
                        millis: rates.stall_millis,
                    };
                }
                lo += rates.stall;
                if p < lo + rates.drop {
                    return Fault::DropUplink;
                }
                lo += rates.drop;
                if p < lo + rates.corrupt {
                    return Fault::Corrupt;
                }
                Fault::None
            }
        }
    }
}

/// Per-worker fault bookkeeping: turns the pure schedule into
/// effective faults by holding crash outages open across rounds.
#[derive(Clone, Debug, Default)]
pub struct FaultState {
    /// Offline while `round < offline_until`.
    offline_until: usize,
}

impl FaultState {
    /// Effective fault for this worker at `round`: [`Fault::Offline`]
    /// inside a crash window (including the crash round itself),
    /// otherwise the scheduled action.
    pub fn effective(&mut self, plan: &FaultPlan, worker: usize, round: usize) -> Fault {
        if round < self.offline_until {
            return Fault::Offline;
        }
        match plan.action(worker, round) {
            Fault::Crash { outage } => {
                self.offline_until = round + outage.max(1);
                Fault::Offline
            }
            f => f,
        }
    }

    pub fn is_offline(&self, round: usize) -> bool {
        round < self.offline_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_never_faults() {
        let plan = FaultPlan::None;
        let mut st = FaultState::default();
        for w in 0..8 {
            for r in 0..50 {
                assert_eq!(st.effective(&plan, w, r), Fault::None);
            }
        }
    }

    #[test]
    fn seeded_plan_is_deterministic_and_order_free() {
        let plan = FaultPlan::hostile(99);
        // same cell, queried in any order, any number of times
        let probe = plan.action(3, 17);
        for _ in 0..3 {
            assert_eq!(plan.action(3, 17), probe);
        }
        let forward: Vec<Fault> =
            (0..40).flat_map(|r| (0..6).map(move |w| (w, r))).map(|(w, r)| plan.action(w, r)).collect();
        let backward: Vec<Fault> = (0..40)
            .rev()
            .flat_map(|r| (0..6).rev().map(move |w| (w, r)))
            .map(|(w, r)| plan.action(w, r))
            .collect();
        let mut back_sorted = backward;
        back_sorted.reverse();
        assert_eq!(forward, back_sorted);
    }

    #[test]
    fn hostile_plan_hits_every_fault_kind() {
        let plan = FaultPlan::hostile(7);
        let mut seen = [false; 4]; // crash, stall, drop, corrupt
        for w in 0..24 {
            for r in 0..40 {
                match plan.action(w, r) {
                    Fault::Crash { .. } => seen[0] = true,
                    Fault::Stall { .. } => seen[1] = true,
                    Fault::DropUplink => seen[2] = true,
                    Fault::Corrupt => seen[3] = true,
                    _ => {}
                }
            }
        }
        assert_eq!(seen, [true; 4], "hostile mix must exercise all faults");
    }

    #[test]
    fn crash_window_holds_then_rejoins() {
        let plan = FaultPlan::scripted([(0, 2, Fault::Crash { outage: 3 })]);
        let mut st = FaultState::default();
        assert_eq!(st.effective(&plan, 0, 0), Fault::None);
        assert_eq!(st.effective(&plan, 0, 1), Fault::None);
        assert_eq!(st.effective(&plan, 0, 2), Fault::Offline); // crash round
        assert_eq!(st.effective(&plan, 0, 3), Fault::Offline);
        assert_eq!(st.effective(&plan, 0, 4), Fault::Offline);
        assert_eq!(st.effective(&plan, 0, 5), Fault::None); // rejoined
        assert!(!st.is_offline(5));
    }

    #[test]
    fn parse_specs() {
        assert!(matches!(FaultPlan::parse("none", 1).unwrap(), FaultPlan::None));
        assert!(matches!(FaultPlan::parse("hostile", 1).unwrap(), FaultPlan::Seeded { .. }));
        assert!(FaultPlan::parse("meteor", 1).is_err());
    }
}
