//! Simulated fleet: 10³+ workers without 10³ engines or threads.
//!
//! A thousand real `ProposedTrainer`s would blow the memory budget
//! and measure thread-scheduler noise, not aggregation.  What the
//! tentpole actually needs at that scale is (a) a realistic *vote
//! distribution* per round and (b) the real admission / tally /
//! commit path under chaos.  So the sim fleet keeps **one** template
//! trainer (a real engine training on a representative shard) and
//! derives each worker's packed sign update from the template by
//! flipping a seeded pseudo-random subset of bits — per-(worker,
//! round) streams, so updates are decorrelated like real non-IID
//! shards but reproducible bit-for-bit.
//!
//! Topology is two-level: workers are partitioned across **shard
//! leaders** (one thread each per round), every shard leader owns the
//! fault/health bookkeeping for its own slice (a worker belongs to
//! exactly one shard, so straggler backoff and quarantine are
//! shard-local facts) and tallies its admitted updates word-level
//! into [`LayerVotes`].  Counts are associative, so the root merges
//! shard reports — in shard order — and gets a tally bit-identical to
//! a flat one.
//!
//! **Virtual time.**  The sim fleet never sleeps and never reads the
//! clock: a stalled update is delivered `d` rounds later out of a
//! small template ring buffer, a crashed worker is absent for its
//! outage window, a timed-out worker backs off in round units.  Two
//! runs with the same seeds are bit-identical — which is what lets
//! the chaos acceptance test diff final weights across runs.

use std::collections::VecDeque;
use std::sync::mpsc;

use anyhow::{bail, Result};

use super::async_round::{Admission, AsyncConfig, FleetState};
use super::fault::{Fault, FaultPlan, FaultState};
use super::tally::{count_votes_words, LayerVotes};
use crate::bitops::{BitMatrix, Pool};
use crate::data::build;
use crate::models::Graph;
use crate::naive::{Accel, ProposedTrainer, StepEngine};
use crate::util::rng::Pcg32;

const NOISE_SALT: u64 = 0x5EED_B175;

/// What one shard leader reports to the root for one round: partial
/// word-level vote counts over its admitted updates, plus the
/// per-worker events the root folds into `RoundStat`.
pub struct ShardReport {
    pub shard: usize,
    /// Per-layer weighted vote counts (admitted updates only).
    pub votes: Vec<LayerVotes>,
    pub admitted: usize,
    pub fresh: usize,
    pub stale: usize,
    pub timeouts: usize,
    pub quarantined: usize,
    pub uplink_bytes: usize,
    /// Sum of admitted updates' local losses (template loss of the
    /// round each update was trained against).
    pub loss_sum: f32,
}

/// A stalled update waiting in virtual time: reconstructed from the
/// template ring at delivery, so nothing but three indices is stored.
struct Pending {
    deliver_round: usize,
    update_round: usize,
    local_w: usize,
}

/// One shard leader's persistent state (threads are per-round scoped;
/// state lives here between rounds).
struct Shard {
    id: usize,
    /// Global id of this shard's first worker.
    base: usize,
    fleet: FleetState,
    faults: Vec<FaultState>,
    pending: Vec<Pending>,
}

pub struct SimFleet {
    pub workers: usize,
    shards_n: usize,
    noise_log2: u32,
    seed: u64,
    plan: FaultPlan,
    shards: Vec<Shard>,
    engine: ProposedTrainer,
    shard_x: Vec<f32>,
    shard_y: Vec<usize>,
    batch: usize,
    /// Template ring: (round, per-layer packed deltas, mean loss).
    templates: VecDeque<(usize, Vec<BitMatrix>, f32)>,
    keep_templates: usize,
    bytes_per_update: usize,
}

impl SimFleet {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        graph: &Graph,
        batch: usize,
        dataset: &str,
        samples: usize,
        seed: u64,
        workers: usize,
        shards_n: usize,
        noise_log2: u32,
        async_cfg: AsyncConfig,
        plan: FaultPlan,
        n_weights: usize,
        n_layers: usize,
    ) -> Result<SimFleet> {
        if workers == 0 {
            bail!("need at least one worker");
        }
        let shards_n = shards_n.clamp(1, workers);
        let ds = build(dataset, samples.max(batch), 0, seed)?;
        let engine = ProposedTrainer::new(graph, batch, "adam", Accel::Blocked, seed ^ 0x9e37)?;
        let chunk = workers.div_ceil(shards_n);
        let mut shards = Vec::new();
        let mut base = 0usize;
        let mut sid = 0usize;
        while base < workers {
            let n = chunk.min(workers - base);
            // shard-local admission bookkeeping: quorum is a *global*
            // predicate, so shard fleets run with quorum 1 and the
            // root sums admitted counts
            let mut local = async_cfg;
            local.quorum = 1;
            shards.push(Shard {
                id: sid,
                base,
                fleet: FleetState::new(local, n)?,
                faults: vec![FaultState::default(); n],
                pending: Vec::new(),
            });
            base += n;
            sid += 1;
        }
        // stalled updates older than the ring are inadmissible anyway
        let keep_templates = async_cfg.max_staleness.max(2) + 2;
        Ok(SimFleet {
            workers,
            shards_n,
            noise_log2,
            seed,
            plan,
            shards,
            engine,
            shard_x: ds.train_x,
            shard_y: ds.train_y,
            batch,
            templates: VecDeque::new(),
            keep_templates,
            bytes_per_update: n_weights / 8 + 16 * n_layers,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards_n
    }

    /// Workers that could still contribute fleet-wide.
    pub fn reachable(&self) -> usize {
        self.shards.iter().map(|s| s.fleet.reachable()).sum()
    }

    /// Run one virtual round: train the template once, then fan the
    /// fleet out across shard-leader threads.  Reports come back in
    /// shard order (sorted, so thread finish order cannot perturb the
    /// merge — determinism is by construction, tallies are integer).
    pub fn round(
        &mut self,
        round: usize,
        weights: &[Vec<f32>],
        local_steps: usize,
        lr: f32,
    ) -> Result<Vec<ShardReport>> {
        // 1. template update: one real engine, real local steps
        self.engine.load_weights(weights)?;
        let k = self.shard_x.len() / self.shard_y.len().max(1);
        let n_batches = (self.shard_y.len() / self.batch).max(1);
        let mut loss_sum = 0.0f32;
        for s in 0..local_steps {
            let bi = (round * local_steps + s) % n_batches;
            let x = &self.shard_x[bi * self.batch * k..(bi + 1) * self.batch * k];
            let y = &self.shard_y[bi * self.batch..(bi + 1) * self.batch];
            let (l, _) = self.engine.train_step(x, y, lr)?;
            loss_sum += l;
        }
        let now = self.engine.weights_snapshot();
        let deltas: Vec<BitMatrix> = now
            .iter()
            .zip(weights)
            .map(|(new, old)| {
                let d: Vec<f32> = new.iter().zip(old).map(|(a, b)| a - b).collect();
                BitMatrix::pack(1, d.len(), &d)
            })
            .collect();
        self.templates.push_back((round, deltas, loss_sum / local_steps.max(1) as f32));
        while self.templates.len() > self.keep_templates {
            self.templates.pop_front();
        }

        // 2. shard leaders, one scoped thread each
        let templates = &self.templates;
        let plan = &self.plan;
        let (seed, noise_log2, bytes) = (self.seed, self.noise_log2, self.bytes_per_update);
        let (tx, rx) = mpsc::channel::<ShardReport>();
        std::thread::scope(|scope| {
            for sh in self.shards.iter_mut() {
                let tx = tx.clone();
                scope.spawn(move || {
                    let rep =
                        shard_round(sh, round, templates, plan, seed, noise_log2, bytes);
                    let _ = tx.send(rep);
                });
            }
        });
        drop(tx);
        let mut reports: Vec<ShardReport> = rx.iter().collect();
        reports.sort_by_key(|r| r.shard);
        Ok(reports)
    }
}

/// One shard leader's round: deliver virtually-late updates, roll the
/// fault dice for every broadcast-to worker, tally admitted updates
/// word-level.
fn shard_round(
    sh: &mut Shard,
    round: usize,
    templates: &VecDeque<(usize, Vec<BitMatrix>, f32)>,
    plan: &FaultPlan,
    seed: u64,
    noise_log2: u32,
    bytes_per_update: usize,
) -> ShardReport {
    let (_, tpl, _) = templates.back().expect("current template");
    let mut rep = ShardReport {
        shard: sh.id,
        votes: tpl.iter().map(|d| LayerVotes::zeros(d.rows, d.cols)).collect(),
        admitted: 0,
        fresh: 0,
        stale: 0,
        timeouts: 0,
        quarantined: 0,
        uplink_bytes: 0,
        loss_sum: 0.0,
    };
    // (weight, update) pairs admitted this round; tallied in one
    // word-level sweep at the end
    let mut admitted: Vec<(u32, Vec<BitMatrix>)> = Vec::new();
    // workers that answered fresh this round (a fresh update
    // supersedes a same-round stale delivery from the same worker —
    // the threaded leader's dedupe-keep-freshest rule)
    let mut fresh_set: Vec<bool> = vec![false; sh.faults.len()];

    // snapshot the broadcast set *before* any delivery can flip a
    // straggler back to Active mid-round
    let bset = sh.fleet.broadcast_set(round);

    // a) this round's broadcast set rolls the fault dice
    for local_w in bset {
        let gw = sh.base + local_w;
        match sh.faults[local_w].effective(plan, gw, round) {
            Fault::Offline => {
                sh.fleet.on_timeout(local_w, round);
                rep.timeouts += 1;
            }
            Fault::DropUplink => {
                // trained, uplink vanished: leader-side it is a timeout
                sh.fleet.on_timeout(local_w, round);
                rep.timeouts += 1;
            }
            Fault::Corrupt => {
                // malformed update detected on arrival: sender is
                // quarantined, its votes never reach the tally
                sh.fleet.quarantine(local_w);
                rep.quarantined += 1;
            }
            Fault::Stall { rounds, .. } => {
                sh.fleet.on_timeout(local_w, round);
                rep.timeouts += 1;
                sh.pending.push(Pending {
                    deliver_round: round + rounds.max(1),
                    update_round: round,
                    local_w,
                });
            }
            Fault::None | Fault::Crash { .. } => {
                // (Crash is rewritten to Offline by FaultState)
                if let Admission::Admitted { weight, .. } =
                    sh.fleet.admit(local_w, round, round)
                {
                    sh.fleet.on_uplink_ok(local_w);
                    let (_, tpl, loss) = templates.back().unwrap();
                    fresh_set[local_w] = true;
                    rep.admitted += 1;
                    rep.fresh += 1;
                    rep.uplink_bytes += bytes_per_update;
                    rep.loss_sum += loss;
                    admitted.push((weight, synth_update(tpl, seed, gw, round, noise_log2)));
                }
            }
        }
    }

    // b) stalled updates whose virtual lateness elapsed
    let due: Vec<Pending> = {
        let mut keep = Vec::new();
        let mut due = Vec::new();
        for p in sh.pending.drain(..) {
            if p.deliver_round <= round {
                due.push(p);
            } else {
                keep.push(p);
            }
        }
        sh.pending = keep;
        due
    };
    for p in due {
        if fresh_set[p.local_w] {
            continue; // this worker already answered fresh this round
        }
        let Some((_, tpl, loss)) =
            templates.iter().find(|(r, _, _)| *r == p.update_round)
        else {
            continue; // template evicted ⇒ older than max_staleness anyway
        };
        if let Admission::Admitted { weight, .. } =
            sh.fleet.admit(p.local_w, round, p.update_round)
        {
            sh.fleet.on_uplink_ok(p.local_w);
            rep.admitted += 1;
            rep.stale += 1;
            rep.uplink_bytes += bytes_per_update;
            rep.loss_sum += loss;
            admitted.push((
                weight,
                synth_update(tpl, seed, sh.base + p.local_w, p.update_round, noise_log2),
            ));
        }
    }

    // c) word-level partial tally (serial pool: parallelism is the
    // shard threads themselves; nested pools would inline anyway)
    if !admitted.is_empty() {
        let pool = Pool::serial();
        for (li, votes) in rep.votes.iter_mut().enumerate() {
            let refs: Vec<&BitMatrix> = admitted.iter().map(|(_, u)| &u[li]).collect();
            let ws: Vec<u32> = admitted.iter().map(|(w, _)| *w).collect();
            *votes = count_votes_words(&refs, &ws, &pool);
        }
    }
    rep
}

/// Synthesize worker `gw`'s packed update for `round`: the template's
/// bits with a seeded pseudo-random subset flipped (flip probability
/// 2^-noise_log2 per bit).  Pure in (seed, gw, round) — replayable —
/// and the flip mask is truncated to each row's live bits so the
/// packed zero-tail invariant survives.
fn synth_update(
    template: &[BitMatrix],
    seed: u64,
    gw: usize,
    round: usize,
    noise_log2: u32,
) -> Vec<BitMatrix> {
    template
        .iter()
        .enumerate()
        .map(|(li, t)| {
            let mut u = t.clone();
            let stream = ((gw as u64) << 32) | round as u64;
            let mut g = Pcg32::with_stream(seed ^ NOISE_SALT ^ (li as u64) << 1, stream);
            let tail = t.cols % 64;
            let tail_mask: u64 = if tail == 0 { !0 } else { (1u64 << tail) - 1 };
            for r in 0..t.rows {
                let row = &mut u.data[r * t.words_per_row..(r + 1) * t.words_per_row];
                for (wi, w) in row.iter_mut().enumerate() {
                    // AND of k uniform words ⇒ each bit set w.p. 2^-k
                    let mut m = !0u64;
                    for _ in 0..noise_log2.max(1) {
                        m &= g.next_u64();
                    }
                    if wi + 1 == t.words_per_row {
                        m &= tail_mask;
                    }
                    *w ^= m;
                }
            }
            u
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    fn mini_fleet(workers: usize, shards: usize, plan: FaultPlan) -> SimFleet {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let n_weights: usize = graph
            .nodes
            .iter()
            .filter(|n| n.is_matmul())
            .map(|n| n.w_elems + n.channels)
            .sum();
        let n_layers = 2 * graph.nodes.iter().filter(|n| n.is_matmul()).count();
        SimFleet::new(
            &graph,
            16,
            "syn-mnist64",
            64,
            5,
            workers,
            shards,
            4,
            AsyncConfig::majority(workers),
            plan,
            n_weights,
            n_layers,
        )
        .unwrap()
    }

    fn init_weights() -> Vec<Vec<f32>> {
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let mut rng = Pcg32::new(5);
        let mut ws = Vec::new();
        for node in graph.nodes.iter().filter(|n| n.is_matmul()) {
            ws.push(rng.glorot(node.fan_in, node.channels, node.w_elems));
            ws.push(vec![0.0; node.channels]);
        }
        ws
    }

    #[test]
    fn clean_round_admits_everyone_fresh() {
        let mut fleet = mini_fleet(12, 3, FaultPlan::None);
        let w = init_weights();
        let reports = fleet.round(0, &w, 2, 0.002).unwrap();
        assert_eq!(reports.len(), 3);
        let admitted: usize = reports.iter().map(|r| r.admitted).sum();
        let fresh: usize = reports.iter().map(|r| r.fresh).sum();
        assert_eq!(admitted, 12);
        assert_eq!(fresh, 12);
        assert_eq!(fleet.reachable(), 12);
        // merged tally counts every worker at full fresh weight
        let mut total = reports[0].votes[0].clone();
        for r in &reports[1..] {
            total.merge(&r.votes[0]);
        }
        assert_eq!(total.total, 12 * 3); // 12 workers × weight (staleness 2 ⇒ fresh=3)
    }

    #[test]
    fn shard_split_is_merge_equivalent() {
        // same seed, same plan, different shard counts ⇒ identical
        // merged tallies (counts are associative)
        let w = init_weights();
        let mut flat: Option<Vec<LayerVotes>> = None;
        for shards in [1, 2, 4] {
            let mut fleet = mini_fleet(8, shards, FaultPlan::None);
            let reports = fleet.round(0, &w, 2, 0.002).unwrap();
            let mut merged = reports[0].votes.clone();
            for r in &reports[1..] {
                for (m, v) in merged.iter_mut().zip(&r.votes) {
                    m.merge(v);
                }
            }
            match &flat {
                None => flat = Some(merged),
                Some(f) => assert_eq!(&merged, f, "shards={shards}"),
            }
        }
    }

    #[test]
    fn stalled_update_arrives_next_round_discounted() {
        let plan = FaultPlan::scripted([(0, 0, Fault::Stall { rounds: 1, millis: 0 })]);
        let mut fleet = mini_fleet(4, 2, plan);
        let w = init_weights();
        let r0 = fleet.round(0, &w, 2, 0.002).unwrap();
        assert_eq!(r0.iter().map(|r| r.admitted).sum::<usize>(), 3);
        assert_eq!(r0.iter().map(|r| r.timeouts).sum::<usize>(), 1);
        let r1 = fleet.round(1, &w, 2, 0.002).unwrap();
        // worker 0's round-0 update delivers at round 1, stale
        assert_eq!(r1.iter().map(|r| r.stale).sum::<usize>(), 1);
        // staleness 1 of max 2 ⇒ weight 2, everyone else fresh at 3
        let total: u32 = r1.iter().map(|r| r.votes[0].total).sum();
        assert_eq!(total, 3 * 3 + 2);
    }

    #[test]
    fn corrupt_worker_is_quarantined_forever() {
        let plan = FaultPlan::scripted([(1, 0, Fault::Corrupt)]);
        let mut fleet = mini_fleet(4, 1, plan);
        let w = init_weights();
        let r0 = fleet.round(0, &w, 2, 0.002).unwrap();
        assert_eq!(r0[0].quarantined, 1);
        assert_eq!(r0[0].admitted, 3);
        assert_eq!(fleet.reachable(), 3);
        let r1 = fleet.round(1, &w, 2, 0.002).unwrap();
        assert_eq!(r1[0].admitted, 3, "quarantined worker stays out");
    }

    #[test]
    fn synth_updates_preserve_packed_tail_invariant() {
        let t = BitMatrix::pack(1, 70, &vec![1.0; 70]);
        let u = synth_update(&[t], 9, 3, 7, 1); // heavy noise
        let tail_mask = (1u64 << (70 - 64)) - 1;
        assert_eq!(u[0].data[1] & !tail_mask, 0, "tail bits must stay zero");
        // and the noise actually flips something at p=1/2
        let flipped: u32 =
            u[0].data.iter().zip(&BitMatrix::pack(1, 70, &vec![1.0; 70]).data).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert!(flipped > 10, "{flipped}");
    }

    #[test]
    fn same_seed_rounds_are_bit_identical() {
        let w = init_weights();
        let mut a = mini_fleet(16, 4, FaultPlan::hostile(3));
        let mut b = mini_fleet(16, 4, FaultPlan::hostile(3));
        for round in 0..3 {
            let ra = a.round(round, &w, 2, 0.002).unwrap();
            let rb = b.round(round, &w, 2, 0.002).unwrap();
            for (x, y) in ra.iter().zip(&rb) {
                assert_eq!(x.votes, y.votes, "round {round}");
                assert_eq!(x.admitted, y.admitted);
            }
        }
    }
}
