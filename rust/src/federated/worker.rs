//! Edge worker: a thread owning a private data shard and a proposed-
//! scheme engine.  Per round: load the leader's weights, run local
//! steps under the edge memory envelope, return a bit-packed sign
//! update (1 bit/weight uplink — the federated twin of Alg. 2's
//! binary weight gradients).

use std::sync::mpsc::{Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::bitops::BitMatrix;
use crate::models::Graph;
use crate::naive::{Accel, ProposedTrainer, StepEngine};

/// Leader → worker: weights + round meta.  `None` weights = shutdown.
pub enum RoundMsg {
    Work { round: usize, weights: Vec<Vec<f32>>, local_steps: usize, lr: f32 },
    Shutdown,
}

/// Worker → leader: packed sign(Δw) per layer + local metrics.
pub struct SignUpdate {
    pub worker_id: usize,
    pub round: usize,
    /// Per-layer packed signs of (w_local − w_start); rows×cols match
    /// the layer's logical (fan_in, fan_out).
    pub deltas: Vec<BitMatrix>,
    pub mean_loss: f32,
    pub samples_seen: usize,
}

pub struct WorkerHandle {
    pub id: usize,
    pub tx: Sender<RoundMsg>,
    pub join: JoinHandle<()>,
}

/// Spawn a worker thread.  `shard_x`/`shard_y` is its private data
/// (never leaves the thread — the privacy property federated learning
/// exists for).
#[allow(clippy::too_many_arguments)]
pub fn spawn_worker(
    id: usize,
    graph: Graph,
    batch: usize,
    shard_x: Vec<f32>,
    shard_y: Vec<usize>,
    seed: u64,
    tx_up: Sender<Result<SignUpdate, usize>>,
) -> WorkerHandle {
    let (tx, rx): (Sender<RoundMsg>, Receiver<RoundMsg>) = std::sync::mpsc::channel();
    let join = std::thread::spawn(move || {
        let mut engine = match ProposedTrainer::new(&graph, batch, "adam", Accel::Blocked, seed)
        {
            Ok(e) => e,
            Err(_) => {
                let _ = tx_up.send(Err(id));
                return;
            }
        };
        let k = shard_x.len() / shard_y.len().max(1);
        let n_batches = shard_y.len() / batch;
        while let Ok(msg) = rx.recv() {
            match msg {
                RoundMsg::Shutdown => break,
                RoundMsg::Work { round, weights, local_steps, lr } => {
                    if engine.load_weights(&weights).is_err() {
                        let _ = tx_up.send(Err(id));
                        continue;
                    }
                    let mut loss_sum = 0.0f32;
                    let mut seen = 0usize;
                    for s in 0..local_steps {
                        let bi = (round * local_steps + s) % n_batches.max(1);
                        let x = &shard_x[bi * batch * k..(bi + 1) * batch * k];
                        let y = &shard_y[bi * batch..(bi + 1) * batch];
                        match engine.train_step(x, y, lr) {
                            Ok((l, _)) => {
                                loss_sum += l;
                                seen += batch;
                            }
                            Err(_) => {
                                let _ = tx_up.send(Err(id));
                                continue;
                            }
                        }
                    }
                    // packed sign(Δw): 1 bit per weight uplink
                    let now = engine.weights_snapshot();
                    let deltas = now
                        .iter()
                        .zip(&weights)
                        .map(|(new, old)| {
                            let d: Vec<f32> =
                                new.iter().zip(old).map(|(a, b)| a - b).collect();
                            BitMatrix::pack(1, d.len(), &d)
                        })
                        .collect();
                    let _ = tx_up.send(Ok(SignUpdate {
                        worker_id: id,
                        round,
                        deltas,
                        mean_loss: loss_sum / local_steps.max(1) as f32,
                        samples_seen: seen,
                    }));
                }
            }
        }
    });
    WorkerHandle { id, tx, join }
}
