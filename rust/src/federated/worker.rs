//! Edge worker: a thread owning a private data shard and a proposed-
//! scheme engine.  Per round: load the leader's weights, run local
//! steps under the edge memory envelope, return a bit-packed sign
//! update (1 bit/weight uplink — the federated twin of Alg. 2's
//! binary weight gradients).
//!
//! Every worker consults the shared [`FaultPlan`] before acting on a
//! round, so the chaos harness injects failures *inside* the device,
//! exactly where real fleets fail: a crashed worker goes silent for
//! its outage window (the leader sees timeouts), a stalled worker
//! sleeps past the collection deadline (its update arrives a round
//! late and is staleness-discounted), a dropped uplink trains but
//! never sends, and a corrupt worker uplinks a malformed update the
//! leader must quarantine.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use super::fault::{Fault, FaultPlan, FaultState};
use crate::bitops::BitMatrix;
use crate::models::Graph;
use crate::naive::{Accel, ProposedTrainer, StepEngine};

/// Leader → worker: weights + round meta.
pub enum RoundMsg {
    Work { round: usize, weights: Arc<Vec<Vec<f32>>>, local_steps: usize, lr: f32 },
    Shutdown,
}

/// Worker → leader: packed sign(Δw) per layer + local metrics.
pub struct SignUpdate {
    pub worker_id: usize,
    /// The round this update was trained against (the leader admits
    /// it fresh, staleness-discounted, or not at all).
    pub round: usize,
    /// Per-layer packed signs of (w_local − w_start); rows×cols match
    /// the layer's logical (1, elems) snapshot shape.
    pub deltas: Vec<BitMatrix>,
    pub mean_loss: f32,
    pub samples_seen: usize,
}

impl SignUpdate {
    /// Uplink payload bytes: 1 bit/weight + a small per-layer header.
    pub fn uplink_bytes(&self) -> usize {
        self.deltas.iter().map(|d| d.heap_bytes() + 16).sum()
    }
}

pub struct WorkerHandle {
    pub id: usize,
    pub tx: Sender<RoundMsg>,
    pub join: JoinHandle<()>,
}

/// Spawn a worker thread.  `shard_x`/`shard_y` is its private data
/// (never leaves the thread — the privacy property federated learning
/// exists for).  `plan` is the chaos schedule the worker consults
/// each round.
#[allow(clippy::too_many_arguments)]
pub fn spawn_worker(
    id: usize,
    graph: Graph,
    batch: usize,
    shard_x: Vec<f32>,
    shard_y: Vec<usize>,
    seed: u64,
    tx_up: Sender<Result<SignUpdate, usize>>,
    plan: Arc<FaultPlan>,
) -> WorkerHandle {
    let (tx, rx): (Sender<RoundMsg>, Receiver<RoundMsg>) = std::sync::mpsc::channel();
    let join = std::thread::spawn(move || {
        let mut engine = match ProposedTrainer::new(&graph, batch, "adam", Accel::Blocked, seed)
        {
            Ok(e) => e,
            Err(_) => {
                let _ = tx_up.send(Err(id));
                return;
            }
        };
        let mut faults = FaultState::default();
        let k = shard_x.len() / shard_y.len().max(1);
        let n_batches = shard_y.len() / batch;
        while let Ok(msg) = rx.recv() {
            match msg {
                RoundMsg::Shutdown => break,
                RoundMsg::Work { round, weights, local_steps, lr } => {
                    let fault = faults.effective(&plan, id, round);
                    match fault {
                        // crashed: dark for the outage window — the
                        // leader times us out and backs us off
                        Fault::Offline => continue,
                        // malformed uplink: one mid-stack layer has a
                        // wrong shape, so a leader that only checks
                        // the first layer would be poisoned — the
                        // regression test pins that it is not
                        Fault::Corrupt => {
                            let bad = corrupt_update(id, round, &weights);
                            let _ = tx_up.send(Ok(bad));
                            continue;
                        }
                        _ => {}
                    }
                    if engine.load_weights(&weights).is_err() {
                        let _ = tx_up.send(Err(id));
                        continue;
                    }
                    let mut loss_sum = 0.0f32;
                    let mut seen = 0usize;
                    let mut failed = false;
                    for s in 0..local_steps {
                        let bi = (round * local_steps + s) % n_batches.max(1);
                        let x = &shard_x[bi * batch * k..(bi + 1) * batch * k];
                        let y = &shard_y[bi * batch..(bi + 1) * batch];
                        match engine.train_step(x, y, lr) {
                            Ok((l, _)) => {
                                loss_sum += l;
                                seen += batch;
                            }
                            Err(_) => {
                                let _ = tx_up.send(Err(id));
                                failed = true;
                                break;
                            }
                        }
                    }
                    if failed {
                        continue;
                    }
                    if let Fault::Stall { millis, .. } = fault {
                        // lag the uplink past the leader's deadline;
                        // the update arrives stale next round
                        std::thread::sleep(std::time::Duration::from_millis(millis));
                    }
                    if fault == Fault::DropUplink {
                        continue; // trained, but the uplink vanished
                    }
                    // packed sign(Δw): 1 bit per weight uplink
                    let now = engine.weights_snapshot();
                    let deltas = now
                        .iter()
                        .zip(weights.iter())
                        .map(|(new, old)| {
                            let d: Vec<f32> =
                                new.iter().zip(old).map(|(a, b)| a - b).collect();
                            BitMatrix::pack(1, d.len(), &d)
                        })
                        .collect();
                    let _ = tx_up.send(Ok(SignUpdate {
                        worker_id: id,
                        round,
                        deltas,
                        mean_loss: loss_sum / local_steps.max(1) as f32,
                        samples_seen: seen,
                    }));
                }
            }
        }
    });
    WorkerHandle { id, tx, join }
}

/// A malformed update: right layer count, but one mid-stack layer's
/// shape is wrong (so single-layer validation would miss it).
fn corrupt_update(id: usize, round: usize, weights: &[Vec<f32>]) -> SignUpdate {
    let bad_layer = weights.len() / 2;
    let deltas = weights
        .iter()
        .enumerate()
        .map(|(li, w)| {
            let cols = if li == bad_layer { w.len() + 1 } else { w.len() };
            BitMatrix::zeros(1, cols)
        })
        .collect();
    SignUpdate { worker_id: id, round, deltas, mean_loss: f32::NAN, samples_seen: 0 }
}
