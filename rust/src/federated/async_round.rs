//! Async bounded-staleness round machinery.
//!
//! The lockstep broadcast/collect loop is gone; what replaced it is a
//! *state machine* shared by both fleet transports (engine worker
//! threads with `recv_timeout`, and the virtual-time simulated fleet):
//!
//! - a round commits as soon as **quorum** distinct workers'
//!   round-admissible updates arrive — only admitted updates count
//!   against the deadline (a stale or malformed receive never burns a
//!   live worker's slot);
//! - updates up to `max_staleness` rounds old are admitted with a
//!   **staleness-discounted integer vote weight**
//!   (`max_staleness + 1 − staleness`, see [`vote_weight`] — integer
//!   so tallies stay bit-exact and permutation-invariant);
//! - workers that miss a round's deadline become **stragglers** and
//!   are re-admitted with exponential backoff (sit out `backoff`
//!   rounds, doubling up to a cap on repeated failure, reset on the
//!   first successful uplink);
//! - a malformed sender is **quarantined** — treated as a permanent
//!   dropout, its update discarded whole (all-or-nothing per update);
//! - below quorum the round **stalls and retries** within a bounded
//!   retry budget, then is recorded uncommitted and the fleet moves
//!   on — committed state is never rolled back.

use anyhow::{bail, Result};

/// Knobs of the async round loop (CLI: `--max-staleness`,
/// `--deadline-ms`, `--retry-budget`, `--backoff`, `--quorum`).
#[derive(Clone, Copy, Debug)]
pub struct AsyncConfig {
    /// Distinct contributing workers needed to commit a round.
    pub quorum: usize,
    /// Oldest admissible update age, in rounds (0 = fresh only).
    pub max_staleness: usize,
    /// Threaded fleet: per-round collection deadline (wall clock).
    /// The simulated fleet runs virtual time and ignores this.
    pub deadline_ms: u64,
    /// Collection retries per round while below quorum.
    pub retry_budget: usize,
    /// Rounds a first-time straggler sits out before re-admission.
    pub backoff_base: usize,
    /// Cap on the doubled backoff.
    pub backoff_cap: usize,
}

impl AsyncConfig {
    /// Strict-majority quorum for `workers`, defaults elsewhere.
    pub fn majority(workers: usize) -> AsyncConfig {
        AsyncConfig {
            quorum: workers / 2 + 1,
            max_staleness: 2,
            deadline_ms: 4000,
            retry_budget: 3,
            backoff_base: 1,
            backoff_cap: 8,
        }
    }

    pub fn validate(&self, workers: usize) -> Result<()> {
        if self.quorum == 0 || self.quorum > workers {
            bail!("quorum {} out of range for {} workers", self.quorum, workers);
        }
        Ok(())
    }
}

/// Integer vote weight of an update `staleness` rounds old
/// (`None` = inadmissible).  Fresh = `max_staleness + 1`, oldest
/// admissible = 1: linear discount, all integer.
pub fn vote_weight(staleness: usize, max_staleness: usize) -> Option<u32> {
    (staleness <= max_staleness).then(|| (max_staleness + 1 - staleness) as u32)
}

/// Leader-side view of one worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    Active,
    /// Timed out; sits out until `readmit`, next failure doubles
    /// `backoff` (capped).
    Straggler { readmit: usize, backoff: usize },
    /// Sent a malformed update — permanent dropout.
    Quarantined,
    /// Channel closed / engine failure — permanent dropout.
    Dead,
}

/// Admission verdict for a received update (see [`FleetState::admit`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Counts toward quorum with this vote weight.
    Admitted { weight: u32, staleness: usize },
    /// Older than `max_staleness` — discarded, no slot burned.
    TooStale,
    /// From a quarantined/dead worker — discarded.
    Rejected,
}

/// The whole fleet's round bookkeeping, transport-agnostic.
#[derive(Debug)]
pub struct FleetState {
    pub cfg: AsyncConfig,
    health: Vec<Health>,
    /// Rounds committed so far (monotone; commits never roll back).
    pub committed: usize,
    /// Highest committed round index.
    pub last_committed: Option<usize>,
}

impl FleetState {
    pub fn new(cfg: AsyncConfig, workers: usize) -> Result<FleetState> {
        cfg.validate(workers)?;
        Ok(FleetState {
            cfg,
            health: vec![Health::Active; workers],
            committed: 0,
            last_committed: None,
        })
    }

    pub fn health(&self, worker: usize) -> Health {
        self.health[worker]
    }

    /// Workers that should receive round `round`'s work: active ones
    /// plus stragglers whose backoff has elapsed.
    pub fn broadcast_set(&self, round: usize) -> Vec<usize> {
        self.health
            .iter()
            .enumerate()
            .filter(|(_, h)| match h {
                Health::Active => true,
                Health::Straggler { readmit, .. } => round >= *readmit,
                Health::Quarantined | Health::Dead => false,
            })
            .map(|(w, _)| w)
            .collect()
    }

    /// Admission check for worker `w`'s update tagged `update_round`,
    /// received while collecting `round`.  Does not mutate health —
    /// call [`FleetState::on_uplink_ok`] after accepting the payload.
    pub fn admit(&self, w: usize, round: usize, update_round: usize) -> Admission {
        match self.health[w] {
            Health::Quarantined | Health::Dead => return Admission::Rejected,
            Health::Active | Health::Straggler { .. } => {}
        }
        // an update can only be tagged with a round it was sent work
        // for, i.e. update_round <= round; a "future" tag is malformed
        if update_round > round {
            return Admission::Rejected;
        }
        match vote_weight(round - update_round, self.cfg.max_staleness) {
            Some(weight) => Admission::Admitted { weight, staleness: round - update_round },
            None => Admission::TooStale,
        }
    }

    /// A worker delivered an admissible update: it is live again —
    /// straggler state and backoff reset.
    pub fn on_uplink_ok(&mut self, w: usize) {
        if matches!(self.health[w], Health::Active | Health::Straggler { .. }) {
            self.health[w] = Health::Active;
        }
    }

    /// A broadcast-to worker missed the round deadline: mark it a
    /// straggler (first miss sits out `backoff_base` rounds) or
    /// double an existing straggler's backoff, capped.
    pub fn on_timeout(&mut self, w: usize, round: usize) {
        self.health[w] = match self.health[w] {
            Health::Active => Health::Straggler {
                readmit: round + 1 + self.cfg.backoff_base,
                backoff: self.cfg.backoff_base,
            },
            Health::Straggler { backoff, .. } => {
                let next = (backoff * 2).clamp(1, self.cfg.backoff_cap);
                Health::Straggler { readmit: round + 1 + next, backoff: next }
            }
            h @ (Health::Quarantined | Health::Dead) => h,
        };
    }

    /// Malformed update: permanent dropout, votes discarded whole.
    pub fn quarantine(&mut self, w: usize) {
        if self.health[w] != Health::Dead {
            self.health[w] = Health::Quarantined;
        }
    }

    /// Channel closed / engine error: permanent dropout.
    pub fn mark_dead(&mut self, w: usize) {
        self.health[w] = Health::Dead;
    }

    /// Workers that could still contribute (not quarantined/dead).
    /// `reachable() < quorum` means no future round can commit — the
    /// graceful-degradation exit condition.
    pub fn reachable(&self) -> usize {
        self.health
            .iter()
            .filter(|h| matches!(h, Health::Active | Health::Straggler { .. }))
            .count()
    }

    /// Record a committed round.  Commits are strictly monotone —
    /// attempting to re-commit or roll back is a logic error.
    pub fn commit(&mut self, round: usize) {
        if let Some(last) = self.last_committed {
            assert!(round > last, "commit must be monotone: {round} after {last}");
        }
        self.last_committed = Some(round);
        self.committed += 1;
    }
}

/// Per-round outcome record (`FedResult::round_stats`): what the
/// chaos tests assert monotonicity/quorum claims against, and what
/// the bench distills into commit-latency percentiles.
#[derive(Clone, Debug)]
pub struct RoundStat {
    pub round: usize,
    pub committed: bool,
    /// Distinct workers whose updates were admitted.
    pub admitted: usize,
    pub fresh: usize,
    pub stale: usize,
    /// Collection retries spent below quorum.
    pub retries: usize,
    pub timeouts: usize,
    pub quarantined: usize,
    /// Mean local loss over admitted updates (NaN if uncommitted).
    pub mean_loss: f32,
    /// Admitted uplink payload for the round.
    pub uplink_bytes: usize,
    /// Wall-clock round start → commit (collection only, sim ≈ compute).
    pub commit_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AsyncConfig {
        AsyncConfig {
            quorum: 2,
            max_staleness: 2,
            deadline_ms: 100,
            retry_budget: 2,
            backoff_base: 1,
            backoff_cap: 4,
        }
    }

    #[test]
    fn vote_weight_discounts_linearly() {
        assert_eq!(vote_weight(0, 2), Some(3));
        assert_eq!(vote_weight(1, 2), Some(2));
        assert_eq!(vote_weight(2, 2), Some(1));
        assert_eq!(vote_weight(3, 2), None);
        assert_eq!(vote_weight(0, 0), Some(1));
        assert_eq!(vote_weight(1, 0), None);
    }

    #[test]
    fn admission_rules() {
        let st = FleetState::new(cfg(), 3).unwrap();
        assert_eq!(st.admit(0, 5, 5), Admission::Admitted { weight: 3, staleness: 0 });
        assert_eq!(st.admit(0, 5, 4), Admission::Admitted { weight: 2, staleness: 1 });
        assert_eq!(st.admit(0, 5, 3), Admission::Admitted { weight: 1, staleness: 2 });
        assert_eq!(st.admit(0, 5, 2), Admission::TooStale);
        assert_eq!(st.admit(0, 5, 6), Admission::Rejected, "future-tagged update");
    }

    #[test]
    fn straggler_backoff_doubles_and_resets() {
        let mut st = FleetState::new(cfg(), 3).unwrap();
        st.on_timeout(0, 10);
        assert_eq!(st.health(0), Health::Straggler { readmit: 12, backoff: 1 });
        assert!(!st.broadcast_set(11).contains(&0), "sits out its backoff");
        assert!(st.broadcast_set(12).contains(&0), "re-admitted after backoff");
        st.on_timeout(0, 12); // failed again: 1 -> 2
        assert_eq!(st.health(0), Health::Straggler { readmit: 15, backoff: 2 });
        st.on_timeout(0, 15); // 2 -> 4
        st.on_timeout(0, 20); // 4 -> 8 capped at 4
        assert_eq!(st.health(0), Health::Straggler { readmit: 25, backoff: 4 });
        st.on_uplink_ok(0); // a successful uplink resets everything
        assert_eq!(st.health(0), Health::Active);
    }

    #[test]
    fn quarantine_is_permanent() {
        let mut st = FleetState::new(cfg(), 3).unwrap();
        st.quarantine(1);
        assert_eq!(st.admit(1, 3, 3), Admission::Rejected);
        st.on_uplink_ok(1); // cannot resurrect
        assert_eq!(st.health(1), Health::Quarantined);
        assert!(!st.broadcast_set(4).contains(&1));
        assert_eq!(st.reachable(), 2);
        st.mark_dead(2);
        assert_eq!(st.reachable(), 1);
    }

    #[test]
    fn commits_are_monotone() {
        let mut st = FleetState::new(cfg(), 3).unwrap();
        st.commit(0);
        st.commit(2); // round 1 stalled — fine, still monotone
        assert_eq!(st.committed, 2);
        assert_eq!(st.last_committed, Some(2));
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn rollback_commit_panics() {
        let mut st = FleetState::new(cfg(), 3).unwrap();
        st.commit(3);
        st.commit(3);
    }

    #[test]
    fn bad_quorum_rejected() {
        let mut c = cfg();
        c.quorum = 5;
        assert!(FleetState::new(c, 3).is_err());
        c.quorum = 0;
        assert!(FleetState::new(c, 3).is_err());
    }
}
