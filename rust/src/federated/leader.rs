//! Federated leader: shard routing, async round orchestration,
//! word-level sign-vote aggregation, quorum + staleness + chaos
//! handling.
//!
//! One leader drives one of two transports behind the same round
//! loop and the same [`FleetState`] bookkeeping:
//!
//! - **Threads** — every worker is a real engine thread with a
//!   private shard (small fleets; wall-clock `recv_timeout`
//!   deadlines, collection retries below quorum);
//! - **Sim** — the virtual-time [`SimFleet`] with shard leaders
//!   (10³-worker fleets; deterministic, so the chaos acceptance test
//!   can diff two same-seed runs bit-for-bit).
//!
//! Collection rules (the seed's lockstep loop had three bugs, all
//! pinned by tests now):
//! - only *admitted* updates count toward the round — a stale,
//!   malformed, or duplicate receive never burns a live worker's
//!   collection slot;
//! - an update is validated on arrival against **every** layer shape;
//!   a malformed sender is quarantined before any of its votes touch
//!   the tally, and a round commits all-or-nothing;
//! - fault injection is the seeded [`FaultPlan`] consulted inside the
//!   workers — there is no leader-side "kill worker 0" test hook.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::async_round::{Admission, AsyncConfig, FleetState, Health, RoundStat};
use super::fault::FaultPlan;
use super::sim::SimFleet;
use super::tally::{count_votes_words, LayerVotes};
use super::worker::{spawn_worker, RoundMsg, SignUpdate, WorkerHandle};
use crate::bitops::{BitMatrix, Pool};
use crate::data::build;
use crate::models::{get, lower};
use crate::naive::Plan;
use crate::serve::WeightSnapshot;
use crate::util::rng::Pcg32;

/// Receives every quorum-committed weight state as a packed
/// [`WeightSnapshot`] — `(rounds_committed, snapshot)`, the snapshot
/// version being the committed-round count.  The federated-serving
/// hook: typically `MultiClient::publish` into a co-resident serving
/// tenant, so the fleet's committed model is live behind the
/// multi-tenant runtime the moment the round lands.
pub type CommitSink = Box<dyn FnMut(u64, Arc<WeightSnapshot>) -> Result<()> + Send>;

/// Which transport carries the rounds.
#[derive(Clone, Debug)]
pub enum FleetMode {
    /// Real engine threads, one per worker (small fleets).
    Threads,
    /// Virtual-time simulated fleet with shard leaders (large fleets).
    Sim { shards: usize, noise_log2: u32 },
}

#[derive(Clone, Debug)]
pub struct FedConfig {
    pub workers: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub batch: usize,
    pub model: String,
    pub dataset: String,
    /// Local (on-device) learning rate.
    pub lr: f32,
    /// Federated step size applied to the voted sign.
    pub fed_lr: f32,
    pub seed: u64,
    pub samples_per_worker: usize,
    /// Async round knobs: quorum, staleness, deadline, backoff.
    pub async_cfg: AsyncConfig,
    /// Chaos schedule every worker consults (None = clean).
    pub plan: FaultPlan,
    pub mode: FleetMode,
    /// Pool threads for the root tally (0 = auto).
    pub tally_threads: usize,
}

impl FedConfig {
    /// Defaults for a fleet of `workers`: majority quorum, staleness
    /// 2, no chaos; engine threads up to [`FedConfig::SIM_THRESHOLD`]
    /// workers, the simulated fleet beyond.
    pub fn fleet(workers: usize) -> FedConfig {
        FedConfig {
            workers,
            rounds: 5,
            local_steps: 8,
            batch: 32,
            model: "mlp_mini".into(),
            dataset: "syn-mnist64".into(),
            lr: 0.002,
            fed_lr: 0.01,
            seed: 42,
            samples_per_worker: 256,
            async_cfg: AsyncConfig::majority(workers),
            plan: FaultPlan::None,
            mode: if workers > Self::SIM_THRESHOLD {
                FleetMode::Sim { shards: 8, noise_log2: 4 }
            } else {
                FleetMode::Threads
            },
            tally_threads: 0,
        }
    }

    /// Fleets past this size default to the simulated transport.
    pub const SIM_THRESHOLD: usize = 64;
}

#[derive(Debug)]
pub struct FedResult {
    pub workers: usize,
    pub rounds_attempted: usize,
    pub rounds_committed: usize,
    /// Mean admitted local loss per round (NaN for stalled rounds).
    pub round_losses: Vec<f32>,
    /// Full per-round telemetry (what the chaos tests + bench read).
    pub round_stats: Vec<RoundStat>,
    pub final_weights: Vec<Vec<f32>>,
    /// Workers permanently expelled for malformed updates.
    pub quarantined: usize,
    /// Uplink bytes per worker per round (1 bit/weight + header).
    pub uplink_bytes_per_round: usize,
    /// vs f32 weight upload (the federated communication saving).
    pub uplink_reduction: f64,
}

impl FedResult {
    pub fn summary(&self) -> String {
        format!(
            "federated: {}/{} rounds committed ({} workers) | loss {:.3} -> {:.3} | uplink {:.1} KiB/worker/round ({}x smaller than f32) | {} quarantined",
            self.rounds_committed,
            self.rounds_attempted,
            self.workers,
            self.round_losses.iter().find(|l| l.is_finite()).unwrap_or(&f32::NAN),
            self.round_losses.iter().rev().find(|l| l.is_finite()).unwrap_or(&f32::NAN),
            self.uplink_bytes_per_round as f64 / 1024.0,
            self.uplink_reduction.round(),
            self.quarantined,
        )
    }
}

enum Transport {
    Threads { handles: Vec<WorkerHandle>, rx_up: Receiver<Result<SignUpdate, usize>> },
    Sim(Box<SimFleet>),
}

pub struct Leader {
    cfg: FedConfig,
    transport: Transport,
    fleet: FleetState,
    pool: Pool,
    weights: Vec<Vec<f32>>,
    /// (rows, cols) per weight layer, for on-arrival validation.
    shapes: Vec<(usize, usize)>,
    /// For packing committed weights into serving snapshots.
    plan: Plan,
    commit_sink: Option<CommitSink>,
}

impl Leader {
    pub fn new(cfg: FedConfig) -> Result<Leader> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        cfg.async_cfg.validate(cfg.workers)?;
        let graph = lower(&get(&cfg.model)?)?;
        // Global init: same scheme as the engines (leader owns w_0).
        let mut rng = Pcg32::new(cfg.seed);
        let mut weights = Vec::new();
        let mut shapes = Vec::new();
        for node in graph.nodes.iter().filter(|n| n.is_matmul()) {
            // snapshot order is [w, beta] per layer (see StepEngine)
            let w = rng.glorot(node.fan_in, node.channels, node.w_elems);
            weights.push(w);
            shapes.push((1, node.w_elems));
            weights.push(vec![0.0; node.channels]);
            shapes.push((1, node.channels));
        }
        let n_weights: usize = weights.iter().map(Vec::len).sum();

        let fleet = FleetState::new(cfg.async_cfg, cfg.workers)?;
        let transport = match cfg.mode {
            FleetMode::Sim { shards, noise_log2 } => Transport::Sim(Box::new(SimFleet::new(
                &graph,
                cfg.batch,
                &cfg.dataset,
                cfg.samples_per_worker,
                cfg.seed,
                cfg.workers,
                shards,
                noise_log2,
                cfg.async_cfg,
                cfg.plan.clone(),
                n_weights,
                weights.len(),
            )?)),
            FleetMode::Threads => {
                // Shard routing: contiguous, disjoint, exactly
                // covering the fleet (invariant tested below).
                let total = cfg.samples_per_worker * cfg.workers;
                let ds = build(&cfg.dataset, total, 0, cfg.seed)?;
                let k = ds.sample_elems();
                let plan = Arc::new(cfg.plan.clone());
                let (tx_up, rx_up): (Sender<Result<SignUpdate, usize>>, _) = channel();
                let mut handles = Vec::new();
                for wid in 0..cfg.workers {
                    let lo = wid * cfg.samples_per_worker;
                    let hi = lo + cfg.samples_per_worker;
                    handles.push(spawn_worker(
                        wid,
                        graph.clone(),
                        cfg.batch,
                        ds.train_x[lo * k..hi * k].to_vec(),
                        ds.train_y[lo..hi].to_vec(),
                        cfg.seed ^ (wid as u64 + 1) * 0x9e37,
                        tx_up.clone(),
                        plan.clone(),
                    ));
                }
                Transport::Threads { handles, rx_up }
            }
        };
        let pool = Pool::new(cfg.tally_threads);
        let plan = Plan::from_graph(&graph)?;
        Ok(Leader {
            cfg,
            transport,
            fleet,
            pool,
            weights,
            shapes,
            plan,
            commit_sink: None,
        })
    }

    /// Publish every committed round's weights into `sink` (see
    /// [`CommitSink`]).  Uncommitted rounds publish nothing — the
    /// sink only ever sees quorum-committed states.
    pub fn set_commit_sink(&mut self, sink: CommitSink) {
        self.commit_sink = Some(sink);
    }

    pub fn run(&mut self) -> Result<FedResult> {
        let quorum = self.cfg.async_cfg.quorum;
        let mut round_losses = Vec::new();
        let mut round_stats: Vec<RoundStat> = Vec::new();

        for round in 0..self.cfg.rounds {
            let reachable = match &self.transport {
                Transport::Threads { .. } => self.fleet.reachable(),
                Transport::Sim(f) => f.reachable(),
            };
            if reachable < quorum {
                // no future round can commit: graceful degradation,
                // committed state stays exactly as it is
                break;
            }
            let t0 = Instant::now();
            let (votes, mut stat) = match &mut self.transport {
                Transport::Sim(f) => {
                    let reports =
                        f.round(round, &self.weights, self.cfg.local_steps, self.cfg.lr)?;
                    let mut votes: Vec<LayerVotes> = self
                        .shapes
                        .iter()
                        .map(|&(r, c)| LayerVotes::zeros(r, c))
                        .collect();
                    let mut stat = empty_stat(round);
                    for rep in &reports {
                        for (v, pv) in votes.iter_mut().zip(&rep.votes) {
                            v.merge(pv);
                        }
                        stat.admitted += rep.admitted;
                        stat.fresh += rep.fresh;
                        stat.stale += rep.stale;
                        stat.timeouts += rep.timeouts;
                        stat.quarantined += rep.quarantined;
                        stat.uplink_bytes += rep.uplink_bytes;
                        stat.mean_loss += rep.loss_sum;
                    }
                    stat.mean_loss /= stat.admitted.max(1) as f32;
                    (votes, stat)
                }
                Transport::Threads { handles, rx_up } => collect_threaded(
                    handles,
                    rx_up,
                    &mut self.fleet,
                    &self.shapes,
                    &self.pool,
                    &self.weights,
                    round,
                    &self.cfg,
                ),
            };

            if stat.admitted >= quorum {
                // all layers were validated at admission: applying is
                // infallible, so the commit is all-or-nothing
                for (li, votes) in votes.iter().enumerate() {
                    let w = &mut self.weights[li];
                    for (i, v) in votes.signs().into_iter().enumerate() {
                        if v != 0 {
                            w[i] = (w[i] + self.cfg.fed_lr * v as f32).clamp(-1.0, 1.0);
                        }
                    }
                }
                self.fleet.commit(round);
                stat.committed = true;
                if let Some(sink) = self.commit_sink.as_mut() {
                    let v = self.fleet.committed as u64;
                    let snap = Arc::new(WeightSnapshot::pack(&self.plan, &self.weights, v)?);
                    sink(v, snap)?;
                }
            } else {
                stat.mean_loss = f32::NAN;
            }
            stat.commit_ms = t0.elapsed().as_secs_f64() * 1e3;
            round_losses.push(stat.mean_loss);
            round_stats.push(stat);
        }

        if let Transport::Threads { handles, .. } = &mut self.transport {
            for h in handles.iter() {
                let _ = h.tx.send(RoundMsg::Shutdown);
            }
            while let Some(h) = handles.pop() {
                let _ = h.join.join();
            }
        }

        let n_weights: usize = self.weights.iter().map(Vec::len).sum();
        let uplink = n_weights / 8 + 16 * self.weights.len();
        let quarantined = match &self.transport {
            Transport::Sim(_) => round_stats.iter().map(|s| s.quarantined).sum(),
            Transport::Threads { .. } => (0..self.cfg.workers)
                .filter(|&w| self.fleet.health(w) == Health::Quarantined)
                .count(),
        };
        Ok(FedResult {
            workers: self.cfg.workers,
            rounds_attempted: round_stats.len(),
            rounds_committed: self.fleet.committed,
            round_losses,
            round_stats,
            final_weights: self.weights.clone(),
            quarantined,
            uplink_bytes_per_round: uplink,
            uplink_reduction: (n_weights * 4) as f64 / uplink as f64,
        })
    }
}

fn empty_stat(round: usize) -> RoundStat {
    RoundStat {
        round,
        committed: false,
        admitted: 0,
        fresh: 0,
        stale: 0,
        retries: 0,
        timeouts: 0,
        quarantined: 0,
        mean_loss: 0.0,
        uplink_bytes: 0,
        commit_ms: 0.0,
    }
}

/// One threaded round: broadcast to the admissible set, then collect
/// until deadline — retrying (deadline extensions) below quorum —
/// admitting fresh and bounded-stale updates with discounted weights.
#[allow(clippy::too_many_arguments)]
fn collect_threaded(
    handles: &[WorkerHandle],
    rx_up: &Receiver<Result<SignUpdate, usize>>,
    fleet: &mut FleetState,
    shapes: &[(usize, usize)],
    pool: &Pool,
    weights: &[Vec<f32>],
    round: usize,
    cfg: &FedConfig,
) -> (Vec<LayerVotes>, RoundStat) {
    let mut stat = empty_stat(round);
    let bset = fleet.broadcast_set(round);
    let w_arc = Arc::new(weights.to_vec());
    for &w in &bset {
        let msg = RoundMsg::Work {
            round,
            weights: w_arc.clone(),
            local_steps: cfg.local_steps,
            lr: cfg.lr,
        };
        if handles[w].tx.send(msg).is_err() {
            fleet.mark_dead(w);
        }
    }

    // freshest admitted update per worker: (staleness, weight, update)
    let mut got: BTreeMap<usize, (usize, u32, SignUpdate)> = BTreeMap::new();
    // workers whose *this-round* answer arrived (incl. corrupt/dead):
    // once every broadcast-to worker answered, nothing else can come
    let mut answered: Vec<bool> = vec![false; handles.len()];
    let mut deadline = Instant::now() + Duration::from_millis(cfg.async_cfg.deadline_ms);
    loop {
        let now = Instant::now();
        if now >= deadline {
            // below quorum: stall and retry (extend the collection
            // window) within the bounded retry budget
            if got.len() < cfg.async_cfg.quorum && stat.retries < cfg.async_cfg.retry_budget
            {
                stat.retries += 1;
                deadline = Instant::now() + Duration::from_millis(cfg.async_cfg.deadline_ms);
            } else {
                break;
            }
        }
        let wait = deadline.saturating_duration_since(now);
        match rx_up.recv_timeout(wait) {
            Err(RecvTimeoutError::Timeout) => continue, // deadline check above
            Err(RecvTimeoutError::Disconnected) => break,
            Ok(Err(wid)) => {
                fleet.mark_dead(wid);
                answered[wid] = true;
            }
            Ok(Ok(u)) => {
                let wid = u.worker_id;
                if wid >= handles.len() {
                    continue;
                }
                // satellite fix: validate EVERY layer on arrival; a
                // malformed sender is quarantined before any of its
                // votes can reach the tally
                let valid = u.deltas.len() == shapes.len()
                    && u.deltas
                        .iter()
                        .zip(shapes)
                        .all(|(d, &(r, c))| d.rows == r && d.cols == c);
                if !valid {
                    fleet.quarantine(wid);
                    got.remove(&wid); // discard anything it sent before
                    stat.quarantined += 1;
                    answered[wid] = true;
                    continue;
                }
                if u.round == round {
                    answered[wid] = true;
                }
                match fleet.admit(wid, round, u.round) {
                    Admission::Admitted { weight, staleness } => {
                        fleet.on_uplink_ok(wid);
                        let fresher = match got.get(&wid) {
                            Some((s, _, _)) => staleness < *s,
                            None => true,
                        };
                        if fresher {
                            got.insert(wid, (staleness, weight, u));
                        }
                    }
                    // satellite fix: inadmissible receives burn no
                    // collection slot — the loop runs on the deadline
                    Admission::TooStale | Admission::Rejected => {}
                }
            }
        }
        // every broadcast-to worker answered or is permanently out:
        // nothing else can arrive for this round
        let done = bset.iter().all(|&w| {
            answered[w]
                || !matches!(fleet.health(w), Health::Active | Health::Straggler { .. })
        });
        if done {
            break;
        }
    }

    // broadcast-to workers that never answered this round time out
    for &w in &bset {
        if !answered[w]
            && matches!(fleet.health(w), Health::Active | Health::Straggler { .. })
        {
            fleet.on_timeout(w, round);
            stat.timeouts += 1;
        }
    }

    stat.admitted = got.len();
    stat.fresh = got.values().filter(|(s, _, _)| *s == 0).count();
    stat.stale = stat.admitted - stat.fresh;
    stat.uplink_bytes = got.values().map(|(_, _, u)| u.uplink_bytes()).sum();
    stat.mean_loss = got.values().map(|(_, _, u)| u.mean_loss).sum::<f32>()
        / stat.admitted.max(1) as f32;

    // word-level weighted tally per layer (root pool)
    let votes = shapes
        .iter()
        .enumerate()
        .map(|(li, &(r, c))| {
            if got.is_empty() {
                return LayerVotes::zeros(r, c);
            }
            let refs: Vec<&BitMatrix> = got.values().map(|(_, _, u)| &u.deltas[li]).collect();
            let ws: Vec<u32> = got.values().map(|(_, w, _)| *w).collect();
            count_votes_words(&refs, &ws, pool)
        })
        .collect();
    (votes, stat)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::federated::fault::Fault;

    fn small_cfg() -> FedConfig {
        let mut cfg = FedConfig::fleet(3);
        cfg.rounds = 3;
        cfg.local_steps = 4;
        cfg.batch = 16;
        cfg.lr = 0.003;
        cfg.fed_lr = 0.02;
        cfg.seed = 7;
        cfg.samples_per_worker = 64;
        cfg.async_cfg.deadline_ms = 2000;
        cfg
    }

    #[test]
    fn rounds_commit_and_loss_drops() {
        let mut l = Leader::new(small_cfg()).unwrap();
        let r = l.run().unwrap();
        assert_eq!(r.rounds_committed, 3);
        assert_eq!(r.round_losses.len(), 3);
        assert!(
            r.round_losses[2] < r.round_losses[0],
            "{:?}",
            r.round_losses
        );
        assert!(r.uplink_reduction > 25.0, "{}", r.uplink_reduction);
        assert!(r.round_stats.iter().all(|s| s.committed && s.fresh == 3));
    }

    #[test]
    fn survives_worker_crash_above_quorum() {
        let mut cfg = small_cfg();
        // worker 0 crashes at round 1 and never comes back
        cfg.plan = FaultPlan::scripted([(0, 1, Fault::Crash { outage: 99 })]);
        cfg.async_cfg.deadline_ms = 400;
        let mut l = Leader::new(cfg).unwrap();
        let r = l.run().unwrap();
        // 2 of 3 still meets quorum (2): all rounds commit
        assert_eq!(r.rounds_committed, 3);
        assert!(r.round_stats[1].timeouts >= 1);
    }

    #[test]
    fn below_quorum_stalls_but_does_not_corrupt() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.async_cfg = AsyncConfig::majority(1);
        cfg.async_cfg.deadline_ms = 300;
        cfg.async_cfg.retry_budget = 0;
        cfg.plan = FaultPlan::scripted([(0, 1, Fault::Crash { outage: 99 })]);
        let mut l = Leader::new(cfg).unwrap();
        let w_before_len: usize = l.weights.iter().map(Vec::len).sum();
        let r = l.run().unwrap();
        assert!(r.rounds_committed >= 1);
        assert!(r.rounds_committed < 3);
        let w_after_len: usize = r.final_weights.iter().map(Vec::len).sum();
        assert_eq!(w_before_len, w_after_len);
        // weights stay clipped; stalled rounds report NaN loss
        for w in &r.final_weights {
            assert!(w.iter().all(|v| v.abs() <= 1.0));
        }
        assert!(r.round_stats.iter().any(|s| !s.committed));
    }

    #[test]
    fn corrupt_worker_is_quarantined_and_cannot_poison() {
        let mut cfg = small_cfg();
        // worker 1 uplinks a malformed update in round 0 — the seed's
        // leader would have bailed mid-aggregation on this
        cfg.plan = FaultPlan::scripted([(1, 0, Fault::Corrupt)]);
        cfg.async_cfg.deadline_ms = 2000;
        let mut l = Leader::new(cfg).unwrap();
        let r = l.run().unwrap();
        assert_eq!(r.quarantined, 1);
        // the other two still make quorum every round
        assert_eq!(r.rounds_committed, 3);
        for w in &r.final_weights {
            assert!(w.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
        }
    }

    #[test]
    fn committed_weights_serve_bit_exactly() {
        use crate::naive::Accel;
        use crate::serve::{
            InferAlgo, MultiModelServer, PackedInferEngine, TenantRole, TenantSpec,
        };

        // a serving tenant co-resident with the federated leader: the
        // commit sink publishes every quorum-committed round into it
        let mut spec = TenantSpec::new("fed", "mlp_mini", TenantRole::Serve);
        spec.max_batch = 4;
        let (client, server) = MultiModelServer::new(vec![spec], 1).unwrap();
        let h = std::thread::spawn(move || server.run());

        let mut l = Leader::new(small_cfg()).unwrap();
        let c = client.clone();
        l.set_commit_sink(Box::new(move |_committed, snap| c.publish(0, snap)));
        let r = l.run().unwrap();
        assert_eq!(r.rounds_committed, 3);

        // a request after the last commit serves exactly the
        // committed weights — bit-identical to an engine packed
        // straight from FedResult::final_weights
        let graph = lower(&get("mlp_mini").unwrap()).unwrap();
        let plan = Plan::from_graph(&graph).unwrap();
        let committed =
            Arc::new(WeightSnapshot::pack(&plan, &r.final_weights, 3).unwrap());
        let mut reference =
            PackedInferEngine::new(&graph, InferAlgo::Proposed, Accel::Blocked, 4, committed)
                .unwrap();
        let mut rng = Pcg32::new(19);
        let x = rng.normal_vec(graph.input_elems);
        let mut got = vec![0.0f32; graph.classes];
        let mut want = vec![0.0f32; graph.classes];
        client.infer_one(0, &x, &mut got).unwrap();
        reference.infer_into(&x, 1, &mut want).unwrap();
        assert_eq!(got, want, "served logits != committed weights");

        client.shutdown();
        let tenants = h.join().unwrap().unwrap();
        assert_eq!(tenants[0].serve_engine().unwrap().snapshot().version(), 3);
    }

    #[test]
    fn weights_stay_in_unit_box() {
        let mut cfg = small_cfg();
        cfg.fed_lr = 0.9; // aggressive federated steps
        cfg.rounds = 4;
        let mut l = Leader::new(cfg).unwrap();
        let r = l.run().unwrap();
        for w in &r.final_weights {
            assert!(w.iter().all(|v| v.abs() <= 1.0));
        }
    }

    #[test]
    fn sim_mode_commits_and_matches_shapes() {
        let mut cfg = small_cfg();
        cfg.workers = 40;
        cfg.async_cfg = AsyncConfig::majority(40);
        cfg.mode = FleetMode::Sim { shards: 4, noise_log2: 4 };
        cfg.samples_per_worker = 64;
        let mut l = Leader::new(cfg).unwrap();
        let r = l.run().unwrap();
        assert_eq!(r.rounds_committed, 3);
        assert!(r.round_stats.iter().all(|s| s.fresh == 40));
        for w in &r.final_weights {
            assert!(w.iter().all(|v| v.abs() <= 1.0));
        }
    }
}
