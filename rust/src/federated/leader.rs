//! Federated leader: shard routing, round orchestration, sign-vote
//! aggregation, quorum handling.

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{bail, Result};

use super::worker::{spawn_worker, RoundMsg, SignUpdate, WorkerHandle};
use super::sign_vote;
use crate::data::build;
use crate::models::{get, lower};
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct FedConfig {
    pub workers: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub batch: usize,
    pub model: String,
    pub dataset: String,
    /// Local (on-device) learning rate.
    pub lr: f32,
    /// Federated step size applied to the voted sign.
    pub fed_lr: f32,
    pub seed: u64,
    pub samples_per_worker: usize,
    /// Test hook: drop this worker id after round 0 (dropout test).
    pub drop_worker: Option<usize>,
}

#[derive(Debug)]
pub struct FedResult {
    pub rounds_committed: usize,
    pub round_losses: Vec<f32>,
    pub final_weights: Vec<Vec<f32>>,
    /// Uplink bytes per worker per round (1 bit/weight + header).
    pub uplink_bytes_per_round: usize,
    /// vs f32 weight upload (the federated communication saving).
    pub uplink_reduction: f64,
}

impl FedResult {
    pub fn summary(&self) -> String {
        format!(
            "federated: {} rounds committed | loss {:.3} -> {:.3} | uplink {:.1} KiB/worker/round ({}x smaller than f32)",
            self.rounds_committed,
            self.round_losses.first().unwrap_or(&f32::NAN),
            self.round_losses.last().unwrap_or(&f32::NAN),
            self.uplink_bytes_per_round as f64 / 1024.0,
            self.uplink_reduction.round()
        )
    }
}

pub struct Leader {
    cfg: FedConfig,
    handles: Vec<WorkerHandle>,
    rx_up: Receiver<Result<SignUpdate, usize>>,
    weights: Vec<Vec<f32>>,
    /// (rows, cols) per weight layer, for vote shape checks.
    shapes: Vec<(usize, usize)>,
}

impl Leader {
    pub fn new(cfg: FedConfig) -> Result<Leader> {
        if cfg.workers == 0 {
            bail!("need at least one worker");
        }
        let graph = lower(&get(&cfg.model)?)?;
        // Global init: same scheme as the engines (leader owns w_0).
        let mut rng = Pcg32::new(cfg.seed);
        let mut weights = Vec::new();
        let mut shapes = Vec::new();
        for node in graph.nodes.iter().filter(|n| n.is_matmul()) {
            // snapshot order is [w, beta] per layer (see StepEngine)
            let w = rng.glorot(node.fan_in, node.channels, node.w_elems);
            weights.push(w);
            shapes.push((1, node.w_elems));
            weights.push(vec![0.0; node.channels]);
            shapes.push((1, node.channels));
        }

        // Shard routing: contiguous, disjoint, exactly covering the
        // fleet (invariant tested below).
        let total = cfg.samples_per_worker * cfg.workers;
        let ds = build(&cfg.dataset, total, 0, cfg.seed)?;
        let k = ds.sample_elems();

        let (tx_up, rx_up): (Sender<Result<SignUpdate, usize>>, _) = channel();
        let mut handles = Vec::new();
        for wid in 0..cfg.workers {
            let lo = wid * cfg.samples_per_worker;
            let hi = lo + cfg.samples_per_worker;
            let shard_x = ds.train_x[lo * k..hi * k].to_vec();
            let shard_y = ds.train_y[lo..hi].to_vec();
            handles.push(spawn_worker(
                wid,
                graph.clone(),
                cfg.batch,
                shard_x,
                shard_y,
                cfg.seed ^ (wid as u64 + 1) * 0x9e37,
                tx_up.clone(),
            ));
        }
        Ok(Leader { cfg, handles, rx_up, weights, shapes })
    }

    /// Quorum: strict majority of the configured fleet.
    fn quorum(&self) -> usize {
        self.cfg.workers / 2 + 1
    }

    pub fn run(&mut self) -> Result<FedResult> {
        let mut round_losses = Vec::new();
        let mut committed = 0usize;
        let mut alive: Vec<bool> = vec![true; self.handles.len()];

        for round in 0..self.cfg.rounds {
            // broadcast
            for h in &self.handles {
                if !alive[h.id] {
                    continue;
                }
                let msg = RoundMsg::Work {
                    round,
                    weights: self.weights.clone(),
                    local_steps: self.cfg.local_steps,
                    lr: self.cfg.lr,
                };
                if h.tx.send(msg).is_err() {
                    alive[h.id] = false;
                }
            }
            // collect (workers that died mid-round count as dropouts)
            let expected = alive.iter().filter(|&&a| a).count();
            let mut updates: Vec<SignUpdate> = Vec::new();
            for _ in 0..expected {
                match self.rx_up.recv() {
                    Ok(Ok(u)) if u.round == round => updates.push(u),
                    Ok(Ok(_stale)) => {}
                    Ok(Err(wid)) => alive[wid] = false,
                    Err(_) => break,
                }
            }
            if updates.len() < self.quorum() {
                // below quorum: stall the round, never corrupt state
                round_losses.push(f32::NAN);
                continue;
            }
            let mean_loss =
                updates.iter().map(|u| u.mean_loss).sum::<f32>() / updates.len() as f32;
            round_losses.push(mean_loss);

            // sign-vote aggregation per layer
            for (li, (_r, n)) in self.shapes.iter().enumerate() {
                let layer_updates: Vec<&crate::bitops::BitMatrix> =
                    updates.iter().map(|u| &u.deltas[li]).collect();
                for u in &layer_updates {
                    if u.cols != *n {
                        bail!("worker sent malformed update (layer {li})");
                    }
                }
                let vote = sign_vote(&layer_updates);
                let w = &mut self.weights[li];
                for (i, &v) in vote.iter().enumerate() {
                    if v != 0 {
                        w[i] = (w[i] + self.cfg.fed_lr * v as f32).clamp(-1.0, 1.0);
                    }
                }
            }
            committed += 1;

            // test hook: simulate a straggler death
            if self.cfg.drop_worker == Some(round) {
                let victim = 0;
                let _ = self.handles[victim].tx.send(RoundMsg::Shutdown);
                alive[victim] = false;
            }
        }

        for h in &self.handles {
            let _ = h.tx.send(RoundMsg::Shutdown);
        }
        while let Some(h) = self.handles.pop() {
            let _ = h.join.join();
        }

        let n_weights: usize = self.weights.iter().map(Vec::len).sum();
        let uplink = n_weights / 8 + 16 * self.weights.len();
        Ok(FedResult {
            rounds_committed: committed,
            round_losses,
            final_weights: self.weights.clone(),
            uplink_bytes_per_round: uplink,
            uplink_reduction: (n_weights * 4) as f64 / uplink as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> FedConfig {
        FedConfig {
            workers: 3,
            rounds: 3,
            local_steps: 4,
            batch: 16,
            model: "mlp_mini".into(),
            dataset: "syn-mnist64".into(),
            lr: 0.003,
            fed_lr: 0.02,
            seed: 7,
            samples_per_worker: 64,
            drop_worker: None,
        }
    }

    #[test]
    fn rounds_commit_and_loss_drops() {
        let mut l = Leader::new(small_cfg()).unwrap();
        let r = l.run().unwrap();
        assert_eq!(r.rounds_committed, 3);
        assert_eq!(r.round_losses.len(), 3);
        assert!(
            r.round_losses[2] < r.round_losses[0],
            "{:?}",
            r.round_losses
        );
        assert!(r.uplink_reduction > 25.0, "{}", r.uplink_reduction);
    }

    #[test]
    fn survives_worker_dropout_above_quorum() {
        let mut cfg = small_cfg();
        cfg.drop_worker = Some(0); // kill one of three after round 0
        cfg.rounds = 3;
        let mut l = Leader::new(cfg).unwrap();
        let r = l.run().unwrap();
        // 2 of 3 still meets quorum (2): all rounds commit
        assert_eq!(r.rounds_committed, 3);
    }

    #[test]
    fn below_quorum_stalls_but_does_not_corrupt() {
        let mut cfg = small_cfg();
        cfg.workers = 1;
        cfg.drop_worker = Some(0); // sole worker dies after round 0
        cfg.rounds = 3;
        let mut l = Leader::new(cfg).unwrap();
        let w_before_len: usize = l.weights.iter().map(Vec::len).sum();
        let r = l.run().unwrap();
        assert!(r.rounds_committed >= 1);
        assert!(r.rounds_committed < 3);
        let w_after_len: usize = r.final_weights.iter().map(Vec::len).sum();
        assert_eq!(w_before_len, w_after_len);
        // weights stay clipped
        for w in &r.final_weights {
            assert!(w.iter().all(|v| v.abs() <= 1.0));
        }
    }

    #[test]
    fn weights_stay_in_unit_box() {
        let mut cfg = small_cfg();
        cfg.fed_lr = 0.9; // aggressive federated steps
        cfg.rounds = 4;
        let mut l = Leader::new(cfg).unwrap();
        let r = l.run().unwrap();
        for w in &r.final_weights {
            assert!(w.iter().all(|v| v.abs() <= 1.0));
        }
    }
}
