//! Word-level sign-vote tallies.
//!
//! A round's updates are packed [`BitMatrix`] panels (bit = +1 vote),
//! so per-weight vote counting is a *popcount problem*, not a loop
//! problem.  The word path stacks the updates' rows into a K×n bit
//! panel, word-transposes it (the Hacker's-Delight 64×64 block
//! transpose [`BitMatrix`] already has) to n×K — after which each
//! weight's K votes are contiguous words — and counts them with the
//! runtime-dispatched [`crate::bitops::simd::popcount`] kernels,
//! row-parallel over the [`Pool`].  At 10³ workers a weight's votes
//! are 16 words: one cache line of popcounts instead of 1000 bit
//! probes.  CI gates the word path ≥10× over the scalar reference at
//! that scale on the dense models.
//!
//! **Staleness discounting** keeps everything integer (and therefore
//! bit-exact and permutation-invariant): an update admitted `s`
//! rounds late votes with integer weight `max_staleness + 1 - s`.
//! Updates are grouped by weight — a fresh-only round is exactly one
//! popcount sweep — and a weight-w group adds `w · popcount` per
//! weight.
//!
//! **Hierarchy**: counts are associative where sign-majorities are
//! not (a majority of shard majorities ≠ the fleet majority), so
//! shard leaders forward [`LayerVotes`] — weighted one-counts plus
//! total weight — and the root [`LayerVotes::merge`]s them.  A
//! two-level tally is bit-identical to a flat one by construction;
//! the chaos tests pin it anyway.

use std::collections::BTreeMap;
use std::sync::mpsc;

use crate::bitops::{simd, BitMatrix, Pool};

/// Weighted vote counts for one weight layer: `ones[i]` is the total
/// weight voting +1 on weight `i`, `total` the weight of all votes.
/// The signed tally of weight `i` is `2·ones[i] − total`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerVotes {
    pub rows: usize,
    pub cols: usize,
    pub ones: Vec<u32>,
    pub total: u32,
}

impl LayerVotes {
    pub fn zeros(rows: usize, cols: usize) -> LayerVotes {
        LayerVotes { rows, cols, ones: vec![0; rows * cols], total: 0 }
    }

    /// Fold another shard's counts in (associative + commutative:
    /// two-level aggregation is bit-identical to flat).
    pub fn merge(&mut self, other: &LayerVotes) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols), "vote shape mismatch");
        for (a, b) in self.ones.iter_mut().zip(&other.ones) {
            *a += b;
        }
        self.total += other.total;
    }

    /// Majority sign per weight: +1 / −1, 0 on an exact (weighted) tie.
    pub fn signs(&self) -> Vec<i8> {
        self.ones
            .iter()
            .map(|&o| match (2 * o as i64).cmp(&(self.total as i64)) {
                std::cmp::Ordering::Greater => 1,
                std::cmp::Ordering::Less => -1,
                std::cmp::Ordering::Equal => 0,
            })
            .collect()
    }

    pub fn heap_bytes(&self) -> usize {
        self.ones.len() * 4
    }
}

/// Scalar reference tally: per-weight bit probes.  The word path is
/// asserted bit-exact against this (property-tested over random
/// shapes, off-word-grid cols, thread counts, and exact ties).
pub fn count_votes_scalar(updates: &[&BitMatrix], weights: &[u32]) -> LayerVotes {
    assert_eq!(updates.len(), weights.len());
    assert!(!updates.is_empty());
    let (rows, cols) = (updates[0].rows, updates[0].cols);
    let mut v = LayerVotes::zeros(rows, cols);
    for (u, &w) in updates.iter().zip(weights) {
        assert_eq!((u.rows, u.cols), (rows, cols), "malformed update shape");
        if w == 0 {
            continue;
        }
        v.total += w;
        for r in 0..rows {
            for c in 0..cols {
                if u.get(r, c) > 0.0 {
                    v.ones[r * cols + c] += w;
                }
            }
        }
    }
    v
}

/// Word-level tally (see module docs): stack → word-transpose → SIMD
/// popcount per weight, pool-parallel over weights, grouped by
/// staleness weight.  Bit-exact vs [`count_votes_scalar`].
pub fn count_votes_words(updates: &[&BitMatrix], weights: &[u32], pool: &Pool) -> LayerVotes {
    assert_eq!(updates.len(), weights.len());
    assert!(!updates.is_empty());
    let (rows, cols) = (updates[0].rows, updates[0].cols);
    let mut v = LayerVotes::zeros(rows, cols);
    // group by discount weight: staleness admits ≤ max_staleness + 1
    // distinct weights, so this is a handful of groups at most (one
    // for an all-fresh round)
    let mut groups: BTreeMap<u32, Vec<&BitMatrix>> = BTreeMap::new();
    for (u, &w) in updates.iter().zip(weights) {
        assert_eq!((u.rows, u.cols), (rows, cols), "malformed update shape");
        if w == 0 {
            continue;
        }
        groups.entry(w).or_default().push(u);
    }
    let mut stacked = BitMatrix::zeros(1, 1);
    let mut t = BitMatrix::zeros(1, 1);
    for (&w, group) in &groups {
        v.total += w * group.len() as u32;
        for rr in 0..rows {
            // stack the group's row rr: one update per stacked row —
            // packed rows have zero tail bits, so the stack does too
            stacked.reshape(group.len(), cols);
            let wpr = stacked.words_per_row;
            for (k, u) in group.iter().enumerate() {
                stacked.data[k * wpr..(k + 1) * wpr].copy_from_slice(u.row_words(rr));
            }
            // word transpose: weight i's votes become row i's words
            stacked.transpose_into(&mut t);
            let seg = &mut v.ones[rr * cols..(rr + 1) * cols];
            pool.run_rows(cols, 1, seg, |r0, band| {
                for (i, o) in band.iter_mut().enumerate() {
                    *o += w * simd::popcount(t.row_words(r0 + i)) as u32;
                }
            });
        }
    }
    v
}

/// Majority sign vote, word path, unit weights — the drop-in fast
/// twin of [`crate::federated::sign_vote`].
pub fn sign_vote_words(updates: &[&BitMatrix], pool: &Pool) -> Vec<i8> {
    let weights = vec![1u32; updates.len()];
    count_votes_words(updates, &weights, pool).signs()
}

/// Shard-parallel flat tally: splits one big update set across `pool`
/// worker *shards* (each tallied word-level, serial inside the shard
/// to avoid nested-pool inlining), then merges counts — the same
/// compute shape as the ShardLeader → root topology, collapsed into
/// one call for benches and the 10³-worker CLI path.
pub fn count_votes_sharded(
    updates: &[&BitMatrix],
    weights: &[u32],
    shards: usize,
) -> LayerVotes {
    assert_eq!(updates.len(), weights.len());
    assert!(!updates.is_empty());
    let shards = shards.clamp(1, updates.len());
    if shards == 1 {
        return count_votes_words(updates, weights, &Pool::serial());
    }
    let chunk = updates.len().div_ceil(shards);
    let (tx, rx) = mpsc::channel::<LayerVotes>();
    std::thread::scope(|s| {
        for (us, ws) in updates.chunks(chunk).zip(weights.chunks(chunk)) {
            let tx = tx.clone();
            s.spawn(move || {
                let _ = tx.send(count_votes_words(us, ws, &Pool::serial()));
            });
        }
    });
    drop(tx);
    let mut acc: Option<LayerVotes> = None;
    while let Ok(part) = rx.recv() {
        match &mut acc {
            None => acc = Some(part),
            Some(a) => a.merge(&part),
        }
    }
    acc.expect("at least one shard")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn pack(v: &[f32], rows: usize, cols: usize) -> BitMatrix {
        BitMatrix::pack(rows, cols, v)
    }

    fn random_updates(g: &mut Pcg32, k: usize, rows: usize, cols: usize) -> Vec<BitMatrix> {
        (0..k).map(|_| pack(&g.normal_vec(rows * cols), rows, cols)).collect()
    }

    #[test]
    fn word_matches_scalar_unit_weights() {
        let mut g = Pcg32::new(11);
        for (k, rows, cols) in
            [(1, 1, 1), (3, 1, 5), (5, 2, 64), (7, 3, 65), (9, 1, 130), (64, 1, 70), (65, 2, 33)]
        {
            let ms = random_updates(&mut g, k, rows, cols);
            let refs: Vec<&BitMatrix> = ms.iter().collect();
            let w = vec![1u32; k];
            for threads in [1, 2, 4] {
                let got = count_votes_words(&refs, &w, &Pool::new(threads));
                let want = count_votes_scalar(&refs, &w);
                assert_eq!(got, want, "k={k} {rows}x{cols} t{threads}");
            }
        }
    }

    #[test]
    fn word_matches_scalar_staleness_weights() {
        let mut g = Pcg32::new(12);
        let ms = random_updates(&mut g, 13, 1, 200);
        let refs: Vec<&BitMatrix> = ms.iter().collect();
        let w: Vec<u32> = (0..13).map(|i| [3u32, 1, 2, 0][i % 4]).collect();
        let got = count_votes_words(&refs, &w, &Pool::new(2));
        let want = count_votes_scalar(&refs, &w);
        assert_eq!(got, want);
        // zero-weight updates contribute nothing
        assert_eq!(want.total, w.iter().sum::<u32>());
    }

    #[test]
    fn signs_handle_exact_ties() {
        let a = pack(&[1.0, -1.0], 1, 2);
        let b = pack(&[-1.0, 1.0], 1, 2);
        let v = count_votes_scalar(&[&a, &b], &[1, 1]);
        assert_eq!(v.signs(), vec![0, 0]);
        // weighted tie: 2·(+1) vs 1·(+1)+1·(−1)… weight 2 fresh beats two stale
        let v = count_votes_scalar(&[&a, &b], &[2, 1]);
        assert_eq!(v.signs(), vec![1, -1]);
        // and a weighted exact tie
        let v = count_votes_scalar(&[&a, &b], &[2, 2]);
        assert_eq!(v.signs(), vec![0, 0]);
    }

    #[test]
    fn merge_is_flat_equivalent() {
        let mut g = Pcg32::new(13);
        let ms = random_updates(&mut g, 12, 1, 150);
        let refs: Vec<&BitMatrix> = ms.iter().collect();
        let w: Vec<u32> = (0..12).map(|i| 1 + (i % 3) as u32).collect();
        let flat = count_votes_scalar(&refs, &w);
        // two shards of 7 + 5
        let mut left = count_votes_scalar(&refs[..7], &w[..7]);
        let right = count_votes_scalar(&refs[7..], &w[7..]);
        left.merge(&right);
        assert_eq!(left, flat);
        // sharded word path agrees too, any shard count
        for shards in [1, 2, 3, 5] {
            assert_eq!(count_votes_sharded(&refs, &w, shards), flat, "shards={shards}");
        }
    }

    #[test]
    fn sign_vote_words_matches_module_reference() {
        let mut g = Pcg32::new(14);
        let ms = random_updates(&mut g, 9, 1, 99);
        let refs: Vec<&BitMatrix> = ms.iter().collect();
        assert_eq!(sign_vote_words(&refs, &Pool::new(2)), crate::federated::sign_vote(&refs));
    }
}
