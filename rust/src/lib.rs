//! `bnn-edge`: low-memory binary-neural-network training on the edge.
//!
//! Rust + JAX + Pallas reproduction of Wang et al., *"Enabling Binary
//! Neural Network Training on the Edge"* (2021).  Python/JAX/Pallas
//! exists only on the compile path (`python/compile` → `artifacts/`);
//! this crate owns the entire runtime: the PJRT executor, the pure-Rust
//! training engines (the paper's Raspberry-Pi prototype substitute),
//! the memory model, the energy model, the training coordinator and
//! the federated edge-fleet coordinator.
//!
//! Layer map (see DESIGN.md):
//! - [`runtime`]   — load + execute AOT HLO train/eval steps via PJRT
//! - [`models`]    — model zoo + shape inference (full-scale + mini)
//! - [`memmodel`]  — the paper's variable representation & lifetime
//!                   analysis (Table 2 and every memory column)
//! - [`bitops`]    — bit-packed XNOR-popcount GEMM substrate
//! - [`naive`]     — pure-Rust Algorithms 1 & 2 (measured memory path)
//! - [`optim`]     — Adam / SGD+momentum / Bop + LR schedules
//! - [`data`]      — synthetic edge datasets (MNIST/CIFAR/SVHN-like)
//! - [`energy`]    — memory-traffic energy model (Fig. 7c)
//! - [`memtrack`]  — tracking allocator: *measured* peak heap (Fig. 6)
//! - [`coordinator`] — run plans, step loop, metrics, checkpoints,
//!                   memory envelopes, batch auto-tuning
//! - [`serve`]     — forward-only packed inference: dynamic batching
//!                   + copy-on-publish weight snapshots
//! - [`federated`] — leader/worker fleet with sign-vote aggregation
//! - [`util`]      — zero-dependency substrates (JSON, f16, RNG, CLI,
//!                   stats, tables) replacing serde/clap/criterion,
//!                   which are unreachable in this offline image

pub mod bitops;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod federated;
pub mod memmodel;
pub mod memtrack;
pub mod models;
pub mod naive;
pub mod optim;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod util;
