//! Minimal JSON: recursive-descent parser and writer.
//!
//! Replaces serde_json (unreachable offline).  Supports the full JSON
//! grammar; numbers parse to f64.  Used for artifact manifests,
//! metrics logs, experiment configs and checkpoint metadata.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Object keys are ordered (BTreeMap) so output is
/// deterministic — checkpoints hash stably.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing ergonomics.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs: enough for manifests
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk = &self.b[start..start + len];
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{s}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#)
            .unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1.0);
        assert!(matches!(a[2].get("b").unwrap(), Json::Null));
    }

    #[test]
    fn parse_exponents() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64().unwrap(), -0.025);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse("\"\\u00e9 caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{e9} caf\u{e9}");
    }

    #[test]
    fn object_builder() {
        let mut o = Json::obj();
        o.set("x", 1.0.into()).set("y", "z".into());
        assert_eq!(o.to_string(), r#"{"x":1,"y":"z"}"#);
    }
}
