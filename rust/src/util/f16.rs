//! IEEE binary16 (`f16`) and bfloat16 conversion.
//!
//! The proposed training scheme stores weights, momenta and gradients
//! in 16-bit floats (Table 2).  The naive engine uses these routines
//! for *actual* 16-bit storage (so measured memory honestly halves),
//! and the HLO path's f32⇄f16 round-trips must match them bit-for-bit
//! — verified against the golden dumps.
//!
//! Round-to-nearest-even, same as XLA's `convert` op.

/// f32 -> IEEE binary16 bit pattern (round-to-nearest-even).
///
/// Production path: branch-light bit manipulation (Giesen's
/// float_to_half_fast3 shape) — ~3 ns/elem vs ~10 ns for the readable
/// reference below; exhaustively verified equal in tests.
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    const F32_INFTY: u32 = 255 << 23;
    const F16_MAX: u32 = (127 + 16) << 23;
    // 0.5f32: adding it to a subnormal-range value aligns the mantissa
    // so the integer difference is the rounded f16 subnormal
    const DENORM_MAGIC_BITS: u32 = ((127 - 15) + (23 - 10) + 1) << 23;
    let bits = x.to_bits();
    let sign = (bits >> 16) as u16 & 0x8000;
    let mut f = bits & 0x7fff_ffff;

    let o = if f >= F16_MAX {
        // overflow -> inf; NaN keeps a quiet payload
        if f > F32_INFTY {
            0x7e00
        } else {
            0x7c00
        }
    } else if f < (113 << 23) {
        // zero / f16-subnormal range: float-add rounding trick (RTNE
        // courtesy of the FPU)
        let v = f32::from_bits(f) + f32::from_bits(DENORM_MAGIC_BITS);
        (v.to_bits().wrapping_sub(DENORM_MAGIC_BITS)) as u16
    } else {
        // normal: rebias exponent, round mantissa to nearest even
        let mant_odd = (f >> 13) & 1;
        f = f.wrapping_add(0xc800_0fff); // ((15u32 - 127) << 23) + 0xfff
        f = f.wrapping_add(mant_odd);
        (f >> 13) as u16
    };
    sign | o
}

/// Readable reference implementation (kept for cross-verification).
pub fn f32_to_f16_bits_ref(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if e >= -14 {
        // normal f16
        let mut m = mant >> 13; // keep 10 bits
        let rest = mant & 0x1fff;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7c00;
            }
        }
        sign | ((he as u16) << 10) | (m as u16)
    } else if e >= -25 {
        // subnormal f16
        let full = mant | 0x0080_0000; // implicit 1
        let shift = (-14 - e) + 13;
        let m = full >> shift;
        let rest = full & ((1 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m = m;
        if rest > half || (rest == half && (m & 1) == 1) {
            m += 1;
        }
        sign | (m as u16)
    } else {
        sign // underflow to zero
    }
}

/// IEEE binary16 bit pattern -> f32 (exact), branch-light (Giesen's
/// half_to_float_fast4 shape): shift the payload into place and fix
/// the exponent bias with one multiply by 2^112, which also
/// normalizes f16 subnormals for free.  ~2 ns/elem; sits on the
/// optimizer-update hot loop (Table 2's f16 momenta) — see
/// EXPERIMENTS.md §Perf.
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    if h & 0x7c00 == 0 {
        // zero / f16-subnormal: exact integer scale, *avoiding* the
        // x86 denormal-multiply penalty (~100 cy) that Adam's tiny
        // second moments would otherwise hit every update
        let v = (h & 0x3ff) as f32 * f32::from_bits((127 - 24) << 23); // *2^-24
        return if h & 0x8000 != 0 { -v } else { v };
    }
    let magic = f32::from_bits((254 - 15) << 23); // 2^112
    let inf_thresh = f32::from_bits((127 + 16) << 23); // 65536.0
    let o = ((h as u32) & 0x7fff) << 13;
    let mut f = f32::from_bits(o) * magic;
    if f >= inf_thresh {
        // was f16 inf/nan: force f32 exponent to all-ones
        f = f32::from_bits(f.to_bits() | (255 << 23));
    }
    f32::from_bits(f.to_bits() | ((h as u32 & 0x8000) << 16))
}

/// Computed reference decode (kept for cross-verification + LUT build).
pub fn f16_bits_to_f32_ref(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            // value = mant * 2^-24; after k left-shifts e = -1-k and
            // the unbiased exponent is e - 13 (biased: e + 114)
            sign | (((e + 114) as u32) << 23) | (m << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip f32 through binary16 (the storage emulation used by the
/// HLO path; must match XLA `convert(f16) -> convert(f32)`).
pub fn q16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// f32 -> bfloat16 bit pattern (round-to-nearest-even).  Table 6 uses
/// bfloat16 (TPU-native) instead of binary16.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x40; // quiet NaN
    }
    let rest = bits & 0xffff;
    let mut hi = bits >> 16;
    if rest > 0x8000 || (rest == 0x8000 && (hi & 1) == 1) {
        hi += 1;
    }
    hi as u16
}

/// bfloat16 bit pattern -> f32 (exact).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Round-trip f32 through bfloat16.
pub fn qbf16(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// A 16-bit stored float vector: the naive engine's storage type for
/// W, momenta and gradients under the proposed scheme.  2 bytes per
/// element on the heap — the tracking allocator sees the real saving.
#[derive(Clone, Debug, Default)]
pub struct F16Vec(pub Vec<u16>);

impl F16Vec {
    pub fn from_f32(xs: &[f32]) -> F16Vec {
        F16Vec(xs.iter().map(|&x| f32_to_f16_bits(x)).collect())
    }

    pub fn zeros(n: usize) -> F16Vec {
        F16Vec(vec![0; n])
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn get(&self, i: usize) -> f32 {
        f16_bits_to_f32(self.0[i])
    }

    pub fn set(&mut self, i: usize, v: f32) {
        self.0[i] = f32_to_f16_bits(v);
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.0.iter().map(|&h| f16_bits_to_f32(h)).collect()
    }

    /// Decode into a caller-owned buffer (no allocation).
    pub fn write_f32_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.0.len());
        for (o, &h) in out.iter_mut().zip(&self.0) {
            *o = f16_bits_to_f32(h);
        }
    }

    /// Re-encode a caller buffer into this carrier in place (no
    /// allocation): lengths must match.
    pub fn fill_from_f32(&mut self, xs: &[f32]) {
        assert_eq!(self.0.len(), xs.len());
        for (h, &x) in self.0.iter_mut().zip(xs) {
            *h = f32_to_f16_bits(x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(q16(x), x, "{x}");
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16 max
        assert_eq!(f32_to_f16_bits(65536.0), 0x7c00); // -> inf
        assert_eq!(f16_bits_to_f32(0x3c00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7c00), f32::INFINITY);
    }

    #[test]
    fn subnormals_roundtrip() {
        let tiny = f16_bits_to_f32(0x0001); // smallest subnormal
        assert!(tiny > 0.0);
        assert_eq!(f32_to_f16_bits(tiny), 0x0001);
        assert_eq!(q16(tiny / 3.0), 0.0); // underflow
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10:
        // must round to even mantissa (1.0)
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(q16(x), 1.0);
        // 1 + 3*2^-11 is halfway between m=1 and m=2: rounds to even m=2
        let y = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(q16(y), 1.0 + 2.0 * 2f32.powi(-10));
    }

    #[test]
    fn fast_encode_matches_reference_exhaustive() {
        // all f16 values' f32 images round-trip identically via both
        // encoders, and a wide random sweep agrees bit-for-bit
        for bits in 0..=0xffffu16 {
            let x = f16_bits_to_f32_ref(bits);
            if x.is_nan() {
                assert!(f16_bits_to_f32(f32_to_f16_bits(x)).is_nan());
                continue;
            }
            assert_eq!(f32_to_f16_bits(x), f32_to_f16_bits_ref(x), "{bits:#06x}");
        }
        let mut g = crate::util::rng::Pcg32::new(99);
        for _ in 0..200_000 {
            let x = f32::from_bits(g.next_u32());
            if x.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(x), f32_to_f16_bits_ref(x), "{x}");
        }
    }

    #[test]
    fn lut_decode_matches_reference_exhaustive() {
        for bits in 0..=0xffffu16 {
            let a = f16_bits_to_f32(bits);
            let b = f16_bits_to_f32_ref(bits);
            if b.is_nan() {
                assert!(a.is_nan());
            } else {
                assert_eq!(a.to_bits(), b.to_bits(), "{bits:#06x}");
            }
        }
    }

    #[test]
    fn idempotent() {
        let g = &mut crate::util::rng::Pcg32::new(7);
        for _ in 0..10_000 {
            let x = (g.next_f32() - 0.5) * 1000.0;
            let q = q16(x);
            assert_eq!(q16(q), q);
        }
    }

    #[test]
    fn nan_and_signs() {
        assert!(q16(f32::NAN).is_nan());
        assert_eq!(q16(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(q16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn bf16_truncates_mantissa() {
        assert_eq!(qbf16(1.0), 1.0);
        assert_eq!(f32_to_bf16_bits(1.0), 0x3f80);
        // bf16 keeps f32 range: no overflow at f16's limit
        assert_eq!(qbf16(65536.0), 65536.0);
        assert!(qbf16(f32::NAN).is_nan());
    }

    #[test]
    fn bf16_round_nearest_even() {
        // halfway cases round to even
        let x = f32::from_bits(0x3f80_8000); // 1.0 + halfway
        assert_eq!(f32_to_bf16_bits(x), 0x3f80); // even stays
        let y = f32::from_bits(0x3f81_8000);
        assert_eq!(f32_to_bf16_bits(y), 0x3f82); // odd rounds up
    }

    #[test]
    fn f16vec_storage() {
        let v = F16Vec::from_f32(&[1.0, -0.5, 3.25]);
        assert_eq!(v.to_f32(), vec![1.0, -0.5, 3.25]);
        assert_eq!(std::mem::size_of_val(&v.0[..]), 6); // 2 B/elem
    }
}
