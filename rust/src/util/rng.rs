//! PCG32 RNG + distributions.  Deterministic, seedable, dependency-free
//! (crates.io `rand` is unreachable offline).  Used for dataset
//! synthesis, weight init, shuffling and the property-test harness.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Pcg32 {
        Pcg32::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Pcg32 {
        let mut r = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n).  Rejection-free bounded sampling
    /// (Lemire) is overkill here; modulo bias is < 2^-24 for our n.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Glorot/Xavier uniform init (paper Sec. 3 cites Glorot & Bengio).
    pub fn glorot(&mut self, fan_in: usize, fan_out: usize, n: usize) -> Vec<f32> {
        let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
        (0..n).map(|_| self.uniform(-limit, limit)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut g = Pcg32::new(3);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = g.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut g = Pcg32::new(4);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = g.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg32::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        g.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut g = Pcg32::new(6);
        for _ in 0..10_000 {
            assert!(g.below(7) < 7);
        }
    }

    #[test]
    fn glorot_limits() {
        let mut g = Pcg32::new(7);
        let w = g.glorot(100, 50, 1000);
        let lim = (6.0f32 / 150.0).sqrt();
        assert!(w.iter().all(|&x| x.abs() <= lim));
        assert!(w.iter().any(|&x| x.abs() > lim * 0.5));
    }
}
