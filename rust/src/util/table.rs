//! Paper-style aligned table rendering for the report module and
//! bench harness output (`results/*.md` and stdout).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    align: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            align: header
                .iter()
                .map(|_| Align::Right)
                .collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, idx: usize, a: Align) -> Table {
        self.align[idx] = a;
        self
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) -> &mut Table {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as GitHub-flavored markdown (also readable on a tty).
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String], out: &mut String| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                let cell = match self.align[i] {
                    Align::Left => format!(" {:<width$} ", c, width = w[i]),
                    Align::Right => format!(" {:>width$} ", c, width = w[i]),
                };
                out.push_str(&cell);
                out.push('|');
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        out.push('|');
        for (i, wi) in w.iter().enumerate() {
            let dashes = "-".repeat(*wi);
            match self.align[i] {
                Align::Left => out.push_str(&format!(" {dashes} |")),
                Align::Right => out.push_str(&format!(" {dashes}:|")),
            }
        }
        out.push('\n');
        for r in &self.rows {
            fmt_row(r, &mut out);
        }
        out
    }
}

/// Format a float with `d` decimals.
pub fn f(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

/// Format a reduction factor "3.71x".
pub fn factor(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format a signed pp delta "+0.35" / "-1.34".
pub fn pp(x: f64) -> String {
    format!("{x:+.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("T", &["Model", "Acc"]).align(0, Align::Left);
        t.row(&["mlp", "98.2"]);
        t.row(&["binarynet", "88.7"]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| Model     |  Acc |"));
        assert!(md.contains("| binarynet | 88.7 |"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["only-one"]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(factor(3.714), "3.71x");
        assert_eq!(pp(0.35), "+0.35");
        assert_eq!(pp(-1.34), "-1.34");
    }
}
