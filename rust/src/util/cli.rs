//! Tiny CLI flag parser (clap is unreachable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments.  Used by the launcher (`main.rs`) and every
//! example binary.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".into());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}={v}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Required string flag.
    pub fn req(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{key}"),
        }
    }

    /// Worker-thread count for the tiled GEMM backend: `--threads N`,
    /// with 0 / absent meaning auto-detect (see `bitops::Pool`).
    pub fn threads(&self) -> Result<usize> {
        self.usize_or("threads", 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flags_and_positionals() {
        // NB: a bare boolean flag greedily consumes a following
        // non-flag token, so put booleans last or use --flag=true.
        let a = parse("train extra --model mlp --steps=200 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("model"), Some("mlp"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 200);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.str_or("model", "mlp_mini"), "mlp_mini");
        assert_eq!(a.usize_or("batch", 64).unwrap(), 64);
        assert_eq!(a.f64_or("lr", 0.001).unwrap(), 0.001);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("--steps nope");
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn required() {
        let a = parse("--x 1");
        assert!(a.req("x").is_ok());
        assert!(a.req("y").is_err());
    }

    #[test]
    fn threads_flag() {
        assert_eq!(parse("--threads 4").threads().unwrap(), 4);
        assert_eq!(parse("run").threads().unwrap(), 0);
        assert!(parse("--threads many").threads().is_err());
    }

    #[test]
    fn negative_number_as_value() {
        // "--lr -0.5": -0.5 does not start with --, so consumed as value
        let a = parse("--lr -0.5");
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), -0.5);
    }
}
