//! Criterion-style micro/macro benchmark harness (criterion itself is
//! unreachable offline).  Warmup, fixed sample count, mean / median /
//! stddev / min, throughput helpers, and stable-schema JSON emission
//! (`BENCH_*.json`) so successive PRs can diff perf trajectories.
//! Every `rust/benches/*.rs` target (`harness = false`) drives this.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn stddev_s(&self) -> f64 {
        let m = self.mean_s();
        let v = self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / self.samples.len().max(1) as f64;
        v.sqrt()
    }

    pub fn min_s(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Throughput in Giga-ops/s given `ops` per iteration, from the
    /// median sample (robust to warmup/preemption outliers).
    pub fn giops(&self, ops: f64) -> f64 {
        ops / self.median_s() / 1e9
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<44} mean {:>10}  median {:>10}  sd {:>9}  n={}",
            self.name,
            fmt_time(self.mean_s()),
            fmt_time(self.median_s()),
            fmt_time(self.stddev_s()),
            self.samples.len()
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Benchmark runner: warms up for `warmup`, then collects `samples`
/// timed iterations of `f`.
pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    pub max_total: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            samples: 12,
            max_total: Duration::from_secs(30),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Bencher {
        Bencher {
            warmup: Duration::from_millis(50),
            samples: 5,
            max_total: Duration::from_secs(10),
            ..Default::default()
        }
    }

    /// Time `f` (which should include one full unit of work).
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.samples);
        let t0 = Instant::now();
        for _ in 0..self.samples {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_secs_f64());
            if t0.elapsed() > self.max_total {
                break;
            }
        }
        let r = BenchResult { name: name.to_string(), samples };
        println!("{}", r.summary());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// `black_box` stand-in: defeat the optimizer without unstable APIs.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// Write bench records as a pretty JSON array, creating parent
/// directories as needed.  Callers keep each record's schema stable
/// across PRs (e.g. `BENCH_gemm.json`:
/// `{backend, m, k, n, giops, threads}`) so perf is diffable.
pub fn write_json_rows<P: AsRef<std::path::Path>>(
    path: P,
    rows: Vec<Json>,
) -> std::io::Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, Json::Arr(rows).to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            samples: 3,
            ..Default::default()
        };
        let mut n = 0u64;
        let r = b.bench("spin", || {
            for i in 0..1000 {
                n = black_box(n.wrapping_add(i));
            }
        });
        assert_eq!(r.samples.len(), 3);
        assert!(r.mean_s() > 0.0);
        assert!(r.min_s() <= r.median_s());
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with("s"));
    }

    #[test]
    fn giops_from_median() {
        let r = BenchResult { name: "x".into(), samples: vec![0.5, 1.0, 2.0] };
        // 1e9 ops at 1.0s median = 1 GiOp/s
        assert!((r.giops(1e9) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn json_rows_roundtrip() {
        let dir = std::env::temp_dir().join("bnn_edge_bench_test");
        let path = dir.join("BENCH_test.json");
        let mut row = Json::obj();
        row.set("backend", Json::from("tiled"));
        row.set("giops", Json::from(12.5));
        write_json_rows(&path, vec![row]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].req("backend").unwrap().as_str().unwrap(), "tiled");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
