//! Zero-dependency substrates.
//!
//! This offline image can only resolve the `xla` crate's vendored
//! dependency closure — no serde, clap, tokio, rand or criterion — so
//! everything a production launcher normally pulls from crates.io is
//! implemented here, small and tested:
//!
//! - [`json`]  — recursive-descent JSON parser + writer (manifests,
//!              metrics, configs)
//! - [`f16`]   — IEEE binary16 and bfloat16 conversion (storage
//!              emulation for the naive engine + memory accounting)
//! - [`rng`]   — PCG32/xorshift RNG + normal sampling (datasets, init)
//! - [`stats`] — mean/stddev/percentiles + online Welford accumulator
//! - [`cli`]   — flag parser for the launcher and examples
//! - [`table`] — paper-style aligned table rendering
//! - [`bench`] — criterion-style timing harness for `cargo bench`

pub mod bench;
pub mod cli;
pub mod f16;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// Mebibytes, the paper's memory unit.
pub const MIB: f64 = 1024.0 * 1024.0;
/// Gibibytes (Table 6's unit).
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
