//! Summary statistics: Welford online accumulator, percentiles,
//! geometric mean (the paper reports geomean memory reductions).

/// Online mean/variance (Welford).  Numerically stable for long
/// metric streams (loss curves, step timings).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Welford {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// p-th percentile (0..=100) by linear interpolation; sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Geometric mean — the paper's aggregate for memory-reduction factors.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn geomean_factors() {
        // paper Table 4: geomean of the 5 memory reductions ~ 3.67x
        let r = [2.78f64, 4.17, 4.17, 3.71, 3.71];
        let g = geomean(&r);
        assert!((g - 3.67).abs() < 0.02, "{g}");
    }

    #[test]
    fn single_element() {
        let mut w = Welford::new();
        w.push(5.0);
        assert_eq!(w.mean(), 5.0);
        assert_eq!(w.var(), 0.0);
        assert_eq!(median(&[5.0]), 5.0);
    }
}
