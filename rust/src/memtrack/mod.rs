//! Tracking allocator: *measured* peak heap, the Fig. 6 counterpart
//! to the memory model's estimates.
//!
//! The paper measured its naïve C++ prototype with Valgrind on a
//! Raspberry Pi; here a `#[global_allocator]` wrapper counts live and
//! peak bytes with atomics (≈2 ns/alloc overhead — negligible next to
//! GEMM work).  Binaries opt in with:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: bnn_edge::memtrack::TrackingAlloc = bnn_edge::memtrack::TrackingAlloc;
//! ```
//!
//! `measure(f)` then returns the peak heap growth while `f` ran —
//! the number compared against `memmodel::breakdown` in Fig. 6, where
//! measured ≈ modeled + ~5% process overhead + batch-correlated
//! copy overhead (both reproduced here by real allocations).
//!
//! Thread-safety: the live/peak counters are `AtomicUsize`, so
//! allocations from *any* thread — including the persistent GEMM /
//! bit-im2col worker pool (`bitops::Pool`) executing bands inside a
//! measured scope — are attributed to that scope's peak.  Concurrent
//! `measure` scopes are serialized by an internal mutex (the peak
//! baseline is a single global), so calls from multiple threads are
//! safe, just ordered.  The measured counterpart of the conv-path
//! model (`memmodel::conv_cols_transient`) lives in
//! rust/tests/memtrack_conv.rs.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static ENABLED: AtomicBool = AtomicBool::new(false);
/// Monotonic count of heap allocation *events* (allocs + grow
/// reallocs; frees are not counted).  The step-arena work asserts
/// this stays flat across steady-state training steps — a stronger
/// invariant than a flat peak, which reuse-through-malloc could fake.
static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);

/// Global-allocator wrapper delegating to the system allocator while
/// maintaining live/peak counters.
pub struct TrackingAlloc;

// SAFETY: delegates allocation to `System`; only adds atomic counters.
unsafe impl GlobalAlloc for TrackingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            track_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        track_dealloc(layout.size());
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = unsafe { System.realloc(ptr, layout, new_size) };
        if !p.is_null() {
            track_dealloc(layout.size());
            track_alloc(new_size);
        }
        p
    }
}

#[inline]
fn track_alloc(size: usize) {
    ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
    let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
    if ENABLED.load(Ordering::Relaxed) {
        PEAK.fetch_max(live, Ordering::Relaxed);
    }
}

#[inline]
fn track_dealloc(size: usize) {
    LIVE.fetch_sub(size, Ordering::Relaxed);
}

/// Live heap bytes right now (0 if no TrackingAlloc installed).
pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Heap allocation events so far (0 if no TrackingAlloc installed).
/// Diff across a scope to count the allocations it performed: the
/// steady-state training-step tests assert the diff is *zero* once
/// the step arena is warm — a flat peak alone can be faked by the
/// system allocator reusing freed blocks.
pub fn alloc_count() -> usize {
    ALLOC_EVENTS.load(Ordering::Relaxed)
}

/// True when a TrackingAlloc is installed as the global allocator
/// (detected by live_bytes becoming non-zero after an allocation).
pub fn is_active() -> bool {
    let before = live_bytes();
    let v = std::hint::black_box(vec![0u8; 4096]);
    let during = live_bytes();
    drop(v);
    during > before
}

/// Measured peak-heap statistics for a scoped run.
#[derive(Clone, Copy, Debug)]
pub struct PeakStats {
    /// Live bytes when the scope began.
    pub baseline: usize,
    /// Maximum live bytes observed inside the scope.
    pub peak: usize,
    /// Heap allocation events performed inside the scope.
    pub allocs: usize,
}

impl PeakStats {
    /// Peak growth over baseline — the "peak memory use of the
    /// training step" of Figs. 6/7.
    pub fn growth(&self) -> usize {
        self.peak.saturating_sub(self.baseline)
    }

    pub fn growth_mib(&self) -> f64 {
        self.growth() as f64 / crate::util::MIB
    }
}

/// Serializes `measure` scopes: PEAK/ENABLED are process-global, so
/// two overlapping scopes would clobber each other's baseline.  Held
/// across the measured closure; allocator paths never touch it.
static MEASURE_SCOPE: Mutex<()> = Mutex::new(());

std::thread_local! {
    /// True while this thread owns MEASURE_SCOPE — lets a nested
    /// `measure` on the same thread fold into the outer scope
    /// instead of self-deadlocking on the mutex.
    static IN_MEASURE: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Run `f` with peak tracking and return (result, stats).
///
/// Safe to call from any thread (scopes from different threads are
/// serialized), and the atomic counters attribute worker-thread
/// allocations — e.g. the tiled GEMM pool's bands — to the
/// enclosing scope.  A *nested* call on the same thread does not
/// deadlock: it folds into the outer scope (shared peak watermark,
/// own baseline).
pub fn measure<T, F: FnOnce() -> T>(f: F) -> (T, PeakStats) {
    if IN_MEASURE.with(|c| c.get()) {
        // nested on the measuring thread: reuse the outer watermark
        let baseline = live_bytes();
        let a0 = alloc_count();
        let out = f();
        let peak = PEAK.load(Ordering::Relaxed).max(baseline);
        return (out, PeakStats { baseline, peak, allocs: alloc_count() - a0 });
    }
    let _guard = MEASURE_SCOPE.lock().unwrap_or_else(|e| e.into_inner());
    IN_MEASURE.with(|c| c.set(true));
    let baseline = live_bytes();
    let a0 = alloc_count();
    PEAK.store(baseline, Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
    let out = f();
    ENABLED.store(false, Ordering::Relaxed);
    IN_MEASURE.with(|c| c.set(false));
    let peak = PEAK.load(Ordering::Relaxed);
    (out, PeakStats { baseline, peak, allocs: alloc_count() - a0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    // NB: the lib test harness does NOT install TrackingAlloc (only
    // binaries do), so these tests exercise the bookkeeping API
    // directly rather than real allocation flow.

    #[test]
    fn peak_stats_growth() {
        let s = PeakStats { baseline: 1000, peak: 5096, allocs: 0 };
        assert_eq!(s.growth(), 4096);
        let s2 = PeakStats { baseline: 10, peak: 5, allocs: 0 };
        assert_eq!(s2.growth(), 0); // saturates
    }

    #[test]
    fn counters_move() {
        let a0 = alloc_count();
        track_alloc(128);
        assert!(live_bytes() >= 128);
        assert!(alloc_count() > a0, "alloc events must count up");
        track_dealloc(128);
        // frees do not decrement the event counter
        assert!(alloc_count() > a0);
    }

    #[test]
    fn measure_returns_value() {
        let (v, st) = measure(|| 40 + 2);
        assert_eq!(v, 42);
        assert!(st.peak >= st.baseline);
    }

    #[test]
    fn nested_measure_does_not_deadlock() {
        let (v, outer) = measure(|| {
            let (inner_v, inner) = measure(|| 40 + 2);
            assert_eq!(inner_v, 42);
            assert!(inner.peak >= inner.baseline);
            inner_v
        });
        assert_eq!(v, 42);
        assert!(outer.peak >= outer.baseline);
    }

    #[test]
    fn concurrent_measures_are_serialized() {
        // overlapping scopes from several threads must each see a
        // coherent baseline ≤ peak (the scope mutex orders them)
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    measure(|| std::hint::black_box(vec![0u8; 1024 * (i + 1)]).len())
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let (len, st) = h.join().unwrap();
            assert_eq!(len, 1024 * (i + 1));
            assert!(st.peak >= st.baseline);
        }
    }
}
