//! Pure-Rust training engines: Algorithms 1 and 2, end to end.
//!
//! These are the paper's Raspberry-Pi prototypes (Sec. 6.2), rebuilt:
//!
//! - [`StandardTrainer`] — Algorithm 1: float32 everything, ℓ2 batch
//!   norm.  The paper's "naïve C++ (standard)".
//! - [`ProposedTrainer`] — Algorithm 2: *actually* bit-packed binary
//!   activations/STE masks/weight gradients and f16-stored weights,
//!   momenta and gradients, ℓ1 + BNN-specific batch norm.  The
//!   paper's "naïve C++ (proposed)" — measured memory really shrinks.
//!
//! Each comes in three compute modes (Fig. 7's naïve vs CBLAS story,
//! plus the tiled multi-threaded backend — see [`crate::bitops::Backend`]):
//!
//! - `Accel::Naive`   — direct convolution/GEMM loops, minimal
//!   buffers: lowest memory, slowest.
//! - `Accel::Blocked` — cache-blocked GEMM and the XNOR path for
//!   binary×binary: ~order-of-magnitude faster, buying speed with
//!   transient buffer memory as the paper reports (1.59–2.08× memory
//!   for 8.6–29.8× speed).  Binary conv layers run the **fused**
//!   pipeline — `bitops::im2col_packed` signs and packs patches
//!   straight into bit panels, so the f32 im2col buffer only remains
//!   on the real-input first layer.
//! - `Accel::Tiled(threads)` — the blocked memory strategy with the
//!   SIMD/4×4 tiled kernels, bit-im2col and GEMM both row-parallel
//!   over the persistent worker pool (`0` = auto).
//!
//! Both engines cache each layer's binarized weights in a
//! [`crate::bitops::PackedWeightCache`], packing at most once per
//! step (invalidated on weight update).
//!
//! Since PR 4 the engines execute a *general* layer graph: strided
//! and VALID convs (explicit [`crate::bitops::ConvGeom`] threaded
//! through the whole packed pipeline), general kside/stride max-pools,
//! global average pooling, and residual blocks (ResNetE two-conv and
//! Bi-Real single-conv skips with the strided 1×1-avg-pool +
//! channel-duplication downsample shortcut).  The layer-graph control
//! flow is shared between the engines (`ops`); each engine implements
//! only its per-matmul-layer storage/precision policy.  Every zoo
//! model — including `cnv` and the full/mini residual nets — builds a
//! plan and takes gradient steps on all `Accel` tiers.
//!
//! Both engines are cross-validated against the AOT HLO step (same
//! algorithm, same numerics class) in rust/tests/.

pub(crate) mod arena;
pub(crate) mod ops;
mod plan;
mod proposed;
pub mod schedule;
mod standard;

pub use plan::{LayerPlan, Plan, SkipGeom};
pub use proposed::ProposedTrainer;
pub use standard::StandardTrainer;
// the f32 im2col/col2im/transpose references, public for the conv
// perf bench and the memtrack/property tests that diff the fused
// bit-im2col and the streaming conv backward against them
pub use standard::{col2im, im2col, transpose};
// the general max-pool kernels, public for the property tests that
// diff them against a per-window reference (the serve engine also
// replays the forward kernel)
pub use standard::{maxpool_backward_into, maxpool_forward_into, pool_out_dims};
// forward kernels the serve engine's inference schedule replays
// (crate::serve mirrors each trainer's forward branch structure
// exactly, for bit-identical logits)
pub(crate) use proposed::bn_l1_forward_packed_into;
pub(crate) use standard::{bn_l2_forward_into, conv_direct_into, im2col_into, sign_into};

use anyhow::Result;

use crate::models::Graph;
use crate::util::rng::Pcg32;

/// Compute mode (Fig. 7: naïve vs "CBLAS"-accelerated, plus the
/// tiled multi-threaded backend of this crate's perf work).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Accel {
    Naive,
    Blocked,
    /// 4×4 tiled kernels, row-parallel over N worker threads
    /// (`0` = auto-detect).  Memory strategy is the same
    /// memory-for-speed trade as `Blocked`.
    Tiled(usize),
}

impl Accel {
    /// The GEMM dispatch tier this mode runs on.
    pub fn backend(&self) -> crate::bitops::Backend {
        match self {
            Accel::Naive => crate::bitops::Backend::Naive,
            Accel::Blocked => crate::bitops::Backend::Blocked,
            Accel::Tiled(t) => crate::bitops::Backend::Tiled { threads: *t },
        }
    }
}

/// Engine-agnostic step interface used by the coordinator, benches
/// and the federated workers.
pub trait StepEngine {
    /// One training step on a batch; returns (loss, accuracy).
    fn train_step(&mut self, x: &[f32], labels: &[usize], lr: f32) -> Result<(f32, f32)>;
    /// Forward-only evaluation; returns (loss, accuracy).
    fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)>;
    /// Bytes of persistent state currently held (weights, momenta,
    /// gradient accumulators, packed-weight cache) — *measured*, not
    /// modeled.
    fn state_bytes(&self) -> usize;
    /// Batch size the engine was built for.
    fn batch(&self) -> usize;
    /// Microbatch the step executes in (== batch unless gradient
    /// accumulation was requested).
    fn microbatch(&self) -> usize {
        self.batch()
    }
    /// Bytes resident in the engine's step arena (0 for engines
    /// without one, e.g. the HLO runtime).  `state_bytes() +
    /// arena_bytes()` after a warmup step is the engine's whole
    /// steady-state footprint — the number `memmodel::step_envelope`
    /// prices and `benches/perf_step.rs` reports.
    fn arena_bytes(&self) -> usize {
        0
    }
    /// Flat snapshot of the latent weights (checkpointing/federated).
    fn weights_snapshot(&self) -> Vec<Vec<f32>>;
    /// Overwrite latent weights from a snapshot.
    fn load_weights(&mut self, w: &[Vec<f32>]) -> Result<()>;
    /// True when the engine's arena is quiescent (no pass active,
    /// every slot parked).  The multi-tenant runtime asserts this at
    /// every preemption boundary before a tenant changes lanes;
    /// engines without an arena are trivially idle.
    fn arena_idle(&self) -> bool {
        true
    }
}

/// Build an engine by algorithm name ("standard" | "proposed").
pub fn build_engine(
    algo: &str,
    graph: &Graph,
    batch: usize,
    optimizer: &str,
    accel: Accel,
    seed: u64,
) -> Result<Box<dyn StepEngine>> {
    build_engine_micro(algo, graph, batch, 0, optimizer, accel, seed)
}

/// [`build_engine`] with microbatch gradient accumulation: the step
/// executes in `microbatch`-sized chunks (0 = whole batch) with
/// per-chunk (ghost) batch-norm statistics and ∂W/∂β accumulated
/// across chunks before one optimizer update, so peak step memory
/// scales with the microbatch instead of the logical batch.
pub fn build_engine_micro(
    algo: &str,
    graph: &Graph,
    batch: usize,
    microbatch: usize,
    optimizer: &str,
    accel: Accel,
    seed: u64,
) -> Result<Box<dyn StepEngine>> {
    Ok(match algo {
        "standard" => Box::new(StandardTrainer::with_microbatch(
            graph, batch, microbatch, optimizer, accel, seed,
        )?),
        "proposed" => Box::new(ProposedTrainer::with_microbatch(
            graph, batch, microbatch, optimizer, accel, seed,
        )?),
        _ => anyhow::bail!("unknown algo '{algo}' (standard|proposed)"),
    })
}

/// [`build_engine_micro`], but with a `Send` bound on the box so the
/// engine can be checked out by whichever multi-tenant lane thread
/// picks its tenant next.  Both naive trainers are plain owned data
/// (auto-`Send`); only the boxed trait object loses that, hence the
/// separate builder.
pub fn build_engine_micro_send(
    algo: &str,
    graph: &Graph,
    batch: usize,
    microbatch: usize,
    optimizer: &str,
    accel: Accel,
    seed: u64,
) -> Result<Box<dyn StepEngine + Send>> {
    Ok(match algo {
        "standard" => Box::new(StandardTrainer::with_microbatch(
            graph, batch, microbatch, optimizer, accel, seed,
        )?),
        "proposed" => Box::new(ProposedTrainer::with_microbatch(
            graph, batch, microbatch, optimizer, accel, seed,
        )?),
        _ => anyhow::bail!("unknown algo '{algo}' (standard|proposed)"),
    })
}

// ------------------------------------------------------- shared math

/// Softmax cross-entropy + gradient w.r.t. logits (divided by B).
/// Returns (mean loss, accuracy); writes dlogits in place.
pub(crate) fn softmax_xent_grad(
    logits: &[f32],
    labels: &[usize],
    classes: usize,
    dlogits: &mut [f32],
) -> (f32, f32) {
    let b = labels.len();
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for i in 0..b {
        let row = &logits[i * classes..(i + 1) * classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let mut argmax = 0;
        for (c, &v) in row.iter().enumerate() {
            let p = (v - max).exp() / denom;
            dlogits[i * classes + c] = (p - if labels[i] == c { 1.0 } else { 0.0 }) / b as f32;
            if v > row[argmax] {
                argmax = c;
            }
        }
        let p_true = (row[labels[i]] - max).exp() / denom;
        loss -= (p_true.max(1e-12)).ln() as f64;
        if argmax == labels[i] {
            correct += 1;
        }
    }
    ((loss / b as f64) as f32, correct as f32 / b as f32)
}

/// Glorot init for a layer plan, mirroring python init_params.
pub(crate) fn glorot_init(rng: &mut Pcg32, fan_in: usize, fan_out: usize, n: usize) -> Vec<f32> {
    rng.glorot(fan_in, fan_out, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_xent_uniform() {
        // uniform logits: loss = ln(C), acc = chance-ish
        let classes = 4;
        let logits = vec![0.0; 2 * classes];
        let mut d = vec![0.0; 2 * classes];
        let (loss, _) = softmax_xent_grad(&logits, &[1, 2], classes, &mut d);
        assert!((loss - (classes as f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero
        for i in 0..2 {
            let s: f32 = d[i * classes..(i + 1) * classes].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_xent_confident_correct() {
        let logits = vec![10.0, -10.0, -10.0];
        let mut d = vec![0.0; 3];
        let (loss, acc) = softmax_xent_grad(&logits, &[0], 3, &mut d);
        assert!(loss < 1e-3);
        assert_eq!(acc, 1.0);
        assert!(d[0].abs() < 1e-3); // p ~ 1, grad ~ 0
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let classes = 5;
        let mut logits = vec![0.3, -0.2, 1.1, 0.0, -0.7];
        let labels = [2usize];
        let mut d = vec![0.0; classes];
        let (l0, _) = softmax_xent_grad(&logits, &labels, classes, &mut d);
        let eps = 1e-3;
        for c in 0..classes {
            logits[c] += eps;
            let mut tmp = vec![0.0; classes];
            let (l1, _) = softmax_xent_grad(&logits, &labels, classes, &mut tmp);
            logits[c] -= eps;
            let fd = (l1 - l0) / eps;
            assert!((fd - d[c]).abs() < 1e-3, "c={c} fd={fd} an={}", d[c]);
        }
    }
}
