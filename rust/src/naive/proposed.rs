//! Algorithm 2 — the paper's proposed low-memory BNN training step,
//! with *genuinely* reduced storage:
//!
//! - retained activations: **bit-packed** X̂ (matmul inputs) and
//!   BN-output signs, plus packed STE masks — 1 bit each (Table 2's
//!   "X" and mask rows realized 32× smaller on the heap);
//! - per-channel BN statistics ψ, ω: f16;
//! - latent weights / momenta: f16 [`Store`];
//! - weight gradients: bit-packed ∂Ŵ retained through the update
//!   phase, consumed via `update_fn` with the `1/√N_l` attenuation
//!   (Alg. 2 lines 16+18) — no f32 gradient buffer ever exists;
//! - gradients flowing between layers are held in f16 across layer
//!   boundaries (∂X/∂Y rows of Table 2).
//!
//! The forward f32 activation between a BN and the next binarization
//! is transient, exactly as the paper's lifetime analysis assumes.
//! Residual skips (and their gradients at the block boundary) are
//! f32 — the high-precision skip path of Sec. 2 — and are handled by
//! the shared layer-graph core in [`super::ops`].

use anyhow::{bail, Result};

use super::ops::{self, EngineOps};
use super::plan::{LayerPlan, Plan};
use super::standard::{col2im, conv_direct, im2col, maxpool_forward, sign_vec, transpose};
use super::{glorot_init, softmax_xent_grad, Accel, StepEngine};
use crate::bitops::{
    conv_dx_streaming, im2col_packed, BitMask, BitMatrix, ConvGeom, PackedWeightCache,
};
use crate::models::Graph;
use crate::optim::{OptState, Store};
use crate::util::f16::F16Vec;
use crate::util::rng::Pcg32;

/// Per-matmul-layer retained residuals (Alg. 2's memory inventory).
#[derive(Default)]
struct Residuals {
    /// Bit-packed binarized matmul input (rows × k); None for the
    /// first layer (f32 input kept separately).
    xhat: Option<BitMatrix>,
    /// f32 copy of the first layer's input batch.
    x_first: Option<Vec<f32>>,
    /// Packed STE mask 1{|x| ≤ 1} over the matmul input.
    ste: Option<BitMask>,
    /// Packed signs of the BN output (x_next − β) — the backward's
    /// only activation dependence (the paper's key trick).
    bn_sign: Option<BitMatrix>,
    /// ψ (mean absolute deviation) and ω (mean magnitude), f16.
    psi: F16Vec,
    omega: F16Vec,
    /// Bit-packed binarized weight gradient ∂Ŵ (retained to update).
    dw_sign: Option<BitMatrix>,
    /// ∂β (channels are tiny; f32).
    dbeta: Vec<f32>,
}

pub struct ProposedTrainer {
    plan: Plan,
    batch: usize,
    accel: Accel,
    optimizer: String,
    /// Latent weights, f16-stored (binary-valued ±1 under Bop).
    weights: Vec<Store>,
    betas: Vec<Store>,
    opt_w: Vec<OptState>,
    opt_b: Vec<OptState>,
    res: Vec<Residuals>,
    pool_masks: Vec<BitMask>,
    /// Per-step packed Ŵᵀ cache: each layer packs at most once per
    /// step (invalidated when the update phase writes new weights).
    wcache: PackedWeightCache,
}

impl ProposedTrainer {
    pub fn new(
        graph: &Graph,
        batch: usize,
        optimizer: &str,
        accel: Accel,
        seed: u64,
    ) -> Result<ProposedTrainer> {
        let plan = Plan::from_graph(graph)?;
        if batch == 0 {
            bail!("batch must be positive");
        }
        let mut rng = Pcg32::new(seed);
        let mut weights = Vec::new();
        let mut betas = Vec::new();
        let mut opt_w = Vec::new();
        let mut opt_b = Vec::new();
        for l in &plan.layers {
            let wl = l.weight_len();
            if wl == 0 {
                continue;
            }
            let mut w = glorot_init(&mut rng, l.fan_in(), l.channels(), wl);
            if optimizer == "bop" {
                for v in w.iter_mut() {
                    *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                }
            }
            weights.push(Store::from_f32(w, true)); // f16 latent
            betas.push(Store::from_f32(vec![0.0; l.channels()], true));
            opt_w.push(OptState::new(optimizer, wl, true));
            opt_b.push(OptState::new(optimizer, l.channels(), true));
        }
        let wcache = PackedWeightCache::new(weights.len());
        Ok(ProposedTrainer {
            plan,
            batch,
            accel,
            optimizer: optimizer.to_string(),
            weights,
            betas,
            opt_w,
            opt_b,
            res: Vec::new(),
            pool_masks: Vec::new(),
            wcache,
        })
    }

    /// Total weight packs so far — the once-per-step probe the tests
    /// (and the ISSUE acceptance criteria) assert on.
    pub fn weight_pack_count(&self) -> usize {
        self.wcache.pack_count()
    }

    /// Packed Ŵᵀ (n×k) for layer `wi`, straight from the f16 sign
    /// bits — cached so repeat uses within a step cost nothing.
    fn packed_wt(&mut self, wi: usize, k: usize, n: usize) -> &BitMatrix {
        let weights = &self.weights;
        self.wcache.wt(wi, || match &weights[wi] {
            Store::F16(v) => BitMatrix::pack_f16_t(&v.0, k, n),
            Store::F32(v) => {
                let wt = transpose(v, k, n);
                BitMatrix::pack(n, k, &wt)
            }
        })
    }

    /// Binary matmul Y = X̂ Ŵ: XNOR-popcount path over the cached
    /// packed Ŵᵀ (no per-matmul re-pack — §Perf).
    fn bin_matmul(&mut self, xhat: &BitMatrix, wi: usize, k: usize, n: usize) -> Vec<f32> {
        let backend = self.accel.backend();
        let mut y = vec![0.0f32; xhat.rows * n];
        let wpt = self.packed_wt(wi, k, n);
        backend.xnor_gemm(xhat, wpt, &mut y);
        y
    }

    /// dX = dY Ŵᵀ — real × binary GEMM.  The accelerated path unpacks
    /// the *cached* packed Ŵᵀ into a transient ±1 f32 buffer (the
    /// paper's memory-for-speed trade; no re-pack, no f32 transpose).
    fn real_bin_matmul_t(
        &mut self,
        dy: &[f32],
        wi: usize,
        rows: usize,
        k: usize,
        n: usize,
    ) -> Vec<f32> {
        let mut dx = vec![0.0f32; rows * k];
        match self.accel {
            Accel::Naive => {
                let w = self.weights[wi].to_f32();
                for r in 0..rows {
                    let dyr = &dy[r * n..(r + 1) * n];
                    let dxr = &mut dx[r * k..(r + 1) * k];
                    for (j, &g) in dyr.iter().enumerate() {
                        if g == 0.0 {
                            continue;
                        }
                        for (kk, dxv) in dxr.iter_mut().enumerate() {
                            let s = if w[kk * n + j] >= 0.0 { 1.0 } else { -1.0 };
                            *dxv += g * s;
                        }
                    }
                }
            }
            _ => {
                let backend = self.accel.backend();
                let wt = self.packed_wt(wi, k, n).unpack(); // (n×k) signs
                backend.gemm_f32(rows, n, k, dy, &wt, &mut dx);
            }
        }
        dx
    }

    /// ∂W = X̂ᵀ ∂Y — binary × real GEMM, immediately binarized into a
    /// packed ∂Ŵ (the f32 accumulator is one K-row at a time).
    fn dw_packed(
        &self,
        xhat: Option<&BitMatrix>,
        x_first: Option<&[f32]>,
        dy: &[f32],
        rows: usize,
        k: usize,
        n: usize,
    ) -> BitMatrix {
        let mut dw_bits = BitMatrix::zeros(k, n);
        match self.accel {
            Accel::Blocked | Accel::Tiled(_) => {
                // k×n f32 dW accumulator, then pack.  The contraction
                // runs straight off the *retained packed* X̂ — the
                // (rows×k) f32 unpack and (k×rows) transpose of the
                // pre-fusion path (the backward's rows×k transients)
                // never exist.  Bit-identical to that path: per-cell
                // accumulation order is unchanged.
                let backend = self.accel.backend();
                let mut dw = vec![0.0f32; k * n];
                match xhat {
                    Some(xh) => backend.packed_at_gemm_f32(xh, dy, n, &mut dw),
                    None => {
                        // real-input first layer: f32 input, but the
                        // transpose copy is gone (AᵀB GEMM)
                        backend.gemm_f32_at(rows, k, n, x_first.unwrap(), dy, &mut dw);
                    }
                }
                dw_bits = BitMatrix::pack(k, n, &dw);
            }
            Accel::Naive => {
                // row-at-a-time accumulator: k-loop outer keeps only
                // an n-sized f32 scratch alive
                let mut acc = vec![0.0f32; n];
                for kk in 0..k {
                    acc.fill(0.0);
                    for r in 0..rows {
                        let xv = match xhat {
                            Some(xh) => xh.get(r, kk),
                            None => x_first.unwrap()[r * k + kk],
                        };
                        if xv == 0.0 {
                            continue;
                        }
                        let dyr = &dy[r * n..(r + 1) * n];
                        for (j, &g) in dyr.iter().enumerate() {
                            acc[j] += xv * g;
                        }
                    }
                    for (j, &v) in acc.iter().enumerate() {
                        if v >= 0.0 {
                            dw_bits.data[kk * dw_bits.words_per_row + (j >> 6)] |=
                                1u64 << (j & 63);
                        }
                    }
                }
            }
        }
        dw_bits
    }

    fn forward(&mut self, x: &[f32], retain: bool) -> Result<Vec<f32>> {
        self.res.clear();
        self.pool_masks.clear();
        let layers = self.plan.layers.clone();
        ops::forward_plan(self, &layers, x, retain)
    }

    fn backward(&mut self, dlogits: Vec<f32>, lr: f32) -> Result<()> {
        let layers = self.plan.layers.clone();
        ops::backward_plan(self, &layers, dlogits, lr)?;

        // ---- update phase (Alg. 2 lines 17-19): consume packed ∂Ŵ
        for st in self.opt_w.iter_mut().chain(self.opt_b.iter_mut()) {
            st.tick();
        }
        let is_bop = self.optimizer == "bop";
        for (wi, res) in self.res.iter().enumerate() {
            let dw = res.dw_sign.as_ref().expect("backward filled dw");
            let fan_in = dw.rows;
            let atten = 1.0 / (fan_in as f32).sqrt();
            let n = dw.cols;
            let wpr = dw.words_per_row;
            let data = &dw.data;
            self.opt_w[wi].update_fn(
                &mut self.weights[wi],
                |i| {
                    let (r, c) = (i / n, i % n);
                    let bit = data[r * wpr + (c >> 6)] >> (c & 63) & 1;
                    (if bit == 1 { 1.0 } else { -1.0 }) * atten
                },
                lr,
                !is_bop,
            );
            self.opt_b[wi].update(&mut self.betas[wi], &res.dbeta, lr, false);
        }
        // weights changed: cached packed Ŵᵀ is stale
        self.wcache.invalidate_all();
        Ok(())
    }

    /// Shared matmul+BN forward.  `conv`: Some(geometry).
    #[allow(clippy::too_many_arguments)]
    fn matmul_bn_forward(
        &mut self,
        cur: Vec<f32>,
        rows: usize,
        k: usize,
        n: usize,
        first: bool,
        wi: usize,
        retain: bool,
        conv: Option<ConvGeom>,
    ) -> Result<Vec<f32>> {
        let mut res = Residuals::default();
        let y: Vec<f32>;
        if first {
            // real-input layer: f32 GEMM against sign(W)
            let backend = self.accel.backend();
            let w = sign_vec(&self.weights[wi].to_f32());
            y = match conv {
                None => {
                    let mut out = vec![0.0f32; rows * n];
                    backend.gemm_f32(rows, k, n, &cur, &w, &mut out);
                    out
                }
                Some(g) => match self.accel {
                    Accel::Naive => conv_direct(&cur, &w, self.batch, g, n),
                    _ => {
                        let cols = im2col(&cur, self.batch, g);
                        let mut out = vec![0.0f32; rows * n];
                        backend.gemm_f32(rows, k, n, &cols, &w, &mut out);
                        out
                    }
                },
            };
            if retain {
                res.x_first = Some(cur);
            }
        } else {
            // binarize input: packed X̂ + packed STE mask; f32 freed
            let (xhat, ste) = match conv {
                None => {
                    let xh = BitMatrix::pack(rows, k, &cur);
                    let ste =
                        BitMask::from_bools(cur.len(), cur.iter().map(|v| v.abs() <= 1.0));
                    (xh, ste)
                }
                Some(g) => {
                    // mask over the *activation map* (in_elems); the
                    // conv patches are signed+packed straight into
                    // row panels — no f32 im2col buffer, no separate
                    // pack pass (§Perf: the fused binary conv path),
                    // threaded over output rows via the pool
                    let ste =
                        BitMask::from_bools(cur.len(), cur.iter().map(|v| v.abs() <= 1.0));
                    let pool = self.accel.backend().pool();
                    let xh = im2col_packed(&cur, self.batch, g, &pool);
                    (xh, ste)
                }
            };
            drop(cur);
            y = self.bin_matmul(&xhat, wi, k, n);
            if retain {
                res.xhat = Some(xhat);
                res.ste = Some(ste);
            }
        }

        // l1 batch norm (Alg. 2 lines 5-8)
        let beta = self.betas[wi].to_f32();
        let (x_next, psi, omega, bn_sign) = bn_l1_forward_packed(&y, rows, n, &beta);
        if retain {
            res.psi = F16Vec::from_f32(&psi);
            res.omega = F16Vec::from_f32(&omega);
            res.bn_sign = Some(bn_sign);
            self.res.push(res);
        }
        Ok(x_next)
    }

    /// Shared matmul+BN backward; returns the f32 input grad (the
    /// driver holds it f16 across layer boundaries).
    #[allow(clippy::too_many_arguments)]
    fn matmul_bn_backward(
        &mut self,
        dx_next: Vec<f32>,
        rows: usize,
        k: usize,
        n: usize,
        first: bool,
        wi: usize,
        conv: Option<ConvGeom>,
    ) -> Result<Vec<f32>> {
        // BN backward (Alg. 2 lines 10-13) from packed signs + ω, ψ
        let res_view = &self.res[wi];
        let (dy, dbeta) = bn_proposed_backward_packed(
            &dx_next,
            res_view.bn_sign.as_ref().unwrap(),
            &res_view.omega.to_f32(),
            &res_view.psi.to_f32(),
            rows,
            n,
        );
        drop(dx_next);

        // ∂Ŵ (packed, retained for the update phase).  The first
        // layer's retained input is the raw image — im2col it into
        // the (rows × k) matrix the dW GEMM expects (transient).
        let first_cols: Option<Vec<f32>> = match (&res_view.x_first, conv) {
            (Some(xf), Some(g)) => Some(im2col(xf, self.batch, g)),
            (Some(xf), None) => Some(xf.clone()),
            _ => None,
        };
        let dw = self.dw_packed(res_view.xhat.as_ref(), first_cols.as_deref(), &dy, rows, k, n);
        drop(first_cols);

        // ∂X for the upstream layer (skip for the first layer).  The
        // dX matmul takes `&mut self` (it reads the packed-Ŵᵀ cache),
        // so the residuals are re-borrowed afterwards for the STE mask.
        let out = if first {
            Vec::new()
        } else {
            let mut dx = match conv {
                None => self.real_bin_matmul_t(&dy, wi, rows, k, n),
                Some(g) => match self.accel {
                    Accel::Naive => {
                        // reference: full rows×k patch gradients,
                        // then the scatter-add col2im
                        let dcols = self.real_bin_matmul_t(&dy, wi, rows, k, n);
                        col2im(&dcols, self.batch, g)
                    }
                    _ => {
                        // streaming col2im straight off the cached
                        // *packed* Ŵᵀ: per-tap rows×cin panels —
                        // neither the rows×k dcols nor the full
                        // f32 Ŵᵀ unpack ever exists
                        let backend = self.accel.backend();
                        let batch = self.batch;
                        let wt = self.packed_wt(wi, k, n);
                        conv_dx_streaming(&dy, wt, batch, g, backend)
                    }
                },
            };
            let ste = self.res[wi].ste.as_ref().unwrap();
            for (i, v) in dx.iter_mut().enumerate() {
                if !ste.get(i) {
                    *v = 0.0;
                }
            }
            dx
        };
        self.res[wi].dw_sign = Some(dw);
        self.res[wi].dbeta = dbeta;
        Ok(out)
    }
}

impl EngineOps for ProposedTrainer {
    /// ∂X/∂Y between layers is held f16 (Table 2's grad rows); the
    /// f16→f32→f16 round-trips at pool/residual boundaries are
    /// lossless, so behaviour matches the pre-refactor engine bit for
    /// bit.
    type Grad = F16Vec;

    fn batch(&self) -> usize {
        self.batch
    }

    fn grad_to_f32(g: F16Vec) -> Vec<f32> {
        g.to_f32()
    }

    fn grad_from_f32(v: Vec<f32>) -> F16Vec {
        F16Vec::from_f32(&v)
    }

    fn matmul_forward(
        &mut self,
        cur: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        retain: bool,
    ) -> Result<Vec<f32>> {
        match *layer {
            LayerPlan::Dense { k, n, first } => {
                self.matmul_bn_forward(cur, self.batch, k, n, first, wi, retain, None)
            }
            LayerPlan::Conv { g, cout, first } => self.matmul_bn_forward(
                cur,
                g.rows(self.batch),
                g.k(),
                cout,
                first,
                wi,
                retain,
                Some(g),
            ),
            _ => unreachable!("matmul_forward on a non-matmul layer"),
        }
    }

    fn matmul_backward(
        &mut self,
        dnext: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        _lr: f32, // updates happen in the deferred update phase
    ) -> Result<Vec<f32>> {
        match *layer {
            LayerPlan::Dense { k, n, first } => {
                self.matmul_bn_backward(dnext, self.batch, k, n, first, wi, None)
            }
            LayerPlan::Conv { g, cout, first } => self.matmul_bn_backward(
                dnext,
                g.rows(self.batch),
                g.k(),
                cout,
                first,
                wi,
                Some(g),
            ),
            _ => unreachable!("matmul_backward on a non-matmul layer"),
        }
    }

    fn pool_forward(
        &mut self,
        cur: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        retain: bool,
    ) -> Vec<f32> {
        let b = self.batch;
        let (out, mask) = maxpool_forward(&cur, b, h, w, c);
        if retain {
            // pack: 1 bit per input element (was-max)
            let mut bits = vec![false; b * h * w * c];
            const OFF: [(usize, usize); 4] = [(0, 0), (0, 1), (1, 0), (1, 1)];
            for bi in 0..b {
                for oy in 0..h / 2 {
                    for ox in 0..w / 2 {
                        for ch in 0..c {
                            let o = ((bi * (h / 2) + oy) * (w / 2) + ox) * c + ch;
                            let (dy, dx) = OFF[mask[o] as usize];
                            bits[((bi * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch] = true;
                        }
                    }
                }
            }
            self.pool_masks.push(BitMask::from_bools(bits.len(), bits.into_iter()));
        }
        out
    }

    fn pool_backward(&mut self, dnext: Vec<f32>, h: usize, w: usize, c: usize) -> Vec<f32> {
        let b = self.batch;
        let mask = self.pool_masks.pop().expect("pool mask stack underflow");
        let mut dx = vec![0.0f32; b * h * w * c];
        let (oh, ow) = (h / 2, w / 2);
        // route each pooled grad to its masked input cell
        let mut oidx = 0usize;
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ch in 0..c {
                        let g = dnext[oidx];
                        oidx += 1;
                        for (dy, dxo) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                            let ii = ((bi * h + oy * 2 + dy) * w + ox * 2 + dxo) * c + ch;
                            if mask.get(ii) {
                                dx[ii] = g;
                            }
                        }
                    }
                }
            }
        }
        dx
    }
}

impl StepEngine for ProposedTrainer {
    fn train_step(&mut self, x: &[f32], labels: &[usize], lr: f32) -> Result<(f32, f32)> {
        if x.len() != self.batch * self.plan.input_elems || labels.len() != self.batch {
            bail!("bad batch shapes");
        }
        let logits = self.forward(x, true)?;
        let classes = self.plan.classes;
        let mut dlogits = vec![0.0f32; self.batch * classes];
        let (loss, acc) = softmax_xent_grad(&logits, labels, classes, &mut dlogits);
        drop(logits);
        self.backward(dlogits, lr)?;
        self.res.clear();
        self.pool_masks.clear();
        Ok((loss, acc))
    }

    fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)> {
        let logits = self.forward(x, false)?;
        // forward(retain = false) pushes nothing, and it clears any
        // leftovers from an aborted step on entry — but the invariant
        // the backward relies on (res[wi] belongs to *this* step's
        // forward) deserves to be explicit: eval must never leave
        // residuals a later backward could misread.  Regression-pinned
        // in `eval_between_steps_is_invisible_to_training`.
        self.res.clear();
        self.pool_masks.clear();
        let classes = self.plan.classes;
        let mut d = vec![0.0f32; self.batch * classes];
        Ok(softmax_xent_grad(&logits, labels, classes, &mut d))
    }

    fn state_bytes(&self) -> usize {
        self.weights.iter().map(Store::heap_bytes).sum::<usize>()
            + self.betas.iter().map(Store::heap_bytes).sum::<usize>()
            + self.opt_w.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.opt_b.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.wcache.heap_bytes()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn weights_snapshot(&self) -> Vec<Vec<f32>> {
        // interleaved [w0, beta0, w1, beta1, ...] — the HLO engines'
        // param order, so snapshots transfer across engine kinds
        let mut out = Vec::with_capacity(self.weights.len() * 2);
        for (w, b) in self.weights.iter().zip(&self.betas) {
            out.push(w.to_f32());
            out.push(b.to_f32());
        }
        out
    }

    fn load_weights(&mut self, w: &[Vec<f32>]) -> Result<()> {
        if w.len() != self.weights.len() * 2 {
            bail!("snapshot layer count mismatch");
        }
        for (i, chunk) in w.chunks(2).enumerate() {
            if chunk[0].len() != self.weights[i].len()
                || chunk[1].len() != self.betas[i].len()
            {
                bail!("snapshot shape mismatch at layer {i}");
            }
            self.weights[i] = Store::from_f32(chunk[0].clone(), true);
            self.betas[i] = Store::from_f32(chunk[1].clone(), true);
        }
        self.wcache.invalidate_all();
        Ok(())
    }
}

// -------------------------------------------------------- BN kernels

/// ℓ1 BN forward emitting f32 x_next + (ψ, ω, packed sign(xn)).
fn bn_l1_forward_packed(
    y: &[f32],
    rows: usize,
    channels: usize,
    beta: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>, BitMatrix) {
    let mut mu = vec![0.0f32; channels];
    for r in 0..rows {
        for c in 0..channels {
            mu[c] += y[r * channels + c];
        }
    }
    for m in mu.iter_mut() {
        *m /= rows as f32;
    }
    let mut psi = vec![0.0f32; channels];
    for r in 0..rows {
        for c in 0..channels {
            psi[c] += (y[r * channels + c] - mu[c]).abs();
        }
    }
    for p in psi.iter_mut() {
        *p = *p / rows as f32 + 1e-5;
    }
    let mut x_next = vec![0.0f32; y.len()];
    let mut omega = vec![0.0f32; channels];
    let mut sign = BitMatrix::zeros(rows, channels);
    for r in 0..rows {
        let base = r * sign.words_per_row;
        for c in 0..channels {
            let xn = (y[r * channels + c] - mu[c]) / psi[c];
            let v = xn + beta[c];
            x_next[r * channels + c] = v;
            omega[c] += v.abs();
            if xn >= 0.0 {
                sign.data[base + (c >> 6)] |= 1u64 << (c & 63);
            }
        }
    }
    for o in omega.iter_mut() {
        *o /= rows as f32;
    }
    (x_next, psi, omega, sign)
}

/// Proposed BN backward (Alg. 2 lines 10-13) from packed signs.
fn bn_proposed_backward_packed(
    dx: &[f32],
    xhat: &BitMatrix,
    omega: &[f32],
    psi: &[f32],
    rows: usize,
    channels: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut mean_v = vec![0.0f32; channels];
    let mut mean_vx = vec![0.0f32; channels];
    let mut dbeta = vec![0.0f32; channels];
    for r in 0..rows {
        for c in 0..channels {
            let d = dx[r * channels + c];
            let v = d / psi[c];
            mean_v[c] += v;
            mean_vx[c] += v * xhat.get(r, c);
            dbeta[c] += d;
        }
    }
    for c in 0..channels {
        mean_v[c] /= rows as f32;
        mean_vx[c] /= rows as f32;
    }
    let mut dy = vec![0.0f32; dx.len()];
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            dy[r * channels + c] = v - mean_v[c] - omega[c] * mean_vx[c] * xhat.get(r, c);
        }
    }
    (dy, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    fn make(model: &str, batch: usize, accel: Accel, opt: &str) -> ProposedTrainer {
        let g = lower(&get(model).unwrap()).unwrap();
        ProposedTrainer::new(&g, batch, opt, accel, 42).unwrap()
    }

    fn toy_batch(n: usize, k: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
        let mut g = Pcg32::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes).map(|_| g.normal_vec(k)).collect();
        let mut x = Vec::with_capacity(n * k);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            for j in 0..k {
                x.push(protos[c][j] + 0.3 * g.normal());
            }
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn mlp_mini_learns() {
        let mut t = make("mlp_mini", 32, Accel::Blocked, "adam");
        let (x, y) = toy_batch(32, 64, 10, 1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.6, "{first:?} -> {last}");
    }

    #[test]
    fn conv_net_learns() {
        let mut t = make("cnv_mini", 16, Accel::Blocked, "adam");
        let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.8, "{first:?} -> {last}");
    }

    #[test]
    fn residual_nets_learn() {
        for model in ["resnete_mini", "bireal_mini"] {
            let mut t = make(model, 16, Accel::Blocked, "adam");
            let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 14);
            let mut first = None;
            let mut last = 0.0;
            for _ in 0..25 {
                let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
                first.get_or_insert(loss);
                last = loss;
            }
            assert!(last.is_finite(), "{model}");
            assert!(last < first.unwrap(), "{model}: {first:?} -> {last}");
        }
    }

    #[test]
    fn bop_trains_binary_weights() {
        let mut t = make("mlp_mini", 32, Accel::Blocked, "bop");
        let (x, y) = toy_batch(32, 64, 10, 3);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let (loss, _) = t.train_step(&x, &y, 0.001).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap(), "{first:?} -> {last}");
        // weights must remain exactly binary under Bop (even slots;
        // odd slots are BN biases)
        for (i, w) in t.weights_snapshot().iter().enumerate() {
            if i % 2 == 0 {
                assert!(w.iter().all(|&v| v == 1.0 || v == -1.0));
            }
        }
    }

    #[test]
    fn naive_and_blocked_agree() {
        let mut a = make("mlp_mini", 8, Accel::Naive, "adam");
        let mut b = make("mlp_mini", 8, Accel::Blocked, "adam");
        let (x, y) = toy_batch(8, 64, 10, 4);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-3, "step {step}: {la} vs {lb}");
        }
    }

    #[test]
    fn tiled_matches_blocked_exactly() {
        // the XNOR tiers are bit-exact and the parallel f32 path only
        // re-bands the same blocked kernel, so whole training runs are
        // numerically identical across blocked and tiled(threads) —
        // residual models exercise the skip handling too
        for (model, batch, k) in [
            ("mlp_mini", 8, 64),
            ("cnv_mini", 4, 16 * 16 * 3),
            ("resnete_mini", 4, 16 * 16 * 3),
        ] {
            let mut b = make(model, batch, Accel::Blocked, "adam");
            let mut t2 = make(model, batch, Accel::Tiled(2), "adam");
            let (x, y) = toy_batch(batch, k, 10, 5);
            for step in 0..3 {
                let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
                let (lt, _) = t2.train_step(&x, &y, 0.01).unwrap();
                assert!((lb - lt).abs() < 1e-6, "{model} step {step}: {lb} vs {lt}");
            }
            for (wb, wt) in b.weights_snapshot().iter().zip(t2.weights_snapshot().iter()) {
                assert_eq!(wb, wt, "{model}");
            }
        }
    }

    #[test]
    fn weights_packed_at_most_once_per_step() {
        let mut t = make("mlp_mini", 8, Accel::Blocked, "adam");
        let (x, y) = toy_batch(8, 64, 10, 9);
        assert_eq!(t.weight_pack_count(), 0);
        t.train_step(&x, &y, 0.01).unwrap();
        let per_step = t.weight_pack_count();
        // forward packs each non-first matmul layer once; the backward
        // dX matmul must reuse the cache rather than re-pack
        assert!(per_step >= 1 && per_step <= t.weights.len(), "{per_step}");
        t.train_step(&x, &y, 0.01).unwrap();
        t.train_step(&x, &y, 0.01).unwrap();
        assert_eq!(t.weight_pack_count(), 3 * per_step);
        // eval re-packs once after the update invalidated the cache...
        t.eval(&x, &y).unwrap();
        let after_eval = t.weight_pack_count();
        assert_eq!(after_eval, 4 * per_step);
        // ...and a second eval with unchanged weights packs nothing
        t.eval(&x, &y).unwrap();
        assert_eq!(t.weight_pack_count(), after_eval);
        // loading new weights invalidates
        let snap = t.weights_snapshot();
        t.load_weights(&snap).unwrap();
        t.eval(&x, &y).unwrap();
        assert_eq!(t.weight_pack_count(), after_eval + per_step);
    }

    #[test]
    fn state_is_half_of_standard() {
        use super::super::standard::StandardTrainer;
        let g = lower(&get("mlp").unwrap()).unwrap();
        let s = StandardTrainer::new(&g, 16, "adam", Accel::Blocked, 1).unwrap();
        let p = ProposedTrainer::new(&g, 16, "adam", Accel::Blocked, 1).unwrap();
        let ratio = s.state_bytes() as f64 / p.state_bytes() as f64;
        assert!((ratio - 2.0).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn bn_l1_forward_centers() {
        let mut g = Pcg32::new(5);
        let rows = 128;
        let ch = 6;
        let y: Vec<f32> = g.normal_vec(rows * ch).iter().map(|v| v * 2.0 + 0.5).collect();
        let (xn, psi, omega, sgn) = bn_l1_forward_packed(&y, rows, ch, &vec![0.0; ch]);
        for c in 0..ch {
            let m: f32 = (0..rows).map(|r| xn[r * ch + c]).sum::<f32>() / rows as f32;
            assert!(m.abs() < 1e-4, "{m}");
            assert!(psi[c] > 0.0);
            assert!(omega[c] > 0.0);
        }
        // packed signs match xn signs (beta = 0)
        for r in 0..rows {
            for c in 0..ch {
                assert_eq!(
                    sgn.get(r, c),
                    if xn[r * ch + c] >= 0.0 { 1.0 } else { -1.0 }
                );
            }
        }
    }

    #[test]
    fn proposed_bn_backward_matches_ref_math() {
        // cross-check against the formula (mirrors python ref.py)
        let mut g = Pcg32::new(6);
        let (rows, ch) = (32, 4);
        let dx = g.normal_vec(rows * ch);
        let xh_f: Vec<f32> = g.normal_vec(rows * ch);
        let xhat = BitMatrix::pack(rows, ch, &xh_f);
        let omega: Vec<f32> = (0..ch).map(|_| g.uniform(0.1, 1.0)).collect();
        let psi: Vec<f32> = (0..ch).map(|_| g.uniform(0.1, 1.0)).collect();
        let (dy, dbeta) = bn_proposed_backward_packed(&dx, &xhat, &omega, &psi, rows, ch);
        for c in 0..ch {
            let v: Vec<f32> = (0..rows).map(|r| dx[r * ch + c] / psi[c]).collect();
            let mv: f32 = v.iter().sum::<f32>() / rows as f32;
            let mvx: f32 = (0..rows)
                .map(|r| v[r] * xhat.get(r, c))
                .sum::<f32>()
                / rows as f32;
            for r in 0..rows {
                let want = v[r] - mv - omega[c] * mvx * xhat.get(r, c);
                assert!((dy[r * ch + c] - want).abs() < 1e-5);
            }
            let db: f32 = (0..rows).map(|r| dx[r * ch + c]).sum();
            assert!((dbeta[c] - db).abs() < 1e-4);
        }
    }

    #[test]
    fn eval_does_not_mutate() {
        let mut t = make("mlp_mini", 8, Accel::Blocked, "adam");
        let (x, y) = toy_batch(8, 64, 10, 7);
        let before = t.weights_snapshot();
        t.eval(&x, &y).unwrap();
        assert_eq!(before, t.weights_snapshot());
    }

    #[test]
    fn eval_between_steps_is_invisible_to_training() {
        // an eval interleaved between train steps must leave no stale
        // residuals/pool masks behind (the backward indexes res[wi]
        // positionally — a leak would be misread as this step's X̂) and
        // must not perturb the training trajectory at all.  Run on a
        // residual model so the skip path is covered too.
        let (x, y) = toy_batch(8, 16 * 16 * 3, 10, 11);
        let (xe, ye) = toy_batch(8, 16 * 16 * 3, 10, 12);
        for model in ["cnv_mini", "bireal_mini"] {
            let mut a = make(model, 8, Accel::Blocked, "adam");
            let mut b = make(model, 8, Accel::Blocked, "adam");
            a.train_step(&x, &y, 0.01).unwrap();
            b.train_step(&x, &y, 0.01).unwrap();
            b.eval(&xe, &ye).unwrap();
            assert!(b.res.is_empty(), "{model}: eval left residuals behind");
            assert!(b.pool_masks.is_empty(), "{model}: eval left pool masks behind");
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(la, lb, "{model}: eval perturbed the training trajectory");
            for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
                assert_eq!(wa, wb, "{model}");
            }
        }
    }
}
