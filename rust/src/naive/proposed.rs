//! Algorithm 2 — the paper's proposed low-memory BNN training step,
//! with *genuinely* reduced storage:
//!
//! - retained activations: **bit-packed** X̂ (matmul inputs) and
//!   BN-output signs, plus packed STE masks — 1 bit each (Table 2's
//!   "X" and mask rows realized 32× smaller on the heap);
//! - per-channel BN statistics ψ, ω: f16;
//! - latent weights / momenta: f16 [`Store`];
//! - weight gradients: bit-packed ∂Ŵ retained through the update
//!   phase, consumed via `update_fn` with the `1/√N_l` attenuation
//!   (Alg. 2 lines 16+18) — no f32 gradient buffer survives a chunk;
//! - gradients flowing between layers are held in f16 across layer
//!   boundaries (∂X/∂Y rows of Table 2).
//!
//! Since the step-arena refactor every per-step buffer — the packed
//! panels, f16 carriers, BN scratch, GEMM outputs — is a [`StepCtx`]
//! arena checkout: steady-state steps perform zero heap allocations.
//! Under `--microbatch` accumulation (chunks > 1) ∂W accumulates in
//! a persistent f32 buffer across chunks before binarization — the
//! sign of a sum is not a function of the chunk signs, so exactness
//! w.r.t. the equivalent single-pass step requires the f32 carrier;
//! it is weight-scale (batch-independent), so the microbatch memory
//! story is unchanged.  Single-chunk steps keep the paper's packed
//! ∂Ŵ inventory exactly.
//!
//! The forward f32 activation between a BN and the next binarization
//! is transient, exactly as the paper's lifetime analysis assumes.
//! Residual skips (and their gradients at the block boundary) are
//! f32 — the high-precision skip path of Sec. 2 — and are handled by
//! the shared layer-graph core in [`super::ops`].

use std::sync::Arc;

use anyhow::{bail, Result};

use super::arena::StepCtx;
use super::ops::{self, EngineOps};
use super::plan::{LayerPlan, Plan};
use super::schedule::{self, StepSchedule};
use super::standard::{col2im_into, conv_direct_into, sign_into, transpose};
use super::{glorot_init, Accel, StepEngine};
use crate::bitops::im2col::{
    conv_dw_first_streaming_into, conv_fwd_first_streaming_into, im2col_at,
};
use crate::bitops::{
    conv_dx_streaming_into, im2col_packed_into, simd, BPanels, BitMask, BitMatrix, ConvGeom,
    PackedWeightCache,
};
use crate::models::Graph;
use crate::optim::{OptState, Store};
use crate::util::f16::F16Vec;
use crate::util::rng::Pcg32;

/// Per-matmul-layer retained residuals (Alg. 2's memory inventory).
/// Every buffer is an arena checkout, returned when the chunk (or
/// step) drains.
#[derive(Default)]
struct Residuals {
    /// Bit-packed binarized matmul input (rows × k); None for the
    /// first layer (f32 input kept separately).
    xhat: Option<BitMatrix>,
    /// f32 copy of the first layer's input batch.
    x_first: Option<Vec<f32>>,
    /// Packed STE mask 1{|x| ≤ 1} over the matmul input.
    ste: Option<BitMask>,
    /// Packed signs of the BN output (x_next − β) — the backward's
    /// only activation dependence (the paper's key trick).
    bn_sign: Option<BitMatrix>,
    /// ψ (mean absolute deviation) and ω (mean magnitude), f16.
    psi: F16Vec,
    omega: F16Vec,
    /// Bit-packed binarized weight gradient ∂Ŵ (retained to update;
    /// single-chunk mode only — accumulating steps use `dw_acc`).
    dw_sign: Option<BitMatrix>,
}

pub struct ProposedTrainer {
    plan: Plan,
    /// Logical batch (what `train_step` consumes per call).
    batch: usize,
    /// Execution microbatch (chunk size; buffers are sized by this).
    micro: usize,
    accel: Accel,
    optimizer: String,
    /// Latent weights, f16-stored (binary-valued ±1 under Bop).
    weights: Vec<Store>,
    betas: Vec<Store>,
    opt_w: Vec<OptState>,
    opt_b: Vec<OptState>,
    res: Vec<Residuals>,
    pool_masks: Vec<BitMask>,
    /// u32 winner-index masks for general (non-2×2) retained pools,
    /// where the packed 1-bit was-max encoding is ambiguous.
    pool_masks_u32: Vec<Vec<u32>>,
    /// f32 ∂W accumulators, allocated only when chunks > 1 (see the
    /// module docs); empty single-chunk.
    dw_acc: Vec<Vec<f32>>,
    /// ∂β accumulators (channel-scale f32; always used).
    dbeta_acc: Vec<Vec<f32>>,
    /// Per-step packed Ŵᵀ cache: each layer packs at most once per
    /// step (invalidated when the update phase writes new weights).
    wcache: PackedWeightCache,
    /// The compiled buffer schedule this engine executes (train pass
    /// + eval pass, slot-colored; see `naive::schedule`).
    sched: Arc<StepSchedule>,
    ctx: StepCtx,
}

impl ProposedTrainer {
    pub fn new(
        graph: &Graph,
        batch: usize,
        optimizer: &str,
        accel: Accel,
        seed: u64,
    ) -> Result<ProposedTrainer> {
        ProposedTrainer::with_microbatch(graph, batch, 0, optimizer, accel, seed)
    }

    /// Build with gradient accumulation (see
    /// [`super::build_engine_micro`]); `microbatch` must divide
    /// `batch` (0 = whole batch).
    pub fn with_microbatch(
        graph: &Graph,
        batch: usize,
        microbatch: usize,
        optimizer: &str,
        accel: Accel,
        seed: u64,
    ) -> Result<ProposedTrainer> {
        let plan = Plan::from_graph(graph)?;
        if batch == 0 {
            bail!("batch must be positive");
        }
        let micro = if microbatch == 0 { batch } else { microbatch };
        if batch % micro != 0 {
            bail!("microbatch {micro} must divide batch {batch}");
        }
        let accumulating = batch / micro > 1;
        let mut rng = Pcg32::new(seed);
        let mut weights = Vec::new();
        let mut betas = Vec::new();
        let mut opt_w = Vec::new();
        let mut opt_b = Vec::new();
        let mut dw_acc = Vec::new();
        let mut dbeta_acc = Vec::new();
        for l in &plan.layers {
            let wl = l.weight_len();
            if wl == 0 {
                continue;
            }
            let mut w = glorot_init(&mut rng, l.fan_in(), l.channels(), wl);
            if optimizer == "bop" {
                for v in w.iter_mut() {
                    *v = if *v >= 0.0 { 1.0 } else { -1.0 };
                }
            }
            weights.push(Store::from_f32(w, true)); // f16 latent
            betas.push(Store::from_f32(vec![0.0; l.channels()], true));
            opt_w.push(OptState::new(optimizer, wl, true));
            opt_b.push(OptState::new(optimizer, l.channels(), true));
            dw_acc.push(if accumulating { vec![0.0; wl] } else { Vec::new() });
            dbeta_acc.push(vec![0.0; l.channels()]);
        }
        let wcache = PackedWeightCache::new(weights.len());
        let sched = Arc::new(schedule::compile_step(
            &plan,
            "proposed",
            accel == Accel::Naive,
            micro,
            batch / micro,
        )?);
        let mut ctx = StepCtx::default();
        ctx.arena.install(&sched.slots);
        Ok(ProposedTrainer {
            plan,
            batch,
            micro,
            accel,
            optimizer: optimizer.to_string(),
            weights,
            betas,
            opt_w,
            opt_b,
            res: Vec::new(),
            pool_masks: Vec::new(),
            pool_masks_u32: Vec::new(),
            dw_acc,
            dbeta_acc,
            wcache,
            sched,
            ctx,
        })
    }

    /// The compiled schedule this engine executes.
    pub fn schedule(&self) -> &Arc<StepSchedule> {
        &self.sched
    }

    /// Swap in an externally compiled schedule (e.g. one
    /// deserialized from JSON) and reinstall the arena slots; see
    /// `StandardTrainer::install_schedule`.
    pub fn install_schedule(&mut self, sched: Arc<StepSchedule>) {
        self.ctx.arena.install(&sched.slots);
        self.sched = sched;
    }

    /// Total weight packs so far — the once-per-step probe the tests
    /// (and the ISSUE acceptance criteria) assert on.
    pub fn weight_pack_count(&self) -> usize {
        self.wcache.pack_count()
    }

    fn chunks(&self) -> usize {
        self.batch / self.micro
    }

    /// Packed Ŵᵀ (n×k) for layer `wi`, straight from the f16 sign
    /// bits — cached so repeat uses within a step cost nothing; the
    /// repack after an update rewrites the retained storage in place.
    fn packed_wt(&mut self, wi: usize, k: usize, n: usize) -> &BitMatrix {
        let weights = &self.weights;
        self.wcache.wt(wi, |dst| match &weights[wi] {
            Store::F16(v) => BitMatrix::pack_f16_t_into(&v.0, k, n, dst),
            Store::F32(v) => {
                // cold path (proposed weights are always f16-stored)
                let wt = transpose(v, k, n);
                BitMatrix::pack_into(n, k, &wt, dst);
            }
        })
    }

    /// [`Self::packed_wt`] plus the layer's cached interleaved B
    /// panels when the width rule packs them (wide-N forward
    /// dispatch; see `PackedWeightCache::wt_with_panels`).
    fn packed_wt_with_panels(
        &mut self,
        wi: usize,
        k: usize,
        n: usize,
    ) -> (&BitMatrix, Option<&BPanels>) {
        let weights = &self.weights;
        self.wcache.wt_with_panels(wi, |dst| match &weights[wi] {
            Store::F16(v) => BitMatrix::pack_f16_t_into(&v.0, k, n, dst),
            Store::F32(v) => {
                let wt = transpose(v, k, n);
                BitMatrix::pack_into(n, k, &wt, dst);
            }
        })
    }

    /// Drain residuals + pool masks back to the arena.
    fn drain_res(&mut self) {
        for r in self.res.drain(..) {
            if let Some(m) = r.xhat {
                self.ctx.arena.put_bits(m);
            }
            if let Some(v) = r.x_first {
                self.ctx.arena.put_f32(v);
            }
            if let Some(m) = r.ste {
                self.ctx.arena.put_mask(m);
            }
            if let Some(m) = r.bn_sign {
                self.ctx.arena.put_bits(m);
            }
            self.ctx.arena.put_f16(r.psi);
            self.ctx.arena.put_f16(r.omega);
            if let Some(m) = r.dw_sign {
                self.ctx.arena.put_bits(m);
            }
        }
        for m in self.pool_masks.drain(..) {
            self.ctx.arena.put_mask(m);
        }
        for m in self.pool_masks_u32.drain(..) {
            self.ctx.arena.put_u32(m);
        }
    }

    fn begin_step(&mut self) {
        self.drain_res();
        self.ctx.drain_skip_stacks();
        for dw in self.dw_acc.iter_mut() {
            dw.fill(0.0);
        }
        for db in self.dbeta_acc.iter_mut() {
            db.fill(0.0);
        }
    }

    /// Deferred update phase (Alg. 2 lines 17-19): consume the packed
    /// ∂Ŵ (single chunk) or the binarized f32 accumulator (chunks >
    /// 1) with the 1/√N_l attenuation.
    fn apply_update(&mut self, lr: f32) {
        for st in self.opt_w.iter_mut().chain(self.opt_b.iter_mut()) {
            st.tick();
        }
        let is_bop = self.optimizer == "bop";
        let single = self.chunks() == 1;
        for wi in 0..self.weights.len() {
            if single {
                let res = &self.res[wi];
                let dw = res.dw_sign.as_ref().expect("backward filled dw");
                let fan_in = dw.rows;
                let atten = 1.0 / (fan_in as f32).sqrt();
                let n = dw.cols;
                let wpr = dw.words_per_row;
                let data = &dw.data;
                self.opt_w[wi].update_fn(
                    &mut self.weights[wi],
                    |i| {
                        let (r, c) = (i / n, i % n);
                        let bit = data[r * wpr + (c >> 6)] >> (c & 63) & 1;
                        (if bit == 1 { 1.0 } else { -1.0 }) * atten
                    },
                    lr,
                    !is_bop,
                );
            } else {
                let dw = &self.dw_acc[wi];
                let n = self.betas[wi].len();
                let fan_in = dw.len() / n;
                let atten = 1.0 / (fan_in as f32).sqrt();
                self.opt_w[wi].update_fn(
                    &mut self.weights[wi],
                    |i| (if dw[i] >= 0.0 { 1.0 } else { -1.0 }) * atten,
                    lr,
                    !is_bop,
                );
            }
            self.opt_b[wi].update(&mut self.betas[wi], &self.dbeta_acc[wi], lr, false);
        }
        // weights changed: cached packed Ŵᵀ is stale
        self.wcache.invalidate_all();
    }

    /// Shared matmul+BN forward.  `conv`: Some(geometry).
    #[allow(clippy::too_many_arguments)]
    fn matmul_bn_forward(
        &mut self,
        cur: Vec<f32>,
        rows: usize,
        k: usize,
        n: usize,
        first: bool,
        wi: usize,
        retain: bool,
        conv: Option<ConvGeom>,
    ) -> Result<Vec<f32>> {
        let b = self.micro;
        let mut res = Residuals::default();
        let y: Vec<f32>;
        if first {
            // real-input layer: f32 GEMM against sign(W)
            let backend = self.accel.backend();
            let mut w = self.ctx.arena.take_f32(k * n);
            store_sign_into(&self.weights[wi], &mut w);
            y = match conv {
                None => {
                    let mut out = self.ctx.arena.take_f32(rows * n);
                    backend.gemm_f32(rows, k, n, &cur, &w, &mut out);
                    out
                }
                Some(g) => match self.accel {
                    Accel::Naive => {
                        let mut out = self.ctx.arena.take_zeroed_f32(rows * n);
                        conv_direct_into(&cur, &w, b, g, n, &mut out);
                        out
                    }
                    _ => {
                        // tap-streamed f32 im2col: one rows×cin
                        // panel, never the rows×k cols buffer —
                        // bit-identical to the unfused GEMM
                        let mut out = self.ctx.arena.take_f32(rows * n);
                        let mut panel = self.ctx.arena.take_f32(rows * g.cin);
                        conv_fwd_first_streaming_into(
                            &cur, &w, b, g, n, backend, &mut out, &mut panel,
                        );
                        self.ctx.arena.put_f32(panel);
                        out
                    }
                },
            };
            self.ctx.arena.put_f32(w);
            if retain {
                res.x_first = Some(cur);
            } else {
                self.ctx.arena.put_f32(cur);
            }
        } else {
            // binarize input: packed X̂ + packed STE mask; the f32
            // activation recycles immediately
            let mut ste = self.ctx.arena.take_mask(cur.len());
            ste.fill_from_bools(cur.iter().map(|v| v.abs() <= 1.0));
            let mut xhat = self.ctx.arena.take_bits(rows, k);
            match conv {
                None => BitMatrix::pack_into(rows, k, &cur, &mut xhat),
                Some(g) => {
                    // conv patches signed+packed straight into row
                    // panels — no f32 im2col buffer (§Perf: the fused
                    // binary conv path), threaded over output rows
                    let pool = self.accel.backend().pool();
                    im2col_packed_into(&cur, b, g, &pool, &mut xhat);
                }
            }
            self.ctx.arena.put_f32(cur);
            // binary matmul: XNOR-popcount over the cached packed Ŵᵀ
            let mut out = self.ctx.arena.take_f32(rows * n);
            {
                let backend = self.accel.backend();
                let (wpt, bp) = self.packed_wt_with_panels(wi, k, n);
                backend.xnor_gemm_packed(&xhat, wpt, bp, &mut out);
            }
            y = out;
            if retain {
                res.xhat = Some(xhat);
                res.ste = Some(ste);
            } else {
                self.ctx.arena.put_bits(xhat);
                self.ctx.arena.put_mask(ste);
            }
        }

        // l1 batch norm (Alg. 2 lines 5-8)
        let mut beta = self.ctx.arena.take_f32(n);
        self.betas[wi].write_f32_into(&mut beta);
        let mut x_next = self.ctx.arena.take_f32(rows * n);
        let mut psi = self.ctx.arena.take_f32(n);
        let mut omega = self.ctx.arena.take_f32(n);
        let mut mu = self.ctx.arena.take_f32(n);
        let mut sign = self.ctx.arena.take_zeroed_bits(rows, n);
        bn_l1_forward_packed_into(
            &y, rows, n, &beta, &mut x_next, &mut psi, &mut omega, &mut mu, &mut sign,
        );
        self.ctx.arena.put_f32(y);
        self.ctx.arena.put_f32(beta);
        self.ctx.arena.put_f32(mu);
        if retain {
            let mut pf = self.ctx.arena.take_f16(n);
            pf.fill_from_f32(&psi);
            let mut of = self.ctx.arena.take_f16(n);
            of.fill_from_f32(&omega);
            res.psi = pf;
            res.omega = of;
            res.bn_sign = Some(sign);
            self.res.push(res);
        } else {
            self.ctx.arena.put_bits(sign);
        }
        self.ctx.arena.put_f32(psi);
        self.ctx.arena.put_f32(omega);
        Ok(x_next)
    }

    /// Shared matmul+BN backward; returns the f32 input grad (the
    /// driver holds it f16 across layer boundaries).
    #[allow(clippy::too_many_arguments)]
    fn matmul_bn_backward(
        &mut self,
        dx_next: Vec<f32>,
        rows: usize,
        k: usize,
        n: usize,
        first: bool,
        wi: usize,
        conv: Option<ConvGeom>,
    ) -> Result<Vec<f32>> {
        let b = self.micro;
        // BN backward (Alg. 2 lines 10-13) from packed signs + ω, ψ
        let mut dy = self.ctx.arena.take_f32(rows * n);
        {
            let mut psi = self.ctx.arena.take_f32(n);
            let mut omega = self.ctx.arena.take_f32(n);
            self.res[wi].psi.write_f32_into(&mut psi);
            self.res[wi].omega.write_f32_into(&mut omega);
            let mut mv = self.ctx.arena.take_f32(n);
            let mut mvx = self.ctx.arena.take_f32(n);
            bn_proposed_backward_packed_into(
                &dx_next,
                self.res[wi].bn_sign.as_ref().unwrap(),
                &omega,
                &psi,
                rows,
                n,
                &mut dy,
                &mut self.dbeta_acc[wi],
                &mut mv,
                &mut mvx,
            );
            self.ctx.arena.put_f32(psi);
            self.ctx.arena.put_f32(omega);
            self.ctx.arena.put_f32(mv);
            self.ctx.arena.put_f32(mvx);
        }
        self.ctx.arena.put_f32(dx_next);

        // ∂Ŵ / ∂W accumulation.  The first layer's retained input is
        // the raw image — im2col it into the (rows × k) matrix the dW
        // GEMM expects (transient arena buffer).
        self.accumulate_dw(wi, &dy, rows, k, n, first, conv);

        // ∂X for the upstream layer (skip for the first layer)
        let out = if first {
            Vec::new()
        } else {
            let mut dx = match conv {
                None => match self.accel {
                    Accel::Naive => {
                        // naive dense dX straight off the f16 signs
                        let mut dx = self.ctx.arena.take_zeroed_f32(rows * k);
                        naive_dy_wt_into(&self.weights[wi], &dy, rows, k, n, &mut dx);
                        dx
                    }
                    _ => {
                        // dX = dY Ŵᵀ: unpack the *cached* packed Ŵᵀ
                        // into a transient ±1 f32 buffer (the paper's
                        // memory-for-speed trade; no re-pack, no f32
                        // transpose)
                        let mut wt_f = self.ctx.arena.take_f32(n * k);
                        {
                            let wpt = self.packed_wt(wi, k, n);
                            wpt.unpack_into(&mut wt_f);
                        }
                        let mut dx = self.ctx.arena.take_f32(rows * k);
                        self.accel.backend().gemm_f32(rows, n, k, &dy, &wt_f, &mut dx);
                        self.ctx.arena.put_f32(wt_f);
                        dx
                    }
                },
                Some(g) => match self.accel {
                    Accel::Naive => {
                        // reference: full rows×k patch gradients, then
                        // the scatter-add col2im
                        let mut dcols = self.ctx.arena.take_zeroed_f32(rows * k);
                        naive_dy_wt_into(&self.weights[wi], &dy, rows, k, n, &mut dcols);
                        let mut dx = self.ctx.arena.take_zeroed_f32(g.in_len(b));
                        col2im_into(&dcols, b, g, &mut dx);
                        self.ctx.arena.put_f32(dcols);
                        dx
                    }
                    _ => {
                        // streaming col2im straight off the cached
                        // *packed* Ŵᵀ: per-tap rows×cin panels —
                        // neither the rows×k dcols nor the full
                        // f32 Ŵᵀ unpack ever exists
                        let backend = self.accel.backend();
                        let mut dx = self.ctx.arena.take_zeroed_f32(g.in_len(b));
                        let mut panel = self.ctx.arena.take_f32(rows * g.cin);
                        let mut wtap = self.ctx.arena.take_f32(n * g.cin);
                        {
                            let wpt = self.packed_wt(wi, k, n);
                            conv_dx_streaming_into(
                                &dy, wpt, b, g, backend, &mut dx, &mut panel, &mut wtap,
                            );
                        }
                        self.ctx.arena.put_f32(panel);
                        self.ctx.arena.put_f32(wtap);
                        dx
                    }
                },
            };
            let ste = self.res[wi].ste.as_ref().unwrap();
            for (i, v) in dx.iter_mut().enumerate() {
                if !ste.get(i) {
                    *v = 0.0;
                }
            }
            dx
        };
        self.ctx.arena.put_f32(dy);
        Ok(out)
    }

    /// ∂W = X̂ᵀ ∂Y.  Single-chunk: binarized straight into a packed
    /// ∂Ŵ (Alg. 2's bool gradient; the f32 accumulator is transient).
    /// Accumulating: added into the persistent f32 `dw_acc`,
    /// binarized once at the update phase.
    fn accumulate_dw(
        &mut self,
        wi: usize,
        dy: &[f32],
        rows: usize,
        k: usize,
        n: usize,
        _first: bool,
        conv: Option<ConvGeom>,
    ) {
        let b = self.micro;
        let single = self.chunks() == 1;
        match self.accel {
            Accel::Blocked | Accel::Tiled(_) => {
                // k×n f32 accumulator (transient single-chunk, the
                // persistent dw_acc otherwise), contracted straight
                // off the *retained packed* X̂ — the (rows×k) f32
                // unpack and (k×rows) transpose never exist.
                let backend = self.accel.backend();
                let mut dw = if single {
                    self.ctx.arena.take_f32(k * n)
                } else {
                    std::mem::take(&mut self.dw_acc[wi])
                };
                let mut scratch = if single {
                    Vec::new()
                } else {
                    self.ctx.arena.take_f32(k * n)
                };
                {
                    let dst = if single { &mut dw } else { &mut scratch };
                    match &self.res[wi].xhat {
                        Some(xh) => backend.packed_at_gemm_f32(xh, dy, n, dst),
                        None => match conv {
                            Some(g) => {
                                // tap-streamed first-conv ∂W: one
                                // rows×cin panel instead of the
                                // rows×k f32 im2col (bit-identical
                                // to the unfused AᵀB)
                                let x = self.res[wi].x_first.as_ref().unwrap();
                                let mut panel = self.ctx.arena.take_f32(rows * g.cin);
                                conv_dw_first_streaming_into(
                                    x, dy, b, g, n, backend, dst, &mut panel,
                                );
                                self.ctx.arena.put_f32(panel);
                            }
                            None => {
                                let x = self.res[wi].x_first.as_ref().unwrap();
                                backend.gemm_f32_at(rows, k, n, x, dy, dst);
                            }
                        },
                    }
                }
                if single {
                    let mut bits = self.ctx.arena.take_bits(k, n);
                    BitMatrix::pack_into(k, n, &dw, &mut bits);
                    self.res[wi].dw_sign = Some(bits);
                    self.ctx.arena.put_f32(dw);
                } else {
                    simd::add_assign_f32(&mut dw, &scratch);
                    self.ctx.arena.put_f32(scratch);
                    self.dw_acc[wi] = dw;
                }
            }
            Accel::Naive => {
                // row-at-a-time accumulator: k-loop outer keeps only
                // an n-sized f32 scratch alive (no k×n f32 buffer on
                // the naive tier, single-chunk or accumulating)
                let mut acc = self.ctx.arena.take_f32(n);
                let mut bits = if single {
                    Some(self.ctx.arena.take_zeroed_bits(k, n))
                } else {
                    None
                };
                for kk in 0..k {
                    acc.fill(0.0);
                    for r in 0..rows {
                        let xv = match &self.res[wi].xhat {
                            Some(xh) => xh.get(r, kk),
                            None => {
                                let x = self.res[wi].x_first.as_ref().unwrap();
                                match conv {
                                    // patch element straight off the
                                    // geometry — the rows×k cols
                                    // buffer never exists
                                    Some(g) => im2col_at(x, &g, r, kk),
                                    None => x[r * k + kk],
                                }
                            }
                        };
                        if xv == 0.0 {
                            continue;
                        }
                        let dyr = &dy[r * n..(r + 1) * n];
                        for (j, &g) in dyr.iter().enumerate() {
                            acc[j] += xv * g;
                        }
                    }
                    match &mut bits {
                        Some(bm) => {
                            for (j, &v) in acc.iter().enumerate() {
                                if v >= 0.0 {
                                    bm.data[kk * bm.words_per_row + (j >> 6)] |=
                                        1u64 << (j & 63);
                                }
                            }
                        }
                        None => {
                            let row = &mut self.dw_acc[wi][kk * n..(kk + 1) * n];
                            simd::add_assign_f32(row, &acc);
                        }
                    }
                }
                self.ctx.arena.put_f32(acc);
                if let Some(bm) = bits {
                    self.res[wi].dw_sign = Some(bm);
                }
            }
        }
    }
}

/// Naive-tier dY·Ŵᵀ into a **zeroed** `out` (rows × k), reading ±1
/// signs straight off the latent weight store — the shared inner
/// loop of the dense-dX and conv patch-gradient reference paths (the
/// pre-arena `real_bin_matmul_t`).
fn naive_dy_wt_into(w: &Store, dy: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), rows * k);
    debug_assert_eq!(dy.len(), rows * n);
    for r in 0..rows {
        let dyr = &dy[r * n..(r + 1) * n];
        let orow = &mut out[r * k..(r + 1) * k];
        for (j, &g) in dyr.iter().enumerate() {
            if g == 0.0 {
                continue;
            }
            for (kk, ov) in orow.iter_mut().enumerate() {
                let s = if w.get(kk * n + j) >= 0.0 { 1.0 } else { -1.0 };
                *ov += g * s;
            }
        }
    }
}

/// sign(W) into a caller-owned buffer, straight off the store (the
/// f16 path never materializes an intermediate f32 vector).
fn store_sign_into(w: &Store, out: &mut [f32]) {
    assert_eq!(w.len(), out.len());
    match w {
        Store::F32(v) => sign_into(v, out),
        Store::F16(v) => {
            for (o, &h) in out.iter_mut().zip(&v.0) {
                // +1 unless strictly negative (matches pack_f16_t and
                // sign_vec-of-decoded: f16 -0.0 decodes to -0.0 ≥ 0)
                *o = if h >> 15 == 0 || h & 0x7fff == 0 { 1.0 } else { -1.0 };
            }
        }
    }
}

impl EngineOps for ProposedTrainer {
    /// ∂X/∂Y between layers is held f16 (Table 2's grad rows); the
    /// f16→f32→f16 round-trips at pool/residual boundaries are
    /// lossless, so behaviour matches the pre-refactor engine bit for
    /// bit.
    type Grad = F16Vec;

    fn micro(&self) -> usize {
        self.micro
    }

    fn ctx(&mut self) -> &mut StepCtx {
        &mut self.ctx
    }

    fn grad_to_f32(&mut self, g: F16Vec) -> Vec<f32> {
        let mut v = self.ctx.arena.take_f32(g.len());
        g.write_f32_into(&mut v);
        self.ctx.arena.put_f16(g);
        v
    }

    fn grad_from_f32(&mut self, v: Vec<f32>) -> F16Vec {
        let mut h = self.ctx.arena.take_f16(v.len());
        h.fill_from_f32(&v);
        self.ctx.arena.put_f32(v);
        h
    }

    fn recycle_grad(&mut self, g: F16Vec) {
        self.ctx.arena.put_f16(g);
    }

    fn matmul_forward(
        &mut self,
        cur: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
        retain: bool,
    ) -> Result<Vec<f32>> {
        match *layer {
            LayerPlan::Dense { k, n, first } => {
                self.matmul_bn_forward(cur, self.micro, k, n, first, wi, retain, None)
            }
            LayerPlan::Conv { g, cout, first } => self.matmul_bn_forward(
                cur,
                g.rows(self.micro),
                g.k(),
                cout,
                first,
                wi,
                retain,
                Some(g),
            ),
            _ => unreachable!("matmul_forward on a non-matmul layer"),
        }
    }

    fn matmul_backward(
        &mut self,
        dnext: Vec<f32>,
        wi: usize,
        layer: &LayerPlan,
    ) -> Result<Vec<f32>> {
        match *layer {
            LayerPlan::Dense { k, n, first } => {
                self.matmul_bn_backward(dnext, self.micro, k, n, first, wi, None)
            }
            LayerPlan::Conv { g, cout, first } => self.matmul_bn_backward(
                dnext,
                g.rows(self.micro),
                g.k(),
                cout,
                first,
                wi,
                Some(g),
            ),
            _ => unreachable!("matmul_backward on a non-matmul layer"),
        }
    }

    fn pool_forward(
        &mut self,
        cur: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
        retain: bool,
    ) -> Vec<f32> {
        let b = self.micro;
        let (oh, ow) = super::standard::pool_out_dims(h, w, kside, stride);
        let cells = b * oh * ow * c;
        let mut out = self.ctx.arena.take_f32(cells);
        let mut mask = self.ctx.arena.take_u32(cells);
        super::standard::maxpool_forward_into(
            &cur, b, h, w, c, kside, stride, &mut out, &mut mask,
        );
        self.ctx.arena.put_f32(cur);
        if retain {
            if (kside, stride) == (2, 2) {
                // pack: 1 bit per input element (was-max); unambiguous
                // because non-overlapping 2×2 windows partition the
                // input, so each bit maps to exactly one window
                let mut bits = self.ctx.arena.take_mask(b * h * w * c);
                const OFF: [(usize, usize); 4] = [(0, 0), (0, 1), (1, 0), (1, 1)];
                for bi in 0..b {
                    for oy in 0..oh {
                        for ox in 0..ow {
                            for ch in 0..c {
                                let o = ((bi * oh + oy) * ow + ox) * c + ch;
                                let (dy, dx) = OFF[mask[o] as usize];
                                bits.set(((bi * h + oy * 2 + dy) * w + ox * 2 + dx) * c + ch);
                            }
                        }
                    }
                }
                self.pool_masks.push(bits);
                self.ctx.arena.put_u32(mask);
            } else {
                // general pools keep the u32 winner index: a 1-bit
                // was-max mask is ambiguous once windows overlap
                self.pool_masks_u32.push(mask);
            }
        } else {
            self.ctx.arena.put_u32(mask);
        }
        out
    }

    fn pool_backward(
        &mut self,
        dnext: Vec<f32>,
        h: usize,
        w: usize,
        c: usize,
        kside: usize,
        stride: usize,
    ) -> Vec<f32> {
        let b = self.micro;
        let mut dx = self.ctx.arena.take_zeroed_f32(b * h * w * c);
        if (kside, stride) == (2, 2) {
            let mask = self.pool_masks.pop().expect("pool mask stack underflow");
            let (oh, ow) = (h / 2, w / 2);
            // route each pooled grad to its masked input cell
            let mut oidx = 0usize;
            for bi in 0..b {
                for oy in 0..oh {
                    for ox in 0..ow {
                        for ch in 0..c {
                            let g = dnext[oidx];
                            oidx += 1;
                            for (dy, dxo) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                                let ii = ((bi * h + oy * 2 + dy) * w + ox * 2 + dxo) * c + ch;
                                if mask.get(ii) {
                                    dx[ii] = g;
                                }
                            }
                        }
                    }
                }
            }
            self.ctx.arena.put_mask(mask);
        } else {
            let mask = self.pool_masks_u32.pop().expect("pool mask stack underflow");
            super::standard::maxpool_backward_into(
                &dnext, &mask, b, h, w, c, kside, stride, &mut dx,
            );
            self.ctx.arena.put_u32(mask);
        }
        self.ctx.arena.put_f32(dnext);
        dx
    }

    fn end_chunk(&mut self) {
        if self.chunks() > 1 {
            // accumulating steps keep nothing across chunks (∂W/∂β
            // live in the persistent accumulators); single-chunk
            // steps retain res until the update phase consumes ∂Ŵ
            self.drain_res();
        }
    }
}

impl StepEngine for ProposedTrainer {
    fn train_step(&mut self, x: &[f32], labels: &[usize], lr: f32) -> Result<(f32, f32)> {
        if x.len() != self.batch * self.plan.input_elems || labels.len() != self.batch {
            bail!("bad batch shapes");
        }
        self.begin_step();
        let sched = self.sched.clone();
        self.ctx.arena.begin_pass(sched.train_pass().clone());
        let r = ops::run_train_chunks(self, &sched, x, labels);
        let (loss, acc) = match r {
            Ok(v) => v,
            Err(e) => {
                self.ctx.arena.abort_pass();
                return Err(e);
            }
        };
        self.apply_update(lr);
        // single-chunk steps retained `res` through the update phase
        // (packed ∂Ŵ lives there); this drain is the pass's tail
        self.drain_res();
        self.ctx.arena.end_pass();
        Ok((loss, acc))
    }

    fn eval(&mut self, x: &[f32], labels: &[usize]) -> Result<(f32, f32)> {
        if x.len() != self.batch * self.plan.input_elems || labels.len() != self.batch {
            bail!("bad batch shapes");
        }
        // forward(retain = false) pushes nothing, but the invariant
        // the backward relies on (res[wi] belongs to *this* step's
        // forward) deserves to be explicit: eval must never leave
        // residuals a later backward could misread.  Regression-pinned
        // in `eval_between_steps_is_invisible_to_training`.
        self.drain_res();
        self.ctx.drain_skip_stacks();
        let sched = self.sched.clone();
        self.ctx.arena.begin_pass(sched.eval_pass().clone());
        let r = ops::run_eval_chunks(self, &sched, x, labels);
        match r {
            Ok(v) => {
                self.ctx.arena.end_pass();
                Ok(v)
            }
            Err(e) => {
                self.ctx.arena.abort_pass();
                Err(e)
            }
        }
    }

    fn state_bytes(&self) -> usize {
        self.weights.iter().map(Store::heap_bytes).sum::<usize>()
            + self.betas.iter().map(Store::heap_bytes).sum::<usize>()
            + self.opt_w.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.opt_b.iter().map(OptState::heap_bytes).sum::<usize>()
            + self.dw_acc.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.dbeta_acc.iter().map(|v| v.len() * 4).sum::<usize>()
            + self.wcache.heap_bytes()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn microbatch(&self) -> usize {
        self.micro
    }

    fn arena_bytes(&self) -> usize {
        self.ctx.arena.heap_bytes()
    }

    fn weights_snapshot(&self) -> Vec<Vec<f32>> {
        // interleaved [w0, beta0, w1, beta1, ...] — the HLO engines'
        // param order, so snapshots transfer across engine kinds
        let mut out = Vec::with_capacity(self.weights.len() * 2);
        for (w, b) in self.weights.iter().zip(&self.betas) {
            out.push(w.to_f32());
            out.push(b.to_f32());
        }
        out
    }

    fn load_weights(&mut self, w: &[Vec<f32>]) -> Result<()> {
        if w.len() != self.weights.len() * 2 {
            bail!("snapshot layer count mismatch");
        }
        for (i, chunk) in w.chunks(2).enumerate() {
            if chunk[0].len() != self.weights[i].len()
                || chunk[1].len() != self.betas[i].len()
            {
                bail!("snapshot shape mismatch at layer {i}");
            }
            self.weights[i] = Store::from_f32(chunk[0].clone(), true);
            self.betas[i] = Store::from_f32(chunk[1].clone(), true);
        }
        self.wcache.invalidate_all();
        Ok(())
    }

    fn arena_idle(&self) -> bool {
        self.ctx.arena.idle()
    }
}

// -------------------------------------------------------- BN kernels

/// ℓ1 BN forward emitting f32 x_next + (ψ, ω, packed sign(xn)).
#[cfg(test)]
fn bn_l1_forward_packed(
    y: &[f32],
    rows: usize,
    channels: usize,
    beta: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>, BitMatrix) {
    let mut x_next = vec![0.0f32; y.len()];
    let mut psi = vec![0.0f32; channels];
    let mut omega = vec![0.0f32; channels];
    let mut mu = vec![0.0f32; channels];
    let mut sign = BitMatrix::zeros(rows, channels);
    bn_l1_forward_packed_into(
        y, rows, channels, beta, &mut x_next, &mut psi, &mut omega, &mut mu, &mut sign,
    );
    (x_next, psi, omega, sign)
}

/// [`bn_l1_forward_packed`] into caller-owned buffers.  `x_next`,
/// `psi`, `omega`, `mu` are overwritten (recycled dirty storage
/// fine); `sign` must be a **zeroed** packed matrix (bits OR in).
#[allow(clippy::too_many_arguments)]
pub(crate) fn bn_l1_forward_packed_into(
    y: &[f32],
    rows: usize,
    channels: usize,
    beta: &[f32],
    x_next: &mut [f32],
    psi: &mut [f32],
    omega: &mut [f32],
    mu: &mut [f32],
    sign: &mut BitMatrix,
) {
    debug_assert_eq!(y.len(), rows * channels);
    debug_assert_eq!(x_next.len(), y.len());
    debug_assert_eq!((sign.rows, sign.cols), (rows, channels));
    mu.fill(0.0);
    for r in 0..rows {
        for c in 0..channels {
            mu[c] += y[r * channels + c];
        }
    }
    for m in mu.iter_mut() {
        *m /= rows as f32;
    }
    psi.fill(0.0);
    for r in 0..rows {
        for c in 0..channels {
            psi[c] += (y[r * channels + c] - mu[c]).abs();
        }
    }
    for p in psi.iter_mut() {
        *p = *p / rows as f32 + 1e-5;
    }
    omega.fill(0.0);
    for r in 0..rows {
        let base = r * sign.words_per_row;
        for c in 0..channels {
            let xn = (y[r * channels + c] - mu[c]) / psi[c];
            let v = xn + beta[c];
            x_next[r * channels + c] = v;
            omega[c] += v.abs();
            if xn >= 0.0 {
                sign.data[base + (c >> 6)] |= 1u64 << (c & 63);
            }
        }
    }
    for o in omega.iter_mut() {
        *o /= rows as f32;
    }
}

/// Proposed BN backward (Alg. 2 lines 10-13) from packed signs.
#[cfg(test)]
fn bn_proposed_backward_packed(
    dx: &[f32],
    xhat: &BitMatrix,
    omega: &[f32],
    psi: &[f32],
    rows: usize,
    channels: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut dy = vec![0.0f32; dx.len()];
    let mut dbeta = vec![0.0f32; channels];
    let mut mv = vec![0.0f32; channels];
    let mut mvx = vec![0.0f32; channels];
    bn_proposed_backward_packed_into(
        dx, xhat, omega, psi, rows, channels, &mut dy, &mut dbeta, &mut mv, &mut mvx,
    );
    (dy, dbeta)
}

/// [`bn_proposed_backward_packed`] into caller-owned buffers.  `dy`,
/// `mv`, `mvx` are overwritten; `dbeta_acc` is **added into** — the
/// microbatch accumulation point for ∂β.
#[allow(clippy::too_many_arguments)]
fn bn_proposed_backward_packed_into(
    dx: &[f32],
    xhat: &BitMatrix,
    omega: &[f32],
    psi: &[f32],
    rows: usize,
    channels: usize,
    dy: &mut [f32],
    dbeta_acc: &mut [f32],
    mv: &mut [f32],
    mvx: &mut [f32],
) {
    debug_assert_eq!(dx.len(), rows * channels);
    debug_assert_eq!(dy.len(), dx.len());
    mv.fill(0.0);
    mvx.fill(0.0);
    for r in 0..rows {
        for c in 0..channels {
            let d = dx[r * channels + c];
            let v = d / psi[c];
            mv[c] += v;
            mvx[c] += v * xhat.get(r, c);
            dbeta_acc[c] += d;
        }
    }
    for c in 0..channels {
        mv[c] /= rows as f32;
        mvx[c] /= rows as f32;
    }
    for r in 0..rows {
        for c in 0..channels {
            let v = dx[r * channels + c] / psi[c];
            dy[r * channels + c] = v - mv[c] - omega[c] * mvx[c] * xhat.get(r, c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{get, lower};

    fn make(model: &str, batch: usize, accel: Accel, opt: &str) -> ProposedTrainer {
        let g = lower(&get(model).unwrap()).unwrap();
        ProposedTrainer::new(&g, batch, opt, accel, 42).unwrap()
    }

    fn toy_batch(n: usize, k: usize, classes: usize, seed: u64) -> (Vec<f32>, Vec<usize>) {
        let mut g = Pcg32::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes).map(|_| g.normal_vec(k)).collect();
        let mut x = Vec::with_capacity(n * k);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % classes;
            for j in 0..k {
                x.push(protos[c][j] + 0.3 * g.normal());
            }
            y.push(c);
        }
        (x, y)
    }

    #[test]
    fn mlp_mini_learns() {
        let mut t = make("mlp_mini", 32, Accel::Blocked, "adam");
        let (x, y) = toy_batch(32, 64, 10, 1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.6, "{first:?} -> {last}");
    }

    #[test]
    fn conv_net_learns() {
        let mut t = make("cnv_mini", 16, Accel::Blocked, "adam");
        let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 2);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap() * 0.8, "{first:?} -> {last}");
    }

    #[test]
    fn residual_nets_learn() {
        for model in ["resnete_mini", "bireal_mini"] {
            let mut t = make(model, 16, Accel::Blocked, "adam");
            let (x, y) = toy_batch(16, 16 * 16 * 3, 10, 14);
            let mut first = None;
            let mut last = 0.0;
            for _ in 0..25 {
                let (loss, _) = t.train_step(&x, &y, 0.003).unwrap();
                first.get_or_insert(loss);
                last = loss;
            }
            assert!(last.is_finite(), "{model}");
            assert!(last < first.unwrap(), "{model}: {first:?} -> {last}");
        }
    }

    #[test]
    fn bop_trains_binary_weights() {
        let mut t = make("mlp_mini", 32, Accel::Blocked, "bop");
        let (x, y) = toy_batch(32, 64, 10, 3);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..60 {
            let (loss, _) = t.train_step(&x, &y, 0.001).unwrap();
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < first.unwrap(), "{first:?} -> {last}");
        // weights must remain exactly binary under Bop (even slots;
        // odd slots are BN biases)
        for (i, w) in t.weights_snapshot().iter().enumerate() {
            if i % 2 == 0 {
                assert!(w.iter().all(|&v| v == 1.0 || v == -1.0));
            }
        }
    }

    #[test]
    fn naive_and_blocked_agree() {
        let mut a = make("mlp_mini", 8, Accel::Naive, "adam");
        let mut b = make("mlp_mini", 8, Accel::Blocked, "adam");
        let (x, y) = toy_batch(8, 64, 10, 4);
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert!((la - lb).abs() < 1e-3, "step {step}: {la} vs {lb}");
        }
    }

    #[test]
    fn tiled_matches_blocked_exactly() {
        // the XNOR tiers are bit-exact and the parallel f32 path only
        // re-bands the same blocked kernel, so whole training runs are
        // numerically identical across blocked and tiled(threads) —
        // residual models exercise the skip handling too
        for (model, batch, k) in [
            ("mlp_mini", 8, 64),
            ("cnv_mini", 4, 16 * 16 * 3),
            ("resnete_mini", 4, 16 * 16 * 3),
        ] {
            let mut b = make(model, batch, Accel::Blocked, "adam");
            let mut t2 = make(model, batch, Accel::Tiled(2), "adam");
            let (x, y) = toy_batch(batch, k, 10, 5);
            for step in 0..3 {
                let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
                let (lt, _) = t2.train_step(&x, &y, 0.01).unwrap();
                assert!((lb - lt).abs() < 1e-6, "{model} step {step}: {lb} vs {lt}");
            }
            for (wb, wt) in b.weights_snapshot().iter().zip(t2.weights_snapshot().iter()) {
                assert_eq!(wb, wt, "{model}");
            }
        }
    }

    #[test]
    fn microbatch_full_chunk_is_identical() {
        // micro == batch is the single-chunk path: bit-identical to
        // the default trainer, packed ∂Ŵ inventory included
        let g = lower(&get("cnv_mini").unwrap()).unwrap();
        let (x, y) = toy_batch(8, 16 * 16 * 3, 10, 25);
        let mut a = ProposedTrainer::new(&g, 8, "adam", Accel::Blocked, 3).unwrap();
        let mut b =
            ProposedTrainer::with_microbatch(&g, 8, 8, "adam", Accel::Blocked, 3).unwrap();
        for step in 0..3 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(la, lb, "step {step}");
        }
        assert_eq!(a.weights_snapshot(), b.weights_snapshot());
    }

    #[test]
    fn microbatch_threads_are_still_identical() {
        // accumulation must not break the cross-thread bit-exactness
        // invariant of the fused tiers
        let g = lower(&get("cnv_mini").unwrap()).unwrap();
        let (x, y) = toy_batch(8, 16 * 16 * 3, 10, 26);
        let mut a =
            ProposedTrainer::with_microbatch(&g, 8, 4, "adam", Accel::Blocked, 3).unwrap();
        let mut b =
            ProposedTrainer::with_microbatch(&g, 8, 4, "adam", Accel::Tiled(2), 3).unwrap();
        for step in 0..2 {
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(la, lb, "step {step}");
        }
        assert_eq!(a.weights_snapshot(), b.weights_snapshot());
    }

    #[test]
    fn steady_state_stops_allocating_from_the_arena() {
        for accel in [Accel::Blocked, Accel::Tiled(2)] {
            let mut t = make("cnv_mini", 4, accel, "adam");
            let (x, y) = toy_batch(4, 16 * 16 * 3, 10, 27);
            let bytes = t.ctx.arena.heap_bytes();
            assert_eq!(bytes, t.sched.arena_bytes(), "{accel:?}: install != schedule");
            for _ in 0..5 {
                t.train_step(&x, &y, 0.01).unwrap();
            }
            assert_eq!(t.ctx.arena.heap_bytes(), bytes, "{accel:?}: arena grew");
        }
    }

    #[test]
    fn weights_packed_at_most_once_per_step() {
        let mut t = make("mlp_mini", 8, Accel::Blocked, "adam");
        let (x, y) = toy_batch(8, 64, 10, 9);
        assert_eq!(t.weight_pack_count(), 0);
        t.train_step(&x, &y, 0.01).unwrap();
        let per_step = t.weight_pack_count();
        // forward packs each non-first matmul layer once; the backward
        // dX matmul must reuse the cache rather than re-pack
        assert!(per_step >= 1 && per_step <= t.weights.len(), "{per_step}");
        t.train_step(&x, &y, 0.01).unwrap();
        t.train_step(&x, &y, 0.01).unwrap();
        assert_eq!(t.weight_pack_count(), 3 * per_step);
        // eval re-packs once after the update invalidated the cache...
        t.eval(&x, &y).unwrap();
        let after_eval = t.weight_pack_count();
        assert_eq!(after_eval, 4 * per_step);
        // ...and a second eval with unchanged weights packs nothing
        t.eval(&x, &y).unwrap();
        assert_eq!(t.weight_pack_count(), after_eval);
        // loading new weights invalidates
        let snap = t.weights_snapshot();
        t.load_weights(&snap).unwrap();
        t.eval(&x, &y).unwrap();
        assert_eq!(t.weight_pack_count(), after_eval + per_step);
    }

    #[test]
    fn state_accounting_vs_standard() {
        use super::super::standard::StandardTrainer;
        let g = lower(&get("mlp").unwrap()).unwrap();
        let s = StandardTrainer::new(&g, 16, "adam", Accel::Blocked, 1).unwrap();
        let p = ProposedTrainer::new(&g, 16, "adam", Accel::Blocked, 1).unwrap();
        // Standard holds W + β + 2 Adam momenta + the retained f32
        // ∂W/∂β accumulators, all f32 (16·w-ish); proposed halves the
        // parameter classes to f16 and keeps no weight-scale f32
        // accumulator single-chunk (6·w-ish): the ratio is ~8/3 at
        // w ≫ channels, comfortably above the paper's 2× state story.
        let ratio = s.state_bytes() as f64 / p.state_bytes() as f64;
        assert!((2.2..3.0).contains(&ratio), "{ratio}");
        // parameter + momenta classes alone (Table 2's rows) still
        // halve exactly: subtract the accumulators from both sides
        let s_params = s.state_bytes()
            - s.weights_snapshot().iter().map(|v| v.len() * 4).sum::<usize>(); // dW + dβ acc are exactly one f32 per param
        let p_params = p.state_bytes()
            - p.weights_snapshot().iter().skip(1).step_by(2).map(|v| v.len() * 4).sum::<usize>();
        let r2 = s_params as f64 / p_params as f64;
        assert!((r2 - 2.0).abs() < 0.01, "{r2}");
    }

    #[test]
    fn bn_l1_forward_centers() {
        let mut g = Pcg32::new(5);
        let rows = 128;
        let ch = 6;
        let y: Vec<f32> = g.normal_vec(rows * ch).iter().map(|v| v * 2.0 + 0.5).collect();
        let (xn, psi, omega, sgn) = bn_l1_forward_packed(&y, rows, ch, &vec![0.0; ch]);
        for c in 0..ch {
            let m: f32 = (0..rows).map(|r| xn[r * ch + c]).sum::<f32>() / rows as f32;
            assert!(m.abs() < 1e-4, "{m}");
            assert!(psi[c] > 0.0);
            assert!(omega[c] > 0.0);
        }
        // packed signs match xn signs (beta = 0)
        for r in 0..rows {
            for c in 0..ch {
                assert_eq!(
                    sgn.get(r, c),
                    if xn[r * ch + c] >= 0.0 { 1.0 } else { -1.0 }
                );
            }
        }
    }

    #[test]
    fn proposed_bn_backward_matches_ref_math() {
        // cross-check against the formula (mirrors python ref.py)
        let mut g = Pcg32::new(6);
        let (rows, ch) = (32, 4);
        let dx = g.normal_vec(rows * ch);
        let xh_f: Vec<f32> = g.normal_vec(rows * ch);
        let xhat = BitMatrix::pack(rows, ch, &xh_f);
        let omega: Vec<f32> = (0..ch).map(|_| g.uniform(0.1, 1.0)).collect();
        let psi: Vec<f32> = (0..ch).map(|_| g.uniform(0.1, 1.0)).collect();
        let (dy, dbeta) = bn_proposed_backward_packed(&dx, &xhat, &omega, &psi, rows, ch);
        for c in 0..ch {
            let v: Vec<f32> = (0..rows).map(|r| dx[r * ch + c] / psi[c]).collect();
            let mv: f32 = v.iter().sum::<f32>() / rows as f32;
            let mvx: f32 = (0..rows)
                .map(|r| v[r] * xhat.get(r, c))
                .sum::<f32>()
                / rows as f32;
            for r in 0..rows {
                let want = v[r] - mv - omega[c] * mvx * xhat.get(r, c);
                assert!((dy[r * ch + c] - want).abs() < 1e-5);
            }
            let db: f32 = (0..rows).map(|r| dx[r * ch + c]).sum();
            assert!((dbeta[c] - db).abs() < 1e-4);
        }
    }

    #[test]
    fn eval_does_not_mutate() {
        let mut t = make("mlp_mini", 8, Accel::Blocked, "adam");
        let (x, y) = toy_batch(8, 64, 10, 7);
        let before = t.weights_snapshot();
        t.eval(&x, &y).unwrap();
        assert_eq!(before, t.weights_snapshot());
    }

    #[test]
    fn eval_between_steps_is_invisible_to_training() {
        // an eval interleaved between train steps must leave no stale
        // residuals/pool masks behind (the backward indexes res[wi]
        // positionally — a leak would be misread as this step's X̂) and
        // must not perturb the training trajectory at all.  Run on a
        // residual model so the skip path is covered too.
        let (x, y) = toy_batch(8, 16 * 16 * 3, 10, 11);
        let (xe, ye) = toy_batch(8, 16 * 16 * 3, 10, 12);
        for model in ["cnv_mini", "bireal_mini"] {
            let mut a = make(model, 8, Accel::Blocked, "adam");
            let mut b = make(model, 8, Accel::Blocked, "adam");
            a.train_step(&x, &y, 0.01).unwrap();
            b.train_step(&x, &y, 0.01).unwrap();
            b.eval(&xe, &ye).unwrap();
            assert!(b.res.is_empty(), "{model}: eval left residuals behind");
            assert!(b.pool_masks.is_empty(), "{model}: eval left pool masks behind");
            assert!(b.pool_masks_u32.is_empty(), "{model}: eval left u32 pool masks behind");
            let (la, _) = a.train_step(&x, &y, 0.01).unwrap();
            let (lb, _) = b.train_step(&x, &y, 0.01).unwrap();
            assert_eq!(la, lb, "{model}: eval perturbed the training trajectory");
            for (wa, wb) in a.weights_snapshot().iter().zip(b.weights_snapshot().iter()) {
                assert_eq!(wa, wb, "{model}");
            }
        }
    }
}
